"""L2: the JAX golden model (build-time only; never on the run path).

Two views of the same network, ``tiny-cnn`` from the Rust zoo
(``rust/src/model/zoo.rs``), layer-for-layer:

* :func:`tiny_cnn_int8` — the **quantized forward pass** built from the
  L1 Pallas kernels (:mod:`~compile.kernels.com_conv`,
  :mod:`~compile.kernels.cim_mvm`) with the shared int8 semantics of
  :mod:`~compile.kernels.ops`. This is the function
  ``python/compile/aot.py`` lowers to HLO text; the Rust runtime loads
  it and the cycle simulator is checked against it bit-exactly.
* :func:`tiny_cnn_float` — the fp32 twin used to *train* the network on
  a synthetic dataset, so the paper's accuracy experiment ("only the
  quantization error is considered", Section IV-A) runs end to end:
  train fp32 → post-training-quantize → compare fp32 vs int8 accuracy.

Network (zoo::tiny_cnn, input 3x16x16):

====  =========================  ==========
idx   layer                      requant
====  =========================  ==========
0     conv 16, 3x3, s1, p1 +ReLU  shift 7
1     maxpool 2x2
2     conv 32, 3x3, s1, p1 +ReLU  shift 7
3     conv 32, 3x3, s1, p1 linear shift 7
4     res-add(from=2) +ReLU
5     maxpool 2x2
6     conv 32, 3x3, s1, p1 +ReLU  shift 7
7     avgpool 4x4
8     flatten
9     fc 10 (logits)              shift 7
====  =========================  ==========

Weight layouts match refcompute: conv ``[M, C, K, K]``, fc ``[out, in]``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ops, ref
from .kernels.cim_mvm import cim_mvm
from .kernels.com_conv import com_conv2d, w_from_mckk

SHIFT = 7  # DEFAULT_REQUANT_SHIFT in rust/src/model/builder.rs

# (out_ch, in_ch) of the five weight layers, in network order.
TINY_CONV_SHAPES = [(16, 3), (32, 16), (32, 32), (32, 32)]
TINY_FC_SHAPE = (10, 32)
INPUT_SHAPE = (3, 16, 16)
NUM_CLASSES = 10


# --------------------------------------------------------------------
# Quantized forward (the golden model)
# --------------------------------------------------------------------

DEFAULT_SHIFTS = (SHIFT,) * 5


def tiny_cnn_int8(x, w0, w2, w3, w6, w9, shifts=DEFAULT_SHIFTS):
    """Bit-exact int8 forward of zoo::tiny_cnn.

    ``x`` int8 ``[3, 16, 16]``; conv weights int8 ``[M, C, 3, 3]``;
    ``w9`` int8 ``[10, 32]``; ``shifts`` the per-weight-layer requant
    shifts (the hardware's per-layer `requant_shift` field — the
    quantizer picks power-of-two weight scales so these shifts keep
    every layer on the input activation scale). Returns int8 logits
    ``[10]``.
    """
    s0, s2, s3, s6, s9 = shifts
    y = com_conv2d(x, w_from_mckk(w0), 1, 1, s0, True)          # conv0
    y = ops.max_pool(y, 2, 2)                                   # pool1
    skip = com_conv2d(y, w_from_mckk(w2), 1, 1, s2, True)       # conv2
    y = com_conv2d(skip, w_from_mckk(w3), 1, 1, s3, False)      # conv3
    y = ops.res_add(y, skip)                                    # res4
    y = ops.max_pool(y, 2, 2)                                   # pool5
    y = com_conv2d(y, w_from_mckk(w6), 1, 1, s6, True)          # conv6
    y = ops.avg_pool(y, 4, 4)                                   # pool7
    y = y.reshape(-1)                                           # flatten8
    y = cim_mvm(y[None, :], jnp.transpose(w9), s9, False)       # fc9
    return y[0]


def tiny_cnn_int8_ref(x, w0, w2, w3, w6, w9, shifts=DEFAULT_SHIFTS):
    """The same forward through the pure-jnp oracles (no Pallas) —
    pytest asserts it equals :func:`tiny_cnn_int8` exactly."""
    s0, s2, s3, s6, s9 = shifts
    y = ref.conv2d_ref(x, w0, 1, 1, s0, True)
    y = ops.max_pool(y, 2, 2)
    skip = ref.conv2d_ref(y, w2, 1, 1, s2, True)
    y = ref.conv2d_ref(skip, w3, 1, 1, s3, False)
    y = ops.res_add(y, skip)
    y = ops.max_pool(y, 2, 2)
    y = ref.conv2d_ref(y, w6, 1, 1, s6, True)
    y = ops.avg_pool(y, 4, 4)
    y = y.reshape(-1)
    return ref.fc_ref(y[None, :], w9, s9, False)[0]


# --------------------------------------------------------------------
# Float twin + training (the accuracy experiment)
# --------------------------------------------------------------------

def _conv_f32(x, w, padding):
    """fp32 CHW conv, weight [M, C, K, K]."""
    return jax.lax.conv_general_dilated(
        x[None], w, (1, 1), [(padding, padding)] * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]


def _max_pool_f32(x, k, s):
    return jnp.max(ops._pool_windows(x, k, s), axis=0)


def _avg_pool_f32(x, k, s):
    return jnp.mean(ops._pool_windows(x, k, s), axis=0)


def tiny_cnn_float(params, x):
    """fp32 forward with the same topology. ``params`` is the dict from
    :func:`init_params`; ``x`` fp32 ``[3, 16, 16]``."""
    y = jax.nn.relu(_conv_f32(x, params["w0"], 1))
    y = _max_pool_f32(y, 2, 2)
    skip = jax.nn.relu(_conv_f32(y, params["w2"], 1))
    y = _conv_f32(skip, params["w3"], 1)
    y = jax.nn.relu(y + skip)
    y = _max_pool_f32(y, 2, 2)
    y = jax.nn.relu(_conv_f32(y, params["w6"], 1))
    y = _avg_pool_f32(y, 4, 4)
    return y.reshape(-1) @ params["w9"].T


def init_params(key):
    """He-initialized fp32 parameters."""
    ks = jax.random.split(key, 5)
    def conv(k, m, c):
        return jax.random.normal(k, (m, c, 3, 3)) * np.sqrt(2.0 / (c * 9))
    return {
        "w0": conv(ks[0], 16, 3),
        "w2": conv(ks[1], 32, 16),
        "w3": conv(ks[2], 32, 32),
        "w6": conv(ks[3], 32, 32),
        "w9": jax.random.normal(ks[4], TINY_FC_SHAPE) * np.sqrt(2.0 / 32),
    }


def class_templates(template_key):
    """Smooth low-frequency per-class template fields (the fixed
    "ground truth" of the synthetic task)."""
    coarse = jax.random.normal(template_key, (NUM_CLASSES, 3, 4, 4))
    templates = jax.image.resize(coarse, (NUM_CLASSES, 3, 16, 16), "linear")
    return templates / jnp.max(jnp.abs(templates))


def make_dataset(sample_key, n: int, template_key=None):
    """Synthetic 10-class dataset: per-class template + noise,
    normalized to [-1, 1]. ``template_key`` fixes the task (train and
    held-out test sets must share it); ``sample_key`` draws the
    samples."""
    if template_key is None:
        template_key = jax.random.PRNGKey(7)
    templates = class_templates(template_key)
    lkey, nkey = jax.random.split(sample_key)
    labels = jax.random.randint(lkey, (n,), 0, NUM_CLASSES)
    noise = 0.9 * jax.random.normal(nkey, (n, *INPUT_SHAPE))
    x = jnp.clip(templates[labels] + noise, -1.0, 1.0)
    return x, labels


@jax.jit
def _loss(params, xb, yb):
    logits = jax.vmap(lambda x: tiny_cnn_float(params, x))(xb)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))


@jax.jit
def _sgd_step(params, xb, yb, lr):
    g = jax.grad(_loss)(params, xb, yb)
    return jax.tree.map(lambda p, gg: p - lr * gg, params, g)


def train(key, steps: int = 300, batch: int = 64, lr: float = 0.05,
          n_train: int = 512):
    """Train the fp32 TinyCNN on the synthetic dataset; returns
    (params, train_x, train_y)."""
    dkey, pkey, skey = jax.random.split(key, 3)
    x, y = make_dataset(dkey, n_train)
    params = init_params(pkey)
    for i in range(steps):
        idx = jax.random.randint(
            jax.random.fold_in(skey, i), (batch,), 0, n_train
        )
        params = _sgd_step(params, x[idx], y[idx], lr)
    return params, x, y


# --------------------------------------------------------------------
# Post-training quantization
# --------------------------------------------------------------------

def quantize_input(x):
    """fp32 [-1, 1] input -> int8 (scale 64)."""
    return jnp.clip(jnp.round(x * 64.0), -128, 127).astype(jnp.int8)


def quantize_params(params):
    """Weight-only power-of-two quantization (no activation
    calibration). Prefer :func:`calibrate_and_quantize` — kept for
    tests that need shift control without a calibration set.

    Returns ``(qparams, shifts)`` with shifts ordered (w0, w2, w3, w6,
    w9).
    """
    import math

    qparams, shifts = {}, {}
    for k, w in params.items():
        mx = float(jnp.max(jnp.abs(w)))
        g = int(math.floor(math.log2(127.0 / max(mx, 1e-6))))
        g = max(0, min(g, 14))
        qparams[k] = jnp.clip(
            jnp.round(w * (2.0 ** g)), -128, 127
        ).astype(jnp.int8)
        shifts[k] = g
    order = ["w0", "w2", "w3", "w6", "w9"]
    return qparams, tuple(shifts[k] for k in order)


def _pow2_scale_exp(amax: float) -> int:
    """Largest p with ``amax * 2**p <= 127`` (power-of-two activation
    scale exponent)."""
    import math

    return int(math.floor(math.log2(127.0 / max(amax, 1e-6))))


def calibrate_and_quantize(params, calib_x):
    """Post-training quantization with activation-range calibration.

    All scales are powers of two, so every layer's rescaling is exactly
    one arithmetic right shift — the hardware's per-layer
    ``requant_shift``. For each weight layer: weight scale ``2^g``
    (largest fitting int8), input activation scale ``2^p_in``, output
    activation scale ``2^p_out`` chosen from the calibration batch's
    observed max, giving ``shift = g + p_in - p_out >= 0``. The two
    residual-add operands (conv2's output and conv3's output) are
    constrained to one common scale, as the ROFM adder has no
    rescaler. This is the "only the quantization error is considered"
    regime of Section IV-A, made concrete.

    Returns ``(qparams, shifts, logit_scale_exp)``.
    """
    import math

    # ---- float calibration: per-tensor activation maxima
    def amax(t):
        return float(jnp.max(jnp.abs(t)))

    a0 = a2 = a3 = ares = a6 = alog = 1e-6
    for xx in calib_x:
        y0 = jax.nn.relu(_conv_f32(xx, params["w0"], 1))
        p1 = _max_pool_f32(y0, 2, 2)
        skip = jax.nn.relu(_conv_f32(p1, params["w2"], 1))
        y3 = _conv_f32(skip, params["w3"], 1)
        r = jax.nn.relu(y3 + skip)
        p5 = _max_pool_f32(r, 2, 2)
        y6 = jax.nn.relu(_conv_f32(p5, params["w6"], 1))
        av = _avg_pool_f32(y6, 4, 4)
        lg = av.reshape(-1) @ params["w9"].T
        a0, a2, a3 = max(a0, amax(y0)), max(a2, amax(skip)), max(a3, amax(y3))
        ares, a6, alog = max(ares, amax(r)), max(a6, amax(y6)), max(alog, amax(lg))

    # ---- weight scales 2^g
    g, qparams = {}, {}
    for k, w in params.items():
        mx = float(jnp.max(jnp.abs(w)))
        gk = max(0, min(int(math.floor(math.log2(127.0 / max(mx, 1e-6)))), 14))
        g[k] = gk
        qparams[k] = jnp.clip(
            jnp.round(w * (2.0 ** gk)), -128, 127
        ).astype(jnp.int8)

    # ---- activation scale exponents (input fixed at 2^6 = 64)
    p_in = 6
    p0 = _pow2_scale_exp(a0)
    # one shared scale for the residual operands and their sum
    p_res = _pow2_scale_exp(max(a2, a3, ares))
    p6 = _pow2_scale_exp(a6)
    p_log = _pow2_scale_exp(alog)

    def shift(gk, pi, po):
        # right shift only: if the layer would need a left shift,
        # coarsen the output scale instead
        return max(gk + pi - po, 0)

    shifts = (
        shift(g["w0"], p_in, p0),
        shift(g["w2"], p0, p_res),
        shift(g["w3"], p_res, p_res),
        shift(g["w6"], p_res, p6),
        shift(g["w9"], p6, p_log),
    )
    return qparams, shifts, p_log


def accuracy_float(params, x, y) -> float:
    logits = jax.vmap(lambda xx: tiny_cnn_float(params, xx))(x)
    return float(jnp.mean(jnp.argmax(logits, axis=1) == y))


def accuracy_int8(qparams, shifts, x, y) -> float:
    """int8 accuracy through the oracle path (bit-identical to the
    Pallas path and the Rust simulator)."""
    @jax.jit
    def batch(xb):
        return jax.vmap(
            lambda xx: tiny_cnn_int8_ref(
                quantize_input(xx), qparams["w0"], qparams["w2"],
                qparams["w3"], qparams["w6"], qparams["w9"], shifts,
            )
        )(xb)
    logits = batch(x)
    return float(jnp.mean(jnp.argmax(logits, axis=1) == y))


# --------------------------------------------------------------------
# Binary interchange with the Rust side
# --------------------------------------------------------------------

MAGIC = b"DMN1"


def write_weights_bin(path, qparams, shifts):
    """``artifacts/tiny_weights.bin``: magic, then for each of the five
    weight arrays (network order) a u32 requant shift, a u32 length and
    raw int8 bytes. Mirrored by ``rust/src/eval/accuracy.rs``."""
    order = ["w0", "w2", "w3", "w6", "w9"]
    with open(path, "wb") as f:
        f.write(MAGIC)
        for k, sh in zip(order, shifts):
            a = np.asarray(qparams[k], dtype=np.int8).reshape(-1)
            f.write(np.uint32(sh).tobytes())
            f.write(np.uint32(a.size).tobytes())
            f.write(a.tobytes())


def write_testset_bin(path, x_i8, y):
    """``artifacts/tiny_testset.bin``: magic, u32 count, then per image
    a u32 label + 3*16*16 raw int8 pixels."""
    x_i8 = np.asarray(x_i8, dtype=np.int8)
    y = np.asarray(y, dtype=np.uint32)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(len(y)).tobytes())
        for img, lbl in zip(x_i8, y):
            f.write(np.uint32(lbl).tobytes())
            f.write(img.reshape(-1).tobytes())
