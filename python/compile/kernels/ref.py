"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with the most boring possible jnp code (no Pallas, no blocking); pytest
asserts exact integer equality between kernel and oracle across shape /
stride / padding sweeps (``python/tests/``).

The oracles also define the semantics the Rust reference
(``rust/src/model/refcompute.rs``) mirrors, so kernel == oracle == Rust
reference == cycle simulator, all bit-exact.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ops


def cim_mvm_ref(x, w, shift: int = 0, relu: bool = False):
    """Reference crossbar MVM: ``y = requant(x @ w)``.

    ``x`` int8 ``[Cin]`` (or ``[B, Cin]``), ``w`` int8 ``[Cin, Cout]``.
    Accumulation in int32 — exactly what a chain of 256x256 PEs with
    in-network partial-sum addition computes.
    """
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    return ops.requant(acc, shift, relu)


def conv2d_ref(x, w, stride: int = 1, padding: int = 0,
               shift: int = 0, relu: bool = False):
    """Reference direct convolution.

    ``x`` int8 ``[C, H, W]``, ``w`` int8 ``[M, C, K, K]`` (the Rust/
    refcompute layout). Returns int8 ``[M, Ho, Wo]``.
    """
    m, c, k, _ = w.shape
    xp = ops.pad_chw(x, padding).astype(jnp.int32)
    _, hp, wp = xp.shape
    oh = (hp - k) // stride + 1
    ow = (wp - k) // stride + 1
    acc = jnp.zeros((m, oh, ow), jnp.int32)
    for kr in range(k):
        for kc in range(k):
            xs = xp[:, kr : kr + (oh - 1) * stride + 1 : stride,
                    kc : kc + (ow - 1) * stride + 1 : stride]
            acc = acc + jnp.einsum(
                "chw,mc->mhw", xs, w[:, :, kr, kc].astype(jnp.int32)
            )
    return ops.requant(acc, shift, relu)


def fc_ref(x, w, shift: int = 0, relu: bool = False):
    """Reference FC layer: ``y = requant(x @ W^T)``.

    ``w`` int8 ``[out, in]`` (refcompute layout).
    """
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32).T)
    return ops.requant(acc, shift, relu)


def project_ref(x, w, stride: int, shift: int = 0):
    """Reference 1x1 strided projection (ResNet skip), ``w`` ``[M, C]``."""
    xs = x[:, ::stride, ::stride].astype(jnp.int32)
    acc = jnp.einsum("chw,mc->mhw", xs, w.astype(jnp.int32))
    return ops.requant(acc, shift, False)
