"""L1 Pallas kernel: the CIM crossbar MVM (the PE hot-spot).

One grid step is one **tile** of the paper's architecture: a stationary
``(N_c, N_m)`` int8 weight block (the 256x256 crossbar held in
VMEM ≈ the CIM array) multiplied by a streamed ``N_c`` slice of the
input vector (≈ the RIFM buffer beat), accumulated in int32
(≈ ADC + shift-add). The grid walks ``(⌈Cin/N_c⌉, ⌈Cout/N_m⌉)`` —
isomorphic to the FC tile-array mapping of paper Fig. 2: rows of the
grid are the partial-sum chains down a tile column, accumulated
"on the move" into the int32 accumulator; the last row requantizes
(the last tile's M-type Act instruction) and emits int8.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
an ASIC NoC; on TPU the same insight — stationary weight block + streamed
activations + in-place partial-sum accumulation, never a materialized
Toeplitz matrix — maps to MXU-shaped (256,256) blocks with BlockSpec
expressing the HBM→VMEM schedule the paper expresses with tiles.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ops

# The paper's crossbar dimensions (Section IV-A).
N_C = 256
N_M = 256


def _mvm_kernel(x_ref, w_ref, acc_ref, y_ref, *, shift: int, relu: bool,
                n_rows: int):
    """One (row-block, col-block) tile step.

    ``acc_ref`` is an int32 output used as the running partial-sum
    register chain; ``y_ref`` is the int8 result written by the last
    row block (the chain's final tile).
    """
    rb = pl.program_id(0)

    # chain start: clear the accumulator (first tile has no incoming psum)
    @pl.when(rb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the PE: int8 x int8 -> int32 MAC over the stationary block
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jnp.dot(x, w)

    # last tile of the column: M-type requantization, emit the OFM beat
    @pl.when(rb == n_rows - 1)
    def _emit():
        y_ref[...] = ops.requant(acc_ref[...], shift, relu)


@functools.partial(jax.jit, static_argnames=("shift", "relu"))
def cim_mvm(x, w, shift: int = 0, relu: bool = False):
    """Blocked crossbar MVM: ``y = requant(x @ w, shift, relu)``.

    ``x`` int8 ``[B, Cin]``, ``w`` int8 ``[Cin, Cout]`` — Cin/Cout need
    not be multiples of 256 (ragged edges are zero-padded, which is
    exact for integer MACs).
    """
    b, cin = x.shape
    cin_w, cout = w.shape
    assert cin == cin_w, (cin, cin_w)
    rbs = -(-cin // N_C)
    cbs = -(-cout // N_M)
    # zero-pad to whole tiles (zeros contribute nothing to integer MACs)
    xp = jnp.pad(x, ((0, 0), (0, rbs * N_C - cin)))
    wp = jnp.pad(w, ((0, rbs * N_C - cin), (0, cbs * N_M - cout)))

    kernel = functools.partial(
        _mvm_kernel, shift=shift, relu=relu, n_rows=rbs
    )
    acc, y = pl.pallas_call(
        kernel,
        grid=(rbs, cbs),
        in_specs=[
            # the streamed input slice: one RIFM beat per row block
            pl.BlockSpec((b, N_C), lambda rb, cb: (0, rb)),
            # the stationary crossbar block of tile (rb, cb)
            pl.BlockSpec((N_C, N_M), lambda rb, cb: (rb, cb)),
        ],
        out_specs=[
            # partial-sum chain state for the current column
            pl.BlockSpec((b, N_M), lambda rb, cb: (0, cb)),
            pl.BlockSpec((b, N_M), lambda rb, cb: (0, cb)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, cbs * N_M), jnp.int32),
            jax.ShapeDtypeStruct((b, cbs * N_M), jnp.int8),
        ],
        interpret=True,
    )(xp, wp)
    del acc  # chain registers; only the requantized OFM leaves the array
    return y[:, :cout]
