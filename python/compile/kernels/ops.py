"""Shared int8 arithmetic semantics (build-time JAX).

These functions fix the exact quantized arithmetic the whole stack agrees
on — bit-identical to ``rust/src/model/refcompute.rs``:

* activations and weights are ``int8``, accumulation is ``int32``;
* conv/fc requantization: ``y = clamp_i8(relu?(acc >> shift))`` with an
  **arithmetic** right shift, ReLU applied *after* the shift, then
  saturation to ``[-128, 127]``;
* residual add: ``y = clamp_i8(max(a + b, 0))`` (ReLU fused, as in
  ResNet);
* max pool: plain ``int8`` max; average pool: ``floor(sum / k**2)``
  (floor division — matches Rust ``div_euclid`` for positive divisors).

All helpers are pure ``jax.numpy`` so they lower into the same HLO module
as the Pallas kernels that call them.
"""

from __future__ import annotations

import jax.numpy as jnp

I8_MIN = -128
I8_MAX = 127


def clamp_i8(v):
    """Saturate an int32 tensor to int8 range (returns int8)."""
    return jnp.clip(v, I8_MIN, I8_MAX).astype(jnp.int8)


def requant(acc, shift: int, relu: bool):
    """The shared conv/fc requantization: arithmetic shift, optional
    ReLU, saturation.

    ``acc`` is int32. ``jnp.right_shift`` on a signed dtype is an
    arithmetic shift (sign-propagating), matching Rust ``i32 >> shift``.
    """
    v = jnp.right_shift(acc.astype(jnp.int32), jnp.int32(shift))
    if relu:
        v = jnp.maximum(v, 0)
    return clamp_i8(v)


def res_add(a, b):
    """Residual add with fused ReLU: ``clamp_i8(max(a + b, 0))``."""
    s = a.astype(jnp.int32) + b.astype(jnp.int32)
    return clamp_i8(jnp.maximum(s, 0))


def pad_chw(x, padding: int):
    """Zero-pad an int8 CHW tensor on H and W."""
    if padding == 0:
        return x
    return jnp.pad(x, ((0, 0), (padding, padding), (padding, padding)))


def _pool_windows(x, kernel: int, stride: int):
    """Stack the k*k shifted strided views of a CHW tensor: returns
    ``(k*k, C, Ho, Wo)``."""
    c, h, w = x.shape
    oh = (h - kernel) // stride + 1
    ow = (w - kernel) // stride + 1
    views = []
    for kr in range(kernel):
        for kc in range(kernel):
            v = x[:, kr : kr + (oh - 1) * stride + 1 : stride,
                  kc : kc + (ow - 1) * stride + 1 : stride]
            views.append(v)
    return jnp.stack(views)


def max_pool(x, kernel: int, stride: int):
    """Max pooling over a CHW int8 tensor (ROFM ``Cmp.``, Table II)."""
    return jnp.max(_pool_windows(x, kernel, stride), axis=0)


def avg_pool(x, kernel: int, stride: int):
    """Average pooling with floor division (ROFM ``Mul.`` with a scaling
    factor, Table II)."""
    s = jnp.sum(_pool_windows(x, kernel, stride).astype(jnp.int32), axis=0)
    return clamp_i8(jnp.floor_divide(s, kernel * kernel))
