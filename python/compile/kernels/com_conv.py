"""L1 Pallas kernel: im2col-free COM-ordered convolution.

The paper's central dataflow claim (Section III-B): convolution without
converting the IFM to a Toeplitz matrix. Kernel pixel ``(kr, kc)`` and
input-channel block ``cb`` live in their own tile holding the stationary
``(C_b, M)`` weight slice; the IFM streams past every tile once, and each
tile's point-wise MAC result (the *partial-sum*) is added into the moving
accumulation — K partial sums form a *group-sum*, K group-sums form the
output.

This kernel is that dataflow, expressed on the Pallas grid: grid step
``(cb, kr, kc)`` is one tile; it takes a **shifted strided view** of the
padded IFM (the stream alignment the RIFM counter implements), MACs it
against its stationary weight slice, and accumulates into the int32
carry (``acc_ref`` — the psum/group-sum moving through the ROFM
network). The final grid step applies the M-type requantization. At no
point does an im2col matrix exist.

``interpret=True``: CPU PJRT cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ops

# Channel block size (crossbar rows, Section IV-A).
N_C = 256


def _com_conv_kernel(x_ref, w_ref, acc_ref, y_ref, *, k: int, stride: int,
                     oh: int, ow: int, n_cb: int, shift: int, relu: bool):
    """One tile step: kernel position (kr, kc), channel block cb."""
    cb, kr, kc = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    # chain start: no incoming partial sum yet
    @pl.when((cb == 0) & (kr == 0) & (kc == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The RIFM alignment: this tile MACs the IFM pixels whose window
    # offset matches its kernel position — a (kr, kc)-shifted,
    # stride-strided view of the padded stream. No Toeplitz matrix.
    xb = x_ref[...]  # (C_b, Hp, Wp) stationary-resident stream window
    cb_ch = xb.shape[0]
    xs = jax.lax.dynamic_slice(
        xb, (0, kr, kc), (cb_ch, (oh - 1) * stride + 1, (ow - 1) * stride + 1)
    )[:, ::stride, ::stride].astype(jnp.int32)

    # the PE: point-wise MAC against the stationary (C_b, M) slice,
    # partial-sum added to the moving accumulation (COM)
    w = w_ref[0, 0].astype(jnp.int32)  # (C_b, M)
    acc_ref[...] += jnp.einsum("chw,cm->mhw", xs, w)

    # last tile (kr = kc = K-1, last channel block): M-type Act/quantize
    @pl.when((cb == n_cb - 1) & (kr == k - 1) & (kc == k - 1))
    def _emit():
        y_ref[...] = ops.requant(acc_ref[...], shift, relu)


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "shift", "relu")
)
def com_conv2d(x, w, stride: int = 1, padding: int = 0, shift: int = 0,
               relu: bool = False):
    """COM-dataflow convolution: ``y = requant(conv(x, w), shift, relu)``.

    ``x`` int8 ``[C, H, W]``; ``w`` int8 ``[K, K, C, M]`` (kernel-
    position major — the tile mapping order of paper Fig. 3(a); use
    :func:`w_from_mckk` to convert from the ``[M, C, K, K]`` refcompute
    layout). Returns int8 ``[M, Ho, Wo]``.
    """
    c, _, _ = x.shape
    k, k2, cw, m = w.shape
    assert k == k2 and cw == c, (w.shape, x.shape)
    xp = ops.pad_chw(x, padding)
    _, hp, wp = xp.shape
    oh = (hp - k) // stride + 1
    ow = (wp - k) // stride + 1

    # split channels into crossbar-row blocks (zero-pad the ragged edge)
    n_cb = -(-c // N_C)
    cpad = n_cb * N_C - c
    xp = jnp.pad(xp, ((0, cpad), (0, 0), (0, 0)))
    wpad = jnp.pad(w, ((0, 0), (0, 0), (0, cpad), (0, 0)))

    kernel = functools.partial(
        _com_conv_kernel,
        k=k, stride=stride, oh=oh, ow=ow, n_cb=n_cb, shift=shift, relu=relu,
    )
    acc, y = pl.pallas_call(
        kernel,
        grid=(n_cb, k, k),
        in_specs=[
            # the streamed IFM window for channel block cb (whole padded
            # plane: the stream passes every tile once)
            pl.BlockSpec((N_C, hp, wp), lambda cb, kr, kc: (cb, 0, 0)),
            # tile (cb, kr, kc)'s stationary weight slice
            pl.BlockSpec(
                (1, 1, N_C, m), lambda cb, kr, kc: (kr, kc, cb, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((m, oh, ow), lambda cb, kr, kc: (0, 0, 0)),
            pl.BlockSpec((m, oh, ow), lambda cb, kr, kc: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, oh, ow), jnp.int32),
            jax.ShapeDtypeStruct((m, oh, ow), jnp.int8),
        ],
        interpret=True,
    )(xp, wpad)
    del acc  # the moving group-sums; only the OFM leaves the array
    return y


def w_from_mckk(w):
    """Convert ``[M, C, K, K]`` (refcompute layout) to the kernel's
    ``[K, K, C, M]`` tile-mapping order (paper Fig. 3(a): "pixels in
    kernels are mapped to CIM arrays according to their locations and
    channels in sequence")."""
    return jnp.transpose(w, (2, 3, 1, 0))
