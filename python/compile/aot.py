"""AOT compilation: lower the L2/L1 JAX functions to HLO **text**.

Python runs once, here, at build time (``make artifacts``); the Rust
coordinator loads the resulting ``artifacts/*.hlo.txt`` through the
``xla`` crate's PJRT CPU client and never imports Python again.

HLO *text* — not a serialized ``HloModuleProto`` — is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids that the
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. Lowering goes
stablehlo → XlaComputation (``return_tuple=True``; the Rust side
unwraps with ``to_tuple1``). See /opt/xla-example/README.md.

Artifacts produced (``artifacts/``):

=========================  ==================================================
tiny_cnn_int8.hlo.txt      zoo::tiny_cnn int8 forward, weights as *inputs*
                           (x, w0, w2, w3, w6, w9) — the golden model the
                           cycle simulator is checked against bit-exactly
tiny_trained_int8.hlo.txt  the same network with the *calibrated requant
                           shifts* baked in; weights stay inputs (loaded
                           from tiny_weights.bin at run time)
cim_mvm_256.hlo.txt        one 256x256 crossbar MVM (the PE hot-spot)
com_conv_k3.hlo.txt        one COM-dataflow 3x3 conv layer
tiny_weights.bin           trained int8 weights + per-layer requant shifts
tiny_testset.bin           held-out int8 test set (label + pixels)
accuracy.json              fp32 vs int8 accuracy (the Table IV accuracy row
                           for the trainable substitute network)
manifest.json              shapes/dtypes of every artifact entry point
=========================  ==================================================
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels.cim_mvm import cim_mvm
from .kernels.com_conv import com_conv2d

SEED = 0xD0311  # build is fully deterministic


def to_hlo_text(fn, *example_args) -> str:
    """Lower a jittable function to HLO text (see module docs)."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def i8(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int8)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    ap.add_argument("--train-steps", type=int, default=300)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {}

    def emit(name: str, text: str, entry: dict):
        path = os.path.join(args.out, name)
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = entry
        print(f"wrote {name} ({len(text)} chars)")

    # ---- golden model: weights as inputs, default shift-7 requant
    x = i8(model.INPUT_SHAPE)
    ws = [i8((m, c, 3, 3)) for (m, c) in model.TINY_CONV_SHAPES]
    w9 = i8(model.TINY_FC_SHAPE)
    emit(
        "tiny_cnn_int8.hlo.txt",
        to_hlo_text(model.tiny_cnn_int8, x, *ws, w9),
        {
            "inputs": ["x[3,16,16]i8", "w0[16,3,3,3]i8", "w2[32,16,3,3]i8",
                       "w3[32,32,3,3]i8", "w6[32,32,3,3]i8", "w9[10,32]i8"],
            "outputs": ["logits[10]i8"],
            "shifts": list(model.DEFAULT_SHIFTS),
        },
    )

    # ---- kernel hot-spots
    emit(
        "cim_mvm_256.hlo.txt",
        to_hlo_text(
            functools.partial(cim_mvm, shift=7, relu=True),
            i8((1, 256)), i8((256, 256)),
        ),
        {"inputs": ["x[1,256]i8", "w[256,256]i8"],
         "outputs": ["y[1,256]i8"], "shift": 7, "relu": True},
    )
    emit(
        "com_conv_k3.hlo.txt",
        to_hlo_text(
            functools.partial(com_conv2d, stride=1, padding=1,
                              shift=7, relu=True),
            i8((16, 16, 16)), i8((3, 3, 16, 32)),
        ),
        {"inputs": ["x[16,16,16]i8", "w[3,3,16,32]i8(kkcm)"],
         "outputs": ["y[32,16,16]i8"], "shift": 7, "relu": True},
    )

    # ---- accuracy experiment: train fp32, calibrate, quantize
    key = jax.random.PRNGKey(SEED)
    params, train_x, train_y = model.train(key, steps=args.train_steps)
    test_x, test_y = model.make_dataset(
        jax.random.PRNGKey(SEED + 1), 256
    )
    qparams, shifts, p_log = model.calibrate_and_quantize(
        params, train_x[:32]
    )
    acc_f = model.accuracy_float(params, test_x, test_y)
    acc_q = model.accuracy_int8(qparams, shifts, test_x, test_y)
    print(f"accuracy: fp32 {acc_f:.4f} -> int8 {acc_q:.4f} "
          f"(shifts {shifts})")

    # NOTE: weights stay *inputs* (loaded from tiny_weights.bin at run
    # time) — xla_extension 0.5.1's HLO text parser mis-decodes large
    # baked s8 constant arrays, so only the calibrated shifts are baked.
    emit(
        "tiny_trained_int8.hlo.txt",
        to_hlo_text(
            functools.partial(model.tiny_cnn_int8, shifts=shifts),
            x, *ws, w9,
        ),
        {"inputs": ["x[3,16,16]i8", "w0[16,3,3,3]i8", "w2[32,16,3,3]i8",
                    "w3[32,32,3,3]i8", "w6[32,32,3,3]i8", "w9[10,32]i8"],
         "outputs": ["logits[10]i8"],
         "shifts": list(shifts), "logit_scale_exp": p_log},
    )

    model.write_weights_bin(
        os.path.join(args.out, "tiny_weights.bin"), qparams, shifts
    )
    model.write_testset_bin(
        os.path.join(args.out, "tiny_testset.bin"),
        np.stack([model.quantize_input(xx) for xx in test_x]),
        test_y,
    )
    with open(os.path.join(args.out, "accuracy.json"), "w") as f:
        json.dump(
            {
                "network": "tiny-cnn",
                "dataset": "synthetic-10class (256 held-out)",
                "fp32_accuracy": acc_f,
                "int8_accuracy": acc_q,
                "shifts": list(shifts),
                "train_steps": args.train_steps,
                "seed": SEED,
            },
            f, indent=2,
        )
    manifest["tiny_weights.bin"] = {
        "format": "DMN1 [u32 shift, u32 len, i8 data] x5 (w0,w2,w3,w6,w9)"
    }
    manifest["tiny_testset.bin"] = {
        "format": "DMN1 u32 count, then [u32 label, 768 x i8] per image"
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
