"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Exact integer equality everywhere — int8 arithmetic has no tolerance.
Shape/stride/padding sweeps come from `hypothesis` so the blocked
(ragged-edge) paths are exercised, not just friendly sizes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ops, ref
from compile.kernels.cim_mvm import cim_mvm, N_C
from compile.kernels.com_conv import com_conv2d, w_from_mckk

RNG = np.random.default_rng(0xD0311)


def i8a(shape, bound=15):
    return jnp.array(
        RNG.integers(-bound, bound + 1, shape, dtype=np.int8)
    )


# ------------------------------------------------------------------
# cim_mvm
# ------------------------------------------------------------------

class TestCimMvm:
    def test_single_tile_exact(self):
        x, w = i8a((1, 256)), i8a((256, 256))
        got = cim_mvm(x, w, shift=7, relu=True)
        want = ref.cim_mvm_ref(x, w, shift=7, relu=True)
        np.testing.assert_array_equal(got, want)

    def test_multi_row_blocks_chain_accumulation(self):
        # Cin > 256: the grid's row dimension is the psum chain
        x, w = i8a((2, 700)), i8a((700, 256))
        np.testing.assert_array_equal(
            cim_mvm(x, w, 4, False), ref.cim_mvm_ref(x, w, 4, False)
        )

    def test_multi_col_blocks(self):
        x, w = i8a((1, 256)), i8a((256, 600))
        np.testing.assert_array_equal(
            cim_mvm(x, w, 0, False), ref.cim_mvm_ref(x, w, 0, False)
        )

    def test_ragged_both_dims(self):
        x, w = i8a((3, 300)), i8a((300, 270))
        np.testing.assert_array_equal(
            cim_mvm(x, w, 7, True), ref.cim_mvm_ref(x, w, 7, True)
        )

    def test_saturation(self):
        # max-magnitude operands force both saturation rails
        x = jnp.full((1, 512), 127, jnp.int8)
        w = jnp.full((512, 8), 127, jnp.int8)
        y = cim_mvm(x, w, 0, False)
        assert int(y[0, 0]) == 127
        y = cim_mvm(x, -w, 0, False)
        assert int(y[0, 0]) == -128

    def test_relu_after_shift(self):
        # acc = -127: >>7 = -1 (arithmetic), relu -> 0
        x = jnp.array([[-1]], jnp.int8)
        w = jnp.array([[127]], jnp.int8)
        assert int(cim_mvm(x, w, 7, True)[0, 0]) == 0
        assert int(cim_mvm(x, w, 7, False)[0, 0]) == -1

    @settings(deadline=None, max_examples=20)
    @given(
        b=st.integers(1, 3),
        cin=st.integers(1, 520),
        cout=st.integers(1, 300),
        shift=st.integers(0, 10),
        relu=st.booleans(),
    )
    def test_property_matches_ref(self, b, cin, cout, shift, relu):
        x, w = i8a((b, cin)), i8a((cin, cout))
        np.testing.assert_array_equal(
            cim_mvm(x, w, shift, relu), ref.cim_mvm_ref(x, w, shift, relu)
        )


# ------------------------------------------------------------------
# com_conv2d
# ------------------------------------------------------------------

class TestComConv:
    def test_3x3_padded(self):
        x, w = i8a((5, 8, 8)), i8a((7, 5, 3, 3))
        got = com_conv2d(x, w_from_mckk(w), 1, 1, 7, True)
        np.testing.assert_array_equal(got, ref.conv2d_ref(x, w, 1, 1, 7, True))

    def test_no_padding(self):
        x, w = i8a((2, 6, 6)), i8a((3, 2, 3, 3))
        got = com_conv2d(x, w_from_mckk(w), 1, 0, 0, False)
        np.testing.assert_array_equal(got, ref.conv2d_ref(x, w, 1, 0, 0, False))

    def test_stride_two(self):
        x, w = i8a((2, 9, 9)), i8a((4, 2, 3, 3))
        got = com_conv2d(x, w_from_mckk(w), 2, 1, 5, True)
        np.testing.assert_array_equal(got, ref.conv2d_ref(x, w, 2, 1, 5, True))

    def test_1x1_kernel(self):
        x, w = i8a((6, 4, 4)), i8a((5, 6, 1, 1))
        got = com_conv2d(x, w_from_mckk(w), 1, 0, 0, True)
        np.testing.assert_array_equal(got, ref.conv2d_ref(x, w, 1, 0, 0, True))

    def test_channel_blocking_over_256(self):
        # C > 256 exercises the cb grid dimension (multi-tile chains)
        x, w = i8a((300, 4, 4), 3), i8a((8, 300, 3, 3), 3)
        got = com_conv2d(x, w_from_mckk(w), 1, 1, 7, False)
        np.testing.assert_array_equal(got, ref.conv2d_ref(x, w, 1, 1, 7, False))

    def test_5x5_kernel(self):
        x, w = i8a((3, 10, 10)), i8a((4, 3, 5, 5))
        got = com_conv2d(x, w_from_mckk(w), 1, 2, 6, True)
        np.testing.assert_array_equal(got, ref.conv2d_ref(x, w, 1, 2, 6, True))

    @settings(deadline=None, max_examples=15)
    @given(
        c=st.integers(1, 8),
        m=st.integers(1, 8),
        h=st.integers(3, 10),
        k=st.sampled_from([1, 3]),
        stride=st.integers(1, 2),
        padding=st.integers(0, 1),
        shift=st.integers(0, 9),
        relu=st.booleans(),
    )
    def test_property_matches_ref(self, c, m, h, k, stride, padding, shift, relu):
        if h + 2 * padding < k:
            return
        x, w = i8a((c, h, h)), i8a((m, c, k, k))
        got = com_conv2d(x, w_from_mckk(w), stride, padding, shift, relu)
        np.testing.assert_array_equal(
            got, ref.conv2d_ref(x, w, stride, padding, shift, relu)
        )


# ------------------------------------------------------------------
# ops semantics (the shared arithmetic contract)
# ------------------------------------------------------------------

class TestOps:
    def test_requant_matches_rust_unit_cases(self):
        # mirrors refcompute.rs requant_semantics test
        acc = jnp.array([255, -300, 256, -1], jnp.int32)
        y = ops.requant(acc, 0, False)
        np.testing.assert_array_equal(np.array(y[:2]), [127, -128])
        assert int(ops.requant(jnp.array([-300]), 0, True)[0]) == 0
        assert int(ops.requant(jnp.array([256]), 7, False)[0]) == 2
        # arithmetic shift: -1 >> 7 == -1
        assert int(ops.requant(jnp.array([-1]), 7, False)[0]) == -1
        assert int(ops.requant(jnp.array([-1]), 7, True)[0]) == 0

    def test_res_add_matches_rust(self):
        a = jnp.array([100, -100, 3], jnp.int8)
        b = jnp.array([100, 50, 4], jnp.int8)
        np.testing.assert_array_equal(np.array(ops.res_add(a, b)), [127, 0, 7])

    def test_avg_pool_floor_division(self):
        # sum = -3: floor(-3/4) = -1 (floor, not trunc)
        x = jnp.array([[[1, 2], [3, -9]]], jnp.int8)
        assert int(ops.avg_pool(x, 2, 2)[0, 0, 0]) == -1

    def test_max_pool(self):
        x = jnp.array([[[1, 5, -3, -7], [2, 0, -1, -9]]], jnp.int8)
        np.testing.assert_array_equal(
            np.array(ops.max_pool(x, 2, 2)[0]), [[5, -1]]
        )

    @settings(deadline=None, max_examples=20)
    @given(c=st.integers(1, 4), h=st.sampled_from([2, 4, 6]))
    def test_max_pool_bounds_avg_pool(self, c, h):
        x = i8a((c, h, h), 100)
        mx, av = ops.max_pool(x, 2, 2), ops.avg_pool(x, 2, 2)
        assert bool(jnp.all(mx >= av))
