"""L2 model tests: quantized forward vs oracle, training, quantization,
and the artifact interchange formats."""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ops

RNG = np.random.default_rng(1)


def rand_weights():
    ws = [
        jnp.array(RNG.integers(-15, 16, (m, c, 3, 3), dtype=np.int8))
        for (m, c) in model.TINY_CONV_SHAPES
    ]
    w9 = jnp.array(RNG.integers(-15, 16, model.TINY_FC_SHAPE, dtype=np.int8))
    return ws, w9


class TestTinyCnnInt8:
    def test_pallas_equals_oracle(self):
        ws, w9 = rand_weights()
        x = jnp.array(RNG.integers(-31, 32, model.INPUT_SHAPE, dtype=np.int8))
        a = model.tiny_cnn_int8(x, *ws, w9)
        b = model.tiny_cnn_int8_ref(x, *ws, w9)
        np.testing.assert_array_equal(a, b)

    def test_output_shape_and_dtype(self):
        ws, w9 = rand_weights()
        x = jnp.zeros(model.INPUT_SHAPE, jnp.int8)
        y = model.tiny_cnn_int8(x, *ws, w9)
        assert y.shape == (10,)
        assert y.dtype == jnp.int8

    def test_custom_shifts_change_scale(self):
        ws, w9 = rand_weights()
        x = jnp.array(RNG.integers(-31, 32, model.INPUT_SHAPE, dtype=np.int8))
        y7 = model.tiny_cnn_int8_ref(x, *ws, w9, (7,) * 5)
        y9 = model.tiny_cnn_int8_ref(x, *ws, w9, (9, 7, 7, 7, 7))
        assert not np.array_equal(np.array(y7), np.array(y9))

    def test_deterministic(self):
        ws, w9 = rand_weights()
        x = jnp.array(RNG.integers(-31, 32, model.INPUT_SHAPE, dtype=np.int8))
        a = model.tiny_cnn_int8(x, *ws, w9)
        b = model.tiny_cnn_int8(x, *ws, w9)
        np.testing.assert_array_equal(a, b)


class TestTrainingAndQuantization:
    @pytest.fixture(scope="class")
    def trained(self):
        params, x, y = model.train(jax.random.PRNGKey(3), steps=250)
        return params, x, y

    def test_float_learns(self, trained):
        params, x, y = trained
        acc = model.accuracy_float(params, x[:128], y[:128])
        assert acc > 0.75, f"train accuracy {acc}"

    def test_quantization_preserves_accuracy(self, trained):
        params, x, y = trained
        qp, shifts, _ = model.calibrate_and_quantize(params, x[:32])
        acc_f = model.accuracy_float(params, x[:128], y[:128])
        acc_q = model.accuracy_int8(qp, shifts, x[:128], y[:128])
        assert acc_q > acc_f - 0.1, f"int8 {acc_q} vs fp32 {acc_f}"

    def test_shifts_are_nonnegative_and_small(self, trained):
        params, x, _ = trained
        _, shifts, _ = model.calibrate_and_quantize(params, x[:16])
        assert all(0 <= s <= 15 for s in shifts), shifts

    def test_quantized_weights_are_int8(self, trained):
        params, x, _ = trained
        qp, _, _ = model.calibrate_and_quantize(params, x[:16])
        for k, v in qp.items():
            assert v.dtype == jnp.int8, k


class TestDataset:
    def test_shared_templates_fixed_task(self):
        x1, y1 = model.make_dataset(jax.random.PRNGKey(0), 8)
        x2, y2 = model.make_dataset(jax.random.PRNGKey(1), 8)
        # different samples, same task: same label space, same shapes
        assert x1.shape == x2.shape == (8, *model.INPUT_SHAPE)
        assert not np.array_equal(np.array(x1), np.array(x2))

    def test_input_range(self):
        x, _ = model.make_dataset(jax.random.PRNGKey(0), 16)
        assert float(jnp.max(jnp.abs(x))) <= 1.0

    def test_quantize_input_range(self):
        x, _ = model.make_dataset(jax.random.PRNGKey(0), 4)
        q = model.quantize_input(x[0])
        assert q.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 64


class TestInterchangeFormats:
    def test_weights_bin_roundtrip(self):
        ws, w9 = rand_weights()
        qp = {"w0": ws[0], "w2": ws[1], "w3": ws[2], "w6": ws[3], "w9": w9}
        shifts = (8, 11, 8, 9, 6)
        with tempfile.NamedTemporaryFile(suffix=".bin") as f:
            model.write_weights_bin(f.name, qp, shifts)
            raw = open(f.name, "rb").read()
        assert raw[:4] == model.MAGIC
        off = 4
        for key, sh in zip(["w0", "w2", "w3", "w6", "w9"], shifts):
            got_shift, n = struct.unpack_from("<II", raw, off)
            off += 8
            data = np.frombuffer(raw, np.int8, n, off)
            off += n
            assert got_shift == sh
            np.testing.assert_array_equal(
                data, np.asarray(qp[key], np.int8).reshape(-1)
            )
        assert off == len(raw)

    def test_testset_bin_roundtrip(self):
        x = RNG.integers(-64, 65, (3, *model.INPUT_SHAPE)).astype(np.int8)
        y = np.array([1, 5, 9], np.uint32)
        with tempfile.NamedTemporaryFile(suffix=".bin") as f:
            model.write_testset_bin(f.name, x, y)
            raw = open(f.name, "rb").read()
        assert raw[:4] == model.MAGIC
        (count,) = struct.unpack_from("<I", raw, 4)
        assert count == 3
        off = 8
        for i in range(3):
            (lbl,) = struct.unpack_from("<I", raw, off)
            off += 4
            img = np.frombuffer(raw, np.int8, 768, off)
            off += 768
            assert lbl == y[i]
            np.testing.assert_array_equal(img, x[i].reshape(-1))


class TestArtifacts:
    """Validate the built artifacts directory (requires `make artifacts`)."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.fixture(autouse=True)
    def _skip_without_artifacts(self):
        if not os.path.exists(os.path.join(self.ART, "manifest.json")):
            pytest.skip("artifacts not built (run `make artifacts`)")

    def test_manifest_lists_all_artifacts(self):
        manifest = json.load(open(os.path.join(self.ART, "manifest.json")))
        for name in [
            "tiny_cnn_int8.hlo.txt", "tiny_trained_int8.hlo.txt",
            "cim_mvm_256.hlo.txt", "com_conv_k3.hlo.txt",
            "tiny_weights.bin", "tiny_testset.bin",
        ]:
            assert name in manifest, name
            assert os.path.exists(os.path.join(self.ART, name)), name

    def test_hlo_text_is_parseable_prefix(self):
        txt = open(os.path.join(self.ART, "tiny_cnn_int8.hlo.txt")).read()
        assert txt.startswith("HloModule"), txt[:40]

    def test_accuracy_json_reports_quantization_gap(self):
        acc = json.load(open(os.path.join(self.ART, "accuracy.json")))
        assert 0.5 < acc["int8_accuracy"] <= 1.0
        assert acc["int8_accuracy"] > acc["fp32_accuracy"] - 0.1
