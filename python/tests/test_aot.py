"""AOT lowering tests: HLO text generation is deterministic, parseable
and integer-only (the whole datapath is int8/int32 — any fp op would
signal a quantization leak)."""

import functools
import re

import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.aot import i8, to_hlo_text
from compile.kernels.cim_mvm import cim_mvm
from compile.kernels.com_conv import com_conv2d


@pytest.fixture(scope="module")
def mvm_hlo():
    return to_hlo_text(
        functools.partial(cim_mvm, shift=7, relu=True),
        i8((1, 256)), i8((256, 256)),
    )


class TestHloText:
    def test_starts_with_hlomodule(self, mvm_hlo):
        assert mvm_hlo.startswith("HloModule")

    def test_deterministic(self, mvm_hlo):
        again = to_hlo_text(
            functools.partial(cim_mvm, shift=7, relu=True),
            i8((1, 256)), i8((256, 256)),
        )
        assert mvm_hlo == again

    def test_returns_tuple(self, mvm_hlo):
        # return_tuple=True: the rust side unwraps with decompose_tuple
        assert re.search(r"ROOT .*tuple", mvm_hlo), "root must be a tuple"

    def test_integer_only_datapath(self, mvm_hlo):
        # s8/s32 everywhere; f32/f64/bf16 anywhere means a quantization
        # leak into the AOT artifact
        for fp in ("f32[", "f64[", "bf16[", "f16["):
            assert fp not in mvm_hlo, f"float type {fp} leaked into HLO"

    def test_conv_kernel_lowers_integer_only(self):
        txt = to_hlo_text(
            functools.partial(com_conv2d, stride=1, padding=1,
                              shift=7, relu=True),
            i8((16, 16, 16)), i8((3, 3, 16, 32)),
        )
        for fp in ("f32[", "f64[", "bf16[", "f16["):
            assert fp not in txt

    def test_tiny_cnn_signature(self):
        x = i8(model.INPUT_SHAPE)
        ws = [i8((m, c, 3, 3)) for (m, c) in model.TINY_CONV_SHAPES]
        w9 = i8(model.TINY_FC_SHAPE)
        txt = to_hlo_text(model.tiny_cnn_int8, x, *ws, w9)
        # six s8 parameters, one s8[10] logits output
        assert txt.count("parameter(") >= 6
        assert "s8[10]" in txt

    def test_shift_is_baked_statically(self):
        # two different shifts must lower to different modules
        a = to_hlo_text(functools.partial(cim_mvm, shift=5, relu=False),
                        i8((1, 64)), i8((64, 64)))
        b = to_hlo_text(functools.partial(cim_mvm, shift=6, relu=False),
                        i8((1, 64)), i8((64, 64)))
        assert a != b
