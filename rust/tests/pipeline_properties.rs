//! Cross-module integration properties: random networks through the
//! whole compile → simulate pipeline, checked against the int8
//! reference and the analytic model.

use domino::coordinator::{ArchConfig, Compiler};
use domino::model::refcompute::{forward, Tensor, Weights};
use domino::model::{Network, NetworkBuilder, TensorShape};
use domino::perfmodel;
use domino::sim::Simulator;
use domino::testutil::{for_all, Rng};

/// Generate a random small network exercising every layer kind.
fn random_net(rng: &mut Rng) -> Network {
    let c = rng.range(1, 5);
    let h = rng.range(6, 11);
    let mut b = NetworkBuilder::new("prop", TensorShape::new(c, h, h));
    let n_blocks = rng.range(1, 4);
    let mut ch = c;
    let mut cur_h = h;
    for _ in 0..n_blocks {
        let out = rng.range(2, 9);
        match rng.range(0, 4) {
            0 => {
                b = b.conv(out, 3, 1, 1);
                ch = out;
            }
            1 => {
                b = b.conv(out, 1, 1, 0);
                ch = out;
            }
            2 if cur_h >= 5 => {
                b = b.conv(out, 3, 2, 1);
                ch = out;
                cur_h = cur_h.div_ceil(2);
            }
            _ => {
                // residual pair (identity skip)
                b = b.conv(ch, 3, 1, 1).conv_linear(ch, 3, 1, 1);
                let idx = b.next_index() - 2;
                b = b.res_add(idx);
            }
        }
        if cur_h >= 4 && rng.range(0, 2) == 0 {
            b = b.max_pool(2, 2);
            cur_h /= 2;
        }
    }
    let _ = ch;
    b.flatten().fc_logits(rng.range(2, 7)).build()
}

#[test]
fn random_networks_simulate_exactly() {
    for_all("sim_equals_reference", 25, |rng| {
        let net = random_net(rng);
        let arch = if rng.range(0, 2) == 0 {
            ArchConfig::default()
        } else {
            ArchConfig::tiny(rng.range(4, 17))
        };
        let compiler = Compiler::new(arch);
        let weights = Weights::random(&net, rng.next_u64()).unwrap();
        let program = compiler.compile_with_weights(&net, &weights).unwrap();
        let input = Tensor::new(net.input, rng.i8_vec(net.input_len(), 31));
        let mut sim = Simulator::new(&program);
        let got = sim.run_image(&input.data).unwrap();
        let want = forward(&net, &weights, &input).unwrap();
        assert_eq!(got.scores, want.data, "net {} mismatch", net.name);
    });
}

#[test]
fn random_networks_estimate_exactly() {
    // A3 extended: the analytic model's counters equal the engine's on
    // arbitrary generated networks, not just the zoo.
    for_all("estimate_equals_engine", 20, |rng| {
        let net = random_net(rng);
        let program = Compiler::default().compile(&net).unwrap();
        let est = perfmodel::estimate(&program).unwrap();
        let mut sim = Simulator::new(&program);
        let out = sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
        let s = sim.stats();
        assert_eq!(est.counters.pe_macs, s.pe_macs);
        assert_eq!(est.counters.rifm_buffer_accesses, s.rifm_buffer_accesses);
        assert_eq!(est.counters.adds_8b, s.adds_8b);
        assert_eq!(est.counters.onchip_link_bits, s.onchip_link_bits);
        assert_eq!(est.counters.rofm_buffer_accesses, s.rofm_buffer_accesses);
        assert_eq!(est.latency_cycles, out.latency_cycles);
    });
}

#[test]
fn random_networks_fit_hardware_tables() {
    for_all("schedules_fit", 20, |rng| {
        let net = random_net(rng);
        let program = Compiler::default().compile(&net).unwrap();
        assert!(program.schedules_fit_hardware(), "{}", net.name);
    });
}

#[test]
fn duplication_is_functionally_invisible() {
    // water-filled duplication must not change any output bit
    for_all("dup_invariant", 10, |rng| {
        let net = random_net(rng);
        let weights = Weights::random(&net, rng.next_u64()).unwrap();
        let input = rng.i8_vec(net.input_len(), 31);
        let base = Compiler::default()
            .compile_with_weights(&net, &weights)
            .unwrap();
        let dup = Compiler::new(ArchConfig::table4(2))
            .compile_with_weights(&net, &weights)
            .unwrap();
        let a = Simulator::new(&base).run_image(&input).unwrap();
        let b = Simulator::new(&dup).run_image(&input).unwrap();
        assert_eq!(a.scores, b.scores);
        // and the event counts stay identical (same work, more tiles)
        let ea = domino::perfmodel::estimate(&base).unwrap();
        let eb = domino::perfmodel::estimate(&dup).unwrap();
        assert_eq!(ea.counters.pe_macs, eb.counters.pe_macs);
        assert!(eb.period_cycles <= ea.period_cycles);
    });
}

#[test]
fn zoo_models_compile_at_paper_operating_points() {
    use domino::model::zoo;
    for (net, chips) in [
        (zoo::vgg11_cifar(), 5usize),
        (zoo::resnet18_cifar(), 6),
        (zoo::vgg16_imagenet(), 10),
        (zoo::vgg19_imagenet(), 10),
    ] {
        let p = Compiler::new(ArchConfig::table4(chips)).compile(&net).unwrap();
        assert!(p.total_tiles <= chips * 240, "{}", net.name);
        assert!(p.schedules_fit_hardware(), "{}", net.name);
    }
}
