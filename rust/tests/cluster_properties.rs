//! Cluster-plane properties, end to end over real TCP: consistent
//! model→backend assignment, replica placement, least-loaded dispatch
//! under concurrency, and failover — both in-process (a backend's
//! endpoint shuts down) and cross-process (a spawned `domino serve`
//! backend is SIGKILLed mid-run). Every accepted inference must come
//! back version-stamped and bit-exact against a local refcompute of
//! the same (network, seed) — failover is only correct if the
//! replacement backend serves the *identical* weights.
//!
//! The fault plane rides the same harness: a backend with an armed
//! [`domino::sim::FaultPlan`] keeps answering its socket while
//! serving silently-wrong bits, and only the router's canary pass
//! catches it — excluded from routing like a dead backend, healed by
//! a fault-aware re-map, then re-admitted by the next passing canary.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use domino::coordinator::ArchConfig;
use domino::model::zoo;
use domino::serve::api::{Dispatcher, Request, Response};
use domino::serve::client::Client;
use domino::serve::net::NetServer;
use domino::serve::{
    ClusterConfig, ModelRegistry, Router, ServeConfig, Server, Service,
};
use domino::testutil::Rng;

const MODEL: &str = "tiny-mlp";
const SEED: u64 = 7;

/// One in-process backend: empty registry, sim server, TCP endpoint.
struct TestBackend {
    service: Arc<Service>,
    net: Option<NetServer>,
    addr: String,
}

fn start_backend() -> TestBackend {
    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start_multi(
        ServeConfig {
            workers: 1,
            max_batch: 2,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        registry,
    )
    .expect("start server");
    let service = Arc::new(Service::new(server, ArchConfig::default()));
    let net = NetServer::bind("127.0.0.1:0", Arc::clone(&service)).expect("bind");
    let addr = net.local_addr().to_string();
    TestBackend {
        service,
        net: Some(net),
        addr,
    }
}

/// A router with probing under test control (no background cadence).
fn test_router(addrs: Vec<String>, replication: usize) -> Router {
    Router::new(
        addrs,
        ClusterConfig {
            replication,
            health_interval: Duration::from_secs(3600),
            request_timeout: Duration::from_secs(30),
            health_timeout: Duration::from_secs(5),
            ..ClusterConfig::default()
        },
    )
    .expect("router")
}

/// Reference logits for `(MODEL, SEED)` on the default arch — what
/// every backend that (re-)loads the model must reproduce exactly.
fn reference(images: &[Vec<i8>]) -> Vec<Vec<i8>> {
    let net = zoo::lookup(MODEL).unwrap();
    let reg = ModelRegistry::new();
    let mv = reg
        .load_seeded(MODEL, &net, ArchConfig::default(), Some(SEED))
        .expect("local reference load");
    images.iter().map(|i| mv.refcompute(i).unwrap()).collect()
}

fn loaded_on(addr: &str) -> BTreeSet<String> {
    let mut c = Client::connect(addr).expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    c.models()
        .expect("list models")
        .into_iter()
        .map(|d| d.name)
        .collect()
}

fn input_len() -> usize {
    let net = zoo::lookup(MODEL).unwrap();
    let reg = ModelRegistry::new();
    reg.load_seeded(MODEL, &net, ArchConfig::default(), Some(SEED))
        .unwrap()
        .input_len()
}

#[test]
fn routing_is_consistent_replicated_and_survives_backend_death() {
    let mut backends: Vec<TestBackend> = (0..3).map(|_| start_backend()).collect();
    let addrs: Vec<String> = backends.iter().map(|b| b.addr.clone()).collect();
    let router = test_router(addrs.clone(), 2);

    // Load through the router: exactly the replication-2 rendezvous
    // owners get the model, the third backend stays empty.
    match router.dispatch(Request::LoadSeeded {
        model: MODEL.to_string(),
        seed: SEED,
        mapping: None,
    }) {
        Response::Loaded(stamp) => assert_eq!(&*stamp.name, MODEL),
        other => panic!("load failed: {other:?}"),
    }
    let assignments = router.status().assignments;
    let owners: BTreeSet<String> = assignments
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, o)| o.iter().cloned().collect())
        .expect("model in assignments");
    assert_eq!(owners.len(), 2, "replication 2 means 2 owners");
    for addr in &addrs {
        let has = loaded_on(addr).contains(MODEL);
        assert_eq!(
            has,
            owners.contains(addr),
            "{addr}: loaded must equal ownership (owners {owners:?})"
        );
    }

    // Consistency: an independent router over the same addresses
    // computes the identical assignment without any traffic.
    let fresh = test_router(addrs.clone(), 2);
    fresh.assume_models(&[MODEL.to_string()]);
    let fresh_owners: BTreeSet<String> = fresh
        .status()
        .assignments
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, o)| o.iter().cloned().collect())
        .unwrap();
    assert_eq!(owners, fresh_owners, "assignment is a pure function of the table");

    // Concurrent inferences through the router: all bit-exact and
    // version-stamped, from several threads at once.
    let ilen = input_len();
    let mut rng = Rng::new(0xC1u64);
    let images: Vec<Vec<i8>> = (0..16).map(|_| rng.i8_vec(ilen, 31)).collect();
    let expected = reference(&images);
    let router = Arc::new(router);
    let mut handles = Vec::new();
    for t in 0..4 {
        let router = Arc::clone(&router);
        let images = images.clone();
        let expected = expected.clone();
        handles.push(std::thread::spawn(move || {
            for i in (t..images.len()).step_by(4) {
                match router.dispatch(Request::Infer {
                    model: Some(MODEL.to_string()),
                    image: images[i].clone(),
                }) {
                    Response::Infer(r) => {
                        assert_eq!(r.logits, expected[i], "logits diverge on image {i}");
                        let stamp = r.model.expect("version-stamped");
                        assert_eq!(&*stamp.name, MODEL);
                        assert!(stamp.version >= 1);
                    }
                    other => panic!("infer {i} failed: {other:?}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // every routed call landed on an owner (dispatch is least-loaded
    // *among owners*, never a non-owner)
    let st = router.status();
    let owner_served: u64 = st
        .backends
        .iter()
        .filter(|b| owners.contains(&b.addr))
        .map(|b| b.served)
        .sum();
    assert!(
        owner_served >= images.len() as u64,
        "owners served {owner_served} < {} routed infers",
        images.len()
    );

    // Kill one owner (its endpoint shuts down mid-cluster). The next
    // infer fails over to the surviving replica; after a health pass
    // the model is re-loaded onto a new owner from the recorded spec.
    let dead_addr = owners.iter().next().unwrap().clone();
    let idx = backends.iter().position(|b| b.addr == dead_addr).unwrap();
    backends[idx].net.take().unwrap().shutdown().unwrap();

    for i in 0..4 {
        match router.dispatch(Request::Infer {
            model: Some(MODEL.to_string()),
            image: images[i].clone(),
        }) {
            Response::Infer(r) => assert_eq!(
                r.logits, expected[i],
                "failover answer diverges on image {i}"
            ),
            other => panic!("infer after backend death failed: {other:?}"),
        }
    }

    router.health_pass();
    let st = router.status();
    let dead = st.backends.iter().find(|b| b.addr == dead_addr).unwrap();
    assert!(!dead.alive, "killed backend must probe dead");
    let new_owners: BTreeSet<String> = st
        .assignments
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, o)| o.iter().cloned().collect())
        .unwrap();
    assert_eq!(new_owners.len(), 2, "replication restored over survivors");
    assert!(!new_owners.contains(&dead_addr));
    for addr in &new_owners {
        assert!(
            loaded_on(addr).contains(MODEL),
            "{addr} must have the model after reconcile"
        );
    }
    // and the re-loaded copy serves the identical weights
    match router.dispatch(Request::Infer {
        model: Some(MODEL.to_string()),
        image: images[0].clone(),
    }) {
        Response::Infer(r) => assert_eq!(r.logits, expected[0]),
        other => panic!("infer after reconcile failed: {other:?}"),
    }

    // cleanup: drop the router first so pooled conns close, then
    // shut the surviving backends down
    drop(router);
    for mut b in backends {
        if let Some(net) = b.net.take() {
            net.shutdown().unwrap();
        }
        if let Ok(service) = Arc::try_unwrap(b.service) {
            service.shutdown().unwrap();
        }
    }
}

#[test]
fn drained_backend_finishes_and_leaves_the_owner_set() {
    let mut backends: Vec<TestBackend> = (0..3).map(|_| start_backend()).collect();
    let addrs: Vec<String> = backends.iter().map(|b| b.addr.clone()).collect();
    let router = test_router(addrs.clone(), 2);
    match router.dispatch(Request::LoadSeeded {
        model: MODEL.to_string(),
        seed: SEED,
        mapping: None,
    }) {
        Response::Loaded(_) => {}
        other => panic!("load failed: {other:?}"),
    }
    let owners: Vec<String> = router
        .status()
        .assignments
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, o)| o.clone())
        .unwrap();

    // drain the primary: no new work routes there, the model moves
    router
        .drain(&owners[0], Duration::from_secs(10))
        .expect("drain known backend");
    let st = router.status();
    let new_owners: Vec<String> = st
        .assignments
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, o)| o.clone())
        .unwrap();
    assert!(!new_owners.contains(&owners[0]), "drained backend still an owner");
    assert_eq!(new_owners.len(), 2);

    // traffic still flows, bit-exact
    let ilen = input_len();
    let mut rng = Rng::new(0xD2u64);
    let images: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(ilen, 31)).collect();
    let expected = reference(&images);
    for (i, img) in images.iter().enumerate() {
        match router.dispatch(Request::Infer {
            model: Some(MODEL.to_string()),
            image: img.clone(),
        }) {
            Response::Infer(r) => assert_eq!(r.logits, expected[i]),
            other => panic!("infer after drain failed: {other:?}"),
        }
    }
    assert!(
        router.drain("127.0.0.1:1", Duration::from_secs(1)).is_err(),
        "draining an unknown address must error"
    );

    drop(router);
    for mut b in backends.drain(..) {
        if let Some(net) = b.net.take() {
            net.shutdown().unwrap();
        }
        if let Ok(service) = Arc::try_unwrap(b.service) {
            service.shutdown().unwrap();
        }
    }
}

#[test]
fn infer_dispatch_multiplexes_over_a_bounded_connection_pool() {
    let mut backends: Vec<TestBackend> = (0..2).map(|_| start_backend()).collect();
    let addrs: Vec<String> = backends.iter().map(|b| b.addr.clone()).collect();
    let router = test_router(addrs.clone(), 2);
    match router.dispatch(Request::LoadSeeded {
        model: MODEL.to_string(),
        seed: SEED,
        mapping: None,
    }) {
        Response::Loaded(_) => {}
        other => panic!("load failed: {other:?}"),
    }

    // Concurrent routed infers, all bit-exact as ever.
    let ilen = input_len();
    let mut rng = Rng::new(0xBEEFu64);
    let images: Vec<Vec<i8>> = (0..24).map(|_| rng.i8_vec(ilen, 31)).collect();
    let expected = reference(&images);
    let router = Arc::new(router);
    let mut handles = Vec::new();
    for t in 0..6 {
        let router = Arc::clone(&router);
        let images = images.clone();
        let expected = expected.clone();
        handles.push(std::thread::spawn(move || {
            for i in (t..images.len()).step_by(6) {
                match router.dispatch(Request::Infer {
                    model: Some(MODEL.to_string()),
                    image: images[i].clone(),
                }) {
                    Response::Infer(r) => {
                        assert_eq!(r.logits, expected[i], "logits diverge on image {i}")
                    }
                    other => panic!("infer {i} failed: {other:?}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // The pooling property: every request was served, yet each
    // backend saw at most `pipe_conns` pipelined dials plus one
    // pooled admin dial — never one socket per in-flight request.
    let cap = (ClusterConfig::default().pipe_conns + 1) as u64;
    let st = router.status();
    let served: u64 = st.backends.iter().map(|b| b.served).sum();
    assert!(
        served >= images.len() as u64,
        "served {served} < {} routed infers",
        images.len()
    );
    for b in &st.backends {
        assert!(
            b.dials <= cap,
            "{}: {} dials for {} served calls (pool cap {cap})",
            b.addr,
            b.dials,
            b.served
        );
        assert!(b.dials >= 1, "{}: pooling must still dial at least once", b.addr);
    }
    assert!(st.render().contains("dials"), "{}", st.render());

    // Failover is untouched by pooling: kill one backend, traffic
    // stays bit-exact, and the survivor's pool absorbs the extra
    // load without needing fresh connections.
    let dead_addr = st.backends[0].addr.clone();
    let survivor = st.backends[1].addr.clone();
    let dials_before = st
        .backends
        .iter()
        .find(|b| b.addr == survivor)
        .unwrap()
        .dials;
    let idx = backends.iter().position(|b| b.addr == dead_addr).unwrap();
    backends[idx].net.take().unwrap().shutdown().unwrap();
    for (i, img) in images.iter().take(4).enumerate() {
        match router.dispatch(Request::Infer {
            model: Some(MODEL.to_string()),
            image: img.clone(),
        }) {
            Response::Infer(r) => {
                assert_eq!(r.logits, expected[i], "failover answer diverges on image {i}")
            }
            other => panic!("infer after backend death failed: {other:?}"),
        }
    }
    let st = router.status();
    assert!(
        st.backends.iter().any(|b| !b.alive),
        "killed backend must be marked dead by the transport error"
    );
    let dials_after = st
        .backends
        .iter()
        .find(|b| b.addr == survivor)
        .unwrap()
        .dials;
    assert!(
        dials_after <= dials_before + 1,
        "failover must reuse the survivor's pooled sockets: \
         {dials_before} dials -> {dials_after}"
    );

    drop(router);
    for mut b in backends.drain(..) {
        if let Some(net) = b.net.take() {
            net.shutdown().unwrap();
        }
        if let Ok(service) = Arc::try_unwrap(b.service) {
            service.shutdown().unwrap();
        }
    }
}

#[test]
fn silently_corrupting_backend_fails_canary_and_heals_back_in() {
    let mut backends: Vec<TestBackend> = (0..2).map(|_| start_backend()).collect();
    let addrs: Vec<String> = backends.iter().map(|b| b.addr.clone()).collect();
    let router = test_router(addrs.clone(), 2);
    match router.dispatch(Request::LoadSeeded {
        model: MODEL.to_string(),
        seed: SEED,
        mapping: None,
    }) {
        Response::Loaded(_) => {}
        other => panic!("load failed: {other:?}"),
    }

    let ilen = input_len();
    let mut rng = Rng::new(0xFA01u64);
    let images: Vec<Vec<i8>> = (0..6).map(|_| rng.i8_vec(ilen, 31)).collect();
    let expected = reference(&images);

    // The plan targets the first tile of the placement — computed
    // from a local compile of the same (network, seed, arch), which
    // is bit-identical to what the backend placed.
    let bad = {
        let net = zoo::lookup(MODEL).unwrap();
        let reg = ModelRegistry::new();
        let mv = reg
            .load_seeded(MODEL, &net, ArchConfig::default(), Some(SEED))
            .unwrap();
        mv.program().tile_coords()[0]
    };
    let plan = domino::sim::FaultPlan::new().stuck_tile(bad, 7).spec();

    // Arm the fault on the rendezvous primary, talking to the
    // backend directly — a broken tile is a property of one machine,
    // not of the cluster.
    let primary = router
        .status()
        .assignments
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, o)| o[0].clone())
        .unwrap();
    let mut direct = Client::connect(&primary).expect("connect primary");
    direct
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let rep = direct.fault_inject(MODEL, &plan).expect("fault inject");
    assert!(rep.armed, "plan must arm");
    assert!(
        rep.corrupted && rep.fires > 0,
        "diagnostic run must observe the corruption: {rep:?}"
    );

    // One health pass later the router knows: the backend is alive
    // (socket answers) but canary-failed (bits are wrong), excluded
    // from the owner set, and reported distinctly from DEAD.
    router.health_pass();
    let st = router.status();
    let sick = st.backends.iter().find(|b| b.addr == primary).unwrap();
    assert!(
        sick.alive && sick.canary_failed,
        "sick backend must be alive-but-canary-failed: {sick:?}"
    );
    let rendered = st.render();
    assert!(rendered.contains("canary-failed"), "{rendered}");
    assert!(!rendered.contains("DEAD"), "{rendered}");
    let owners_now: Vec<String> = st
        .assignments
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, o)| o.clone())
        .unwrap();
    assert!(
        !owners_now.contains(&primary),
        "canary-failed backend must leave the owner set: {owners_now:?}"
    );
    // cluster stats surface the degradation by model
    match router.dispatch(Request::Stats) {
        Response::Stats(s) => assert!(
            s.models.iter().any(|m| m.model == MODEL && m.degraded),
            "cluster stats must OR-fold the degraded flag"
        ),
        other => panic!("stats failed: {other:?}"),
    }

    // Routed traffic never sees the corrupt bits.
    for (i, img) in images.iter().enumerate() {
        match router.dispatch(Request::Infer {
            model: Some(MODEL.to_string()),
            image: img.clone(),
        }) {
            Response::Infer(r) => assert_eq!(
                r.logits, expected[i],
                "router served corrupt bits on image {i}"
            ),
            other => panic!("infer {i} failed: {other:?}"),
        }
    }

    // Heal through the router: Canary{heal} routes to the model's
    // true primary (sick backends included — the cure must be able
    // to reach the patient), re-maps around the masked tile, and the
    // healed program recovers bit-exactness.
    match router.dispatch(Request::Canary {
        model: MODEL.to_string(),
        seed: 0xCAFE,
        heal: true,
    }) {
        Response::Canary(c) => {
            assert!(!c.ok, "pre-heal canary must fail");
            assert!(c.remapped && c.healed, "heal must re-map and recover: {c:?}");
            assert!(c.version >= 2, "heal publishes a new version");
        }
        other => panic!("canary heal failed: {other:?}"),
    }

    // The next health pass re-admits the healed backend.
    router.health_pass();
    let st = router.status();
    assert!(
        st.backends.iter().all(|b| b.alive && !b.canary_failed),
        "healed cluster must be fully routable: {st:?}"
    );
    let owners_after: Vec<String> = st
        .assignments
        .iter()
        .find(|(m, _)| m == MODEL)
        .map(|(_, o)| o.clone())
        .unwrap();
    assert!(
        owners_after.contains(&primary),
        "healed backend must rejoin the owner set: {owners_after:?}"
    );
    match router.dispatch(Request::Stats) {
        Response::Stats(s) => assert!(
            s.models.iter().all(|m| !(m.model == MODEL && m.degraded)),
            "degraded flag must clear after heal"
        ),
        other => panic!("stats failed: {other:?}"),
    }
    // and the healed backend itself serves bit-exact, on the new
    // version, with the armed plan still in place (its sites are
    // simply never exercised by the re-mapped placement)
    let r = direct.infer(Some(MODEL), images[0].clone()).expect("direct infer");
    assert_eq!(r.logits, expected[0], "healed backend must serve bit-exact");
    assert!(r.model.expect("stamped").version >= 2);

    drop(direct);
    drop(router);
    for mut b in backends.drain(..) {
        if let Some(net) = b.net.take() {
            net.shutdown().unwrap();
        }
        if let Ok(service) = Arc::try_unwrap(b.service) {
            service.shutdown().unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// Cross-process failover: spawned `domino serve` backends, one killed
// with SIGKILL mid-run.

/// Kills the children on drop so a failing assertion never orphans
/// backend processes.
struct Children(Vec<std::process::Child>);

impl Drop for Children {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn spawn_backend() -> (std::process::Child, String) {
    use std::io::BufRead;
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_domino"))
        .args([
            "serve",
            "--backend",
            "sim",
            "--models",
            "",
            "--workers",
            "1",
            "--listen",
            "127.0.0.1:0",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::inherit())
        .spawn()
        .expect("spawn backend");
    let stdout = child.stdout.take().expect("stdout piped");
    let mut reader = std::io::BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if reader.read_line(&mut line).expect("read child stdout") == 0 {
            panic!("backend exited before printing its listen address");
        }
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest.split_whitespace().next().expect("address token").to_string();
        }
    };
    // leak the reader thread-lessly: keep the pipe open for the
    // child's later prints by parking the reader in a drain thread
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    (child, addr)
}

#[test]
fn killing_a_backend_process_mid_run_loses_no_accepted_request() {
    let (c1, a1) = spawn_backend();
    let (c2, a2) = spawn_backend();
    let mut children = Children(vec![c1, c2]);

    let router = test_router(vec![a1, a2], 2);
    match router.dispatch(Request::LoadSeeded {
        model: MODEL.to_string(),
        seed: SEED,
        mapping: None,
    }) {
        Response::Loaded(_) => {}
        other => panic!("load failed: {other:?}"),
    }

    let ilen = input_len();
    let mut rng = Rng::new(0xF0u64);
    let images: Vec<Vec<i8>> = (0..30).map(|_| rng.i8_vec(ilen, 31)).collect();
    let expected = reference(&images);

    for (i, img) in images.iter().enumerate() {
        if i == 10 {
            // SIGKILL one backend between requests: no in-flight work
            // is lost, and everything after must fail over
            children.0[0].kill().expect("kill backend");
            children.0[0].wait().expect("reap backend");
        }
        match router.dispatch(Request::Infer {
            model: Some(MODEL.to_string()),
            image: img.clone(),
        }) {
            Response::Infer(r) => {
                assert_eq!(
                    r.logits, expected[i],
                    "request {i} diverged from refcompute"
                );
                let stamp = r.model.expect("version-stamped");
                assert_eq!(&*stamp.name, MODEL);
            }
            other => panic!("request {i} was not answered: {other:?}"),
        }
    }

    let st = router.status();
    assert!(
        st.backends.iter().any(|b| !b.alive),
        "the killed backend must be marked dead after the transport error"
    );
}
