//! Multi-model serving properties: tagged requests are answered by the
//! model (and version) they were submitted to — cross-checked
//! bit-for-bit against `refcompute` per model — under client
//! concurrency, under shutdown-while-loaded, and across hot-swap and
//! unload mid-traffic. A routing bug anywhere in the
//! registry/queue/engine-pool path is a correctness failure here, not
//! a silent misroute.

use std::sync::Arc;

use domino::coordinator::ArchConfig;
use domino::model::{zoo, Network, NetworkBuilder, TensorShape};
use domino::serve::{ModelRegistry, ModelVersion, ServeConfig, Server};
use domino::testutil::Rng;

/// Refcompute oracle for one image under a specific model version.
fn expected_for(mv: &ModelVersion, img: &[i8]) -> Vec<i8> {
    mv.refcompute(img).expect("registry models carry weights")
}

/// A conv+fc net small enough to cycle-simulate in well under a
/// millisecond (used where zoo models would make the test slow).
fn small_net(name: &str, logits: usize) -> Network {
    NetworkBuilder::new(name, TensorShape::new(2, 6, 6))
        .conv(4, 3, 1, 1)
        .flatten()
        .fc_logits(logits)
        .build()
}

/// The fast zoo trio loaded into a fresh registry. Their outputs have
/// three different widths (10/8/6 classes) and three different input
/// lengths, so a cross-model misroute cannot even be shape-correct.
fn trio_registry() -> (Arc<ModelRegistry>, Vec<Arc<ModelVersion>>) {
    let registry = Arc::new(ModelRegistry::new());
    let mut models = Vec::new();
    for name in ["tiny-cnn", "tiny-mlp", "tiny-resnet"] {
        let net = zoo::by_name(name).unwrap();
        models.push(registry.load(name, &net, ArchConfig::default()).unwrap());
    }
    (registry, models)
}

#[test]
fn concurrent_clients_across_three_models_are_answered_by_their_model() {
    let (registry, models) = trio_registry();
    let server = Arc::new(
        Server::start_multi(
            ServeConfig {
                workers: 3,
                max_batch: 4,
                queue_cap: 256,
                ..ServeConfig::default()
            },
            Arc::clone(&registry),
        )
        .unwrap(),
    );

    // two clients per model, all hammering the server concurrently
    let mut handles = Vec::new();
    for (mi, mv) in models.iter().enumerate() {
        for c in 0..2 {
            let server = Arc::clone(&server);
            let mv = Arc::clone(mv);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xC0FFEE + (mi * 7 + c) as u64);
                for _ in 0..6 {
                    let img = rng.i8_vec(mv.input_len(), 31);
                    let r = server.infer_on(mv.name(), img.clone()).unwrap();
                    let stamp = r.model.expect("sim responses carry a model stamp");
                    assert_eq!(&*stamp.name, mv.name(), "answered by the wrong model");
                    assert_eq!(stamp.id, mv.id());
                    assert_eq!(stamp.version, 1);
                    assert_eq!(
                        r.logits,
                        expected_for(&mv, &img),
                        "{}: response diverged from refcompute",
                        mv.name()
                    );
                }
            }));
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(server.served(), 36);
    assert_eq!(server.failed(), 0);
    assert_eq!(server.rejected(), 0);

    // per-model input validation: a tiny-mlp-sized image is refused by
    // tiny-cnn up front (not routed and crashed later)
    assert!(server.submit_to("tiny-cnn", vec![0i8; 24]).is_err());
    // unknown model errors name the loaded set
    let err = server
        .submit_to("alexnet", vec![0i8; 24])
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("tiny-cnn") && err.contains("tiny-mlp") && err.contains("tiny-resnet"),
        "{err}"
    );

    let server = Arc::try_unwrap(server).ok().expect("sole reference");
    let counts = server.shutdown().unwrap();
    assert_eq!(counts.iter().sum::<u64>(), 36);
}

#[test]
fn shutdown_while_loaded_answers_every_accepted_request_per_model() {
    let (registry, models) = trio_registry();
    let mut rng = Rng::new(0x5EED);
    // several rounds of burst-submit-then-shutdown, queue still full
    for round in 0..3 {
        let server = Server::start_multi(
            ServeConfig {
                workers: 2,
                max_batch: 3,
                queue_cap: 256,
                ..ServeConfig::default()
            },
            Arc::clone(&registry),
        )
        .unwrap();
        let n = 9 + 6 * round;
        let mut pending = Vec::new();
        for i in 0..n {
            let mv = &models[i % models.len()];
            let img = rng.i8_vec(mv.input_len(), 31);
            let rx = server.submit_to(mv.name(), img.clone()).unwrap();
            pending.push((Arc::clone(mv), img, rx));
        }
        // shut down with the queue loaded: workers must drain it and
        // answer every accepted request with its own model's output
        let counts = server.shutdown().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), n as u64, "round {round}");
        for (i, (mv, img, rx)) in pending.into_iter().enumerate() {
            let r = rx.recv().expect("accepted request must be answered");
            let stamp = r.model.expect("stamped");
            assert_eq!(&*stamp.name, mv.name(), "round {round} request {i}");
            assert_eq!(
                r.logits,
                expected_for(&mv, &img),
                "round {round} request {i} diverged"
            );
        }
    }
}

#[test]
fn hot_swap_under_load_drains_old_version_and_routes_new() {
    let registry = Arc::new(ModelRegistry::new());
    let net = small_net("swapper", 5);
    let v1 = registry.load("swapper", &net, ArchConfig::default()).unwrap();
    let server = Arc::new(
        Server::start_multi(
            ServeConfig {
                workers: 2,
                max_batch: 4,
                queue_cap: 1024,
                ..ServeConfig::default()
            },
            Arc::clone(&registry),
        )
        .unwrap(),
    );

    // Clients run two phases of traffic with a barrier between them;
    // the main thread performs the swap before releasing the barrier,
    // so phase 1 requests are all submitted against v1 and phase 2
    // requests strictly after the swap — deterministically exercising
    // both sides regardless of machine speed.
    let clients = 3;
    let half = 15; // requests per client per phase
    let barrier = Arc::new(std::sync::Barrier::new(clients + 1));
    let mut handles = Vec::new();
    for c in 0..clients {
        let server = Arc::clone(&server);
        let barrier = Arc::clone(&barrier);
        let input_len = net.input_len();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xAB + c as u64);
            let mut out = Vec::with_capacity(2 * half);
            for phase in 0..2 {
                for _ in 0..half {
                    let img = rng.i8_vec(input_len, 31);
                    // every accepted request must be answered — a
                    // dropped or hung request fails (or times out) the
                    // test here
                    let r = server
                        .infer_on("swapper", img.clone())
                        .expect("request dropped during hot-swap");
                    out.push((phase, img, r));
                }
                if phase == 0 {
                    barrier.wait();
                }
            }
            out
        }));
    }

    // Let v1 demonstrably serve first: responses completed before the
    // swap is published are guaranteed v1. Phase 1 carries 45 requests,
    // so this wait always terminates before the clients park at the
    // barrier.
    while server.served() < 15 {
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
    // hot-swap to fresh weights while phase-1 traffic is in flight,
    // then release phase 2
    let v2 = registry
        .swap_seeded("swapper", &net, ArchConfig::default(), Some(0xFEED))
        .unwrap();
    assert_eq!(v2.version(), 2);
    barrier.wait();

    let mut seen = [0u64; 2];
    for h in handles {
        for (phase, img, r) in h.join().unwrap() {
            let stamp = r.model.expect("stamped");
            let mv = match stamp.version {
                1 => &v1,
                2 => &v2,
                v => panic!("unexpected version {v}"),
            };
            assert_eq!(stamp.id, mv.id());
            assert_eq!(
                r.logits,
                expected_for(mv, &img),
                "v{} response diverged from its own version's weights",
                stamp.version
            );
            // phase 2 was released only after the swap returned, so it
            // must run on the new program (phase 1 may be either: a
            // request can race the swap and legitimately land on v2)
            if phase == 1 {
                assert_eq!(stamp.version, 2, "post-swap request served by v1");
            }
            seen[(stamp.version - 1) as usize] += 1;
        }
    }
    let total = (clients * 2 * half) as u64;
    assert_eq!(seen[0] + seen[1], total, "zero dropped or hung requests");
    assert!(
        seen[0] >= 15,
        "the >=15 responses completed before the swap must be v1"
    );
    assert!(
        seen[1] >= (clients * half) as u64,
        "every phase-2 request must use the new program"
    );
    assert_eq!(server.served(), total);
    assert_eq!(server.failed(), 0);

    let server = Arc::try_unwrap(server).ok().expect("sole reference");
    server.shutdown().unwrap();
}

#[test]
fn unload_keeps_inflight_requests_and_rejects_new_ones() {
    let registry = Arc::new(ModelRegistry::new());
    let net_a = small_net("alpha", 4);
    let net_b = small_net("beta", 7);
    let va = registry.load("alpha", &net_a, ArchConfig::default()).unwrap();
    registry.load("beta", &net_b, ArchConfig::default()).unwrap();
    let server = Server::start_multi(
        ServeConfig {
            workers: 1,
            max_batch: 2,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        Arc::clone(&registry),
    )
    .unwrap();

    // burst-submit to alpha, then unload it while requests are queued
    let mut rng = Rng::new(0xDEAD);
    let pending: Vec<_> = (0..6)
        .map(|_| {
            let img = rng.i8_vec(net_a.input_len(), 31);
            let rx = server.submit_to("alpha", img.clone()).unwrap();
            (img, rx)
        })
        .collect();
    let unloaded = registry.unload("alpha").unwrap();
    assert_eq!(unloaded.id(), va.id());

    // new submissions for the unloaded name are refused, naming what is
    // still loaded
    let err = server
        .submit_to("alpha", vec![0i8; net_a.input_len()])
        .unwrap_err()
        .to_string();
    assert!(err.contains("beta"), "{err}");

    // every already-accepted alpha request still completes on the
    // unloaded version (drain semantics)
    for (i, (img, rx)) in pending.into_iter().enumerate() {
        let r = rx.recv().expect("in-flight request must survive unload");
        assert_eq!(r.logits, expected_for(&va, &img), "request {i}");
        assert_eq!(r.model.unwrap().id, va.id());
    }

    // beta is unaffected
    let img = rng.i8_vec(net_b.input_len(), 31);
    let r = server.infer_on("beta", img).unwrap();
    assert_eq!(r.logits.len(), 7);
    assert_eq!(server.failed(), 0);
    server.shutdown().unwrap();
}

#[test]
fn load_while_serving_makes_model_routable_without_restart() {
    let registry = Arc::new(ModelRegistry::new());
    let net_a = small_net("first", 3);
    registry.load("first", &net_a, ArchConfig::default()).unwrap();
    let server = Server::start_multi(
        ServeConfig {
            workers: 2,
            max_batch: 4,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        Arc::clone(&registry),
    )
    .unwrap();
    let mut rng = Rng::new(0x10AD);
    // serve a request, then load a second model live and serve it too
    // (its engines are built lazily by the workers on first request)
    let img = rng.i8_vec(net_a.input_len(), 31);
    server.infer_on("first", img).unwrap();
    let net_b = small_net("second", 9);
    let vb = registry.load("second", &net_b, ArchConfig::default()).unwrap();
    for _ in 0..4 {
        let img = rng.i8_vec(net_b.input_len(), 31);
        let r = server.infer_on("second", img.clone()).unwrap();
        assert_eq!(r.logits, expected_for(&vb, &img));
    }
    // with two models loaded, untagged submit demands a name
    assert!(server.submit(vec![0i8; net_a.input_len()]).is_err());
    assert_eq!(server.served(), 5);
    server.shutdown().unwrap();
}
