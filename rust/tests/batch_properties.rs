//! Batched-execution properties: `Simulator::run_batch` must be an
//! exact data-parallel refactoring of sequential `run_image` — same
//! bits out, same merged counters — over an exhaustive sweep of small
//! geometries covering every stage kind, and its pipeline report must
//! agree with the analytic model.

use std::sync::Arc;

use domino::coordinator::{ArchConfig, Compiler, Program};
use domino::model::{Network, NetworkBuilder, Projection, TensorShape};
use domino::perfmodel;
use domino::sim::{CaptureMode, Counters, EnginePool, RecorderConfig, Simulator};
use domino::testutil::Rng;

/// The sweep: every layer kind, strides, padding, pooling flavors,
/// multi-block channel splits, residuals with and without projection.
fn sweep_nets() -> Vec<(Network, ArchConfig)> {
    let mut nets = Vec::new();
    // conv geometry sweep on the default crossbar
    for (k, stride, padding) in [(1usize, 1usize, 0usize), (3, 1, 1), (3, 2, 1), (3, 1, 0)] {
        let net = NetworkBuilder::new("sweep-conv", TensorShape::new(2, 6, 6))
            .conv(4, k, stride, padding)
            .build();
        nets.push((net, ArchConfig::default()));
    }
    // fused pooling, both flavors
    nets.push((
        NetworkBuilder::new("sweep-maxpool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .max_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("sweep-avgpool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .avg_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    // multi-block channels on a tiny crossbar + fc pipeline
    nets.push((
        NetworkBuilder::new("sweep-blocks", TensorShape::new(6, 5, 5))
            .conv(7, 3, 1, 1)
            .flatten()
            .fc(9)
            .fc_logits(5)
            .build(),
        ArchConfig::tiny(4),
    ));
    // residuals: identity and projected skip
    nets.push((
        NetworkBuilder::new("sweep-res", TensorShape::new(4, 6, 6))
            .conv(4, 3, 1, 1)
            .conv_linear(4, 3, 1, 1)
            .res_add(0)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("sweep-res-proj", TensorShape::new(4, 8, 8))
            .conv(4, 3, 1, 1)
            .conv(8, 3, 2, 1)
            .conv_linear(8, 3, 1, 1)
            .res_add_proj(
                0,
                Projection {
                    out_ch: 8,
                    stride: 2,
                },
            )
            .build(),
        ArchConfig::default(),
    ));
    nets
}

#[test]
fn run_batch_is_bit_exact_with_sequential_runs() {
    for (net, arch) in sweep_nets() {
        let program = Compiler::new(arch).compile(&net).unwrap();
        let mut rng = Rng::new(0xBA7C4);
        let inputs: Vec<Vec<i8>> = (0..5)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();

        let mut seq = Simulator::new(&program);
        let seq_outs: Vec<_> = inputs
            .iter()
            .map(|x| seq.run_image(x).unwrap())
            .collect();

        let mut batched = Simulator::new(&program);
        let batch = batched.run_batch_threads(&inputs, 4).unwrap();

        assert_eq!(batch.outputs.len(), seq_outs.len(), "{}", net.name);
        for (i, (b, s)) in batch.outputs.iter().zip(&seq_outs).enumerate() {
            assert_eq!(b.scores, s.scores, "{} image {i} scores", net.name);
            assert_eq!(b.stage_slots, s.stage_slots, "{} image {i}", net.name);
            assert_eq!(
                b.latency_cycles, s.latency_cycles,
                "{} image {i}",
                net.name
            );
            for (si, (bt, st)) in
                b.stage_outputs.iter().zip(&s.stage_outputs).enumerate()
            {
                assert_eq!(
                    bt.data, st.data,
                    "{} image {i} stage {si} tensor",
                    net.name
                );
            }
        }
        assert_eq!(
            batched.stats(),
            seq.stats(),
            "{}: merged batch counters != sequential counters",
            net.name
        );
        assert_eq!(
            batched.stage_stats(),
            seq.stage_stats(),
            "{}: per-stage counters",
            net.name
        );
    }
}

#[test]
fn merged_batch_counters_equal_sum_of_per_image_counters() {
    for (net, arch) in sweep_nets() {
        let program = Compiler::new(arch).compile(&net).unwrap();
        let mut rng = Rng::new(0x5EED5);
        let inputs: Vec<Vec<i8>> = (0..4)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();

        // per-image counters from fresh, independent simulators
        let mut summed = Counters::new();
        for x in &inputs {
            let mut solo = Simulator::new(&program);
            solo.run_image(x).unwrap();
            summed.merge(solo.stats());
        }

        let mut batched = Simulator::new(&program);
        batched.run_batch_threads(&inputs, 2).unwrap();
        assert_eq!(
            batched.stats(),
            &summed,
            "{}: batch merge != sum of per-image counters",
            net.name
        );
    }
}

#[test]
fn batch_pipeline_report_agrees_with_perfmodel() {
    for (net, arch) in sweep_nets() {
        let program = Compiler::new(arch).compile(&net).unwrap();
        let est = perfmodel::estimate(&program).unwrap();
        let mut rng = Rng::new(0xF00D);
        let inputs: Vec<Vec<i8>> = (0..8)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();
        let mut sim = Simulator::new(&program);
        // run_batch itself bails on any engine/perfmodel divergence;
        // assert the reported steady state explicitly as well.
        let batch = sim.run_batch_threads(&inputs, 4).unwrap();
        assert_eq!(
            batch.pipeline.steady_period_cycles, est.period_cycles,
            "{}",
            net.name
        );
        assert!(batch.pipeline.images_per_s > 0.0, "{}", net.name);
        assert_eq!(batch.pipeline.completions.len(), inputs.len());
    }
}

#[test]
fn pooled_engines_interleaved_across_models_match_fresh_simulators() {
    // The engine-pool property: one pool holding a reusable engine per
    // model, with runs interleaved across models and images, produces
    // outputs AND counters identical to building a fresh `Simulator`
    // for every single run — over the full small-geometry sweep (every
    // stage kind), several rounds deep.
    let programs: Vec<(Network, Arc<Program>)> = sweep_nets()
        .into_iter()
        .map(|(net, arch)| {
            let program = Arc::new(Compiler::new(arch).compile(&net).unwrap());
            (net, program)
        })
        .collect();
    let mut pool = EnginePool::new();
    let mut rng = Rng::new(0x900D);
    for round in 0..3 {
        for (k, (net, program)) in programs.iter().enumerate() {
            let img = rng.i8_vec(net.input_len(), 31);
            let engine = pool.engine(k as u64, program);
            // pooled engines default to CaptureMode::Final (serving);
            // this property compares intermediate tensors too
            engine.set_capture(CaptureMode::AllStages);
            engine.reset_stats();
            let got = engine.run_image(&img).unwrap();
            assert_eq!(
                got.stage_outputs.len(),
                program.stages.len(),
                "{}: AllStages capture must include every stage",
                net.name
            );

            let mut fresh = Simulator::new(program);
            let want = fresh.run_image(&img).unwrap();
            assert_eq!(got.scores, want.scores, "{} round {round}", net.name);
            assert_eq!(got.stage_slots, want.stage_slots, "{}", net.name);
            assert_eq!(got.latency_cycles, want.latency_cycles, "{}", net.name);
            for (si, (a, b)) in got
                .stage_outputs
                .iter()
                .zip(&want.stage_outputs)
                .enumerate()
            {
                assert_eq!(a.data, b.data, "{} round {round} stage {si}", net.name);
            }
            assert_eq!(
                engine.stats(),
                fresh.stats(),
                "{} round {round}: pooled counters != fresh counters",
                net.name
            );
            assert_eq!(
                engine.stage_stats(),
                fresh.stage_stats(),
                "{} round {round}: per-stage counters",
                net.name
            );
        }
    }
    assert_eq!(
        pool.len(),
        programs.len(),
        "one engine per model, reused across rounds"
    );
}

#[test]
fn pooled_engine_without_reset_accumulates_like_one_simulator() {
    // Leaving the counters alone between runs must behave exactly like
    // one long-lived Simulator over the same image sequence.
    let net = NetworkBuilder::new("pool-accum", TensorShape::new(3, 8, 8))
        .conv(6, 3, 1, 1)
        .max_pool(2, 2)
        .flatten()
        .fc_logits(4)
        .build();
    let program = Arc::new(Compiler::default().compile(&net).unwrap());
    let mut rng = Rng::new(0xACC);
    let inputs: Vec<Vec<i8>> = (0..5)
        .map(|_| rng.i8_vec(net.input_len(), 31))
        .collect();

    let mut pool = EnginePool::new();
    let mut seq = Simulator::new(&program);
    for (i, img) in inputs.iter().enumerate() {
        let got = pool.engine(9, &program).run_image(img).unwrap();
        let want = seq.run_image(img).unwrap();
        assert_eq!(got.scores, want.scores, "image {i}");
    }
    assert_eq!(pool.engine(9, &program).stats(), seq.stats());
}

#[test]
fn batch_thread_count_does_not_change_results() {
    let net = NetworkBuilder::new("sweep-threads", TensorShape::new(3, 8, 8))
        .conv(6, 3, 1, 1)
        .max_pool(2, 2)
        .flatten()
        .fc_logits(4)
        .build();
    let program = Compiler::default().compile(&net).unwrap();
    let mut rng = Rng::new(0x7EAD);
    let inputs: Vec<Vec<i8>> = (0..6)
        .map(|_| rng.i8_vec(net.input_len(), 31))
        .collect();
    let mut reference: Option<(Vec<Vec<i8>>, Counters)> = None;
    for threads in [1usize, 2, 3, 6, 16] {
        let mut sim = Simulator::new(&program);
        let batch = sim.run_batch_threads(&inputs, threads).unwrap();
        let scores: Vec<Vec<i8>> =
            batch.outputs.iter().map(|o| o.scores.clone()).collect();
        match &reference {
            None => reference = Some((scores, sim.stats().clone())),
            Some((want_scores, want_stats)) => {
                assert_eq!(&scores, want_scores, "threads={threads}");
                assert_eq!(sim.stats(), want_stats, "threads={threads}");
            }
        }
    }
}

#[test]
fn recording_is_thread_count_invariant() {
    // Regression: run_batch_threads used to silently fall back to one
    // worker whenever recording was on. Now each worker forks its own
    // recorder and the chunks are absorbed back in image order, so the
    // merged event stream is byte-identical across thread counts — and
    // the batch genuinely runs multi-threaded while recording.
    let net = NetworkBuilder::new("sweep-rec-threads", TensorShape::new(3, 8, 8))
        .conv(6, 3, 1, 1)
        .max_pool(2, 2)
        .flatten()
        .fc_logits(4)
        .build();
    let program = Compiler::default().compile(&net).unwrap();
    let mut rng = Rng::new(0x7EAD);
    let inputs: Vec<Vec<i8>> = (0..6)
        .map(|_| rng.i8_vec(net.input_len(), 31))
        .collect();
    let mut reference: Option<(Vec<u8>, Vec<Vec<i8>>, Counters)> = None;
    for threads in [1usize, 2, 3, 6, 16] {
        let mut sim = Simulator::with_recorder(&program, RecorderConfig::default());
        let batch = sim.run_batch_threads(&inputs, threads).unwrap();
        if threads > 1 {
            assert!(
                batch.threads > 1,
                "recording must not force a single-threaded batch (asked for {threads}, \
                 got {})",
                batch.threads
            );
        }
        let scores: Vec<Vec<i8>> =
            batch.outputs.iter().map(|o| o.scores.clone()).collect();
        let bytes = sim.recording().to_bytes();
        assert!(!bytes.is_empty(), "threads={threads}: nothing recorded");
        match &reference {
            None => reference = Some((bytes, scores, sim.stats().clone())),
            Some((want_bytes, want_scores, want_stats)) => {
                assert_eq!(&scores, want_scores, "threads={threads}: scores");
                assert_eq!(sim.stats(), want_stats, "threads={threads}: counters");
                assert_eq!(
                    &bytes, want_bytes,
                    "threads={threads}: merged recording must be byte-identical"
                );
            }
        }
    }
}
