//! Integration properties of the traffic record/replay plane
//! (`serve::traffic`): a session recorded off a live service replays
//! byte-identically into a fresh service (in-process and over TCP),
//! the on-disk log format round-trips through a real file, and the
//! trace-budget guard sheds concurrent `Trace` storms with typed
//! errors that are visible in `Stats`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use domino::coordinator::ArchConfig;
use domino::serve::api::{Request, Response};
use domino::serve::client::Client;
use domino::serve::net::{NetConfig, NetServer};
use domino::serve::traffic::{
    replay, replay_with, ReplaySpeed, TrafficLog, TrafficRecorder,
};
use domino::serve::{ModelRegistry, ServeConfig, Server, Service};
use domino::testutil::Rng;

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        workers: 2,
        max_batch: 4,
        queue_cap: 256,
        ..ServeConfig::default()
    }
}

/// A service over an *empty* registry: models enter through
/// `dispatch(LoadSeeded …)`, so a recorded session is self-contained
/// and replaying it reconstructs the same versions from the same
/// seeds.
fn empty_service() -> Service {
    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start_multi(serve_cfg(), registry).unwrap();
    Service::new(server, ArchConfig::default())
}

fn input_len_of(service: &Service, model: &str) -> usize {
    let reg = service.server().registry().unwrap();
    reg.get(model).unwrap().input_len()
}

/// Drive a self-contained session — loads, mixed-model infers, admin
/// lookups, a stats poll — against `service` while a recorder is
/// armed, and return the captured log.
fn record_session(service: &Service) -> TrafficLog {
    let recorder = TrafficRecorder::arm(service);
    for (model, seed) in [("tiny-mlp", 0x11u64), ("tiny-cnn", 0x22u64)] {
        let resp = service.dispatch(Request::LoadSeeded {
            model: model.to_string(),
            seed,
            mapping: None,
        });
        assert!(matches!(resp, Response::Loaded(_)), "{resp:?}");
    }
    let mut rng = Rng::new(7);
    for i in 0..6 {
        let model = if i % 2 == 0 { "tiny-mlp" } else { "tiny-cnn" };
        let image = rng.i8_vec(input_len_of(service, model), 31);
        let resp = service.dispatch(Request::Infer {
            model: Some(model.to_string()),
            image,
        });
        assert!(matches!(resp, Response::Infer(_)), "{resp:?}");
    }
    service.dispatch(Request::ModelInfo {
        model: "tiny-cnn".to_string(),
    });
    service.dispatch(Request::ListModels);
    service.dispatch(Request::Stats);
    service.clear_tap();
    recorder.finish()
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "domino_traffic_{tag}_{}.log",
        std::process::id()
    ))
}

#[test]
fn recorded_session_replays_byte_identically_through_a_file() {
    let service = empty_service();
    let log = record_session(&service);
    service.shutdown().unwrap();
    assert_eq!(log.len(), 2 + 6 + 3, "loads + infers + admin lookups");

    // the on-disk format round-trips through a real file
    let path = temp_path("roundtrip");
    log.save(&path).unwrap();
    let loaded = TrafficLog::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(log, loaded);

    // replay into a FRESH empty service: the log's own load requests
    // rebuild the models (weights are a pure function of net + seed),
    // so every comparable response is byte-identical; the lone Stats
    // reply is point-in-time and skipped
    let fresh = empty_service();
    let report = replay(&loaded, &fresh, ReplaySpeed::MaxRate);
    fresh.shutdown().unwrap();
    assert_eq!(report.total, log.len() as u64);
    assert_eq!(report.skipped, 1, "exactly the Stats poll is skipped");
    assert_eq!(
        report.mismatched, 0,
        "replay diverged: {:?}",
        report.first_mismatch
    );
    assert!(report.is_identical());

    // determinism: a second fresh service replays identically too
    let again = empty_service();
    let report2 = replay(&loaded, &again, ReplaySpeed::MaxRate);
    again.shutdown().unwrap();
    assert_eq!(report2.mismatched, 0, "{:?}", report2.first_mismatch);
    assert_eq!(report2.matched, report.matched);
}

#[test]
fn recorded_session_replays_byte_identically_over_tcp() {
    // record in-process …
    let service = empty_service();
    let log = record_session(&service);
    service.shutdown().unwrap();

    // … and replay against a fresh TCP endpoint: the wire encode →
    // decode → dispatch → encode cycle must not perturb a single byte
    let remote = Arc::new(empty_service());
    let net = NetServer::bind_with(
        "127.0.0.1:0",
        Arc::clone(&remote),
        NetConfig {
            poll: Duration::from_millis(20),
            ..NetConfig::default()
        },
    )
    .unwrap();
    let addr = net.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    client
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let report = replay_with(&log, ReplaySpeed::MaxRate, |req| {
        client.call(&req).unwrap_or_else(|e| Response::Error {
            message: format!("transport: {e:#}"),
        })
    });
    drop(client);
    net.shutdown().unwrap();
    match Arc::try_unwrap(remote) {
        Ok(svc) => {
            svc.shutdown().unwrap();
        }
        Err(_) => panic!("endpoint leaked a service handle"),
    }
    assert_eq!(report.total, log.len() as u64);
    assert_eq!(
        report.mismatched, 0,
        "remote replay diverged: {:?}",
        report.first_mismatch
    );
    assert_eq!(report.skipped, 1);
}

#[test]
fn wallclock_replay_honors_recorded_gaps() {
    // a synthetic 2-entry log with a 120 ms gap: wall-clock replay
    // must take at least the gap, max-rate must be much faster
    let service = empty_service();
    let resp = service.dispatch(Request::LoadSeeded {
        model: "tiny-mlp".to_string(),
        seed: 0x33,
        mapping: None,
    });
    assert!(matches!(resp, Response::Loaded(_)));
    let recorder = TrafficRecorder::arm(&service);
    service.dispatch(Request::ListModels);
    std::thread::sleep(Duration::from_millis(120));
    service.dispatch(Request::ListModels);
    service.clear_tap();
    let log = recorder.finish();
    assert_eq!(log.len(), 2);
    let gap = log.entries[1].at_us - log.entries[0].at_us;
    assert!(gap >= 120_000, "recorded gap {gap} us");

    let wallclock = replay(&log, &service, ReplaySpeed::Wallclock);
    assert!(
        wallclock.elapsed >= Duration::from_millis(110),
        "wall-clock replay finished in {:?}, ignoring the recorded gap",
        wallclock.elapsed
    );
    let fast = replay(&log, &service, ReplaySpeed::MaxRate);
    assert!(
        fast.elapsed < wallclock.elapsed,
        "max-rate ({:?}) should beat wall-clock ({:?})",
        fast.elapsed,
        wallclock.elapsed
    );
    assert_eq!(wallclock.mismatched + fast.mismatched, 0);
    service.shutdown().unwrap();
}

#[test]
fn trace_budget_zero_sheds_with_typed_error_and_counter() {
    let service = empty_service().with_trace_budget(0);
    let resp = service.dispatch(Request::LoadSeeded {
        model: "tiny-mlp".to_string(),
        seed: 0x44,
        mapping: None,
    });
    assert!(matches!(resp, Response::Loaded(_)));

    // budget 0: every trace is shed, deterministically, with a typed
    // error — never a hang, never an untyped failure
    for _ in 0..3 {
        match service.dispatch(Request::Trace {
            model: "tiny-mlp".to_string(),
            image_seed: 1,
            window: 8,
        }) {
            Response::Error { message } => {
                assert!(
                    message.contains("trace budget exhausted"),
                    "unexpected shed message: {message}"
                );
            }
            other => panic!("budget 0 must shed traces, got {other:?}"),
        }
    }
    match service.dispatch(Request::Stats) {
        Response::Stats(s) => assert_eq!(s.trace_rejected, 3),
        other => panic!("expected Stats, got {other:?}"),
    }
    service.shutdown().unwrap();
}

#[test]
fn concurrent_trace_storm_stays_typed_and_accounted() {
    let service = empty_service();
    let resp = service.dispatch(Request::LoadSeeded {
        model: "tiny-mlp".to_string(),
        seed: 0x55,
        mapping: None,
    });
    assert!(matches!(resp, Response::Loaded(_)));

    // 6 concurrent traces against the default budget of 2: every
    // response is either a real recording or the typed budget error,
    // the books balance, and the data plane stays serviceable
    let threads = 6;
    let (ok, shed) = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let service = &service;
            handles.push(scope.spawn(move || {
                match service.dispatch(Request::Trace {
                    model: "tiny-mlp".to_string(),
                    image_seed: t as u64,
                    window: 4,
                }) {
                    Response::Trace(_) => (1u64, 0u64),
                    Response::Error { message }
                        if message.contains("trace budget exhausted") =>
                    {
                        (0, 1)
                    }
                    other => panic!("untyped trace response: {other:?}"),
                }
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |(a, b), (x, y)| (a + x, b + y))
    });
    assert_eq!(ok + shed, threads as u64, "every trace gets a response");
    assert!(ok >= 1, "at least one trace must win a budget slot");
    match service.dispatch(Request::Stats) {
        Response::Stats(s) => assert_eq!(s.trace_rejected, shed),
        other => panic!("expected Stats, got {other:?}"),
    }

    // the observability storm must not have wedged the data plane
    let image = Rng::new(9).i8_vec(input_len_of(&service, "tiny-mlp"), 31);
    let resp = service.dispatch(Request::Infer {
        model: Some("tiny-mlp".to_string()),
        image,
    });
    assert!(matches!(resp, Response::Infer(_)), "{resp:?}");
    service.shutdown().unwrap();
}
