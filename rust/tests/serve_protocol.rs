//! End-to-end tests of the TCP service endpoint: the full admin cycle
//! (load → mixed-model infer → swap → stats → unload) driven through
//! the in-crate `Client`, every response cross-checked against the
//! refcompute oracle of the model version stamped on it; registry
//! persistence across a simulated restart; hostile-input handling;
//! and the bound-address / port-in-use ergonomics.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use domino::coordinator::explore::{self, ExploreBounds, Objective};
use domino::coordinator::ArchConfig;
use domino::model::zoo;
use domino::serve::api::{MappingSpec, RegistryManifest, Request, Response};
use domino::serve::client::Client;
use domino::serve::net::{NetConfig, NetServer};
use domino::serve::{wire, ModelRegistry, ServeConfig, Server, Service};
use domino::sim::flight::RecorderConfig;
use domino::sim::Simulator;
use domino::testutil::Rng;

fn fast_net_cfg() -> NetConfig {
    NetConfig {
        max_conns: 64,
        poll: Duration::from_millis(20),
        ..NetConfig::default()
    }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        workers: 2,
        max_batch: 4,
        queue_cap: 256,
        ..ServeConfig::default()
    }
}

/// Start a sim server over the given seeded zoo models and expose it
/// on an ephemeral TCP port.
fn start_endpoint(models: &[(&str, u64)]) -> (Arc<Service>, NetServer, String) {
    let registry = Arc::new(ModelRegistry::new());
    for (name, seed) in models {
        let net = zoo::lookup(name).unwrap();
        registry
            .load_seeded(&net.name, &net, ArchConfig::default(), Some(*seed))
            .unwrap();
    }
    let server = Server::start_multi(serve_cfg(), registry).unwrap();
    let service = Arc::new(Service::new(server, ArchConfig::default()));
    let net = NetServer::bind_with("127.0.0.1:0", Arc::clone(&service), fast_net_cfg()).unwrap();
    let addr = net.local_addr().to_string();
    (service, net, addr)
}

#[test]
fn zero_dispatchers_rejected_with_typed_error() {
    let (service, net, _addr) = start_endpoint(&[("tiny-mlp", 1)]);
    drop(net);
    let err = NetServer::bind_with(
        "127.0.0.1:0",
        service,
        NetConfig {
            dispatchers: 0,
            ..NetConfig::default()
        },
    )
    .unwrap_err();
    assert!(
        err.downcast_ref::<domino::serve::net::ZeroDispatchers>()
            .is_some(),
        "expected ZeroDispatchers as root cause, got: {err:#}"
    );
}

fn connect(addr: &str) -> Client {
    let mut c = Client::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c
}

#[test]
fn full_admin_cycle_over_tcp_with_refcompute_crosschecks() {
    let (service, net, addr) = start_endpoint(&[("tiny-mlp", 0x11)]);
    // port 0 resolved to a real ephemeral port
    assert_ne!(net.local_addr().port(), 0);

    let mut admin = connect(&addr);

    // admin plane: load a second model remotely
    let st = admin.load_seeded("tiny-resnet", 0x22).unwrap();
    assert_eq!(&*st.name, "tiny-resnet");
    assert_eq!(st.version, 1);

    // observability plane: both models described
    let models = admin.models().unwrap();
    let names: Vec<&str> = models.iter().map(|d| d.name.as_str()).collect();
    assert_eq!(names, ["tiny-mlp", "tiny-resnet"]);
    let info = admin.model_info("tiny-resnet").unwrap();
    let resnet_net = zoo::tiny_resnet();
    assert_eq!(info.input_len as usize, resnet_net.input_len());
    assert_eq!(info.classes, 6);

    // data plane: concurrent clients interleave both models; every
    // response must be stamped with its own model and bit-exact under
    // that version's weights
    let registry = Arc::clone(service.server().registry().unwrap());
    let model_names = ["tiny-mlp", "tiny-resnet"];
    let versions: Vec<_> = model_names
        .iter()
        .map(|n| registry.get(n).unwrap())
        .collect();
    let mut handles = Vec::new();
    for c in 0..3 {
        let addr = addr.clone();
        let versions = versions.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = connect(&addr);
            let mut rng = Rng::new(0xC11E + c as u64);
            for i in 0..8 {
                let mi = (c + i) % 2;
                let mv = &versions[mi];
                let img = rng.i8_vec(mv.input_len(), 31);
                let reply = client.infer(Some(mv.name()), img.clone()).unwrap();
                let stamp = reply.model.as_ref().expect("stamped");
                assert_eq!(&*stamp.name, mv.name(), "routed to the wrong model");
                assert_eq!(stamp.id, mv.id());
                assert_eq!(
                    reply.logits,
                    mv.refcompute(&img).unwrap(),
                    "{} response diverged from refcompute over TCP",
                    mv.name()
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // admin plane: hot-swap tiny-resnet remotely; a request after the
    // swap must be served by v2 with the new weights
    let st2 = admin.swap("tiny-resnet", Some(0x33)).unwrap();
    assert_eq!(st2.version, 2);
    let v2 = registry.get("tiny-resnet").unwrap();
    assert_eq!(v2.id(), st2.id);
    let img = Rng::new(7).i8_vec(v2.input_len(), 31);
    let reply = admin.infer(Some("tiny-resnet"), img.clone()).unwrap();
    assert_eq!(reply.model.as_ref().unwrap().version, 2);
    assert_eq!(reply.logits, v2.refcompute(&img).unwrap());

    // unload: new requests refused with a typed error naming the
    // survivors; the other model is unaffected
    admin.unload("tiny-resnet").unwrap();
    let err = admin
        .infer(Some("tiny-resnet"), img.clone())
        .unwrap_err()
        .to_string();
    assert!(err.contains("tiny-mlp"), "{err}");
    let mlp = registry.get("tiny-mlp").unwrap();
    let mlp_img = Rng::new(9).i8_vec(mlp.input_len(), 31);
    let mlp_reply = admin.infer(Some("tiny-mlp"), mlp_img.clone()).unwrap();
    assert_eq!(mlp_reply.logits, mlp.refcompute(&mlp_img).unwrap());

    // observability plane: per-model stats — 24 concurrent + 1
    // post-swap resnet + 1 mlp = 26 served; metrics history survives
    // the unload
    let stats = admin.stats().unwrap();
    assert_eq!(stats.served, 26);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.failed, 0);
    let by_name = |n: &str| {
        stats
            .models
            .iter()
            .find(|m| m.model == n)
            .unwrap_or_else(|| panic!("no stats entry for {n}"))
            .clone()
    };
    let mlp_stats = by_name("tiny-mlp");
    let resnet_stats = by_name("tiny-resnet");
    assert_eq!(mlp_stats.served, 13);
    assert_eq!(resnet_stats.served, 13);
    assert_eq!(mlp_stats.queue_depth, 0, "queue drained");
    assert_eq!(resnet_stats.queue_depth, 0);
    assert!(mlp_stats.p50_us.is_some() && mlp_stats.p99_us.is_some());
    assert!(mlp_stats.p50_us <= mlp_stats.p99_us);
    assert_eq!(mlp_stats.samples, 13);

    // clean shutdown: drain the endpoint, then the server; every
    // accepted request was answered
    drop(admin);
    net.shutdown().unwrap();
    let Ok(service) = Arc::try_unwrap(service) else {
        panic!("sole service ref")
    };
    let counts = service.shutdown().unwrap();
    assert_eq!(counts.iter().sum::<u64>(), 26);
}

#[test]
fn untagged_infer_routes_to_sole_model_over_tcp() {
    let (service, net, addr) = start_endpoint(&[("tiny-mlp", 0x44)]);
    let mv = service
        .server()
        .registry()
        .unwrap()
        .get("tiny-mlp")
        .unwrap();
    let mut client = connect(&addr);
    let img = Rng::new(3).i8_vec(mv.input_len(), 31);
    // model: None = "the sole loaded model", exactly like Server::submit
    let reply = client.infer(None, img.clone()).unwrap();
    assert_eq!(&*reply.model.as_ref().unwrap().name, "tiny-mlp");
    assert_eq!(reply.logits, mv.refcompute(&img).unwrap());
    // wrong-size image comes back as a typed error, not a dropped
    // connection
    let err = client.infer(None, vec![0i8; 3]).unwrap_err().to_string();
    assert!(err.contains("24"), "{err}");
    drop(client);
    net.shutdown().unwrap();
    let Ok(service) = Arc::try_unwrap(service) else {
        panic!("sole service ref")
    };
    service.shutdown().unwrap();
}

#[test]
fn port_in_use_error_names_the_address() {
    let (service, net, addr) = start_endpoint(&[("tiny-mlp", 0x55)]);
    let err = match NetServer::bind_with(&addr, Arc::clone(&service), fast_net_cfg()) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("rebinding a bound address must fail"),
    };
    assert!(err.contains(&addr), "error must name the address: {err}");
    net.shutdown().unwrap();
    let Ok(service) = Arc::try_unwrap(service) else {
        panic!("sole service ref")
    };
    service.shutdown().unwrap();
}

#[test]
fn malformed_truncated_and_oversized_frames_reject_cleanly() {
    let (service, net, addr) = start_endpoint(&[("tiny-mlp", 0x66)]);

    // 1. garbage payload: typed error response, connection stays usable
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        wire::write_frame(&mut stream, b"this is not json").unwrap();
        let frame = wire::read_frame(&mut stream).unwrap().expect("error frame");
        match wire::decode_response(&frame).unwrap() {
            Response::Error { message } => assert!(message.contains("bad request"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        // the same connection still serves a valid request afterwards
        wire::write_frame(&mut stream, &wire::encode_request(&Request::Stats)).unwrap();
        let frame = wire::read_frame(&mut stream).unwrap().expect("stats frame");
        assert!(matches!(
            wire::decode_response(&frame).unwrap(),
            Response::Stats(_)
        ));
    }

    // 2. hostile oversized length prefix: one framing-error frame, then
    // the connection is closed — and the server keeps accepting
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        use std::io::Write;
        stream
            .write_all(&((wire::MAX_FRAME + 1) as u32).to_be_bytes())
            .unwrap();
        stream.flush().unwrap();
        let frame = wire::read_frame(&mut stream)
            .unwrap()
            .expect("framing-error frame");
        match wire::decode_response(&frame).unwrap() {
            Response::Error { message } => assert!(message.contains("framing"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        assert!(
            wire::read_frame(&mut stream).unwrap().is_none(),
            "server closes after a framing error"
        );
    }

    // 3. truncated frame then disconnect: the server must survive
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        use std::io::Write;
        stream.write_all(&[0u8, 0]).unwrap(); // half a header
        stream.flush().unwrap();
        drop(stream);
    }

    // the endpoint is still healthy for new typed clients
    let mut client = connect(&addr);
    assert!(client.stats().is_ok());
    drop(client);
    net.shutdown().unwrap();
    let Ok(service) = Arc::try_unwrap(service) else {
        panic!("sole service ref")
    };
    service.shutdown().unwrap();
}

/// The acceptance path for the mapping plane: a *non-default* mapping
/// picked by the explorer is loadable over TCP, reported by
/// `ModelInfo`, served with refcompute-verified responses, and
/// survives a manifest restart at exactly the same mapping (the old
/// manifest restored every model at the service-wide default).
#[test]
fn explored_mapping_loads_over_tcp_and_survives_restart() {
    let path = std::env::temp_dir().join(format!(
        "domino-registry-mapping-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    // pick a feasible candidate whose arch differs from the default
    let tnet = zoo::tiny_resnet();
    let cands =
        explore::explore(&tnet, &ArchConfig::default(), &ExploreBounds::default(), Objective::Tiles)
            .unwrap();
    assert!(!cands.is_empty(), "explorer must rank candidates");
    let cand = cands
        .iter()
        .find(|c| c.feasible && c.arch != ArchConfig::default())
        .expect("a feasible non-default candidate");
    let spec = MappingSpec::of_choice(&cand.choice);

    // ---- first life ----
    let man = Arc::new(RegistryManifest::open(&path).unwrap());
    let registry = Arc::new(ModelRegistry::new());
    let mlp = zoo::tiny_mlp();
    let mv0 = registry
        .load_seeded(&mlp.name, &mlp, ArchConfig::default(), Some(0x5))
        .unwrap();
    man.record(&mlp.name, &mlp.name, Some(0x5), mv0.version(), Some(ArchConfig::default()));
    man.save().unwrap();
    let server = Server::start_multi(serve_cfg(), Arc::clone(&registry)).unwrap();
    let service = Arc::new(Service::with_manifest(
        server,
        ArchConfig::default(),
        Arc::clone(&man),
    ));
    let net = NetServer::bind_with("127.0.0.1:0", Arc::clone(&service), fast_net_cfg()).unwrap();
    let addr = net.local_addr().to_string();

    // load the winner remotely, at its mapping, with a recorded seed
    let mut client = connect(&addr);
    let st = client
        .load_mapped("tiny-resnet", Some(0x77), Some(spec))
        .unwrap();
    assert_eq!(&*st.name, "tiny-resnet");
    let mv = registry.get("tiny-resnet").unwrap();
    assert_eq!(
        mv.program().arch, cand.arch,
        "load must apply the requested mapping"
    );

    // ModelInfo reports the chosen mapping + placement stats
    let info = client.model_info("tiny-resnet").unwrap();
    let m = info.mapping.expect("live models report their mapping");
    assert_eq!(m.pooling, cand.choice.pooling.name());
    assert_eq!(m.placement, cand.choice.placement.name());
    assert_eq!(m.mesh_cols as usize, cand.choice.mesh_cols);
    assert_eq!(m.chip_aligned, cand.choice.chip_aligned);
    assert_eq!(m.tiles as usize, cand.tiles);
    assert_eq!(m.chips as usize, cand.chips);

    // served responses at this mapping are refcompute-exact
    let img = Rng::new(11).i8_vec(mv.input_len(), 31);
    let reply = client.infer(Some("tiny-resnet"), img.clone()).unwrap();
    assert_eq!(reply.logits, mv.refcompute(&img).unwrap());
    let logits = reply.logits;

    drop(client);
    net.shutdown().unwrap();
    let Ok(service) = Arc::try_unwrap(service) else {
        panic!("sole service ref")
    };
    service.shutdown().unwrap();

    // ---- second life: restore with the service-wide DEFAULT arch ----
    let man2 = Arc::new(RegistryManifest::open(&path).unwrap());
    assert_eq!(man2.len(), 2);
    let registry2 = Arc::new(ModelRegistry::new());
    assert_eq!(man2.restore(&registry2, ArchConfig::default()).unwrap(), 2);
    let r2 = registry2.get("tiny-resnet").unwrap();
    assert_eq!(
        r2.program().arch, cand.arch,
        "the per-model mapping must survive the restart (not the service default)"
    );
    assert_eq!(registry2.get(&mlp.name).unwrap().arch(), ArchConfig::default());
    assert_eq!(
        r2.refcompute(&img).unwrap(),
        logits,
        "restored weights + mapping answer bit-identically"
    );

    // and the restarted endpoint serves it the same
    let server2 = Server::start_multi(serve_cfg(), Arc::clone(&registry2)).unwrap();
    let service2 = Arc::new(Service::with_manifest(
        server2,
        ArchConfig::default(),
        Arc::clone(&man2),
    ));
    let net2 = NetServer::bind_with("127.0.0.1:0", Arc::clone(&service2), fast_net_cfg()).unwrap();
    let mut client2 = connect(&net2.local_addr().to_string());
    let reply2 = client2.infer(Some("tiny-resnet"), img.clone()).unwrap();
    assert_eq!(reply2.logits, logits);
    let info2 = client2.model_info("tiny-resnet").unwrap();
    assert_eq!(info2.mapping.unwrap(), m, "identical mapping stats after restart");

    drop(client2);
    net2.shutdown().unwrap();
    let Ok(service2) = Arc::try_unwrap(service2) else {
        panic!("sole service ref")
    };
    service2.shutdown().unwrap();
    let _ = std::fs::remove_file(&path);
}

/// The acceptance path for the observability plane: a flight recording
/// is retrievable from the live TCP endpoint through the typed client,
/// bit-identical to a local instrumented run of the same model version,
/// deterministic across calls, counted in the per-model `traced` stat,
/// and a typed error for an unloaded model.
#[test]
fn flight_recording_is_served_over_tcp() {
    let (service, net, addr) = start_endpoint(&[("tiny-cnn", 0x99)]);
    let mut client = connect(&addr);

    let t = client.trace("tiny-cnn", 7, 48).unwrap();
    assert_eq!(&*t.model.name, "tiny-cnn");
    assert_eq!(t.image_seed, 7);
    assert_eq!(t.dropped, 0, "tiny models must not evict at default capacity");
    assert!(t.events_total > 0, "a conv net records events");
    assert_eq!(t.events.len(), 48.min(t.events_total as usize));
    assert!(
        t.heatmap.contains("link utilization"),
        "trace reply carries a rendered heatmap:\n{}",
        t.heatmap
    );

    // the reply is exactly what a local instrumented run of the same
    // model version produces: scores, stream length, and the leading
    // window event-for-event (the wire round-trip loses nothing)
    let registry = Arc::clone(service.server().registry().unwrap());
    let mv = registry.get("tiny-cnn").unwrap();
    let mut sim = Simulator::with_recorder(mv.program(), RecorderConfig::default());
    let out = sim
        .run_image(&Rng::new(7).i8_vec(mv.input_len(), 31))
        .unwrap();
    let rec = sim.recording();
    assert_eq!(t.events_total as usize, rec.events.len());
    assert_eq!(t.scores, out.scores, "traced scores diverged over TCP");
    assert_eq!(
        t.events[..],
        rec.events[..t.events.len()],
        "served events must be bit-identical to the local recording"
    );

    // tracing is deterministic: the same (model, seed, window) answers
    // identically on a second call
    let t2 = client.trace("tiny-cnn", 7, 48).unwrap();
    assert_eq!(t2.events, t.events);
    assert_eq!(t2.scores, t.scores);
    assert_eq!(t2.heatmap, t.heatmap);

    // both traces show up in the per-model stats, separate from served
    let stats = client.stats().unwrap();
    assert_eq!(stats.served, 0, "traces are not inferences");
    let m = stats
        .models
        .iter()
        .find(|m| m.model == "tiny-cnn")
        .expect("stats entry for tiny-cnn");
    assert_eq!(m.traced, 2);

    // unloaded model: typed error naming the survivors, connection fine
    let err = client.trace("nope", 1, 4).unwrap_err().to_string();
    assert!(err.contains("not loaded"), "{err}");
    assert!(client.stats().is_ok());

    drop(client);
    net.shutdown().unwrap();
    let Ok(service) = Arc::try_unwrap(service) else {
        panic!("sole service ref")
    };
    service.shutdown().unwrap();
}

#[test]
fn registry_file_persists_across_restart_bit_exactly() {
    let path = std::env::temp_dir().join(format!(
        "domino-registry-protocol-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    // ---- first life: CLI-style startup with a manifest ----
    let man = Arc::new(RegistryManifest::open(&path).unwrap());
    let registry = Arc::new(ModelRegistry::new());
    let mlp = zoo::tiny_mlp();
    let mv = registry
        .load_seeded(&mlp.name, &mlp, ArchConfig::default(), Some(0x7))
        .unwrap();
    man.record(&mlp.name, &mlp.name, Some(0x7), mv.version(), Some(ArchConfig::default()));
    man.save().unwrap();
    let server = Server::start_multi(serve_cfg(), Arc::clone(&registry)).unwrap();
    let service = Arc::new(Service::with_manifest(
        server,
        ArchConfig::default(),
        Arc::clone(&man),
    ));
    let net = NetServer::bind_with("127.0.0.1:0", Arc::clone(&service), fast_net_cfg()).unwrap();
    let addr = net.local_addr().to_string();

    // remote admin ops persist through the manifest: load, then swap
    // to v2 with a recorded seed
    let mut client = connect(&addr);
    client.load_seeded("tiny-resnet", 0x21).unwrap();
    let st = client.swap("tiny-resnet", Some(0x22)).unwrap();
    assert_eq!(st.version, 2);
    let pre = registry.get("tiny-resnet").unwrap();
    let img = Rng::new(1).i8_vec(pre.input_len(), 31);
    let pre_logits = client.infer(Some("tiny-resnet"), img.clone()).unwrap().logits;
    drop(client);
    net.shutdown().unwrap();
    let Ok(service) = Arc::try_unwrap(service) else {
        panic!("sole service ref")
    };
    service.shutdown().unwrap();

    // ---- second life: reload the manifest into a fresh registry ----
    let man2 = Arc::new(RegistryManifest::open(&path).unwrap());
    assert_eq!(man2.len(), 2);
    let registry2 = Arc::new(ModelRegistry::new());
    let restored = man2.restore(&registry2, ArchConfig::default()).unwrap();
    assert_eq!(restored, 2);
    let r2 = registry2.get("tiny-resnet").unwrap();
    assert_eq!(r2.version(), 2, "swap version survives the restart");
    assert_eq!(
        r2.refcompute(&img).unwrap(),
        pre_logits,
        "restored weights are bit-identical"
    );

    // the restarted endpoint answers the same image identically
    let server2 = Server::start_multi(serve_cfg(), Arc::clone(&registry2)).unwrap();
    let service2 = Arc::new(Service::with_manifest(
        server2,
        ArchConfig::default(),
        Arc::clone(&man2),
    ));
    let net2 = NetServer::bind_with("127.0.0.1:0", Arc::clone(&service2), fast_net_cfg()).unwrap();
    let mut client2 = connect(&net2.local_addr().to_string());
    let reply = client2.infer(Some("tiny-resnet"), img.clone()).unwrap();
    assert_eq!(reply.model.as_ref().unwrap().version, 2);
    assert_eq!(reply.logits, pre_logits, "remote restart round-trip");

    // unload drops the entry from the manifest
    client2.unload("tiny-mlp").unwrap();
    drop(client2);
    net2.shutdown().unwrap();
    let Ok(service2) = Arc::try_unwrap(service2) else {
        panic!("sole service ref")
    };
    service2.shutdown().unwrap();
    let man3 = RegistryManifest::open(&path).unwrap();
    assert_eq!(man3.len(), 1, "unload persisted");

    let _ = std::fs::remove_file(&path);
}
