//! Fault-plane properties: the deterministic fault injector must be
//! (1) invisible when empty — an armed-but-empty [`FaultInjector`]
//! engine is bit-identical to the default `NoFaults` engine, outputs
//! *and* counters; (2) reproducible — the same seeded [`FaultPlan`]
//! produces byte-identical outputs and [`FaultReport`]s whether the
//! batch runs on 1, 2, or 4 threads; (3) recoverable — a masked
//! re-map publishes a program that provably avoids the banned tiles
//! while staying refcompute-exact with the original weights; and
//! (4) honest over the wire — `FaultInject`/`Canary{heal}` through a
//! real TCP endpoint detect silent corruption and heal it.

use std::sync::Arc;
use std::time::Duration;

use domino::coordinator::{ArchConfig, Compiler, TileMask};
use domino::model::zoo;
use domino::serve::api::{Request, Response};
use domino::serve::client::Client;
use domino::serve::net::NetServer;
use domino::serve::{ModelRegistry, ServeConfig, Server, Service};
use domino::sim::{CaptureMode, FaultPlan, Simulator};
use domino::testutil::Rng;

fn images(seed: u64, n: usize, len: usize) -> Vec<Vec<i8>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.i8_vec(len, 31)).collect()
}

#[test]
fn empty_fault_injector_is_bit_identical_to_default_engine() {
    for name in ["tiny-mlp", "tiny-cnn", "tiny-resnet"] {
        let net = zoo::by_name(name).unwrap();
        let program = Compiler::default().compile(&net).unwrap();
        let imgs = images(11, 3, net.input_len());

        let mut clean = Simulator::with_capture(&program, CaptureMode::Final);
        let mut armed = Simulator::with_faults(&program, FaultPlan::default());
        armed.set_capture(CaptureMode::Final);
        for (i, img) in imgs.iter().enumerate() {
            let a = clean.run_image(img).unwrap();
            let b = armed.run_image(img).unwrap();
            assert_eq!(a.scores, b.scores, "{name} image {i}: scores diverged");
            assert_eq!(
                a.latency_cycles, b.latency_cycles,
                "{name} image {i}: latency diverged"
            );
        }
        assert_eq!(
            clean.stats(),
            armed.stats(),
            "{name}: counters diverged under an empty fault plan"
        );
        let report = armed.fault_report();
        assert!(report.sites.is_empty(), "{name}: empty plan reported sites");
        assert_eq!(report.total_fires(), 0);
    }
}

#[test]
fn seeded_plan_is_byte_identical_across_batch_thread_counts() {
    let net = zoo::by_name("tiny-cnn").unwrap();
    let program = Compiler::default().compile(&net).unwrap();
    let coords = program.tile_coords();
    let plan = FaultPlan::new()
        .stuck_tile(coords[0], 7)
        .link_flip(coords[coords.len() / 2], 3);
    let imgs = images(23, 8, net.input_len());

    // Spec round-trip: the wire form re-parses to the same plan.
    assert_eq!(FaultPlan::parse(&plan.spec()).unwrap(), plan);

    let run = |threads: usize| {
        let mut sim = Simulator::with_faults(&program, plan.clone());
        sim.set_capture(CaptureMode::Final);
        let batch = sim.run_batch_threads(&imgs, threads).unwrap();
        let outs: Vec<(Vec<i8>, u64)> = batch
            .outputs
            .iter()
            .map(|o| (o.scores.clone(), o.latency_cycles))
            .collect();
        (outs, sim.stats().clone(), sim.fault_report())
    };

    let (base_outs, base_stats, base_report) = run(1);
    assert!(
        base_report.total_fires() > 0,
        "plan on used tiles never fired — test is vacuous"
    );
    for threads in [2, 4] {
        let (outs, stats, report) = run(threads);
        assert_eq!(outs, base_outs, "{threads} threads: outputs diverged");
        assert_eq!(stats, base_stats, "{threads} threads: counters diverged");
        assert_eq!(
            report, base_report,
            "{threads} threads: FaultReport diverged"
        );
    }
}

#[test]
fn transient_window_gates_fault_fires() {
    let net = zoo::by_name("tiny-cnn").unwrap();
    let program = Compiler::default().compile(&net).unwrap();
    let bad = program.tile_coords()[0];
    let imgs = images(31, 1, net.input_len());

    let mut clean = Simulator::with_capture(&program, CaptureMode::Final);
    let clean_out = clean.run_image(&imgs[0]).unwrap();

    // A window entirely past the run: the site is armed but never
    // eligible, so the run is bit-exact with the clean engine.
    let late = FaultPlan::new()
        .stuck_tile(bad, 7)
        .during(u32::MAX - 1, u32::MAX);
    let mut sim = Simulator::with_faults(&program, late);
    sim.set_capture(CaptureMode::Final);
    let out = sim.run_image(&imgs[0]).unwrap();
    assert_eq!(sim.fault_report().total_fires(), 0, "late window fired");
    assert_eq!(out.scores, clean_out.scores, "gated fault corrupted output");

    // A window covering everything behaves like no window at all.
    let always = FaultPlan::new().stuck_tile(bad, 7).during(0, u32::MAX);
    let unwindowed = FaultPlan::new().stuck_tile(bad, 7);
    let mut a = Simulator::with_faults(&program, always);
    a.set_capture(CaptureMode::Final);
    let a_out = a.run_image(&imgs[0]).unwrap();
    let mut u = Simulator::with_faults(&program, unwindowed);
    u.set_capture(CaptureMode::Final);
    let u_out = u.run_image(&imgs[0]).unwrap();
    assert!(a.fault_report().total_fires() > 0, "full window never fired");
    assert_eq!(a_out.scores, u_out.scores);
    assert_eq!(
        a.fault_report().total_fires(),
        u.fault_report().total_fires()
    );
}

#[test]
fn masked_remap_avoids_banned_tiles_and_stays_refcompute_exact() {
    let name = "tiny-cnn";
    let net = zoo::by_name(name).unwrap();
    let reg = ModelRegistry::new();
    let mv = reg
        .load_seeded(name, &net, ArchConfig::default(), Some(9))
        .unwrap();
    let bad = mv.program().tile_coords()[0];
    let imgs = images(41, 4, mv.input_len());
    let oracle: Vec<Vec<i8>> = imgs.iter().map(|i| mv.refcompute(i).unwrap()).collect();

    let mask = TileMask::from_coords([bad]);
    let mv2 = reg.remap_masked(name, &mask).unwrap();
    assert_eq!(mv2.stamp().version, mv.stamp().version + 1);
    assert!(
        !mv2.program().tile_coords().contains(&bad),
        "masked placement still uses the banned tile {bad}"
    );

    // Same weights, new placement: the re-mapped program must compute
    // the exact same bits as the original model's refcompute oracle.
    let mut sim = Simulator::with_capture(mv2.program(), CaptureMode::Final);
    for (i, img) in imgs.iter().enumerate() {
        let out = sim.run_image(img).unwrap();
        assert_eq!(
            out.scores, oracle[i],
            "image {i}: masked re-map is not bit-exact"
        );
        assert_eq!(mv2.refcompute(img).unwrap(), oracle[i]);
    }
}

#[test]
fn fault_inject_and_canary_heal_end_to_end_over_tcp() {
    const MODEL: &str = "tiny-mlp";
    const SEED: u64 = 5;

    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start_multi(
        ServeConfig {
            workers: 1,
            max_batch: 2,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        registry,
    )
    .expect("start server");
    let service = Arc::new(Service::new(server, ArchConfig::default()));
    let net_srv = NetServer::bind("127.0.0.1:0", Arc::clone(&service)).expect("bind");
    let addr = net_srv.local_addr().to_string();

    let mut c = Client::connect(&addr).expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    match c
        .call(&Request::LoadSeeded {
            model: MODEL.to_string(),
            seed: SEED,
            mapping: None,
        })
        .expect("load")
    {
        Response::Loaded(stamp) => assert_eq!(stamp.version, 1),
        other => panic!("load failed: {other:?}"),
    }

    // Local oracle for the same (model, seed): what the endpoint must
    // serve bit-for-bit before the fault and after the heal.
    let znet = zoo::by_name(MODEL).unwrap();
    let local = ModelRegistry::new();
    let lmv = local
        .load_seeded(MODEL, &znet, ArchConfig::default(), Some(SEED))
        .unwrap();
    let bad = lmv.program().tile_coords()[0];
    let imgs = images(47, 3, lmv.input_len());
    let oracle: Vec<Vec<i8>> = imgs.iter().map(|i| lmv.refcompute(i).unwrap()).collect();

    let infer = |c: &mut Client, img: &[i8]| -> Vec<i8> {
        match c
            .call(&Request::Infer {
                model: Some(MODEL.to_string()),
                image: img.to_vec(),
            })
            .expect("infer")
        {
            Response::Infer(r) => r.logits,
            other => panic!("infer failed: {other:?}"),
        }
    };
    assert_eq!(infer(&mut c, &imgs[0]), oracle[0], "clean endpoint wrong");

    // Arm a permanent stuck-at fault on a tile the mapping uses. The
    // diagnostic must see it fire and corrupt outputs silently.
    let spec = FaultPlan::new().stuck_tile(bad, 7).spec();
    let rep = c.fault_inject(MODEL, &spec).expect("fault inject");
    assert!(rep.armed && rep.fires > 0, "diagnostic did not fire: {rep:?}");
    assert!(rep.corrupted, "stuck-at on a used tile was not corrupting");

    // A plain canary detects the corruption but does not touch the
    // mapping; a healing canary re-maps around the fault and verifies.
    let plain = c.canary(MODEL, 0xCA11A2, false).expect("canary");
    assert!(!plain.ok && !plain.remapped);
    let heal = c.canary(MODEL, 0xCA11A2, true).expect("healing canary");
    assert!(!heal.ok, "pre-heal sentinel unexpectedly passed");
    assert!(heal.remapped && heal.healed, "heal failed: {heal:?}");
    assert_eq!(heal.version, 2);

    // Healed endpoint: canary passes, traffic is bit-exact again.
    let after = c.canary(MODEL, 0xCA11A2, false).expect("canary after heal");
    assert!(after.ok, "canary still failing after heal: {after:?}");
    for (i, img) in imgs.iter().enumerate() {
        assert_eq!(infer(&mut c, img), oracle[i], "post-heal image {i} wrong");
    }

    // Empty spec disarms the plan.
    let off = c.fault_inject(MODEL, "").expect("disarm");
    assert!(!off.armed);

    drop(c);
    net_srv.shutdown().unwrap();
    if let Ok(service) = Arc::try_unwrap(service) {
        service.shutdown().unwrap();
    }
}
