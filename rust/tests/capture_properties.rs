//! Capture-mode and psum-arena properties over the full small-geometry
//! sweep (every stage kind: conv geometries, fused max/avg pooling,
//! multi-block channel splits with FC pipelines, residuals with and
//! without projection):
//!
//! * the arena engine is bit-exact with `model::refcompute` under both
//!   capture modes;
//! * [`CaptureMode::Final`] and [`CaptureMode::AllStages`] produce
//!   identical scores, slots, latency and — critically — identical
//!   [`Counters`] (counters feed the energy model; any drift is a
//!   correctness bug, not a perf trade-off);
//! * warm (reused) engines charge exactly what fresh engines charge,
//!   image after image — the reset paths restore everything.
//!
//! The direct pre-refactor comparison (scores + counters vs the frozen
//! pre-arena engine) runs on every `cargo bench --bench engine_perf`.

use domino::coordinator::{ArchConfig, Compiler};
use domino::model::refcompute::{forward_all, Weights};
use domino::model::{Network, NetworkBuilder, Projection, TensorShape};
use domino::sim::{CaptureMode, Simulator};
use domino::testutil::Rng;

/// The sweep (mirrors `batch_properties.rs`).
fn sweep_nets() -> Vec<(Network, ArchConfig)> {
    let mut nets = Vec::new();
    for (k, stride, padding) in [(1usize, 1usize, 0usize), (3, 1, 1), (3, 2, 1), (3, 1, 0)] {
        let net = NetworkBuilder::new("sweep-conv", TensorShape::new(2, 6, 6))
            .conv(4, k, stride, padding)
            .build();
        nets.push((net, ArchConfig::default()));
    }
    nets.push((
        NetworkBuilder::new("sweep-maxpool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .max_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("sweep-avgpool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .avg_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("sweep-blocks", TensorShape::new(6, 5, 5))
            .conv(7, 3, 1, 1)
            .flatten()
            .fc(9)
            .fc_logits(5)
            .build(),
        ArchConfig::tiny(4),
    ));
    nets.push((
        NetworkBuilder::new("sweep-res", TensorShape::new(4, 6, 6))
            .conv(4, 3, 1, 1)
            .conv_linear(4, 3, 1, 1)
            .res_add(0)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("sweep-res-proj", TensorShape::new(4, 8, 8))
            .conv(4, 3, 1, 1)
            .conv(8, 3, 2, 1)
            .conv_linear(8, 3, 1, 1)
            .res_add_proj(
                0,
                Projection {
                    out_ch: 8,
                    stride: 2,
                },
            )
            .build(),
        ArchConfig::default(),
    ));
    nets
}

#[test]
fn arena_engine_matches_refcompute_under_both_captures() {
    for (net, arch) in sweep_nets() {
        let compiler = Compiler::new(arch);
        let weights = Weights::random(&net, compiler.weight_seed).unwrap();
        let program = compiler.compile_with_weights(&net, &weights).unwrap();
        let mut all = Simulator::new(&program);
        let mut fin = Simulator::with_capture(&program, CaptureMode::Final);
        let mut rng = Rng::new(0xCAFE);
        for i in 0..3 {
            let input = domino::model::refcompute::Tensor::new(
                net.input,
                rng.i8_vec(net.input_len(), 31),
            );
            let want = forward_all(&net, &weights, &input).unwrap();
            let a = all.run_image(&input.data).unwrap();
            let f = fin.run_image(&input.data).unwrap();
            assert_eq!(
                a.scores,
                want.last().unwrap().data,
                "{} image {i}: AllStages vs refcompute",
                net.name
            );
            assert_eq!(
                f.scores,
                want.last().unwrap().data,
                "{} image {i}: Final vs refcompute",
                net.name
            );
            assert!(f.stage_outputs.is_empty(), "{}", net.name);
            assert_eq!(a.stage_slots, f.stage_slots, "{}", net.name);
            assert_eq!(a.latency_cycles, f.latency_cycles, "{}", net.name);
        }
        // counters are the energy model's input: any capture-mode or
        // arena-path drift is a correctness bug
        assert_eq!(
            all.stats(),
            fin.stats(),
            "{}: counters differ across capture modes",
            net.name
        );
        assert_eq!(all.stage_stats(), fin.stage_stats(), "{}", net.name);
    }
}

#[test]
fn batched_final_capture_matches_all_stages() {
    // run_batch workers inherit the simulator's capture mode; scores,
    // merged counters and the pipeline report must not depend on it.
    for (net, arch) in sweep_nets() {
        let program = Compiler::new(arch).compile(&net).unwrap();
        let mut rng = Rng::new(0xF1A7);
        let inputs: Vec<Vec<i8>> = (0..5)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();

        let mut all = Simulator::new(&program);
        let batch_all = all.run_batch_threads(&inputs, 3).unwrap();
        let mut fin = Simulator::with_capture(&program, CaptureMode::Final);
        let batch_fin = fin.run_batch_threads(&inputs, 3).unwrap();

        for (i, (a, f)) in batch_all
            .outputs
            .iter()
            .zip(&batch_fin.outputs)
            .enumerate()
        {
            assert_eq!(a.scores, f.scores, "{} image {i}", net.name);
            assert_eq!(a.stage_slots, f.stage_slots, "{} image {i}", net.name);
            assert_eq!(a.latency_cycles, f.latency_cycles, "{} image {i}", net.name);
            assert_eq!(
                a.stage_outputs.len(),
                program.stages.len(),
                "{}: AllStages batch keeps stage tensors",
                net.name
            );
            assert!(
                f.stage_outputs.is_empty(),
                "{}: Final batch must not capture stage tensors",
                net.name
            );
        }
        assert_eq!(all.stats(), fin.stats(), "{}: batched counters", net.name);
        assert_eq!(
            batch_all.pipeline.steady_period_cycles,
            batch_fin.pipeline.steady_period_cycles,
            "{}",
            net.name
        );
    }
}

#[test]
fn warm_engines_charge_exactly_like_fresh_engines() {
    // Image after image on one engine (arena + scratch reused) must be
    // indistinguishable — outputs and counters — from a fresh engine
    // per image. This is the reset-path audit as a property.
    for (net, arch) in sweep_nets() {
        let program = Compiler::new(arch).compile(&net).unwrap();
        let mut rng = Rng::new(0x5EAD);
        let images: Vec<Vec<i8>> = (0..4)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();
        let mut warm = Simulator::new(&program);
        let mut summed = domino::sim::Counters::new();
        for (i, img) in images.iter().enumerate() {
            let got = warm.run_image(img).unwrap();
            let mut fresh = Simulator::new(&program);
            let want = fresh.run_image(img).unwrap();
            assert_eq!(got.scores, want.scores, "{} image {i}", net.name);
            assert_eq!(got.latency_cycles, want.latency_cycles, "{}", net.name);
            for (si, (a, b)) in got
                .stage_outputs
                .iter()
                .zip(&want.stage_outputs)
                .enumerate()
            {
                assert_eq!(a.data, b.data, "{} image {i} stage {si}", net.name);
            }
            summed.merge(fresh.stats());
        }
        assert_eq!(
            warm.stats(),
            &summed,
            "{}: warm-engine counters drifted from fresh-engine counters",
            net.name
        );
    }
}
