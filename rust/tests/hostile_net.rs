//! Hostile-client tests for the TCP endpoint (`serve::net`) and the
//! typed client (`serve::client`): slow-loris partial frames must not
//! block the shutdown drain, garbage payloads inside valid frames get
//! typed errors without killing the connection, a client that stops
//! reading cannot wedge the server, over-capacity refusals are counted
//! and surfaced through `Stats`, and a client whose call dies
//! mid-round-trip poisons itself instead of silently desynchronizing
//! the frame stream.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use domino::coordinator::ArchConfig;
use domino::model::zoo;
use domino::serve::api::{Request, Response};
use domino::serve::client::Client;
use domino::serve::net::{NetConfig, NetServer};
use domino::serve::{wire, ModelRegistry, ServeConfig, Server, Service};
use domino::testutil::Rng;

fn fast_net_cfg() -> NetConfig {
    NetConfig {
        max_conns: 64,
        poll: Duration::from_millis(20),
        write_timeout: Duration::from_millis(500),
        ..NetConfig::default()
    }
}

fn start_endpoint(cfg: NetConfig) -> (Arc<Service>, NetServer, String) {
    let registry = Arc::new(ModelRegistry::new());
    let net = zoo::tiny_mlp();
    registry
        .load_seeded(&net.name, &net, ArchConfig::default(), Some(0x7E57))
        .unwrap();
    let server = Server::start_multi(
        ServeConfig {
            workers: 2,
            max_batch: 4,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        registry,
    )
    .unwrap();
    let service = Arc::new(Service::new(server, ArchConfig::default()));
    let endpoint = NetServer::bind_with("127.0.0.1:0", Arc::clone(&service), cfg).unwrap();
    let addr = endpoint.local_addr().to_string();
    (service, endpoint, addr)
}

fn shutdown_all(service: Arc<Service>, endpoint: NetServer) {
    endpoint.shutdown().unwrap();
    match Arc::try_unwrap(service) {
        Ok(svc) => {
            svc.shutdown().unwrap();
        }
        Err(_) => panic!("endpoint leaked a service handle"),
    }
}

fn infer_image(service: &Service) -> Vec<i8> {
    let reg = service.server().registry().unwrap();
    let len = reg.get("tiny-mlp").unwrap().input_len();
    Rng::new(3).i8_vec(len, 31)
}

#[test]
fn slow_loris_partial_frame_neither_starves_peers_nor_blocks_shutdown() {
    let (service, endpoint, addr) = start_endpoint(fast_net_cfg());

    // the loris: a length prefix promising 64 bytes, then 3 payload
    // bytes, then silence — a frame forever partially received
    let mut loris = TcpStream::connect(&addr).unwrap();
    loris.set_nodelay(true).ok();
    loris.write_all(&64u32.to_be_bytes()).unwrap();
    loris.write_all(b"xyz").unwrap();
    loris.flush().ok();

    // while the loris squats, well-behaved clients are fully served
    let mut client = Client::connect(&addr).unwrap();
    client
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let image = infer_image(&service);
    for _ in 0..4 {
        client.infer(Some("tiny-mlp"), image.clone()).unwrap();
    }
    drop(client);

    // shutdown must drain promptly: the partially received frame is
    // abandoned at the stop flag, never awaited to completion
    let t = Instant::now();
    shutdown_all(service, endpoint);
    let elapsed = t.elapsed();
    assert!(
        elapsed < Duration::from_secs(10),
        "shutdown took {elapsed:?} with a loris holding a partial frame"
    );
    drop(loris);
}

#[test]
fn garbage_payload_in_valid_frame_gets_typed_error_and_connection_survives() {
    let (service, endpoint, addr) = start_endpoint(fast_net_cfg());
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();

    // a valid request first, to prove the connection works
    wire::write_frame(&mut stream, &wire::encode_request(&Request::Stats)).unwrap();
    let frame = wire::read_frame(&mut stream).unwrap().unwrap();
    assert!(matches!(
        wire::decode_response(&frame).unwrap(),
        Response::Stats(_)
    ));

    // then a correctly framed frame full of garbage: the framing layer
    // is intact, so the server answers with a typed error and KEEPS
    // the connection — a decode failure is the client's bug, not a
    // transport fault
    wire::write_frame(&mut stream, b"\x01\x02garbage\xff not json at all").unwrap();
    let frame = wire::read_frame(&mut stream).unwrap().unwrap();
    match wire::decode_response(&frame).unwrap() {
        Response::Error { message } => {
            assert!(message.contains("bad request"), "{message}");
        }
        other => panic!("expected a typed error, got {other:?}"),
    }

    // the same connection still serves valid requests afterwards
    wire::write_frame(&mut stream, &wire::encode_request(&Request::ListModels)).unwrap();
    let frame = wire::read_frame(&mut stream).unwrap().unwrap();
    assert!(matches!(
        wire::decode_response(&frame).unwrap(),
        Response::Models(_)
    ));

    drop(stream);
    shutdown_all(service, endpoint);
}

#[test]
fn non_reading_client_cannot_wedge_the_server_or_its_shutdown() {
    let (service, endpoint, addr) = start_endpoint(fast_net_cfg());

    // the hostile peer pipelines a pile of requests and never reads a
    // byte of the responses; once the socket buffers fill, the
    // server's writes block until `write_timeout` (500 ms here) kills
    // the connection — it must never wait forever
    let mut glutton = TcpStream::connect(&addr).unwrap();
    glutton.set_nodelay(true).ok();
    let reqs: Vec<u8> = {
        let mut buf = Vec::new();
        let payload = wire::encode_request(&Request::ListModels);
        for _ in 0..512 {
            buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
            buf.extend_from_slice(&payload);
        }
        buf
    };
    // the write itself may block once the server stops consuming (its
    // own writes are stuck), so bound it
    glutton
        .set_write_timeout(Some(Duration::from_secs(5)))
        .ok();
    let _ = glutton.write_all(&reqs);

    // a well-behaved client on its own connection stays fully served
    // while the glutton's connection is stalling out
    let mut client = Client::connect(&addr).unwrap();
    client
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let image = infer_image(&service);
    for _ in 0..4 {
        client.infer(Some("tiny-mlp"), image.clone()).unwrap();
    }
    drop(client);

    // and shutdown drains within a few write-timeouts, glutton or not
    let t = Instant::now();
    shutdown_all(service, endpoint);
    let elapsed = t.elapsed();
    assert!(
        elapsed < Duration::from_secs(20),
        "shutdown took {elapsed:?} with a non-reading client attached"
    );
    drop(glutton);
}

#[test]
fn refused_connections_are_counted_and_surfaced_in_stats() {
    let cfg = NetConfig {
        max_conns: 1,
        ..fast_net_cfg()
    };
    let (service, endpoint, addr) = start_endpoint(cfg);

    // occupy the only slot and prove it is live
    let mut first = Client::connect(&addr).unwrap();
    first
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    first.stats().unwrap();

    // the second connection is refused with a typed error frame; the
    // raw read sees the refusal without sending anything at all
    let mut second = TcpStream::connect(&addr).unwrap();
    second
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let frame = wire::read_frame(&mut second).unwrap().unwrap();
    match wire::decode_response(&frame).unwrap() {
        Response::Error { message } => {
            assert!(message.contains("connection capacity"), "{message}");
        }
        other => panic!("expected the capacity refusal, got {other:?}"),
    }
    drop(second);

    // the refusal is visible to the operator through Stats, both via
    // the surviving TCP client and the in-process dispatch
    let stats = first.stats().unwrap();
    assert_eq!(stats.conns_refused, 1);
    match service.dispatch(Request::Stats) {
        Response::Stats(s) => assert_eq!(s.conns_refused, 1),
        other => panic!("expected Stats, got {other:?}"),
    }

    drop(first);
    shutdown_all(service, endpoint);
}

#[test]
fn mid_call_timeout_poisons_the_client_until_reconnect() {
    // a deliberately sluggish fake server: accepts one connection,
    // reads the request, then sits on its hands far past the client's
    // read timeout before answering — the classic slow upstream
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let slow = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        // serve round-trips forever, each delayed well past the
        // client's timeout; the thread dies when the client hangs up
        while let Ok(Some(_)) = wire::read_frame(&mut conn) {
            std::thread::sleep(Duration::from_millis(400));
            let resp = Response::Models(Vec::new());
            if wire::write_frame(&mut conn, &wire::encode_response(&resp)).is_err() {
                break;
            }
        }
    });

    let mut client = Client::connect(&addr).unwrap();
    assert!(!client.is_poisoned());
    client
        .set_read_timeout(Some(Duration::from_millis(50)))
        .unwrap();

    // the call dies mid-round-trip (request written, response late):
    // the frame stream is now desynchronized — the late response is
    // still in flight and would be decoded as the answer to whatever
    // is sent next
    let err = client.call(&Request::ListModels).unwrap_err();
    assert!(client.is_poisoned(), "timeout must poison: {err:#}");

    // every subsequent call fails fast with the poisoned diagnosis,
    // WITHOUT touching the wire (it would read the stale response)
    for _ in 0..2 {
        let err = client.call(&Request::Stats).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("poisoned") && msg.contains("reconnect"),
            "poisoned client must fail fast and say so: {msg}"
        );
    }

    // reconnecting is the documented recovery — and against a prompt
    // server the fresh connection works (reuse the same fake, which is
    // single-connection, by simply proving a fresh Client starts
    // unpoisoned and a healthy endpoint serves it)
    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start_multi(
        ServeConfig {
            workers: 1,
            max_batch: 2,
            queue_cap: 16,
            ..ServeConfig::default()
        },
        registry,
    )
    .unwrap();
    let service = Arc::new(Service::new(server, ArchConfig::default()));
    let endpoint =
        NetServer::bind_with("127.0.0.1:0", Arc::clone(&service), fast_net_cfg()).unwrap();
    let mut fresh = Client::connect(&endpoint.local_addr().to_string()).unwrap();
    fresh
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    assert!(!fresh.is_poisoned());
    fresh.stats().unwrap();
    assert!(!fresh.is_poisoned(), "successful calls must not poison");
    drop(fresh);
    shutdown_all(service, endpoint);

    drop(client);
    slow.join().unwrap();
}

#[test]
fn reconnect_recovers_a_poisoned_client_in_place() {
    use domino::serve::api::InferReply;

    // a fake server whose FIRST connection is sluggish (to poison the
    // client) and whose second connection answers promptly — the same
    // address throughout, so Client::reconnect() can recover in place
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        for i in 0..2 {
            let (mut conn, _) = listener.accept().unwrap();
            while let Ok(Some(_)) = wire::read_frame(&mut conn) {
                if i == 0 {
                    std::thread::sleep(Duration::from_millis(300));
                }
                let resp = Response::Infer(InferReply {
                    logits: vec![1, -2, 3],
                    model: None,
                    queue_us: 0,
                    exec_us: 0,
                });
                if wire::write_frame(&mut conn, &wire::encode_response(&resp)).is_err() {
                    break;
                }
            }
        }
    });

    let mut client = Client::connect(&addr).unwrap();
    client
        .set_read_timeout(Some(Duration::from_millis(50)))
        .unwrap();

    // poison: the response outlives the read timeout
    let err = client.infer(Some("m"), vec![0; 4]).unwrap_err();
    assert!(client.is_poisoned(), "timeout must poison: {err:#}");
    let msg = format!("{:#}", client.infer(Some("m"), vec![0; 4]).unwrap_err());
    assert!(msg.contains("poisoned"), "{msg}");

    // reconnect IN PLACE: same Client value, fresh connection; the old
    // connection's stale in-flight response is stranded on the old
    // socket and can no longer misattribute
    client.reconnect().unwrap();
    assert!(!client.is_poisoned());
    // the fake's second connection still needs the first one's delayed
    // write to finish before it is accepted; wait generously
    client
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let reply = client.infer(Some("m"), vec![0; 4]).unwrap();
    assert_eq!(reply.logits, vec![1, -2, 3]);
    assert!(!client.is_poisoned());

    drop(client);
    server.join().unwrap();
}

#[test]
fn successful_calls_never_poison_and_errors_from_server_are_not_transport_errors() {
    let (service, endpoint, addr) = start_endpoint(fast_net_cfg());
    let mut client = Client::connect(&addr).unwrap();
    client
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();

    // a server-side typed error (unknown model) is a *successful*
    // round-trip: the framing stayed in sync, so the client must NOT
    // poison itself over it
    match client
        .call(&Request::Infer {
            model: Some("no-such-model".to_string()),
            image: vec![0; 4],
        })
        .unwrap()
    {
        Response::Error { .. } => {}
        other => panic!("expected a typed error, got {other:?}"),
    }
    assert!(!client.is_poisoned());

    // and the connection keeps serving real traffic afterwards
    let image = infer_image(&service);
    let reply = client.infer(Some("tiny-mlp"), image).unwrap();
    assert!(!reply.logits.is_empty());
    assert!(!client.is_poisoned());

    drop(client);
    shutdown_all(service, endpoint);
}
