//! Property tests for the `serve::wire` codec: every `Request` /
//! `Response` variant must round-trip bit-exactly — including images
//! with extreme i8 values, u64-extreme ids/seeds and names that need
//! JSON escaping — and malformed / truncated / oversized inputs must
//! reject with a typed error, never a panic.

use std::sync::Arc;

use domino::coordinator::{Placement, PoolingScheme};
use domino::serve::api::{
    CanaryReply, FaultReply, InferReply, MappingDesc, MappingSpec, ModelDesc, Request, Response,
    StatsReply, TraceReply,
};
use domino::serve::wire;
use domino::serve::{ModelMetricsSnapshot, ModelStamp};
use domino::sim::flight::{Event, EventKind};
use domino::testutil::{for_all, Rng};

fn roundtrip_req(req: &Request) {
    let bytes = wire::encode_request(req);
    let back = wire::decode_request(&bytes)
        .unwrap_or_else(|e| panic!("decode of {req:?} failed: {e:#}\nencoded: {bytes:?}"));
    assert_eq!(&back, req, "request round-trip mismatch");
}

fn roundtrip_resp(resp: &Response) {
    let bytes = wire::encode_response(resp);
    let back = wire::decode_response(&bytes)
        .unwrap_or_else(|e| panic!("decode of {resp:?} failed: {e:#}"));
    assert_eq!(&back, resp, "response round-trip mismatch");
}

/// A name drawn from pieces that stress the string escaper: quotes,
/// backslashes, control characters, JSON syntax, multi-byte UTF-8
/// (incl. an astral-plane char, which some encoders emit as a
/// surrogate pair).
fn tricky_name(rng: &mut Rng) -> String {
    const PIECES: &[&str] = &[
        "m", "tiny-cnn", "\"", "\\", "\\\\\"", "\n", "\r", "\t", "\u{0}", "\u{1}",
        "\u{1f}", "caffè", "日本語", "😀", " ", "/", "{}", "[],:", "null", "-12",
    ];
    let n = rng.range(0, 6);
    let mut s = String::new();
    for _ in 0..n {
        s.push_str(PIECES[rng.below(PIECES.len())]);
    }
    s
}

/// An image mixing uniform draws with guaranteed i8 extremes.
fn tricky_image(rng: &mut Rng) -> Vec<i8> {
    let mut img: Vec<i8> = (0..rng.range(0, 24)).map(|_| rng.i8()).collect();
    img.push(i8::MIN);
    img.push(i8::MAX);
    img.push(0);
    img
}

fn tricky_u64(rng: &mut Rng) -> u64 {
    match rng.below(4) {
        0 => 0,
        1 => u64::MAX,
        2 => i64::MAX as u64 + 1, // past the i64 boundary
        _ => rng.next_u64(),
    }
}

fn tricky_stamp(rng: &mut Rng) -> ModelStamp {
    ModelStamp {
        name: Arc::from(tricky_name(rng).as_str()),
        id: tricky_u64(rng),
        version: tricky_u64(rng),
    }
}

/// A per-model mapping spec with every Option drawn independently
/// (typed enums travel as their canonical names).
fn tricky_mapping_spec(rng: &mut Rng) -> Option<MappingSpec> {
    if rng.chance(0.3) {
        return None;
    }
    let opt_u = |rng: &mut Rng| rng.chance(0.5).then(|| tricky_u64(rng));
    Some(MappingSpec {
        pooling: rng.chance(0.5).then(|| {
            if rng.chance(0.5) {
                PoolingScheme::BlockReuse
            } else {
                PoolingScheme::WeightDuplication
            }
        }),
        placement: rng.chance(0.5).then(|| {
            if rng.chance(0.5) {
                Placement::Serpentine
            } else {
                Placement::ColumnMajor
            }
        }),
        mesh_cols: opt_u(rng),
        chip_aligned: rng.chance(0.5).then(|| rng.chance(0.5)),
        sync_chips: opt_u(rng),
    })
}

/// Mapping stats as seen on the wire: pooling/placement are free
/// strings there, so stress them with the tricky-name generator.
fn tricky_mapping_desc(rng: &mut Rng) -> MappingDesc {
    MappingDesc {
        pooling: tricky_name(rng),
        placement: tricky_name(rng),
        mesh_cols: tricky_u64(rng),
        chip_aligned: rng.chance(0.5),
        sync_chips: rng.chance(0.5).then(|| tricky_u64(rng)),
        tiles: tricky_u64(rng),
        chips: tricky_u64(rng),
        worst_link_permille: tricky_u64(rng),
        images_per_s: tricky_u64(rng),
        pj_per_image: tricky_u64(rng),
    }
}

fn tricky_desc(rng: &mut Rng) -> ModelDesc {
    ModelDesc {
        name: tricky_name(rng),
        id: tricky_u64(rng),
        version: tricky_u64(rng),
        input_len: tricky_u64(rng),
        classes: tricky_u64(rng),
        layers: tricky_u64(rng),
        params: tricky_u64(rng),
        macs: tricky_u64(rng),
        mapping: rng.chance(0.5).then(|| tricky_mapping_desc(rng)),
    }
}

fn tricky_snapshot(rng: &mut Rng) -> ModelMetricsSnapshot {
    let opt = |rng: &mut Rng| {
        if rng.chance(0.5) {
            Some(rng.next_u64())
        } else {
            None
        }
    };
    ModelMetricsSnapshot {
        model: tricky_name(rng),
        served: tricky_u64(rng),
        failed: tricky_u64(rng),
        rejected: tricky_u64(rng),
        traced: tricky_u64(rng),
        queue_depth: tricky_u64(rng),
        samples: tricky_u64(rng),
        p50_us: opt(rng),
        p95_us: opt(rng),
        p99_us: opt(rng),
        degraded: rng.chance(0.5),
    }
}

fn tricky_u32(rng: &mut Rng) -> u32 {
    match rng.below(3) {
        0 => 0,
        1 => u32::MAX,
        _ => rng.next_u64() as u32,
    }
}

/// A flight-recorder event stressing every field's extremes (incl. the
/// `NO_TILE` sentinel at `u16::MAX`).
fn tricky_event(rng: &mut Rng) -> Event {
    let u16_or_max = |rng: &mut Rng| {
        if rng.chance(0.2) {
            u16::MAX
        } else {
            rng.next_u64() as u16
        }
    };
    Event {
        kind: EventKind::ALL[rng.below(EventKind::ALL.len())],
        stage: rng.next_u64() as u16,
        chain: u16_or_max(rng),
        ci: u16_or_max(rng),
        slot: tricky_u32(rng),
        a: tricky_u32(rng),
        b: tricky_u32(rng),
    }
}

#[test]
fn every_request_variant_roundtrips() {
    // fixed edge cases first
    roundtrip_req(&Request::Infer {
        model: None,
        image: vec![],
    });
    roundtrip_req(&Request::Infer {
        model: Some(String::new()),
        image: vec![i8::MIN, -1, 0, 1, i8::MAX],
    });
    roundtrip_req(&Request::Load {
        model: "a \"quoted\\name\"\nwith\tcontrol\u{1}chars".to_string(),
        mapping: None,
    });
    roundtrip_req(&Request::LoadSeeded {
        model: "m".to_string(),
        seed: u64::MAX,
        mapping: None,
    });
    roundtrip_req(&Request::Load {
        model: "m".to_string(),
        mapping: Some(MappingSpec::default()),
    });
    roundtrip_req(&Request::LoadSeeded {
        model: "m".to_string(),
        seed: 0,
        mapping: Some(MappingSpec {
            pooling: Some(PoolingScheme::WeightDuplication),
            placement: Some(Placement::ColumnMajor),
            mesh_cols: Some(u64::MAX),
            chip_aligned: Some(false),
            sync_chips: Some(0),
        }),
    });
    roundtrip_req(&Request::Swap {
        model: "m".to_string(),
        seed: None,
    });
    roundtrip_req(&Request::Swap {
        model: "m".to_string(),
        seed: Some(0),
    });
    roundtrip_req(&Request::Unload {
        model: "😀".to_string(),
    });
    roundtrip_req(&Request::ListModels);
    roundtrip_req(&Request::ModelInfo {
        model: "tiny-cnn".to_string(),
    });
    roundtrip_req(&Request::Stats);
    roundtrip_req(&Request::Trace {
        model: "tiny-cnn".to_string(),
        image_seed: u64::MAX,
        window: 0,
    });

    roundtrip_req(&Request::FaultInject {
        model: "tiny-cnn".to_string(),
        plan: "tile:0:1:2:dead;link:3:4:5:flip:31@0-4294967295".to_string(),
    });
    roundtrip_req(&Request::Canary {
        model: "tiny-cnn".to_string(),
        seed: u64::MAX,
        heal: false,
    });

    // randomized sweep across all variants
    for_all("request_roundtrip", 200, |rng| {
        let req = match rng.below(11) {
            0 => Request::Infer {
                model: if rng.chance(0.3) {
                    None
                } else {
                    Some(tricky_name(rng))
                },
                image: tricky_image(rng),
            },
            1 => Request::Load {
                model: tricky_name(rng),
                mapping: tricky_mapping_spec(rng),
            },
            2 => Request::LoadSeeded {
                model: tricky_name(rng),
                seed: tricky_u64(rng),
                mapping: tricky_mapping_spec(rng),
            },
            3 => Request::Swap {
                model: tricky_name(rng),
                seed: if rng.chance(0.5) {
                    Some(tricky_u64(rng))
                } else {
                    None
                },
            },
            4 => Request::Unload {
                model: tricky_name(rng),
            },
            5 => Request::ListModels,
            6 => Request::ModelInfo {
                model: tricky_name(rng),
            },
            7 => Request::Stats,
            8 => Request::Trace {
                model: tricky_name(rng),
                image_seed: tricky_u64(rng),
                window: tricky_u64(rng),
            },
            // the plan travels as an opaque spec string: the codec
            // must round-trip it whether or not it parses as a plan
            9 => Request::FaultInject {
                model: tricky_name(rng),
                plan: tricky_name(rng),
            },
            _ => Request::Canary {
                model: tricky_name(rng),
                seed: tricky_u64(rng),
                heal: rng.chance(0.5),
            },
        };
        roundtrip_req(&req);
    });
}

#[test]
fn every_response_variant_roundtrips() {
    roundtrip_resp(&Response::Infer(InferReply {
        logits: vec![i8::MIN, i8::MAX],
        model: None,
        queue_us: 0,
        exec_us: u64::MAX,
    }));
    roundtrip_resp(&Response::Error {
        message: "nested \"error\": a\\b\nline2 \u{0}".to_string(),
    });
    roundtrip_resp(&Response::Models(vec![]));
    roundtrip_resp(&Response::Stats(StatsReply {
        served: 0,
        rejected: 0,
        failed: 0,
        conns_refused: 0,
        trace_rejected: 0,
        models: vec![],
    }));

    for_all("response_roundtrip", 200, |rng| {
        let resp = match rng.below(11) {
            0 => Response::Infer(InferReply {
                logits: tricky_image(rng),
                model: if rng.chance(0.3) {
                    None
                } else {
                    Some(tricky_stamp(rng))
                },
                queue_us: tricky_u64(rng),
                exec_us: tricky_u64(rng),
            }),
            1 => Response::Loaded(tricky_stamp(rng)),
            2 => Response::Swapped(tricky_stamp(rng)),
            3 => Response::Unloaded(tricky_stamp(rng)),
            4 => Response::Models((0..rng.range(0, 4)).map(|_| tricky_desc(rng)).collect()),
            5 => Response::Info(tricky_desc(rng)),
            6 => Response::Stats(StatsReply {
                served: tricky_u64(rng),
                rejected: tricky_u64(rng),
                failed: tricky_u64(rng),
                conns_refused: tricky_u64(rng),
                trace_rejected: tricky_u64(rng),
                models: (0..rng.range(0, 4)).map(|_| tricky_snapshot(rng)).collect(),
            }),
            7 => Response::Trace(TraceReply {
                model: tricky_stamp(rng),
                image_seed: tricky_u64(rng),
                events_total: tricky_u64(rng),
                dropped: tricky_u64(rng),
                events: (0..rng.range(0, 6)).map(|_| tricky_event(rng)).collect(),
                scores: tricky_image(rng),
                heatmap: tricky_name(rng),
            }),
            8 => Response::Fault(FaultReply {
                model: tricky_stamp(rng),
                armed: rng.chance(0.5),
                sites: tricky_u64(rng),
                fires: tricky_u64(rng),
                lanes: tricky_u64(rng),
                corrupted: rng.chance(0.5),
                mismatched: tricky_u64(rng),
                outputs: tricky_u64(rng),
                report: tricky_name(rng),
            }),
            9 => Response::Canary(CanaryReply {
                model: tricky_stamp(rng),
                ok: rng.chance(0.5),
                mismatched: tricky_u64(rng),
                outputs: tricky_u64(rng),
                remapped: rng.chance(0.5),
                healed: rng.chance(0.5),
                version: tricky_u64(rng),
            }),
            _ => Response::Error {
                message: tricky_name(rng),
            },
        };
        roundtrip_resp(&resp);
    });
}

#[test]
fn truncated_encodings_reject_cleanly() {
    // every strict prefix of a valid encoding must error (or, for the
    // empty prefix at the JSON level, error too) — and never panic
    let req = Request::Infer {
        model: Some("tiny-cnn \"escaped\" 😀".to_string()),
        image: vec![i8::MIN, 0, i8::MAX],
    };
    let bytes = wire::encode_request(&req);
    for cut in 0..bytes.len() {
        assert!(
            wire::decode_request(&bytes[..cut]).is_err(),
            "prefix of {cut} bytes should not decode"
        );
    }

    // the same at the framing level: a frame cut anywhere must read as
    // an error (truncated header or payload) — except a cut at 0
    // bytes, which is a clean EOF (None)
    let mut framed = Vec::new();
    wire::write_frame(&mut framed, &bytes).unwrap();
    for cut in 0..framed.len() {
        let mut r = std::io::Cursor::new(framed[..cut].to_vec());
        match wire::read_frame(&mut r) {
            Ok(None) => assert_eq!(cut, 0, "only an empty stream is a clean EOF"),
            Ok(Some(_)) => panic!("truncated frame of {cut} bytes should not read"),
            Err(_) => {} // expected
        }
    }
    // the intact frame reads back whole
    let mut r = std::io::Cursor::new(framed);
    assert_eq!(wire::read_frame(&mut r).unwrap().unwrap(), bytes);
}

#[test]
fn corrupted_bytes_never_panic() {
    // random single-byte corruptions of valid encodings: the decoder
    // may accept (the mutation can hit a value byte) or reject, but
    // must never panic
    for_all("corruption", 300, |rng| {
        let req = Request::LoadSeeded {
            model: tricky_name(rng),
            seed: tricky_u64(rng),
            mapping: tricky_mapping_spec(rng),
        };
        let mut bytes = wire::encode_request(&req);
        if bytes.is_empty() {
            return;
        }
        let at = rng.below(bytes.len());
        bytes[at] = (rng.next_u64() & 0xFF) as u8;
        let _ = wire::decode_request(&bytes); // must not panic
    });
}

#[test]
fn oversized_frames_reject_before_allocation() {
    // a hostile length prefix is rejected without reading the payload
    let mut header = ((wire::MAX_FRAME + 1) as u32).to_be_bytes().to_vec();
    header.extend_from_slice(b"ignored");
    let mut r = std::io::Cursor::new(header);
    let err = wire::read_frame(&mut r).unwrap_err().to_string();
    assert!(err.contains("exceeds"), "{err}");
}

#[test]
fn wire_json_matches_manifest_and_script_consumers() {
    // the ModelDesc JSON `domino models --json` emits decodes with the
    // same field extractors the protocol uses
    let desc = ModelDesc {
        name: "tiny-cnn".to_string(),
        id: 7,
        version: 2,
        input_len: 768,
        classes: 10,
        layers: 10,
        params: 12345,
        macs: 678901,
        mapping: Some(MappingDesc {
            pooling: "block-reuse".to_string(),
            placement: "serpentine".to_string(),
            mesh_cols: 16,
            chip_aligned: false,
            sync_chips: None,
            tiles: 22,
            chips: 1,
            worst_link_permille: 523,
            images_per_s: 40000,
            pj_per_image: 123456,
        }),
    };
    let text = wire::encode(&wire::desc_to_json(&desc));
    let v = wire::decode(&text).unwrap();
    assert_eq!(wire::str_field(&v, "name").unwrap(), "tiny-cnn");
    assert_eq!(wire::u64_field(&v, "version").unwrap(), 2);
    assert_eq!(wire::u64_field(&v, "macs").unwrap(), 678901);
    assert_eq!(wire::opt_u64_field(&v, "not-there").unwrap(), None);
    let m = v.get("mapping").expect("mapping object present");
    assert_eq!(wire::str_field(m, "placement").unwrap(), "serpentine");
    assert_eq!(wire::u64_field(m, "tiles").unwrap(), 22);
}

// ---------------------------------------------------------------------------
// Protocol v2 back-compat: these run against the real nonblocking
// `NetServer`, fronted by a stub dispatcher whose `Stats` calls park
// on a latch — so "a request is still in flight" is a deterministic
// state, not a sleep race.

mod v2 {
    use super::*;
    use domino::serve::client::Client;
    use domino::serve::net::NetServer;
    use domino::serve::Dispatcher;
    use std::net::{TcpListener, TcpStream};
    use std::sync::{Condvar, Mutex};
    use std::time::Duration;

    struct LatchDispatcher {
        blocked: Mutex<bool>,
        cv: Condvar,
    }

    impl LatchDispatcher {
        fn new(blocked: bool) -> Self {
            Self {
                blocked: Mutex::new(blocked),
                cv: Condvar::new(),
            }
        }

        fn release(&self) {
            *self.blocked.lock().unwrap() = false;
            self.cv.notify_all();
        }
    }

    impl Dispatcher for LatchDispatcher {
        fn dispatch(&self, req: Request) -> Response {
            match req {
                // the deterministic "slow" op: parks until release()
                // (bounded so a test bug can't hang the suite)
                Request::Stats => {
                    let mut b = self.blocked.lock().unwrap();
                    while *b {
                        let (g, t) = self
                            .cv
                            .wait_timeout(b, Duration::from_secs(30))
                            .unwrap();
                        b = g;
                        if t.timed_out() {
                            break;
                        }
                    }
                    Response::Stats(StatsReply {
                        served: 1,
                        rejected: 0,
                        failed: 0,
                        conns_refused: 0,
                        trace_rejected: 0,
                        models: vec![],
                    })
                }
                Request::ListModels => Response::Models(vec![]),
                other => Response::Error {
                    message: format!("stub does not serve {other:?}"),
                },
            }
        }
    }

    fn read_tagged(s: &mut TcpStream) -> (Response, Option<u64>) {
        let frame = wire::read_frame(s)
            .expect("read frame")
            .expect("connection open");
        wire::decode_response_tagged(&frame).expect("decode response")
    }

    #[test]
    fn v1_untagged_requests_are_answered_in_order_even_when_the_first_is_slow() {
        let d = Arc::new(LatchDispatcher::new(true));
        let net = NetServer::bind("127.0.0.1:0", Arc::clone(&d)).unwrap();
        let mut s = TcpStream::connect(net.local_addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

        // two plain v1 frames back-to-back, no rids anywhere: the slow
        // Stats first, the instant ListModels second. The endpoint must
        // hold the finished ListModels reply until Stats completes.
        wire::write_frame(&mut s, &wire::encode_request(&Request::Stats)).unwrap();
        wire::write_frame(&mut s, &wire::encode_request(&Request::ListModels)).unwrap();
        let unlatch = std::thread::spawn({
            let d = Arc::clone(&d);
            move || {
                std::thread::sleep(Duration::from_millis(150));
                d.release();
            }
        });

        let (first, rid) = read_tagged(&mut s);
        assert_eq!(rid, None, "v1 requests get untagged responses");
        assert!(matches!(first, Response::Stats(_)), "got {first:?}");
        let (second, rid) = read_tagged(&mut s);
        assert_eq!(rid, None);
        assert!(matches!(second, Response::Models(_)), "got {second:?}");

        unlatch.join().unwrap();
        drop(s);
        net.shutdown().unwrap();
    }

    #[test]
    fn duplicate_rids_get_typed_errors_and_fresh_rids_complete_out_of_order() {
        let d = Arc::new(LatchDispatcher::new(true));
        let net = NetServer::bind("127.0.0.1:0", Arc::clone(&d)).unwrap();
        let mut s = TcpStream::connect(net.local_addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

        // rid 7: parks in the dispatcher. rid 7 again while in flight:
        // a typed error tagged 7, and the duplicate is NOT dispatched.
        wire::write_frame(
            &mut s,
            &wire::encode_request_tagged(&Request::Stats, Some(7)),
        )
        .unwrap();
        wire::write_frame(
            &mut s,
            &wire::encode_request_tagged(&Request::ListModels, Some(7)),
        )
        .unwrap();
        let (resp, rid) = read_tagged(&mut s);
        assert_eq!(rid, Some(7));
        match resp {
            Response::Error { message } => assert!(
                message.contains("already in flight"),
                "unexpected error: {message}"
            ),
            other => panic!("expected a typed error for the duplicate, got {other:?}"),
        }

        // rid 9 completes and is delivered while rid 7 is still parked:
        // out-of-order completion, no desync.
        wire::write_frame(
            &mut s,
            &wire::encode_request_tagged(&Request::ListModels, Some(9)),
        )
        .unwrap();
        let (resp, rid) = read_tagged(&mut s);
        assert_eq!(rid, Some(9));
        assert!(matches!(resp, Response::Models(_)), "got {resp:?}");

        // release the latch: rid 7 finally answers, correctly tagged
        d.release();
        let (resp, rid) = read_tagged(&mut s);
        assert_eq!(rid, Some(7));
        assert!(matches!(resp, Response::Stats(_)), "got {resp:?}");

        // the connection is still perfectly usable for v1 traffic
        wire::write_frame(&mut s, &wire::encode_request(&Request::ListModels)).unwrap();
        let (resp, rid) = read_tagged(&mut s);
        assert_eq!(rid, None);
        assert!(matches!(resp, Response::Models(_)));

        drop(s);
        net.shutdown().unwrap();
    }

    #[test]
    fn pipelined_client_rejects_unknown_rids_and_poisons_on_desync() {
        // a hand-rolled server that answers with a rid the client
        // never issued — the client must refuse to guess
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let _req = wire::read_frame(&mut s).unwrap().unwrap();
            wire::write_frame(
                &mut s,
                &wire::encode_response_tagged(&Response::Models(vec![]), Some(999)),
            )
            .unwrap();
            // hold the socket open until the client is done failing
            let _ = wire::read_frame(&mut s);
        });

        let mut c = Client::connect(&addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let rid = c.submit(&Request::ListModels).unwrap();

        // awaiting an id that was never submitted: typed error, no
        // poison, nothing read off the wire
        let err = c.await_response(rid + 100).unwrap_err().to_string();
        assert!(err.contains("not outstanding"), "{err}");
        assert!(!c.is_poisoned());

        // the server's answer carries an unknown rid: desync → poison
        let err = c.await_response(rid).unwrap_err().to_string();
        assert!(err.contains("desynchronized"), "{err}");
        assert!(c.is_poisoned());

        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn tagged_roundtrips_and_untagged_encoding_is_v1() {
        for_all("tagged_roundtrip", 200, |rng| {
            let req = Request::LoadSeeded {
                model: tricky_name(rng),
                seed: tricky_u64(rng),
                mapping: tricky_mapping_spec(rng),
            };
            let rid = tricky_u64(rng);
            let bytes = wire::encode_request_tagged(&req, Some(rid));
            let (back, got) = wire::decode_request_tagged(&bytes).unwrap();
            assert_eq!(back, req);
            assert_eq!(got, Some(rid));
            // untagged == the exact v1 bytes, and the v2 decoder reads
            // v1 bytes as rid-less
            let v1 = wire::encode_request(&req);
            assert_eq!(wire::encode_request_tagged(&req, None), v1);
            let (back, got) = wire::decode_request_tagged(&v1).unwrap();
            assert_eq!(back, req);
            assert_eq!(got, None);
        });
    }

    #[test]
    fn corrupted_tagged_frames_never_panic() {
        for_all("tagged_corruption", 300, |rng| {
            let req = Request::Trace {
                model: tricky_name(rng),
                image_seed: tricky_u64(rng),
                window: tricky_u64(rng),
            };
            let rid = if rng.chance(0.5) {
                Some(tricky_u64(rng))
            } else {
                None
            };
            let mut bytes = wire::encode_request_tagged(&req, rid);
            let at = rng.below(bytes.len());
            bytes[at] = (rng.next_u64() & 0xFF) as u8;
            let _ = wire::decode_request_tagged(&bytes); // must not panic
            let _ = wire::frame_in_buffer(&bytes); // nor the frame scanner

            let resp = Response::Error {
                message: tricky_name(rng),
            };
            let mut rbytes = wire::encode_response_tagged(&resp, rid);
            let at = rng.below(rbytes.len());
            rbytes[at] = (rng.next_u64() & 0xFF) as u8;
            let _ = wire::decode_response_tagged(&rbytes); // must not panic
        });
    }
}
