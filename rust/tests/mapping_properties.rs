//! Mapping-invariance properties: the mapping plane may move tiles,
//! replicate arrays and reshape the mesh, but it must never change the
//! *math*. Every placement strategy × pooling scheme (× chip
//! alignment) over the small-geometry sweep must produce
//! refcompute-bit-exact outputs, and the simulated pipeline report
//! must equal the analytic `perfmodel` at every mapping. On top, every
//! explorer-ranked candidate must simulate correctly end-to-end.

use domino::coordinator::explore::{self, ExploreBounds, Objective};
use domino::coordinator::{ArchConfig, Compiler, Placement, PoolingScheme};
use domino::model::refcompute::{forward, Tensor, Weights};
use domino::model::{Network, NetworkBuilder, Projection, TensorShape};
use domino::perfmodel;
use domino::sim::Simulator;
use domino::testutil::Rng;

/// The sweep: every stage kind the compiler can map — conv geometries,
/// both pooling flavors (fused and standalone), multi-block channel
/// splits with FC, residuals with and without projection.
fn sweep_nets() -> Vec<(Network, ArchConfig)> {
    let mut nets = Vec::new();
    for (k, stride, padding) in [(1usize, 1usize, 0usize), (3, 1, 1), (3, 2, 1)] {
        let net = NetworkBuilder::new("map-conv", TensorShape::new(2, 6, 6))
            .conv(4, k, stride, padding)
            .build();
        nets.push((net, ArchConfig::default()));
    }
    nets.push((
        NetworkBuilder::new("map-maxpool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .max_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("map-avgpool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .avg_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("map-blocks", TensorShape::new(6, 5, 5))
            .conv(7, 3, 1, 1)
            .max_pool(2, 2)
            .flatten()
            .fc(9)
            .fc_logits(5)
            .build(),
        ArchConfig::tiny(4),
    ));
    nets.push((
        NetworkBuilder::new("map-res-proj", TensorShape::new(4, 8, 8))
            .conv(4, 3, 1, 1)
            .conv(8, 3, 2, 1)
            .conv_linear(8, 3, 1, 1)
            .res_add_proj(
                0,
                Projection {
                    out_ch: 8,
                    stride: 2,
                },
            )
            .build(),
        ArchConfig::default(),
    ));
    nets
}

/// Every placement × pooling (× alignment) maps to a program whose
/// simulated outputs are bit-exact with the int8 reference, whose
/// batch pipeline report equals the analytic model, and whose MAC
/// count is mapping-invariant.
#[test]
fn every_placement_and_pooling_is_bit_exact_and_matches_perfmodel() {
    for (net, base) in sweep_nets() {
        let weights = Weights::random(&net, 0x5EED).unwrap();
        let mut rng = Rng::new(0xABCD);
        let inputs: Vec<Vec<i8>> = (0..4)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();
        // the oracle is mapping-independent by construction
        let expect: Vec<Vec<i8>> = inputs
            .iter()
            .map(|x| {
                forward(&net, &weights, &Tensor::new(net.input, x.clone()))
                    .unwrap()
                    .data
            })
            .collect();
        for placement in Placement::ALL {
            for pooling in PoolingScheme::ALL {
                for aligned in [false, true] {
                    let mut arch = base;
                    arch.placement = placement;
                    arch.pooling = pooling;
                    arch.chip_aligned_chains = aligned;
                    let program = Compiler::new(arch)
                        .compile_with_weights(&net, &weights)
                        .unwrap();
                    let ctx = format!(
                        "{} {}/{}/aligned={aligned}",
                        net.name,
                        placement.name(),
                        pooling.name()
                    );
                    let mut sim = Simulator::new(&program);
                    // run_batch internally errors if its measured
                    // pipeline report disagrees with perfmodel
                    let batch = sim.run_batch_threads(&inputs, 2).unwrap();
                    for (out, want) in batch.outputs.iter().zip(&expect) {
                        assert_eq!(&out.scores, want, "{ctx}: scores diverged");
                    }
                    let est = perfmodel::estimate(&program).unwrap();
                    assert_eq!(
                        batch.pipeline.steady_period_cycles, est.period_cycles,
                        "{ctx}: pipeline report != perfmodel"
                    );
                    assert_eq!(
                        sim.stats().pe_macs,
                        4 * est.counters.pe_macs,
                        "{ctx}: per-image MACs are mapping-dependent"
                    );
                }
            }
        }
    }
}

/// Every candidate the explorer ranks must be a *runnable* mapping:
/// compile with weights, simulate, and match the reference bit-for-bit
/// — and the explorer's analytic tile/chip counts must match the real
/// compile.
#[test]
fn explorer_ranked_candidates_all_simulate_end_to_end() {
    let net = NetworkBuilder::new("map-explore", TensorShape::new(2, 6, 6))
        .conv(4, 3, 1, 1)
        .max_pool(2, 2)
        .flatten()
        .fc_logits(5)
        .build();
    let base = ArchConfig::default();
    let cands = explore::explore(&net, &base, &ExploreBounds::default(), Objective::Latency)
        .unwrap();
    assert!(!cands.is_empty(), "explorer produced no candidates");
    assert!(cands[0].feasible, "the winner must be feasible");

    let weights = Weights::random(&net, 7).unwrap();
    let img = Rng::new(3).i8_vec(net.input_len(), 31);
    let want = forward(&net, &weights, &Tensor::new(net.input, img.clone()))
        .unwrap()
        .data;
    for c in &cands {
        let program = Compiler::new(c.arch)
            .compile_with_weights(&net, &weights)
            .unwrap();
        assert_eq!(program.total_tiles, c.tiles, "{:?}: tile count", c.choice);
        assert_eq!(program.chips, c.chips, "{:?}: chip count", c.choice);
        let mut sim = Simulator::new(&program);
        let out = sim.run_image(&img).unwrap();
        assert_eq!(out.scores, want, "{:?}: candidate diverged", c.choice);
        // the analytic scores the ranking used must match this program
        let est = perfmodel::estimate(&program).unwrap();
        assert_eq!(est.latency_cycles, c.latency_cycles, "{:?}", c.choice);
        assert_eq!(est.period_cycles, c.period_cycles, "{:?}", c.choice);
    }

    // rankings are monotone in the objective among feasible candidates
    for w in cands.windows(2) {
        if w[0].feasible && w[1].feasible {
            assert!(w[0].latency_cycles <= w[1].latency_cycles);
        }
    }
}

/// The plan IR is the single source of truth for placement: a
/// default-config compile is bit-identical whether driven through
/// `compile` or through an explicit plan + materialize.
#[test]
fn explicit_plan_then_materialize_equals_compile() {
    let net = NetworkBuilder::new("map-phase", TensorShape::new(3, 8, 8))
        .conv(4, 3, 1, 1)
        .max_pool(2, 2)
        .flatten()
        .fc_logits(5)
        .build();
    let weights = Weights::random(&net, 0xC0FFEE).unwrap();
    let compiler = Compiler::default();
    let direct = compiler.compile_with_weights(&net, &weights).unwrap();
    let plan = compiler.plan(&net).unwrap();
    let staged = compiler.materialize(&net, &weights, &plan).unwrap();
    assert_eq!(direct.total_tiles, staged.total_tiles);
    assert_eq!(direct.chips, staged.chips);
    let img = Rng::new(9).i8_vec(net.input_len(), 31);
    let a = Simulator::new(&direct).run_image(&img).unwrap();
    let b = Simulator::new(&staged).run_image(&img).unwrap();
    assert_eq!(a.scores, b.scores);
    assert_eq!(a.latency_cycles, b.latency_cycles);
}
