//! Integration: AOT artifacts (JAX/Pallas → HLO text) vs the Rust
//! reference vs the cycle simulator. These tests skip gracefully when
//! `make artifacts` has not been run.

use domino::coordinator::Compiler;
use domino::model::refcompute::{forward, Tensor, Weights};
use domino::model::zoo;
use domino::runtime::{artifact, artifacts_available, golden, I8Input, Runtime};
use domino::sim::Simulator;
use domino::testutil::Rng;

fn rt_or_skip() -> Option<Runtime> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::cpu().expect("PJRT CPU client"))
}

#[test]
fn golden_hlo_matches_reference_on_many_images() {
    let Some(rt) = rt_or_skip() else { return };
    let n = golden::check_golden_vs_reference(&rt, 8, 2024).unwrap();
    assert_eq!(n, 8);
}

#[test]
fn golden_hlo_matches_cycle_simulator() {
    let Some(rt) = rt_or_skip() else { return };
    let net = zoo::tiny_cnn();
    let compiler = Compiler::default();
    let weights = Weights::random(&net, compiler.weight_seed).unwrap();
    let program = compiler.compile_with_weights(&net, &weights).unwrap();
    let g = golden::GoldenTiny::load(&rt).unwrap();
    let mut rng = Rng::new(5);
    let mut sim = Simulator::new(&program);
    for _ in 0..4 {
        let x = rng.i8_vec(net.input_len(), 31);
        let hlo = g.run(&x, &weights).unwrap();
        let simv = sim.run_image(&x).unwrap();
        assert_eq!(hlo, simv.scores, "HLO vs cycle simulator");
    }
}

#[test]
fn cim_mvm_artifact_matches_reference() {
    let Some(rt) = rt_or_skip() else { return };
    let exe = rt.load(artifact::CIM_MVM).unwrap();
    let mut rng = Rng::new(11);
    let x = rng.i8_vec(256, 15);
    let w = rng.i8_vec(256 * 256, 15);
    let out = exe
        .run_i8(&[
            I8Input { data: &x, dims: &[1, 256] },
            I8Input { data: &w, dims: &[256, 256] },
        ])
        .unwrap();
    // reference: requant(x @ w, shift 7, relu)
    let want: Vec<i8> = (0..256)
        .map(|o| {
            let acc: i32 = (0..256)
                .map(|i| x[i] as i32 * w[i * 256 + o] as i32)
                .sum();
            domino::model::refcompute::requant(acc, 7, true)
        })
        .collect();
    assert_eq!(out[0], want, "cim_mvm_256 artifact");
}

#[test]
fn com_conv_artifact_matches_reference() {
    let Some(rt) = rt_or_skip() else { return };
    let exe = rt.load(artifact::COM_CONV).unwrap();
    let mut rng = Rng::new(12);
    let x = rng.i8_vec(16 * 16 * 16, 15);
    // artifact weight layout: [K,K,C,M] (kkcm)
    let w_kkcm = rng.i8_vec(3 * 3 * 16 * 32, 15);
    let out = exe
        .run_i8(&[
            I8Input { data: &x, dims: &[16, 16, 16] },
            I8Input { data: &w_kkcm, dims: &[3, 3, 16, 32] },
        ])
        .unwrap();
    // reference via refcompute conv2d, converting layout to [M,C,K,K]
    let mut w_mckk = vec![0i8; w_kkcm.len()];
    for kr in 0..3 {
        for kc in 0..3 {
            for c in 0..16 {
                for m in 0..32 {
                    w_mckk[((m * 16 + c) * 3 + kr) * 3 + kc] =
                        w_kkcm[((kr * 3 + kc) * 16 + c) * 32 + m];
                }
            }
        }
    }
    let input = Tensor::new(domino::model::TensorShape::new(16, 16, 16), x);
    let want = domino::model::refcompute::conv2d(&input, &w_mckk, 32, 3, 1, 1, 7, true);
    assert_eq!(out[0], want.data, "com_conv_k3 artifact");
}

#[test]
fn trained_artifact_end_to_end_accuracy() {
    let Some(rt) = rt_or_skip() else { return };
    let dir = domino::runtime::artifacts_dir();
    let hlo = golden::TrainedTiny::load(&rt).unwrap();
    let ts = domino::eval::accuracy::TestSet::load(
        &dir.join(artifact::TESTSET_BIN),
    )
    .unwrap();
    let tw = domino::eval::accuracy::TrainedWeights::load(
        &dir.join(artifact::WEIGHTS_BIN),
    )
    .unwrap();
    let net = domino::eval::accuracy::tiny_cnn_with_shifts(tw.shifts());
    let weights = tw.as_weights();
    // HLO vs rust reference, trained weights, 16 images
    for i in 0..16 {
        let got = hlo.run(&ts.images[i]).unwrap();
        let want = forward(
            &net,
            &weights,
            &Tensor::new(net.input, ts.images[i].clone()),
        )
        .unwrap();
        assert_eq!(got, want.data, "image {i}");
    }
}
