//! Flight-recorder properties at the engine level, over a sweep of
//! small geometries covering every stage kind:
//!
//! * **transparency** — scores, latency and [`Counters`] are identical
//!   probe-on vs probe-off (the probe observes; it never perturbs);
//! * **determinism** — the same program + seed produces byte-identical
//!   event streams from independent simulators;
//! * **bounded memory** — the ring never outgrows its capacity; under
//!   pressure it keeps the newest events and counts the evictions;
//! * **analysis** — timelines/heatmap cross-check against the engine's
//!   own link counters, and the stepper replays the stream exactly.

use domino::coordinator::{ArchConfig, Compiler};
use domino::model::{Network, NetworkBuilder, Projection, TensorShape};
use domino::sim::flight::{
    diff, Breakpoint, EventKind, LinkHeatmap, RecorderConfig, StageTimelines, Stepper,
};
use domino::sim::Simulator;
use domino::testutil::Rng;

/// Small geometries covering conv (strides/padding), fused pooling,
/// multi-block channels + fc, and residuals with projection.
fn sweep_nets() -> Vec<(Network, ArchConfig)> {
    let mut nets = Vec::new();
    for (k, stride, padding) in [(1usize, 1usize, 0usize), (3, 1, 1), (3, 2, 1)] {
        let net = NetworkBuilder::new("flight-conv", TensorShape::new(2, 6, 6))
            .conv(4, k, stride, padding)
            .build();
        nets.push((net, ArchConfig::default()));
    }
    nets.push((
        NetworkBuilder::new("flight-pool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .max_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("flight-blocks", TensorShape::new(6, 5, 5))
            .conv(7, 3, 1, 1)
            .flatten()
            .fc(9)
            .fc_logits(5)
            .build(),
        ArchConfig::tiny(4),
    ));
    nets.push((
        NetworkBuilder::new("flight-res", TensorShape::new(4, 8, 8))
            .conv(4, 3, 1, 1)
            .conv(8, 3, 2, 1)
            .conv_linear(8, 3, 1, 1)
            .res_add_proj(
                0,
                Projection {
                    out_ch: 8,
                    stride: 2,
                },
            )
            .build(),
        ArchConfig::default(),
    ));
    nets
}

#[test]
fn probe_is_transparent_to_scores_and_counters() {
    for (net, arch) in sweep_nets() {
        let program = Compiler::new(arch).compile(&net).unwrap();
        let mut rng = Rng::new(0xF117);
        let img = rng.i8_vec(net.input_len(), 31);

        let mut plain = Simulator::new(&program);
        let want = plain.run_image(&img).unwrap();
        let mut probed = Simulator::with_recorder(&program, RecorderConfig::default());
        let got = probed.run_image(&img).unwrap();

        assert_eq!(got.scores, want.scores, "{}: scores", net.name);
        assert_eq!(
            got.latency_cycles, want.latency_cycles,
            "{}: latency",
            net.name
        );
        assert_eq!(
            probed.stats(),
            plain.stats(),
            "{}: counters must not depend on the probe",
            net.name
        );
        assert_eq!(
            probed.stage_stats(),
            plain.stage_stats(),
            "{}: per-stage counters",
            net.name
        );
        let rec = probed.recording();
        assert!(!rec.events.is_empty(), "{}: nothing recorded", net.name);
        assert_eq!(rec.dropped, 0, "{}: default ring must not evict here", net.name);
        assert_eq!(
            rec.stage_count(),
            program.stages.len(),
            "{}: every stage must appear in the stream",
            net.name
        );
    }
}

#[test]
fn recordings_are_deterministic_byte_for_byte() {
    for (net, arch) in sweep_nets() {
        let program = Compiler::new(arch).compile(&net).unwrap();
        let run = || {
            let mut sim = Simulator::with_recorder(&program, RecorderConfig::default());
            let mut rng = Rng::new(7);
            sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
            sim.recording()
        };
        let (a, b) = (run(), run());
        assert!(
            diff(&a, &b).identical(),
            "{}: independent runs diverged:\n{}",
            net.name,
            diff(&a, &b).render()
        );
        assert_eq!(
            a.to_bytes(),
            b.to_bytes(),
            "{}: byte encodings differ",
            net.name
        );
    }
}

#[test]
fn ring_is_bounded_and_keeps_the_newest_events() {
    let net = NetworkBuilder::new("flight-ring", TensorShape::new(3, 8, 8))
        .conv(6, 3, 1, 1)
        .max_pool(2, 2)
        .flatten()
        .fc_logits(4)
        .build();
    let program = Compiler::default().compile(&net).unwrap();
    let images = 3usize;

    let run = |cap: Option<usize>| {
        let cfg = match cap {
            Some(c) => RecorderConfig::with_capacity(c),
            None => RecorderConfig::default(),
        };
        let mut sim = Simulator::with_recorder(&program, cfg);
        let mut rng = Rng::new(3);
        for _ in 0..images {
            sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
        }
        sim.recording()
    };
    let full = run(None);
    assert!(full.dropped == 0 && full.events.len() > 256, "need pressure");

    // the regression this guards: instrumented runs used to buffer one
    // Vec entry per action, unbounded — memory grew with every image.
    // The ring caps retained events at the configured capacity no
    // matter how long the run gets, and accounts for every eviction.
    let cap = 64usize;
    let small = run(Some(cap));
    assert!(small.events.len() <= cap, "ring outgrew its capacity");
    assert!(small.dropped > 0, "pressure must evict");
    assert_eq!(
        small.events.len() as u64 + small.dropped,
        full.events.len() as u64,
        "every event is either retained or counted as dropped"
    );
    // eviction is oldest-first: the retained window is exactly the
    // tail of the unbounded stream
    assert_eq!(
        small.events[..],
        full.events[full.events.len() - small.events.len()..],
        "ring must keep the newest events"
    );
}

#[test]
fn timelines_and_heatmap_cross_check_the_link_counters() {
    for (net, arch) in sweep_nets() {
        let program = Compiler::new(arch).compile(&net).unwrap();
        let mut sim = Simulator::with_recorder(&program, RecorderConfig::default());
        let mut rng = Rng::new(0x11);
        sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
        let rec = sim.recording();

        // every link event in the stream carries the bits the engine
        // charged its counters with — summed over all stages the two
        // planes must agree exactly
        let (mut on, mut inter) = (0u64, 0u64);
        for e in &rec.events {
            if e.kind == EventKind::LinkTx {
                if e.b == 1 {
                    inter += e.a as u64;
                } else {
                    on += e.a as u64;
                }
            }
        }
        assert_eq!(
            on,
            sim.stats().onchip_link_bits,
            "{}: on-chip link bits",
            net.name
        );
        assert_eq!(
            inter,
            sim.stats().interchip_bits,
            "{}: inter-chip link bits",
            net.name
        );

        // stage timelines partition the same totals per stage
        let per_stage: u64 = (0..rec.stage_count())
            .map(|s| StageTimelines::build(&rec, s).total_link_bits())
            .sum();
        assert!(per_stage <= on + inter, "{}: timelines overcount", net.name);

        // the busiest stage renders a non-empty heatmap whose cells sum
        // to that stage's tile-scoped link bits
        let busiest = LinkHeatmap::busiest_stage(&rec)
            .unwrap_or_else(|| panic!("{}: no link events", net.name));
        let h = LinkHeatmap::build(&rec, busiest, 16).unwrap();
        let cells: u64 = (0..h.tiles)
            .flat_map(|t| (0..h.buckets).map(move |b| (t, b)))
            .map(|(t, b)| h.cell_bits(t, b))
            .sum();
        assert_eq!(cells, h.total_bits, "{}: heatmap loses bits", net.name);
        let rendered = h.render();
        assert!(rendered.contains("link utilization"), "{}", net.name);
        assert!(rendered.lines().count() == h.tiles + 2, "{}", net.name);
    }
}

#[test]
fn stepper_replays_the_stream_exactly() {
    let net = NetworkBuilder::new("flight-step", TensorShape::new(2, 6, 6))
        .conv(4, 3, 1, 1)
        .build();
    let program = Compiler::default().compile(&net).unwrap();
    let mut sim = Simulator::with_recorder(&program, RecorderConfig::default());
    let mut rng = Rng::new(5);
    sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
    let rec = sim.recording();

    // run to the first group-sum push at a row head, from any tile
    let mut stepper = Stepper::new(rec.clone());
    stepper.add_breakpoint(Breakpoint::parse("*,*,push").unwrap());
    let (i, e) = stepper.run_to_break().expect("conv chain has row heads");
    assert_eq!(e.kind, EventKind::Push);
    assert_eq!(rec.events[i], e, "breakpoint returns the stream's event");
    assert_eq!(stepper.pos(), i + 1, "the hit event is consumed");
    assert!(stepper.state().count(EventKind::Push) == 1);

    // a (tile, cycle) breakpoint in cycle units: the first event at
    // tile 0 within the first slot window
    let mut bp = Stepper::new(rec.clone());
    bp.add_breakpoint(Breakpoint::parse("0,0").unwrap());
    let hit = bp.run_to_break().expect("tile 0 acts in slot 0");
    assert_eq!(hit.1.ci, 0);
    assert_eq!(hit.1.slot, 0);

    // stepping to the end applies every event exactly once: the
    // derived per-kind totals equal the stream's own population
    while stepper.step().is_some() {}
    assert!(stepper.done());
    for k in EventKind::ALL {
        let want = rec.events.iter().filter(|e| e.kind == k).count() as u64;
        assert_eq!(
            stepper.state().count(k),
            want,
            "stepper count for {:?}",
            k
        );
    }
    // a breakpoint that never hits is a clean end-of-stream, not an
    // error (the CLI exits 0 on it)
    let mut never = Stepper::new(rec);
    never.add_breakpoint(Breakpoint::parse("60000,*").unwrap());
    assert!(never.run_to_break().is_none());
    assert!(never.done());
}
