//! Bit-exactness properties of the blocked compute kernels (§Perf):
//! the lane-blocked MVM paths (`Pe::mvm_into` packed and borrowed,
//! `Pe::mvm_many_into`) and the vectorized rofm datapaths must be
//! byte-identical — outputs *and* charged [`Counters`] — to the scalar
//! reference kernels, across widths exercising every remainder-lane
//! case (1, LANE−1, LANE, LANE+1, large) and the i8 extremes
//! (including −128, whose products hit the largest magnitudes the
//! datapath can see). A full-engine leg re-runs the small-geometry
//! sweep so the conv micro-batch path is pinned through every
//! geometry: stride, padding, 1x1 kernels, channel blocks, fused
//! pooling, residuals.
//!
//! The direct frozen-scalar comparison (and the ≥1.5x speedup gate)
//! runs on every `cargo bench --bench bench_kernels`.

use domino::model::refcompute::{forward_all, requant, res_add, Tensor, Weights};
use domino::model::{Network, NetworkBuilder, Projection, TensorShape};
use domino::sim::{CaptureMode, Counters, Simulator};
use domino::testutil::{for_all, Rng};
use domino::tile::pe::{LANE, MICRO_BATCH};
use domino::tile::rofm::Rofm;
use domino::tile::Pe;

/// Widths that exercise every remainder-lane case of a LANE-blocked
/// kernel: below one lane, one short of a full lane, exactly full,
/// one over (scalar remainder of 1), and several lanes plus a tail.
fn lane_edge_widths() -> [usize; 6] {
    [1, LANE - 1, LANE, LANE + 1, 2 * LANE + 5, 100]
}

/// An i8 drawn to stress the kernels: zeros (the skip paths), the
/// extremes −128/127 (largest-magnitude products), and the full range.
fn stress_i8(rng: &mut Rng) -> i8 {
    if rng.chance(0.25) {
        0
    } else if rng.chance(0.1) {
        i8::MIN
    } else if rng.chance(0.1) {
        i8::MAX
    } else {
        rng.i8()
    }
}

fn stress_vec(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len).map(|_| stress_i8(rng)).collect()
}

#[test]
fn blocked_mvm_paths_match_scalar_reference_across_remainder_widths() {
    for_all("blocked mvm == scalar reference", 60, |rng| {
        let rows_choices = [1usize, 3, 4, 5, LANE, 37, 256];
        let rows = rows_choices[rng.below(rows_choices.len())];
        let cols = lane_edge_widths()[rng.below(6)];
        let weights = stress_vec(rng, rows * cols);
        // x may be shorter than rows (last channel block of a layer)
        let xlen = if rng.chance(0.3) { rng.range(0, rows) } else { rows };
        let x = stress_vec(rng, xlen);

        let packed = Pe::new(weights.clone(), rows, cols);
        let borrowed = Pe::borrowed(&weights, rows, cols);
        assert!(packed.is_packed());

        let (mut st_s, mut st_p, mut st_b) =
            (Counters::default(), Counters::default(), Counters::default());
        // dirty scratch: the kernels must fully overwrite the output
        let mut want = vec![i32::MIN; cols];
        let mut got_p = vec![i32::MAX; cols];
        let mut got_b = vec![7i32; cols];
        packed.mvm_scalar_into(&x, &mut want, &mut st_s);
        packed.mvm_into(&x, &mut got_p, &mut st_p);
        borrowed.mvm_into(&x, &mut got_b, &mut st_b);
        assert_eq!(want, got_p, "packed panel path diverged ({rows}x{cols})");
        assert_eq!(want, got_b, "borrowed blocked path diverged ({rows}x{cols})");
        assert_eq!(st_s, st_p, "packed path counters diverged");
        assert_eq!(st_s, st_b, "borrowed path counters diverged");
    });
}

#[test]
fn mvm_many_matches_repeated_single_mvm() {
    for_all("mvm_many == repeated mvm", 40, |rng| {
        let rows = [3usize, LANE, 64][rng.below(3)];
        let cols = lane_edge_widths()[rng.below(6)];
        let nb = rng.range(1, MICRO_BATCH);
        let weights = stress_vec(rng, rows * cols);
        let batch: Vec<Vec<i8>> = (0..nb).map(|_| stress_vec(rng, rows)).collect();
        let xs: Vec<&[i8]> = batch.iter().map(|v| v.as_slice()).collect();

        for pe in [Pe::new(weights.clone(), rows, cols), Pe::borrowed(&weights, rows, cols)] {
            let (mut st_one, mut st_many) = (Counters::default(), Counters::default());
            let mut want = vec![0i32; nb * cols];
            for (b, x) in xs.iter().enumerate() {
                pe.mvm_scalar_into(x, &mut want[b * cols..(b + 1) * cols], &mut st_one);
            }
            let mut got = vec![i32::MIN; nb * cols];
            pe.mvm_many_into(&xs, &mut got, &mut st_many);
            assert_eq!(want, got, "micro-batch diverged ({rows}x{cols} nb={nb})");
            assert_eq!(st_one, st_many, "micro-batch counters diverged");
        }
    });
}

#[test]
fn extreme_magnitude_accumulation_is_exact() {
    // The worst case the datapath can see: 256 rows of (−128)·(−128)
    // products — 256 · 16384 = 4 194 304 per lane, far inside i32, so
    // every accumulation grouping is exact (the blocked kernels'
    // bit-exactness-by-construction argument, pinned here).
    let (rows, cols) = (256usize, LANE + 1);
    let weights = vec![i8::MIN; rows * cols];
    let x = vec![i8::MIN; rows];
    let mut st = Counters::default();
    for pe in [Pe::new(weights.clone(), rows, cols), Pe::borrowed(&weights, rows, cols)] {
        let mut out = vec![0i32; cols];
        pe.mvm_into(&x, &mut out, &mut st);
        assert!(out.iter().all(|&v| v == 256 * 16384), "extreme MVM wrong");
    }
}

#[test]
fn vectorized_rofm_datapaths_match_scalar_reference() {
    for_all("rofm _into == scalar reference", 50, |rng| {
        let len = lane_edge_widths()[rng.below(6)];
        // psums in the reachable range (±4.2M, see the MVM bound)
        let psum =
            |rng: &mut Rng| -> i32 { stress_i8(rng) as i32 * stress_i8(rng) as i32 * 256 };
        let sum: Vec<i32> = (0..len).map(|_| psum(rng)).collect();
        let inc: Vec<i32> = (0..len).map(|_| psum(rng)).collect();
        let shift = [0u32, 4, 8][rng.below(3)];

        // add_psum_slices
        let (mut st_s, mut st_v) = (Counters::default(), Counters::default());
        let mut acc_s = sum.clone();
        let mut acc_v = sum.clone();
        for (a, b) in acc_s.iter_mut().zip(inc.iter()) {
            *a += b;
        }
        st_s.adds_8b += 4 * len as u64;
        Rofm::add_psum_slices(&mut acc_v, &inc, &mut st_v);
        assert_eq!(acc_s, acc_v, "add_psum_slices diverged (len={len})");

        // act_into / quantize_into (requant with and without ReLU)
        let mut v_s: Vec<i8> = Vec::new();
        let mut v_v: Vec<i8> = vec![99; 7]; // dirty scratch
        for relu in [true, false] {
            v_s.clear();
            v_s.extend(sum.iter().map(|&v| requant(v, shift, relu)));
            st_s.act_ops_8b += len as u64;
            if relu {
                Rofm::act_into(&sum, shift, &mut v_v, &mut st_v);
            } else {
                Rofm::quantize_into(&sum, shift, &mut v_v, &mut st_v);
            }
            assert_eq!(v_s, v_v, "requant diverged (len={len} relu={relu})");
        }

        // res_add_into / cmp_max over i8 streams with extremes
        let main_v = stress_vec(rng, len);
        let skip_v = stress_vec(rng, len);
        v_s.clear();
        v_s.extend(main_v.iter().zip(&skip_v).map(|(&a, &b)| res_add(a, b)));
        st_s.adds_8b += len as u64;
        st_s.act_ops_8b += len as u64;
        Rofm::res_add_into(&main_v, &skip_v, &mut v_v, &mut st_v);
        assert_eq!(v_s, v_v, "res_add_into diverged (len={len})");

        let mut mx_s = main_v.clone();
        let mut mx_v = main_v.clone();
        for (a, &b) in mx_s.iter_mut().zip(&skip_v) {
            *a = (*a).max(b);
        }
        st_s.pool_ops_8b += len as u64;
        Rofm::cmp_max(&mut mx_v, &skip_v, &mut st_v);
        assert_eq!(mx_s, mx_v, "cmp_max diverged (len={len})");

        // every counter the datapaths charge, charged identically
        assert_eq!(st_s, st_v, "rofm datapath counters diverged (len={len})");
    });
}

/// The small-geometry sweep (mirrors `capture_properties.rs`): every
/// conv shape the micro-batch refill must handle — strides, padding,
/// 1x1 kernels, channel/filter blocks, fused pooling, residuals.
fn sweep_nets() -> Vec<(Network, domino::coordinator::ArchConfig)> {
    use domino::coordinator::ArchConfig;
    let mut nets = Vec::new();
    for (k, stride, padding) in [(1usize, 1usize, 0usize), (3, 1, 1), (3, 2, 1), (3, 1, 0)] {
        let net = NetworkBuilder::new("kp-conv", TensorShape::new(2, 6, 6))
            .conv(4, k, stride, padding)
            .build();
        nets.push((net, ArchConfig::default()));
    }
    nets.push((
        NetworkBuilder::new("kp-maxpool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .max_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("kp-avgpool", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .avg_pool(2, 2)
            .build(),
        ArchConfig::default(),
    ));
    nets.push((
        NetworkBuilder::new("kp-blocks", TensorShape::new(6, 5, 5))
            .conv(7, 3, 1, 1)
            .flatten()
            .fc(9)
            .fc_logits(5)
            .build(),
        domino::coordinator::ArchConfig::tiny(4),
    ));
    nets.push((
        NetworkBuilder::new("kp-res", TensorShape::new(4, 8, 8))
            .conv(4, 3, 1, 1)
            .conv(8, 3, 2, 1)
            .conv_linear(8, 3, 1, 1)
            .res_add_proj(
                0,
                Projection {
                    out_ch: 8,
                    stride: 2,
                },
            )
            .build(),
        ArchConfig::default(),
    ));
    nets
}

#[test]
fn micro_batched_engine_matches_refcompute_over_small_geometry_sweep() {
    // Full-engine identity: the micro-batched conv path must keep the
    // engine bit-exact with refcompute over every small geometry, with
    // identical counters across capture modes and across warm reuse
    // (the micro-batch stash resets cleanly between images).
    for (net, arch) in sweep_nets() {
        let compiler = domino::coordinator::Compiler::new(arch);
        let weights = Weights::random(&net, compiler.weight_seed).unwrap();
        let program = compiler.compile_with_weights(&net, &weights).unwrap();
        let mut all = Simulator::new(&program);
        let mut fin = Simulator::with_capture(&program, CaptureMode::Final);
        let mut rng = Rng::new(0x5EED);
        for i in 0..3 {
            let input = Tensor::new(net.input, rng.i8_vec(net.input_len(), 31));
            let want = forward_all(&net, &weights, &input).unwrap();
            let a = all.run_image(&input.data).unwrap();
            let f = fin.run_image(&input.data).unwrap();
            assert_eq!(
                a.scores,
                want.last().unwrap().data,
                "{} image {i}: scores vs refcompute",
                net.name
            );
            assert_eq!(a.scores, f.scores, "{} image {i}: capture modes", net.name);
            // AllStages captures every stage tensor (each produced
            // through the blocked kernels); the final one is the score
            // vector, pinned to refcompute above
            assert_eq!(
                a.stage_outputs.len(),
                program.stages.len(),
                "{} image {i}: AllStages capture count",
                net.name
            );
            assert_eq!(
                a.stage_outputs.last().unwrap().data,
                a.scores,
                "{} image {i}: final captured stage vs scores",
                net.name
            );
            assert_eq!(a.latency_cycles, f.latency_cycles, "{}", net.name);
        }
        assert_eq!(
            all.stats(),
            fin.stats(),
            "{}: counters differ across capture modes",
            net.name
        );
        assert!(all.stats().pe_mvms > 0, "{}: no MVMs charged", net.name);
    }
}
