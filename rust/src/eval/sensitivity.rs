//! Calibration sensitivity analysis.
//!
//! EXPERIMENTS.md §Calibration fixes exactly one free constant — the
//! on-chip link energy the paper obtains from Noxim but does not
//! publish. This experiment sweeps that constant across the plausible
//! 45 nm range and shows the paper's *headlines* (Domino wins CE
//! against every counterpart; data movement is a minority) are robust
//! to it: only the exact on-chip share moves.

use anyhow::Result;

use crate::counterparts::all_comparisons;
use crate::counterparts::normalize::measure_domino;
use crate::eval::{comparison_network, compile_comparison};
use crate::energy::energy_of;
use crate::sim::stats::Counters;

/// One sweep point.
#[derive(Clone, Debug)]
pub struct SensitivityRow {
    /// Link energy (pJ/bit/hop).
    pub link_pj_per_bit: f64,
    /// min/max normalized-CE ratio over the five comparisons.
    pub ce_ratio_min: f64,
    pub ce_ratio_max: f64,
    /// min/max on-chip data power share.
    pub onchip_min: f64,
    pub onchip_max: f64,
    /// Does Domino still beat every counterpart's normalized CE?
    pub all_ce_wins: bool,
}

/// Recompute an energy breakdown with a substituted link energy by
/// re-pricing the link-bit counter delta.
fn energy_with_link(
    counters: &Counters,
    cim: &crate::energy::CimModel,
    link_j: f64,
) -> crate::energy::EnergyBreakdown {
    let mut e = energy_of(counters, cim);
    e.onchip_links = counters.onchip_link_bits as f64 * link_j;
    e
}

/// Sweep the link energy over `points` (pJ/b/hop).
pub fn sweep(points: &[f64]) -> Result<Vec<SensitivityRow>> {
    // compile + count events once per workload; re-price per point
    let mut cases = Vec::new();
    for comp in all_comparisons() {
        let net = comparison_network(&comp)?;
        let program = compile_comparison(&comp)?;
        let est = crate::perfmodel::estimate(&program)?;
        let ops = net.total_ops()?;
        cases.push((comp, est, ops));
    }

    let mut rows = Vec::with_capacity(points.len());
    for &pj in points {
        let link_j = pj * 1e-12;
        let (mut cmin, mut cmax) = (f64::MAX, f64::MIN);
        let (mut omin, mut omax) = (f64::MAX, f64::MIN);
        let mut all_wins = true;
        for (comp, est, ops) in &cases {
            let cim = comp.domino_cim_model();
            let e = energy_with_link(&est.counters, &cim, link_j);
            let ce = *ops as f64 / e.total() / 1e12;
            let ratio = ce / comp.counterpart.paper_norm_ce;
            let share = e.onchip_data() / e.total();
            cmin = cmin.min(ratio);
            cmax = cmax.max(ratio);
            omin = omin.min(share);
            omax = omax.max(share);
            all_wins &= ratio > 1.0;
        }
        // silence unused warning for measure_domino import parity
        let _ = measure_domino;
        rows.push(SensitivityRow {
            link_pj_per_bit: pj,
            ce_ratio_min: cmin,
            ce_ratio_max: cmax,
            onchip_min: omin,
            onchip_max: omax,
            all_ce_wins: all_wins,
        });
    }
    Ok(rows)
}

/// The default sweep grid (pJ/b/hop): Noxim-plausible 45 nm values.
pub const DEFAULT_GRID: [f64; 5] = [0.025, 0.05, 0.1, 0.15, 0.2];

pub fn render(rows: &[SensitivityRow]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "CALIBRATION SENSITIVITY — on-chip link energy sweep\n"
    );
    let _ = writeln!(
        s,
        "{:>14} {:>18} {:>20} {:>12}",
        "link pJ/b/hop", "CE ratio min-max", "on-chip share %", "CE wins 5/5"
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{:>14.3} {:>8.2} - {:<7.2} {:>9.1} - {:<8.1} {:>12}",
            r.link_pj_per_bit,
            r.ce_ratio_min,
            r.ce_ratio_max,
            100.0 * r.onchip_min,
            100.0 * r.onchip_max,
            if r.all_ce_wins { "yes" } else { "NO" }
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_robust_across_plausible_link_energies() {
        let rows = sweep(&DEFAULT_GRID).unwrap();
        // Domino wins CE against every counterpart at every plausible
        // link energy — the calibration choice does not create the
        // result.
        for r in &rows {
            assert!(r.all_ce_wins, "at {} pJ/b", r.link_pj_per_bit);
            assert!(r.ce_ratio_min > 1.0);
        }
        // on-chip share is monotone in the link energy
        for w in rows.windows(2) {
            assert!(w[1].onchip_max >= w[0].onchip_max);
        }
    }

    #[test]
    fn chosen_point_keeps_offchip_band() {
        let rows = sweep(&[0.05]).unwrap();
        assert!((0.05..0.50).contains(&rows[0].onchip_max));
    }
}
