//! Experiment drivers shared by the CLI, examples and benches — one
//! submodule per paper artifact (see DESIGN.md §5 experiment index).

pub mod accuracy;
pub mod breakdown;
pub mod sensitivity;
pub mod table4;

use anyhow::Result;

use crate::coordinator::{ArchConfig, Compiler};
use crate::coordinator::program::Program;
use crate::counterparts::Comparison;
use crate::model::{zoo, Network};

/// Resolve the workload network of a Table IV comparison.
pub fn comparison_network(comp: &Comparison) -> Result<Network> {
    zoo::by_name(comp.counterpart.model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {}", comp.counterpart.model))
}

/// Compile a comparison's workload at the paper's operating point
/// (240 tiles/chip, duplication water-filled to the published chip
/// count).
pub fn compile_comparison(comp: &Comparison) -> Result<Program> {
    let net = comparison_network(comp)?;
    // analysis-only: Table IV prices events, never runs the datapath
    Compiler::new(ArchConfig::table4(comp.domino.chips)).compile_analysis(&net)
}

/// Minimal JSON value extraction (no serde in this environment): finds
/// `"key": <number>` and returns the number.
pub fn json_number(text: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\"");
    let at = text.find(&pat)?;
    let rest = &text[at + pat.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == 'E' || c == '+'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_number_extracts() {
        let t = r#"{"a": 1.5, "b":-2, "nested": {"c": 3e-2}}"#;
        assert_eq!(json_number(t, "a"), Some(1.5));
        assert_eq!(json_number(t, "b"), Some(-2.0));
        assert_eq!(json_number(t, "c"), Some(0.03));
        assert_eq!(json_number(t, "missing"), None);
    }

    #[test]
    fn all_comparison_networks_resolve() {
        for comp in crate::counterparts::all_comparisons() {
            comparison_network(&comp).unwrap();
        }
    }
}
