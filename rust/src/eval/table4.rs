//! Table IV: the paper's entire quantitative evaluation, regenerated.
//!
//! For each of the five pairwise comparisons: the counterpart's
//! published + normalized column (from `counterparts`), the paper's
//! Domino column, and **our measured Domino row** (compiler → analytic
//! perfmodel → Table III energy charging, with the counterpart's CIM
//! array substituted) — so every printed line is paper-vs-reproduction.

use anyhow::Result;

use crate::counterparts::normalize::{measure_domino, DominoMeasured};
use crate::counterparts::{all_comparisons, Comparison};
use crate::eval::{comparison_network, compile_comparison};

/// One assembled Table IV column pair.
#[derive(Clone, Debug)]
pub struct Table4Entry {
    pub comparison: Comparison,
    pub measured: DominoMeasured,
    /// Our normalized-CE improvement over the counterpart.
    pub ce_ratio: f64,
    /// Our normalized-throughput improvement.
    pub tp_ratio: f64,
}

/// Compute all five comparisons (the full table).
pub fn run() -> Result<Vec<Table4Entry>> {
    all_comparisons().into_iter().map(entry).collect()
}

/// Compute one comparison.
pub fn entry(comparison: Comparison) -> Result<Table4Entry> {
    let net = comparison_network(&comparison)?;
    let program = compile_comparison(&comparison)?;
    let est = crate::perfmodel::estimate(&program)?;
    let cim = comparison.domino_cim_model();
    let measured = measure_domino(&est, &cim, net.total_ops()?);
    let ce_ratio = measured.ce_tops_w / comparison.counterpart.paper_norm_ce;
    let tp_ratio = measured.tops_mm2 / comparison.counterpart.paper_norm_tops_mm2;
    Ok(Table4Entry {
        comparison,
        measured,
        ce_ratio,
        tp_ratio,
    })
}

/// Render the table in the paper's row order (paper value in
/// parentheses after each measured value).
pub fn render(entries: &[Table4Entry]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "TABLE IV — Domino measured vs counterparts (paper's Domino row in parens)\n"
    );
    let _ = writeln!(
        s,
        "{:<18} {:>10} {:>22} {:>24} {:>26} {:>20} {:>22}",
        "workload", "vs", "cores (paper)", "exec us (paper)", "CE TOPS/W (paper|cp)", "TOPS/mm2 (paper)", "ratios CE|TP (paper)"
    );
    for e in entries {
        let cp = &e.comparison.counterpart;
        let dp = &e.comparison.domino;
        let _ = writeln!(
            s,
            "{:<18} {:>10} {:>12} ({:>7}) {:>14.1} ({:>7.1}) {:>12.2} ({:>5.2}|{:>5.2}) {:>12.3} ({:>5.2}) {:>7.2}|{:<5.2} ({:.2}|{:.2})",
            cp.model,
            cp.cite,
            e.measured.tiles,
            dp.cores_per_chip * dp.chips,
            e.measured.exec_us,
            dp.exec_us,
            e.measured.ce_tops_w,
            dp.ce_tops_w,
            cp.paper_norm_ce,
            e.measured.tops_mm2,
            dp.tops_mm2,
            e.ce_ratio,
            e.tp_ratio,
            e.comparison.paper_ce_ratio(),
            e.comparison.paper_throughput_ratio(),
        );
    }
    let ce_min = entries.iter().map(|e| e.ce_ratio).fold(f64::MAX, f64::min);
    let ce_max = entries.iter().map(|e| e.ce_ratio).fold(f64::MIN, f64::max);
    let tp_min = entries.iter().map(|e| e.tp_ratio).fold(f64::MAX, f64::min);
    let tp_max = entries.iter().map(|e| e.tp_ratio).fold(f64::MIN, f64::max);
    let _ = writeln!(
        s,
        "\nheadlines: CE {ce_min:.2}-{ce_max:.2}x (paper 1.77-2.37x), \
         throughput {tp_min:.2}-{tp_max:.2}x (paper 1.28-13.16x)"
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_table_reproduces_headline_shape() {
        let entries = run().unwrap();
        assert_eq!(entries.len(), 5);
        // Domino wins CE against every counterpart (the paper's primary
        // claim), by a factor in the paper's neighbourhood.
        for e in &entries {
            assert!(
                e.ce_ratio > 1.2,
                "{}: CE ratio {:.2}",
                e.comparison.counterpart.key,
                e.ce_ratio
            );
            assert!(e.ce_ratio < 4.0, "CE ratio implausibly high");
        }
        // Throughput: wins for the SRAM pairs and VGG-16, parity (>0.8x)
        // for the storage-dominated VGG-19 pairs (see EXPERIMENTS.md §T4).
        for e in &entries {
            assert!(
                e.tp_ratio > 0.8,
                "{}: TP ratio {:.2}",
                e.comparison.counterpart.key,
                e.tp_ratio
            );
        }
        let wins = entries.iter().filter(|e| e.tp_ratio > 1.0).count();
        assert!(wins >= 3, "throughput wins on {wins}/5 pairs");
    }

    #[test]
    fn measured_tiles_match_paper_budget() {
        for e in run().unwrap() {
            let budget = e.comparison.domino.cores_per_chip * e.comparison.domino.chips;
            assert!(e.measured.tiles <= budget);
            assert!(
                e.measured.tiles as f64 > 0.85 * budget as f64,
                "{}: {} tiles of {budget} budget unused",
                e.comparison.counterpart.key,
                e.measured.tiles
            );
        }
    }

    #[test]
    fn render_contains_all_rows() {
        let entries = run().unwrap();
        let s = render(&entries);
        assert_eq!(s.matches("vgg").count() + s.matches("resnet").count(), 5);
        assert!(s.contains("headlines"));
    }
}
