//! The accuracy experiment (Table IV's accuracy row, Section IV-A: "In
//! the accuracy simulation, only the quantization error is considered").
//!
//! Build time (python, `make artifacts`): a TinyCNN is trained in fp32
//! on a synthetic 10-class dataset, activation-calibrated, and
//! post-training-quantized to int8 with power-of-two scales; the int8
//! weights + per-layer requant shifts and a held-out test set are
//! exported as binary artifacts, and fp32/int8 accuracies recorded in
//! `accuracy.json`.
//!
//! Run time (here): load those artifacts, rebuild the network with the
//! exported shifts, run the **Rust int8 reference** (and optionally the
//! cycle simulator and the AOT HLO) over the test set, and verify the
//! measured int8 accuracy equals the build-time figure bit-for-bit —
//! the end-to-end proof that the deployed datapath only adds
//! quantization error, never datapath error.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::refcompute::{forward, LayerWeights, Tensor, Weights};
use crate::model::{Network, NetworkBuilder, TensorShape};
use crate::runtime::artifact;

/// Trained tiny-cnn weights loaded from `tiny_weights.bin`.
#[derive(Clone, Debug)]
pub struct TrainedWeights {
    /// (shift, flat int8 data) for w0, w2, w3, w6, w9.
    pub layers: Vec<(u32, Vec<i8>)>,
}

/// The held-out test set from `tiny_testset.bin`.
#[derive(Clone, Debug)]
pub struct TestSet {
    pub images: Vec<Vec<i8>>,
    pub labels: Vec<u32>,
}

const MAGIC: &[u8; 4] = b"DMN1";
/// Weight-layer element counts, network order (w0, w2, w3, w6, w9).
const WEIGHT_LENS: [usize; 5] = [16 * 3 * 9, 32 * 16 * 9, 32 * 32 * 9, 32 * 32 * 9, 10 * 32];

fn read_u32(buf: &[u8], off: &mut usize) -> Result<u32> {
    if *off + 4 > buf.len() {
        bail!("truncated artifact at offset {off}");
    }
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

impl TrainedWeights {
    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path)
            .with_context(|| format!("read {}", path.display()))?;
        if buf.len() < 4 || &buf[..4] != MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let mut off = 4;
        let mut layers = Vec::with_capacity(5);
        for (i, &want) in WEIGHT_LENS.iter().enumerate() {
            let shift = read_u32(&buf, &mut off)?;
            let n = read_u32(&buf, &mut off)? as usize;
            if n != want {
                bail!("layer {i}: {n} weights, expected {want}");
            }
            if off + n > buf.len() {
                bail!("layer {i}: truncated data");
            }
            let data: Vec<i8> = buf[off..off + n].iter().map(|&b| b as i8).collect();
            off += n;
            layers.push((shift, data));
        }
        Ok(Self { layers })
    }

    /// Per-layer requant shifts (w0, w2, w3, w6, w9).
    pub fn shifts(&self) -> [u32; 5] {
        [
            self.layers[0].0,
            self.layers[1].0,
            self.layers[2].0,
            self.layers[3].0,
            self.layers[4].0,
        ]
    }

    /// Assemble refcompute weights for [`tiny_cnn_with_shifts`].
    pub fn as_weights(&self) -> Weights {
        let conv = |i: usize| LayerWeights::Conv {
            w: self.layers[i].1.clone(),
        };
        Weights {
            per_layer: vec![
                conv(0),                                   // conv0
                LayerWeights::None,                        // maxpool1
                conv(1),                                   // conv2
                conv(2),                                   // conv3
                LayerWeights::None,                        // res4
                LayerWeights::None,                        // maxpool5
                conv(3),                                   // conv6
                LayerWeights::None,                        // avgpool7
                LayerWeights::None,                        // flatten8
                LayerWeights::Fc { w: self.layers[4].1.clone() }, // fc9
            ],
        }
    }
}

impl TestSet {
    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path)
            .with_context(|| format!("read {}", path.display()))?;
        if buf.len() < 8 || &buf[..4] != MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let mut off = 4;
        let count = read_u32(&buf, &mut off)? as usize;
        let mut images = Vec::with_capacity(count);
        let mut labels = Vec::with_capacity(count);
        for _ in 0..count {
            labels.push(read_u32(&buf, &mut off)?);
            if off + 768 > buf.len() {
                bail!("truncated test image");
            }
            images.push(buf[off..off + 768].iter().map(|&b| b as i8).collect());
            off += 768;
        }
        Ok(Self { images, labels })
    }
}

/// zoo::tiny_cnn with explicit per-weight-layer requant shifts
/// (w0, w2, w3, w6, w9) — the deployed network uses the calibrated
/// shifts exported by the quantizer.
pub fn tiny_cnn_with_shifts(shifts: [u32; 5]) -> Network {
    NetworkBuilder::new("tiny-cnn-trained", TensorShape::new(3, 16, 16))
        .conv_shift(16, 3, 1, 1, true, shifts[0])
        .max_pool(2, 2)
        .conv_shift(32, 3, 1, 1, true, shifts[1])
        .conv_shift(32, 3, 1, 1, false, shifts[2])
        .res_add(2)
        .max_pool(2, 2)
        .conv_shift(32, 3, 1, 1, true, shifts[3])
        .avg_pool(4, 4)
        .flatten()
        .fc_logits_shift(10, shifts[4])
        .build()
}

fn argmax(v: &[i8]) -> usize {
    v.iter()
        .enumerate()
        .max_by_key(|&(i, &x)| (x, std::cmp::Reverse(i)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// The experiment result.
#[derive(Clone, Debug)]
pub struct AccuracyReport {
    pub images: usize,
    /// Total images in the held-out artifact test set.
    pub testset_size: usize,
    /// Accuracy measured through the Rust int8 reference.
    pub int8_accuracy: f64,
    /// Build-time accuracies from accuracy.json.
    pub python_int8_accuracy: Option<f64>,
    pub python_fp32_accuracy: Option<f64>,
}

/// Run the accuracy experiment over `limit` test images (0 = all).
pub fn run(artifacts: &Path, limit: usize) -> Result<AccuracyReport> {
    let tw = TrainedWeights::load(&artifacts.join(artifact::WEIGHTS_BIN))?;
    let ts = TestSet::load(&artifacts.join(artifact::TESTSET_BIN))?;
    let net = tiny_cnn_with_shifts(tw.shifts());
    let weights = tw.as_weights();
    let n = if limit == 0 { ts.images.len() } else { limit.min(ts.images.len()) };

    let mut correct = 0usize;
    for i in 0..n {
        let x = Tensor::new(net.input, ts.images[i].clone());
        let out = forward(&net, &weights, &x)?;
        if argmax(&out.data) == ts.labels[i] as usize {
            correct += 1;
        }
    }

    let json = std::fs::read_to_string(artifacts.join(artifact::ACCURACY_JSON)).ok();
    let (py_i8, py_f32) = json
        .map(|t| {
            (
                crate::eval::json_number(&t, "int8_accuracy"),
                crate::eval::json_number(&t, "fp32_accuracy"),
            )
        })
        .unwrap_or((None, None));

    Ok(AccuracyReport {
        images: n,
        testset_size: ts.images.len(),
        int8_accuracy: correct as f64 / n as f64,
        python_int8_accuracy: py_i8,
        python_fp32_accuracy: py_f32,
    })
}

/// Render the accuracy row.
pub fn render(r: &AccuracyReport) -> String {
    format!(
        "ACCURACY (quantization error only, Section IV-A)\n\
         tiny-cnn on synthetic-10class, {} held-out images\n\
         fp32 (build-time): {}\n\
         int8 (build-time): {}\n\
         int8 (rust datapath): {:.4}{}\n",
        r.images,
        r.python_fp32_accuracy
            .map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "n/a".into()),
        r.python_int8_accuracy
            .map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "n/a".into()),
        r.int8_accuracy,
        match r.python_int8_accuracy {
            _ if r.images < r.testset_size => "  [subset run; full-set match checked in tests]",
            Some(p) if (p - r.int8_accuracy).abs() < 1e-9 =>
                "  [bit-exact match with the JAX golden model]",
            Some(_) => "  [MISMATCH vs build-time figure]",
            None => "",
        }
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifted_network_shape_checks() {
        let net = tiny_cnn_with_shifts([8, 11, 8, 9, 6]);
        net.shapes().unwrap();
        assert_eq!(net.layers[0].requant_shift, 8);
        assert_eq!(net.layers[9].requant_shift, 6);
    }

    #[test]
    fn argmax_prefers_first_on_ties() {
        assert_eq!(argmax(&[1, 3, 3, 2]), 1);
        assert_eq!(argmax(&[-5]), 0);
    }

    #[test]
    fn accuracy_experiment_end_to_end() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join(artifact::WEIGHTS_BIN).exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let r = run(&dir, 64).unwrap();
        assert!(r.int8_accuracy > 0.5, "accuracy {}", r.int8_accuracy);
    }

    #[test]
    fn full_testset_matches_buildtime_accuracy() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join(artifact::WEIGHTS_BIN).exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let r = run(&dir, 0).unwrap();
        if let Some(py) = r.python_int8_accuracy {
            assert!(
                (py - r.int8_accuracy).abs() < 1e-9,
                "rust {} vs python {}",
                r.int8_accuracy,
                py
            );
        }
    }
}
