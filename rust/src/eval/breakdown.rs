//! Power breakdown (paper Section IV-B-3): CIM / on-chip data /
//! off-chip data shares per Table IV workload.
//!
//! "data movement only accounts for a small portion (8% to 32% for
//! on-chip and 0.1% to 3% for off-chip), which means Domino
//! efficiently reduces the overhead of data movement."

use anyhow::Result;

use crate::counterparts::all_comparisons;
use crate::counterparts::normalize::measure_domino;
use crate::eval::{comparison_network, compile_comparison};

/// Per-workload power breakdown.
#[derive(Clone, Debug)]
pub struct BreakdownRow {
    pub workload: &'static str,
    pub cite: &'static str,
    pub power_w: f64,
    pub cim_share: f64,
    pub onchip_share: f64,
    pub offchip_share: f64,
    /// The paper's printed shares for the same row.
    pub paper_onchip_share: f64,
    pub paper_offchip_share: f64,
}

/// Compute the breakdown for every Table IV comparison.
pub fn run() -> Result<Vec<BreakdownRow>> {
    let mut rows = Vec::new();
    for comp in all_comparisons() {
        let net = comparison_network(&comp)?;
        let program = compile_comparison(&comp)?;
        let est = crate::perfmodel::estimate(&program)?;
        let cim = comp.domino_cim_model();
        let m = measure_domino(&est, &cim, net.total_ops()?);
        rows.push(BreakdownRow {
            workload: comp.counterpart.model,
            cite: comp.counterpart.cite,
            power_w: m.power_w,
            cim_share: m.cim_w / m.power_w,
            onchip_share: m.onchip_data_w / m.power_w,
            offchip_share: m.offchip_data_w / m.power_w,
            paper_onchip_share: comp.domino.onchip_data_w / comp.domino.power_w,
            paper_offchip_share: comp.domino.offchip_data_w / comp.domino.power_w,
        });
    }
    Ok(rows)
}

/// Render as the Section IV-B-3 summary.
pub fn render(rows: &[BreakdownRow]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(s, "POWER BREAKDOWN (Section IV-B-3) — measured (paper)\n");
    let _ = writeln!(
        s,
        "{:<18} {:>6} {:>10} {:>8} {:>18} {:>18}",
        "workload", "vs", "power W", "CIM %", "on-chip % (paper)", "off-chip % (paper)"
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{:<18} {:>6} {:>10.3} {:>8.1} {:>10.1} ({:>4.1}) {:>11.2} ({:>4.2})",
            r.workload,
            r.cite,
            r.power_w,
            100.0 * r.cim_share,
            100.0 * r.onchip_share,
            100.0 * r.paper_onchip_share,
            100.0 * r.offchip_share,
            100.0 * r.paper_offchip_share,
        );
    }
    let on_min = rows.iter().map(|r| r.onchip_share).fold(f64::MAX, f64::min);
    let on_max = rows.iter().map(|r| r.onchip_share).fold(f64::MIN, f64::max);
    let off_min = rows.iter().map(|r| r.offchip_share).fold(f64::MAX, f64::min);
    let off_max = rows.iter().map(|r| r.offchip_share).fold(f64::MIN, f64::max);
    let _ = writeln!(
        s,
        "\nrange: on-chip {:.0}-{:.0}% (paper 8-32%), off-chip {:.1}-{:.1}% (paper 0.1-3%)",
        100.0 * on_min,
        100.0 * on_max,
        100.0 * off_min,
        100.0 * off_max
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_movement_is_minor_everywhere() {
        for r in run().unwrap() {
            assert!(
                r.onchip_share < 0.45,
                "{}: on-chip {:.1}%",
                r.workload,
                100.0 * r.onchip_share
            );
            assert!(
                r.offchip_share < 0.05,
                "{}: off-chip {:.2}%",
                r.workload,
                100.0 * r.offchip_share
            );
            let total = r.cim_share + r.onchip_share + r.offchip_share;
            assert!((total - 1.0).abs() < 1e-9, "shares must partition: {total}");
        }
    }

    #[test]
    fn imagenet_models_are_most_cim_dominated() {
        let rows = run().unwrap();
        // Bigger MAC/pixel ratios push the share toward CIM: the VGG-19
        // rows must be more CIM-dominated than VGG-11.
        let vgg11 = rows.iter().find(|r| r.workload == "vgg11-cifar10").unwrap();
        let vgg19 = rows.iter().find(|r| r.workload == "vgg19-imagenet").unwrap();
        assert!(vgg19.cim_share > vgg11.cim_share);
    }

    #[test]
    fn render_reports_ranges() {
        let s = render(&run().unwrap());
        assert!(s.contains("range:"));
        assert!(s.contains("paper 8-32%"));
    }
}
