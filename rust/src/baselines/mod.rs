//! Baseline dataflows Domino is compared against in the ablations.
//!
//! * [`ws_im2col`] — the conventional weight-stationary + im2col NoC
//!   dataflow of [9]-style CIM accelerators ("in [9], IFMs and weights
//!   must be loaded repeatedly during runtime"; "matrix conversion
//!   (e.g., im2col) is compulsory in WS dataflow"). Used by experiment
//!   A1 to quantify what COM saves.
//! * [`pooling`] — the Fig. 4 pooling schemes (weight duplication vs
//!   block reuse) as an ablation over tiles/period/energy.

pub mod pooling;
pub mod ws_im2col;
