//! Fig. 4 pooling-scheme ablation: weight duplication vs block reuse.
//!
//! "Domino duplicates weights to produce four activation results T to Y
//! in every cycle, which aims to maintain synchronization among layers"
//! (Fig. 4(b)) vs "the block reuse scheme that activation results are
//! computed and stored in the last tile" (Fig. 4(c)). The trade is
//! tiles (area) against stage period (throughput): under duplication
//! "computation frequency before pooling layers is 4x higher".

use anyhow::Result;

use crate::coordinator::{ArchConfig, Compiler, PoolingScheme};
use crate::energy::{energy_of, CimModel};
use crate::model::Network;

/// One scheme's cost/perf summary.
#[derive(Clone, Copy, Debug)]
pub struct SchemeReport {
    pub tiles: usize,
    pub chips: usize,
    pub period_cycles: u64,
    pub latency_cycles: u64,
    pub energy_per_image_j: f64,
    pub images_per_s: f64,
}

/// Fig. 4 comparison for one network.
#[derive(Clone, Copy, Debug)]
pub struct PoolingAblation {
    pub block_reuse: SchemeReport,
    pub weight_dup: SchemeReport,
}

fn report(net: &Network, arch: ArchConfig, cim: &CimModel) -> Result<SchemeReport> {
    let program = Compiler::new(arch).compile_analysis(net)?;
    let est = crate::perfmodel::estimate(&program)?;
    let e = energy_of(&est.counters, cim);
    Ok(SchemeReport {
        tiles: program.total_tiles,
        chips: program.chips,
        period_cycles: est.period_cycles,
        latency_cycles: est.latency_cycles,
        energy_per_image_j: e.total(),
        images_per_s: est.images_per_s(),
    })
}

/// Compare the two schemes on `net` (no sync budget: the schemes are
/// isolated from throughput water-filling).
pub fn ablate(net: &Network, cim: &CimModel) -> Result<PoolingAblation> {
    let mut a = ArchConfig::default();
    a.pooling = PoolingScheme::BlockReuse;
    let block_reuse = report(net, a, cim)?;
    let mut b = ArchConfig::default();
    b.pooling = PoolingScheme::WeightDuplication;
    let weight_dup = report(net, b, cim)?;
    Ok(PoolingAblation {
        block_reuse,
        weight_dup,
    })
}

impl PoolingAblation {
    /// Area cost of duplication (tiles ratio).
    pub fn tile_ratio(&self) -> f64 {
        self.weight_dup.tiles as f64 / self.block_reuse.tiles as f64
    }

    /// Throughput gain of duplication.
    pub fn speedup(&self) -> f64 {
        self.weight_dup.images_per_s / self.block_reuse.images_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn duplication_trades_tiles_for_throughput() {
        let net = zoo::vgg11_cifar();
        let ab = ablate(&net, &CimModel::generic_sram()).unwrap();
        assert!(ab.tile_ratio() > 1.5, "tile ratio {:.2}", ab.tile_ratio());
        assert!(ab.speedup() > 1.5, "speedup {:.2}", ab.speedup());
        // energy per image is nearly unchanged (same events)
        let e_ratio = ab.weight_dup.energy_per_image_j / ab.block_reuse.energy_per_image_j;
        assert!((0.8..1.2).contains(&e_ratio), "energy ratio {e_ratio:.3}");
    }

    #[test]
    fn both_schemes_fit_the_same_network(){
        let net = zoo::tiny_cnn();
        let ab = ablate(&net, &CimModel::generic_sram()).unwrap();
        assert!(ab.weight_dup.tiles >= ab.block_reuse.tiles);
        assert!(ab.weight_dup.period_cycles <= ab.block_reuse.period_cycles);
    }
}
