//! The conventional WS + im2col baseline (experiment A1).
//!
//! Models a [9]-style CIM NoC running the same network with the two
//! properties Domino's COM dataflow removes (Section III):
//!
//! 1. **im2col IFM expansion** — every conv input pixel is read and
//!    transmitted once per kernel window it participates in (k²/s²
//!    duplication), through a central buffer: "not only requires
//!    additional circuits but also greatly increases costs of accessing
//!    data in IFMs".
//! 2. **central partial-sum accumulation** — each tile's partial sum
//!    travels to a shared accumulation buffer (global-buffer access +
//!    mean mesh distance) instead of one abutted-neighbour hop; the
//!    accumulator buffer is read-modify-written per arriving psum.
//!
//! The PE work (MACs) is identical by construction — the ablation
//! isolates *data movement*, which is the paper's claim.

use crate::coordinator::program::{Program, StageKind};
use crate::coordinator::schedule::ConvGeometry;
use crate::energy::{energy_of, CimModel, EnergyBreakdown};
use crate::sim::stats::Counters;

/// Mean hop distance to the central accumulator/buffer on a `w x h`
/// mesh (uniform tile positions, buffer at the mesh centre).
pub fn mean_hops_to_center(mesh_cols: usize, mesh_rows: usize) -> f64 {
    // E|x - c| for uniform x over 0..n-1 and c = (n-1)/2 is ~n/4.
    (mesh_cols as f64 + mesh_rows as f64) / 4.0
}

/// Per-image event counters of the baseline running `program`'s
/// network on the same tile allocation.
pub fn baseline_counters(program: &Program) -> Counters {
    let mesh_cols = program.arch.mesh_cols;
    let mesh_rows = program.arch.tiles_per_chip.div_ceil(mesh_cols);
    let hops = mean_hops_to_center(mesh_cols, mesh_rows);
    let mut c = Counters::new();

    c.offchip_io_bits += 8 * program.net.input_len() as u64;
    if let Ok(out) = program.net.output_shape() {
        c.offchip_io_bits += 8 * out.len() as u64;
    }

    for stage in &program.stages {
        match &stage.kind {
            StageKind::Conv(conv) => conv_baseline(conv, hops, &mut c),
            StageKind::Fc(f) => {
                // FC has no im2col expansion; psums still centralize.
                for col in &f.columns {
                    for t in &col.tiles {
                        c.rifm_buffer_accesses += 1;
                        c.pe_mvms += 1;
                        c.pe_macs += (t.rows * t.cols) as u64;
                        let pbits = (t.cols * 32) as u64;
                        c.onchip_link_bits += (pbits as f64 * hops) as u64;
                        c.rofm_buffer_accesses += 2; // central RMW
                        c.adds_8b += 4 * t.cols as u64;
                    }
                    c.act_ops_8b += (col.c_hi - col.c_lo) as u64;
                }
            }
            StageKind::Pool(p) => {
                // pooling reads its window from the central buffer
                let pix = (p.in_shape.h * p.in_shape.w * p.in_shape.c) as u64;
                c.rofm_buffer_accesses += pix / 8; // 64b words
                c.onchip_link_bits += (8.0 * pix as f64 * hops) as u64;
                c.pool_ops_8b += pix;
            }
            StageKind::Res(r) => {
                if let Some(proj) = &r.proj {
                    conv_baseline(proj, hops, &mut c);
                }
                let pix = (r.shape.h * r.shape.w * r.shape.c) as u64;
                c.onchip_link_bits += (2.0 * 8.0 * pix as f64 * hops) as u64;
                c.rofm_buffer_accesses += pix / 8;
                c.adds_8b += pix;
                c.act_ops_8b += pix;
            }
            StageKind::Flatten => {}
        }
    }
    c
}

fn conv_baseline(conv: &crate::coordinator::program::ConvStage, hops: f64, c: &mut Counters) {
    let g = ConvGeometry::new(
        conv.k,
        conv.stride,
        conv.padding,
        conv.in_shape.h,
        conv.in_shape.w,
    );
    let outs = (g.out_h * g.out_w) as u64;
    for chain in &conv.chains {
        let m_lanes = (chain.m_hi - chain.m_lo) as u64;
        for t in &chain.tiles {
            let rows = t.rows as u64;
            // 1. im2col: the tile re-reads its (rows)-deep input slice
            //    for EVERY output window — k² x duplication vs COM's
            //    single streaming pass — via the central buffer.
            let ifm_bits = rows * 8 * outs;
            c.rifm_buffer_accesses += outs; // local receive per window
            c.rofm_buffer_accesses += outs; // central buffer read
            c.onchip_link_bits += (ifm_bits as f64 * hops) as u64;
            // PE work identical to COM
            c.pe_mvms += outs;
            c.pe_macs += rows * t.cols as u64 * outs;
            // 2. central accumulation: psum to the accumulator + RMW
            let pbits = (t.cols * 32) as u64;
            c.onchip_link_bits += (pbits as f64 * hops) as u64 * outs;
            c.rofm_buffer_accesses += 2 * outs;
            c.adds_8b += 4 * t.cols as u64 * outs;
        }
        c.act_ops_8b += m_lanes * outs;
    }
}

/// A1 ablation result: COM vs WS+im2col on the same network + arrays.
#[derive(Clone, Debug)]
pub struct DataflowAblation {
    pub com: EnergyBreakdown,
    pub baseline: EnergyBreakdown,
}

impl DataflowAblation {
    /// Data-movement energy ratio (baseline / COM), the A1 headline.
    pub fn movement_ratio(&self) -> f64 {
        self.baseline.onchip_data() / self.com.onchip_data()
    }

    /// Total-energy ratio.
    pub fn total_ratio(&self) -> f64 {
        self.baseline.total() / self.com.total()
    }
}

/// Run the A1 ablation for a compiled program.
pub fn ablate(program: &Program, cim: &CimModel) -> anyhow::Result<DataflowAblation> {
    let est = crate::perfmodel::estimate(program)?;
    let com = energy_of(&est.counters, cim);
    let baseline = energy_of(&baseline_counters(program), cim);
    Ok(DataflowAblation { com, baseline })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Compiler;
    use crate::model::zoo;

    #[test]
    fn mean_hops_scales_with_mesh() {
        assert!(mean_hops_to_center(16, 15) > mean_hops_to_center(4, 4));
        assert!((mean_hops_to_center(16, 16) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn baseline_preserves_mac_count() {
        let net = zoo::tiny_cnn();
        let p = Compiler::default().compile(&net).unwrap();
        let b = baseline_counters(&p);
        let est = crate::perfmodel::estimate(&p).unwrap();
        assert_eq!(b.pe_macs, est.counters.pe_macs, "ablation must isolate movement");
    }

    #[test]
    fn com_moves_less_data_than_baseline() {
        let net = zoo::vgg11_cifar();
        let p = Compiler::default().compile(&net).unwrap();
        let ab = ablate(&p, &CimModel::generic_sram()).unwrap();
        assert!(
            ab.movement_ratio() > 2.0,
            "im2col+central baseline should move >2x the data, got {:.2}",
            ab.movement_ratio()
        );
        assert!(ab.total_ratio() > 1.0);
    }

    #[test]
    fn baseline_link_traffic_dominated_by_im2col() {
        let net = zoo::tiny_cnn();
        let p = Compiler::default().compile(&net).unwrap();
        let b = baseline_counters(&p);
        let est = crate::perfmodel::estimate(&p).unwrap();
        assert!(b.onchip_link_bits > 4 * est.counters.onchip_link_bits);
    }
}
