//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The bridge half of the three-layer architecture: `make artifacts`
//! runs Python once to lower the L2/L1 functions to HLO *text*
//! (`python/compile/aot.py`); this module loads that text through the
//! `xla` crate (`HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile`) and executes it with concrete int8 buffers.
//! Python never runs again — the compiled executable lives inside the
//! Rust process.
//!
//! Text (not serialized proto) is the interchange format: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Used by integration tests, the `e2e_inference` example and the
//! accuracy experiment to cross-check the cycle simulator's functional
//! datapath against the JAX golden model — int8, so the comparison is
//! exact equality, not allclose.

pub mod golden;

/// The `xla` bindings. With the `pjrt` feature off (the default in the
/// offline build image, which does not vendor the `xla` crate) this is
/// an API-compatible stub whose client constructor returns a clean
/// error — see [`xla_stub`](xla). With `--features pjrt` the real,
/// vendored crate is used instead and every call site stays identical.
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
pub mod xla;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Artifact file names produced by `python/compile/aot.py`.
pub mod artifact {
    /// tiny-cnn forward, weights as inputs (x, w0, w2, w3, w6, w9).
    pub const TINY_CNN: &str = "tiny_cnn_int8.hlo.txt";
    /// tiny-cnn with trained+calibrated weights baked in (input: x).
    pub const TINY_TRAINED: &str = "tiny_trained_int8.hlo.txt";
    /// One 256x256 crossbar MVM (x[1,256], w[256,256]).
    pub const CIM_MVM: &str = "cim_mvm_256.hlo.txt";
    /// One COM-dataflow conv layer (x[16,16,16], w[3,3,16,32]).
    pub const COM_CONV: &str = "com_conv_k3.hlo.txt";
    /// Trained int8 weights + shifts (binary, see model.py).
    pub const WEIGHTS_BIN: &str = "tiny_weights.bin";
    /// Held-out int8 test set (binary).
    pub const TESTSET_BIN: &str = "tiny_testset.bin";
    /// Build-time accuracy record.
    pub const ACCURACY_JSON: &str = "accuracy.json";
}

/// Locate the artifacts directory: `$DOMINO_ARTIFACTS` or `artifacts/`
/// relative to the workspace root / current directory.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("DOMINO_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for base in [".", "..", env!("CARGO_MANIFEST_DIR")] {
        let p = Path::new(base).join("artifacts");
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// True if the AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// A PJRT CPU runtime holding compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact by file name (resolved
    /// against [`artifacts_dir`]) or by path.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = if Path::new(name).exists() {
            PathBuf::from(name)
        } else {
            artifacts_dir().join(name)
        };
        if !path.exists() {
            bail!(
                "artifact {} not found (run `make artifacts`)",
                path.display()
            );
        }
        let path_str = path.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path_str)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable {
            exe,
            name: name.to_string(),
        })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// An int8 input buffer: flat values + dims.
pub struct I8Input<'a> {
    pub data: &'a [i8],
    pub dims: &'a [i64],
}

/// Build an S8 literal from int8 data (the published crate's `vec1`
/// only covers 32/64-bit native types; S8 goes through the untyped
/// constructor + `ArrayElement`).
pub fn literal_i8(data: &[i8], dims: &[i64]) -> Result<xla::Literal> {
    let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        &dims_usize,
        bytes,
    )?)
}

impl Executable {
    /// Execute with int8 inputs; returns the flattened int8 elements of
    /// every tuple output (aot.py lowers with `return_tuple=True`).
    pub fn run_i8(&self, inputs: &[I8Input]) -> Result<Vec<Vec<i8>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| literal_i8(inp.data, inp.dims))
            .collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let tuple = result.decompose_tuple()?;
        tuple
            .into_iter()
            .map(|lit| Ok(lit.to_vec::<i8>()?))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_is_resolvable() {
        // must not panic regardless of build state
        let _ = artifacts_dir();
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        if let Ok(rt) = Runtime::cpu() {
            match rt.load("definitely_not_there.hlo.txt") {
                Ok(_) => panic!("load of missing artifact succeeded"),
                Err(e) => {
                    let msg = format!("{e:#}");
                    assert!(msg.contains("not found"), "{msg}");
                }
            }
        }
    }
}
