//! API-compatible stand-in for the vendored `xla` crate.
//!
//! The offline build image does not ship the `xla` crate (the PJRT C
//! bindings), so by default [`super`] compiles against this stub, which
//! mirrors exactly the slice of the `xla` API the runtime uses.
//! [`PjRtClient::cpu`] returns a clean error, therefore every caller
//! that is gated on `runtime::artifacts_available()` /
//! `Runtime::cpu().is_ok()` skips gracefully and nothing downstream can
//! observe a half-working runtime. Building with `--features pjrt` (and
//! a vendored `xla` dependency) swaps the real crate back in without
//! touching any call site.

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: built without the `pjrt` feature \
         (the `xla` crate is not vendored in this environment)"
            .to_string(),
    ))
}

/// Element types of XLA literals (only what the runtime constructs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    S8,
}

/// A host-side literal: shape + raw bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    pub element_type: ElementType,
    pub dims: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        element_type: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal {
            element_type,
            dims: dims.to_vec(),
            bytes: data.to_vec(),
        })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module (never actually constructed by the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// The PJRT client. The stub client cannot be constructed: `cpu()`
/// always errors, which is what keeps the rest of the stub unreachable.
pub struct PjRtClient {
    _private: std::convert::Infallible,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        match self._private {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self._private {}
    }
}
