//! Golden-model cross-check: the AOT-compiled JAX tiny-cnn vs the
//! Rust reference and the cycle simulator — exact int8 equality.
//!
//! This is the end-to-end proof that all three layers compose: the L1
//! Pallas kernels and L2 JAX model (lowered once to HLO text), the
//! PJRT runtime loading that text, and the L3 compiler+simulator all
//! produce the *same bits* for the same network and weights.

use anyhow::{bail, Context, Result};

use crate::model::refcompute::{LayerWeights, Weights};
use crate::model::zoo;
use crate::runtime::{artifact, Executable, I8Input, Runtime};

/// The loaded tiny-cnn golden model (weights as inputs).
pub struct GoldenTiny {
    exe: Executable,
}

impl GoldenTiny {
    pub fn load(rt: &Runtime) -> Result<Self> {
        Ok(Self {
            exe: rt.load(artifact::TINY_CNN)?,
        })
    }

    /// Run the golden forward with explicit weights (refcompute
    /// layouts: conv `[M][C][3][3]`, fc `[out][in]`).
    pub fn run(&self, x: &[i8], weights: &Weights) -> Result<Vec<i8>> {
        if x.len() != 3 * 16 * 16 {
            bail!("tiny-cnn input must be 3x16x16");
        }
        // weight layers of zoo::tiny_cnn: 0, 2, 3, 6 conv; 9 fc
        let w = |i: usize| -> Result<&[i8]> {
            match &weights.per_layer[i] {
                LayerWeights::Conv { w } | LayerWeights::Fc { w } => Ok(w),
                other => bail!("layer {i}: unexpected weights {other:?}"),
            }
        };
        let dims_conv = [
            (w(0)?, vec![16i64, 3, 3, 3]),
            (w(2)?, vec![32, 16, 3, 3]),
            (w(3)?, vec![32, 32, 3, 3]),
            (w(6)?, vec![32, 32, 3, 3]),
        ];
        let wfc = w(9)?;
        let mut inputs = vec![I8Input {
            data: x,
            dims: &[3, 16, 16],
        }];
        for (data, dims) in &dims_conv {
            inputs.push(I8Input { data, dims });
        }
        inputs.push(I8Input {
            data: wfc,
            dims: &[10, 32],
        });
        let outs = self.exe.run_i8(&inputs)?;
        Ok(outs.into_iter().next().context("empty output tuple")?)
    }
}

/// The trained tiny-cnn: the AOT HLO bakes the *calibrated requant
/// shifts*; the int8 weights are loaded from `tiny_weights.bin` and
/// passed as inputs (xla_extension 0.5.1's HLO text parser mis-decodes
/// large baked s8 constants, so the weights stay host-side).
pub struct TrainedTiny {
    exe: Executable,
    weights: crate::eval::accuracy::TrainedWeights,
}

impl TrainedTiny {
    pub fn load(rt: &Runtime) -> Result<Self> {
        let dir = crate::runtime::artifacts_dir();
        let weights = crate::eval::accuracy::TrainedWeights::load(
            &dir.join(artifact::WEIGHTS_BIN),
        )?;
        Ok(Self {
            exe: rt.load(artifact::TINY_TRAINED)?,
            weights,
        })
    }

    pub fn run(&self, x: &[i8]) -> Result<Vec<i8>> {
        if x.len() != 3 * 16 * 16 {
            bail!("tiny-cnn input must be 3x16x16");
        }
        let w = &self.weights.layers;
        let outs = self.exe.run_i8(&[
            I8Input { data: x, dims: &[3, 16, 16] },
            I8Input { data: &w[0].1, dims: &[16, 3, 3, 3] },
            I8Input { data: &w[1].1, dims: &[32, 16, 3, 3] },
            I8Input { data: &w[2].1, dims: &[32, 32, 3, 3] },
            I8Input { data: &w[3].1, dims: &[32, 32, 3, 3] },
            I8Input { data: &w[4].1, dims: &[10, 32] },
        ])?;
        Ok(outs.into_iter().next().context("empty output tuple")?)
    }
}

/// Cross-check helper used by tests and the e2e example: golden HLO vs
/// the Rust reference on `n` seeded images. Returns the number of
/// compared images.
pub fn check_golden_vs_reference(rt: &Runtime, n: usize, seed: u64) -> Result<usize> {
    let net = zoo::tiny_cnn();
    let weights = Weights::random(&net, crate::coordinator::Compiler::default().weight_seed)?;
    let golden = GoldenTiny::load(rt)?;
    let mut rng = crate::testutil::Rng::new(seed);
    for i in 0..n {
        let x = rng.i8_vec(net.input_len(), 31);
        let got = golden.run(&x, &weights)?;
        let want = crate::model::refcompute::forward(
            &net,
            &weights,
            &crate::model::refcompute::Tensor::new(net.input, x.clone()),
        )?;
        if got != want.data {
            bail!("image {i}: golden {got:?} != reference {:?}", want.data);
        }
    }
    Ok(n)
}
