//! COM dataflow trace: reproduces the timing/location diagram of paper
//! Fig. 3(b) — "black circles represent partial-sums in registers while
//! red ones represent group-sums in buffers".
//!
//! A [`FlightRecorder`](crate::sim::flight::FlightRecorder) captures
//! one event per tile action; this module filters the recording down
//! to one conv chain and renders a tiles x time grid in which each
//! cell shows what moved through the tile at that pixel slot:
//!
//! * `U`  — a partial-sum accumulated in the tile's registers and
//!   forwarded along the chain (black circles);
//! * `G+` — a group-sum queued into the ROFM buffer (red circles);
//! * `G-` — a group-sum popped to seed the next kernel row;
//! * `Y`  — the last tile's M-type activation emitting an output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::Result;

use crate::coordinator::program::{Program, StageKind};
use crate::sim::engine::Simulator;
use crate::sim::flight::{EventKind, RecorderConfig};
use crate::testutil::Rng;

/// One rendered trace.
#[derive(Clone, Debug)]
pub struct ComTrace {
    /// Stage that was traced.
    pub stage: usize,
    pub stage_name: String,
    /// Chain length (tiles down the page).
    pub tiles: usize,
    /// (tile, slot) -> cell label.
    pub cells: BTreeMap<(usize, usize), &'static str>,
    /// Highest slot index recorded.
    pub max_slot: usize,
}

/// Simulate one image under a [`FlightRecorder`](crate::sim::flight)
/// and capture the COM trace of `stage` (chain 0).
pub fn trace_stage(program: &Program, stage: usize, seed: u64) -> Result<ComTrace> {
    let mut sim = Simulator::with_recorder(program, RecorderConfig::default());
    let mut rng = Rng::new(seed);
    sim.run_image(&rng.i8_vec(program.net.input_len(), 31))?;
    let rec = sim.recording();

    let (tiles, name) = match &program.stages[stage].kind {
        StageKind::Conv(c) => (
            c.chains[0].tiles.len(),
            program.stages[stage].name.clone(),
        ),
        _ => anyhow::bail!("trace_stage expects a conv stage"),
    };

    let mut cells = BTreeMap::new();
    let mut max_slot = 0;
    for e in rec
        .events
        .iter()
        .filter(|e| e.stage as usize == stage && e.chain == 0)
    {
        // only tile actions feed the figure; link transfers, stage
        // boundaries, and occupancy samples are other planes
        let label = match e.kind {
            EventKind::Acc => "U",
            EventKind::Push => "G+",
            EventKind::Pop => "G-",
            EventKind::Emit => "Y",
            _ => continue,
        };
        // pops and accs can hit the same (tile, slot); prefer showing
        // the buffer event (the figure's red circles)
        let cell = cells.entry((e.ci as usize, e.slot as usize)).or_insert(label);
        if label == "G+" || label == "G-" {
            *cell = label;
        }
        max_slot = max_slot.max(e.slot as usize);
    }
    Ok(ComTrace {
        stage,
        stage_name: name,
        tiles,
        cells,
        max_slot,
    })
}

impl ComTrace {
    /// Render the tiles x time grid (slots `lo..hi`).
    pub fn render(&self, lo: usize, hi: usize) -> String {
        let hi = hi.min(self.max_slot + 1);
        let mut out = String::new();
        let _ = writeln!(
            out,
            "COM trace of {} (chain 0, {} tiles): U=partial-sum  \
             G+=group-sum queued  G-=group-sum popped  Y=output",
            self.stage_name, self.tiles
        );
        let _ = write!(out, "{:>8} |", "tile\\slot");
        for s in lo..hi {
            let _ = write!(out, "{s:>4}");
        }
        let _ = writeln!(out);
        let _ = writeln!(out, "{:-<width$}", "", width = 10 + 4 * (hi - lo));
        for t in 0..self.tiles {
            let _ = write!(out, "{t:>8} |");
            for s in lo..hi {
                let c = self.cells.get(&(t, s)).copied().unwrap_or("");
                let _ = write!(out, "{c:>4}");
            }
            let _ = writeln!(out);
        }
        out
    }

    /// Number of each event kind (for tests).
    pub fn count(&self, label: &str) -> usize {
        self.cells.values().filter(|&&v| v == label).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Compiler;
    use crate::model::{NetworkBuilder, TensorShape};

    fn small_conv_program() -> Program {
        let net = NetworkBuilder::new("t", TensorShape::new(2, 5, 5))
            .conv(3, 3, 1, 1)
            .build();
        Compiler::default().compile(&net).unwrap()
    }

    #[test]
    fn trace_has_com_structure() {
        let p = small_conv_program();
        let tr = trace_stage(&p, 0, 7).unwrap();
        assert_eq!(tr.tiles, 9, "K²=9 chain");
        // the paper's sequence: partial sums flow, group sums queue at
        // row heads (tiles 3 and 6), outputs leave the last tile
        assert!(tr.count("U") > 0);
        assert!(tr.count("G+") > 0);
        assert!(tr.count("G-") > 0);
        assert_eq!(tr.count("Y"), 25, "one emit per output pixel");
    }

    #[test]
    fn group_sums_queue_exactly_at_row_heads() {
        let p = small_conv_program();
        let tr = trace_stage(&p, 0, 8).unwrap();
        for (&(tile, _), &label) in &tr.cells {
            if label == "G+" || label == "G-" {
                assert!(tile == 3 || tile == 6, "buffer event at tile {tile}");
            }
            if label == "Y" {
                assert_eq!(tile, 8, "emit only at the last tile");
            }
        }
    }

    #[test]
    fn render_is_stable_and_bounded() {
        let p = small_conv_program();
        let tr = trace_stage(&p, 0, 7).unwrap();
        let s1 = tr.render(0, 20);
        let s2 = tr.render(0, 20);
        assert_eq!(s1, s2);
        assert!(s1.lines().count() == tr.tiles + 3);
    }

    #[test]
    fn non_conv_stage_is_rejected() {
        let net = NetworkBuilder::new("t", TensorShape::new(4, 1, 1))
            .fc_logits(3)
            .build();
        let p = Compiler::default().compile(&net).unwrap();
        assert!(trace_stage(&p, 0, 1).is_err());
    }
}
