//! The cycle-level Domino simulator.
//!
//! Executes a compiled [`Program`] stage by stage on real int8 data,
//! reproducing the COM dataflow's exact event sequence:
//!
//! * the IFM streams through each chain in padded raster order, one
//!   pixel slot per tile hop (`sim` slot = 2 instruction cycles, see
//!   `coordinator::schedule`);
//! * every tile PE-MACs the pixels its kernel offset aligns with;
//! * partial sums accumulate hop by hop along the chain (tag-checked:
//!   a misrouted or misscheduled packet panics — this is how the
//!   compiler's schedule/placement logic is validated);
//! * kernel-row group-sums wait in the next row head's ROFM FIFO for
//!   one row period (the paper's "group-sums are queued in the buffer
//!   ... to be ready");
//! * the last tile applies M-type activation (+ fused pooling under
//!   block reuse) and hands the OFM to the next stage.
//!
//! Functional outputs are bit-exact against `model::refcompute` (unit
//! tested here, property-tested in `rust/tests/`), and every
//! architectural event is charged into [`Counters`].
//!
//! ## Runtime state, pooling & the batched path
//!
//! All per-tile runtime state (RIFM and ROFM instances, the ROFM
//! group-sum FIFOs and the psum register queues) is built **once per
//! engine** from the compiled program and *reset* between images —
//! `run_image` allocates no tile state, which is what makes
//! back-to-back and batched simulation cheap. The state owns no borrow
//! of the program: conv tiles own a lane-blocked **packed copy** of
//! their weight block ([`Pe::new`] packs it once, at engine
//! construction), while FC tiles mount theirs on the fly (a zero-alloc
//! `Cow::Borrowed` — one MVM per mount, where packing would cost as
//! much as it saves). Either way the same engine core can sit behind a
//! borrow ([`Simulator`]) or share ownership of its program
//! ([`PooledEngine`]) and live as long as the process does.
//!
//! ## The zero-allocation hot path (§Perf)
//!
//! The steady-state simulation loop performs no **per-event** heap
//! allocation after engine construction (and a first warm-up image):
//! nothing allocates per pixel, per packet or per MVM. What remains is
//! per-stage and per-image — the stage output tensors, the input copy
//! and the returned `RunOutput` — a handful of allocations per image
//! instead of one per simulated event:
//!
//! * **Psum slab arena** — every conv chain owns a
//!   [`crate::noc::packet::PsumArena`]: a preallocated `i32` slab of
//!   fixed-width lane slots sized from the chain's geometry. Partial
//!   sums move through ROFM FIFOs and inter-tile register queues as
//!   `Copy` [`crate::noc::packet::PsumRef`] handles; PE MVMs write
//!   straight into slab slots (`Pe::mvm_into`), and the ROFM adders
//!   accumulate slab-to-slab. No per-packet `Vec<i32>` exists anywhere
//!   on the path.
//! * **Reusable scratch** — per-engine scratch buffers replace every
//!   per-pixel `collect()`: the MVM accumulator, the activation/emit
//!   lane buffer, the pool/res pixel-lane gathers and the FC
//!   input-slice/column-accumulator buffers are all cleared and reused.
//!   Pooling units persist across images and recycle their window
//!   buffers.
//! * **Pixel micro-batching** — a conv tile's MVM is a pure function
//!   of the input image, so each tile visit drains up to
//!   [`MICRO_BATCH`] upcoming valid pixels' MVMs against the tile's
//!   packed weight panel in one [`Pe::mvm_many_into`] pass and
//!   consumes the stashed results in visit order. **Invariant:** only
//!   the arithmetic is batched — RIFM/link/ROFM charges, probe
//!   events, FIFO/arena occupancy samples and fault-injection sites
//!   all stay per-slot, so `Counters`, recordings and injected faults
//!   are 1:1 with per-pixel draining (asserted by the `engine_perf`
//!   frozen baseline and the capture/flight/fault property suites).
//! * **Capture modes** — [`CaptureMode::AllStages`] clones every stage
//!   output tensor into [`RunOutput::stage_outputs`] (tests, tracing);
//!   [`CaptureMode::Final`] keeps only the final scores (the serving
//!   path), retaining just the skip-source tensors residual stages
//!   need. Capture affects host-side copies only — counters and scores
//!   are bit-identical across modes (property-tested).
//!
//! Steady state is debug-asserted: once an image has completed, a
//! chain's arena must never grow again (the conv event sequence is
//! input-independent), and every `reset()` retains capacity.
//!
//! [`EnginePool`] caches one [`PooledEngine`] per model key; the serve
//! workers key it by registry version id so a multi-model server keeps
//! one warm engine per loaded model per worker thread, and
//! [`Simulator::run_batch_threads`] keeps its per-thread worker engines
//! alive across batch calls instead of spinning state up per batch.
//!
//! [`Simulator::run_batch`] data-parallelizes a batch of images across
//! OS threads (each thread owns an independent engine over the same
//! shared `Program`), merges the per-thread [`Counters`] at the end,
//! and reports the pipelined steady-state timing ([`BatchOutput`]):
//! the measured per-stage slot counts are fed through
//! [`crate::sim::pipeline::run_pipelined`] and cross-asserted against
//! the analytic `perfmodel` period, so every batched run re-validates
//! the throughput model that Table IV is built on. Batched outputs are
//! bit-exact with N sequential `run_image` calls (property-tested in
//! `rust/tests/batch_properties.rs`).
//!
//! Latency semantics: `run_image` executes stages back-to-back and
//! reports per-stage slot counts; pipelined throughput (all layers
//! streaming concurrently, which is how the paper's Table IV execution
//! times arise) is derived from the same per-stage periods and
//! validated against these counts.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::program::*;
use crate::coordinator::schedule::{ConvGeometry, CYCLES_PER_SLOT};
use crate::model::refcompute::Tensor;
use crate::model::TensorShape;
use crate::noc::link::LinkKind;
use crate::noc::packet::{PsumArena, PsumRef};
use crate::sim::fault::{FaultInjector, FaultPlan, FaultReport, Faults, NoFaults};
use crate::sim::flight::{FlightRecorder, NullProbe, Probe, RecorderConfig, Recording, NO_TILE};
use crate::sim::pipeline::{run_pipelined, PipelineRun};
use crate::sim::stats::Counters;
use crate::tile::pe::MICRO_BATCH;
use crate::tile::rofm::{PoolUnit, Rofm};
use crate::tile::{Pe, Rifm};

/// Which stage tensors [`Simulator::run_image`] copies out into
/// [`RunOutput`]. Capture is host-side only: scores, latency, slots and
/// every counter are bit-identical across modes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CaptureMode {
    /// Clone every stage's output tensor into
    /// [`RunOutput::stage_outputs`] (tests, the trace tool, debugging).
    #[default]
    AllStages,
    /// Keep only the final scores; `stage_outputs` stays empty. The
    /// serving path — skips one full tensor clone per stage per image.
    Final,
}

/// What a tile did in a slot — offered to the engine's
/// [`Probe`](crate::sim::flight::Probe) (the Fig. 3(b) trace and the
/// flight recorder consume these via
/// [`Probe::action`](crate::sim::flight::Probe::action)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActionKind {
    /// Accumulated (rx [+ PE]) and forwarded a partial sum.
    Acc { opos: (usize, usize) },
    /// Queued a group-sum into the ROFM buffer.
    Push,
    /// Popped a group-sum from the ROFM buffer.
    Pop,
    /// M-type: applied Act/Quant (+pool) and emitted an output.
    Emit { opos: (usize, usize) },
}

/// Result of simulating one image.
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// Final network output values.
    pub scores: Vec<i8>,
    /// Output tensor of every *stage* under [`CaptureMode::AllStages`];
    /// empty under [`CaptureMode::Final`].
    pub stage_outputs: Vec<Tensor>,
    /// Pixel slots each stage was busy (latency = slots x 2 cycles).
    pub stage_slots: Vec<u64>,
    /// End-to-end latency in instruction cycles (non-pipelined).
    pub latency_cycles: u64,
}

/// Result of simulating a batch of images ([`Simulator::run_batch`]).
#[derive(Clone, Debug)]
pub struct BatchOutput {
    /// Per-image outputs, in input order. Bit-exact with sequential
    /// [`Simulator::run_image`] calls on the same inputs.
    pub outputs: Vec<RunOutput>,
    /// Worker threads actually used.
    pub threads: usize,
    /// Host wall-clock time spent simulating the batch.
    pub wall: Duration,
    /// Pipelined (layer-synchronized) timing of the batch, measured by
    /// the stage-level pipeline simulation and asserted against the
    /// analytic `perfmodel` steady-state period.
    pub pipeline: PipelineRun,
}

impl BatchOutput {
    /// Host-side simulation throughput (how fast *we* simulate), in
    /// images per wall-clock second. Returns 0 for a degenerate run
    /// instead of dividing by zero.
    pub fn images_per_s_wall(&self) -> f64 {
        crate::sim::stats::safe_rate(self.outputs.len() as f64, self.wall.as_secs_f64())
    }

    /// Modeled *hardware* throughput in images/s: the steady-state
    /// pipelined rate at the paper's 10 MHz step clock.
    pub fn modeled_images_per_s(&self) -> f64 {
        self.pipeline.images_per_s
    }
}

/// Per-tile runtime state, built once per engine and reset between
/// images. Owns no borrow of the program: the PE weight block is a
/// lane-blocked **packed copy** made once here (so every MVM runs the
/// blocked panel kernel), which is what lets an engine be pooled
/// behind an `Arc<Program>` and reused across images, batches and
/// server workers. The ROFM owns its compiled schedule (cloned once,
/// at construction — not per image as the pre-batching engine did).
struct TileRt {
    rifm: Rifm,
    rofm: Rofm,
    /// Register-path psum handles from the previous chain tile (lanes
    /// live in the owning chain's arena).
    incoming: VecDeque<PsumRef>,
    /// The tile's stationary weight block, packed into the
    /// lane-blocked panel layout once at engine construction (§Perf).
    pe: Pe<'static>,
    /// Micro-batch MVM stash: `mb_out` holds `mb_pix.len()`
    /// consecutive `cols`-wide results for the upcoming valid pixels
    /// listed in `mb_pix`; `mb_pos` is the consumption cursor. Refilled
    /// by [`Self::refill_mvm_batch`], consumed strictly in visit order.
    mb_out: Vec<i32>,
    mb_pix: Vec<usize>,
    mb_pos: usize,
    /// Reused input-gather scratch for the micro-batch refill (one
    /// alloc per tile, not per slot — §Perf).
    xbuf: Vec<i8>,
}

impl TileRt {
    fn new(t: &ConvTile) -> Self {
        Self {
            rifm: Rifm::new_with_config(t.rifm),
            rofm: Rofm::new(t.schedule.clone()),
            incoming: VecDeque::new(),
            pe: Pe::new(t.weights.clone(), t.rows, t.cols),
            mb_out: Vec::new(),
            mb_pix: Vec::with_capacity(MICRO_BATCH),
            mb_pos: 0,
            xbuf: Vec::with_capacity(t.rows * MICRO_BATCH),
        }
    }

    /// Restore the image-start state (empty queues and buffers, all
    /// counters at zero) — after this the tile is indistinguishable
    /// from a freshly configured one. Performs no allocation: every
    /// `clear` below retains its buffer's capacity (debug-asserted, so
    /// a steady-state reset can never silently start reallocating).
    fn reset(&mut self) {
        let cap = self.incoming.capacity();
        self.incoming.clear();
        debug_assert_eq!(self.incoming.capacity(), cap, "reset must retain capacity");
        self.rifm.reset();
        self.rofm.reset();
        self.mb_out.clear();
        self.mb_pix.clear();
        self.mb_pos = 0;
        self.xbuf.clear();
    }

    /// Whether the micro-batch stash is exhausted (next consumption
    /// must refill first).
    fn mb_drained(&self) -> bool {
        self.mb_pos == self.mb_pix.len()
    }

    /// Consume the stashed MVM result for pixel `p`, returning its
    /// offset into `mb_out`. The event loop visits a tile's valid
    /// pixels in strictly increasing order — exactly the refill order —
    /// so consumption is a cursor walk (debug-asserted).
    fn mb_take(&mut self, p: usize) -> usize {
        debug_assert_eq!(
            self.mb_pix[self.mb_pos], p,
            "micro-batch consumed out of visit order"
        );
        let lo = self.mb_pos * self.pe.cols();
        self.mb_pos += 1;
        lo
    }

    /// Refill the micro-batch stash starting at pixel `from`: gather
    /// up to [`MICRO_BATCH`] upcoming *valid* pixels' input vectors
    /// (invalid raster positions contribute no MVM, exactly as the
    /// per-pixel path skipped them before any compute) and drain their
    /// MVMs against the packed panel in one [`Pe::mvm_many_into`]
    /// call. This is pure computation plus the per-MVM PE charges —
    /// every other charge, probe event and fault site stays per-slot
    /// in the caller, so the observable event stream is identical to
    /// per-pixel draining.
    #[allow(clippy::too_many_arguments)]
    fn refill_mvm_batch(
        &mut self,
        cfg: &ConvTile,
        g: &ConvGeometry,
        padding: usize,
        c_lo: usize,
        wp: usize,
        total_pixels: usize,
        input: &Tensor,
        from: usize,
        st: &mut Counters,
    ) {
        self.mb_pix.clear();
        self.mb_pos = 0;
        self.xbuf.clear();
        let mut idx = from;
        while self.mb_pix.len() < MICRO_BATCH && idx < total_pixels {
            let (pr, u) = (idx / wp, idx % wp);
            if g.out_row(pr, cfg.kr).is_some() && g.out_col(u, cfg.kc).is_some() {
                let (py, px) = (
                    pr as isize - padding as isize,
                    u as isize - padding as isize,
                );
                self.xbuf
                    .extend((0..cfg.rows).map(|dc| input.at_padded(c_lo + dc, py, px)));
                self.mb_pix.push(idx);
            }
            idx += 1;
        }
        let nb = self.mb_pix.len();
        self.mb_out.clear();
        self.mb_out.resize(nb * cfg.cols, 0);
        let mut xs: [&[i8]; MICRO_BATCH] = [&[]; MICRO_BATCH];
        for (b, x) in self.xbuf.chunks_exact(cfg.rows).enumerate() {
            xs[b] = x;
        }
        self.pe.mvm_many_into(&xs[..nb], &mut self.mb_out, st);
    }
}

/// Runtime state of one conv chain.
struct ChainRt {
    tiles: Vec<TileRt>,
    /// Partial-sum lane slab shared by the chain's tiles: psums move
    /// between tiles as `PsumRef` handles into this arena (§Perf).
    arena: PsumArena,
    /// Persistent fused-pooling unit (block reuse), reset per image.
    pool: Option<PoolUnit>,
    /// Arena growth count recorded when the chain first completes an
    /// image. The conv event sequence is input-independent, so steady
    /// state must never grow the slab again (debug-asserted).
    settled_grows: Option<u64>,
}

/// Build the per-stage runtime state for a program: one `ChainRt` per
/// conv chain (residual projections included), empty for tile-less
/// stages. FC stages mount their PEs on the fly (a zero-alloc borrow)
/// and keep no router state in the engine, so they need no slot here.
fn build_state(program: &Program) -> Vec<Vec<ChainRt>> {
    fn conv_state(c: &ConvStage) -> Vec<ChainRt> {
        let g = ConvGeometry::new(c.k, c.stride, c.padding, c.in_shape.h, c.in_shape.w);
        let wp = g.wp();
        c.chains
            .iter()
            .map(|chain| {
                let lanes = chain.tiles.first().map(|t| t.cols).unwrap_or(1).max(1);
                debug_assert!(
                    chain.tiles.iter().all(|t| t.cols == lanes),
                    "all tiles of a chain share the output-channel block width"
                );
                // Worst-case psums in flight: one per tile in transit
                // plus up to one padded row period queued per row-head
                // FIFO. Growth past this estimate is handled by the
                // arena (and debug-asserted absent once steady).
                let row_heads = chain.tiles.iter().filter(|t| t.is_row_head).count();
                let slots = chain.tiles.len() + 2 + row_heads * (wp + 2);
                ChainRt {
                    tiles: chain.tiles.iter().map(TileRt::new).collect(),
                    arena: PsumArena::new(lanes, slots),
                    pool: c.fused_pool.map(|p| {
                        if p.max {
                            PoolUnit::new_max(p.kernel, p.stride)
                        } else {
                            PoolUnit::new_avg(p.kernel, p.stride)
                        }
                    }),
                    settled_grows: None,
                }
            })
            .collect()
    }
    program
        .stages
        .iter()
        .map(|stage| match &stage.kind {
            StageKind::Conv(c) => conv_state(c),
            StageKind::Res(r) => r.proj.as_ref().map(conv_state).unwrap_or_default(),
            _ => Vec::new(),
        })
        .collect()
}

/// Reused per-engine scratch buffers: everything the per-pixel /
/// per-tile inner loops would otherwise `collect()` or allocate
/// (§Perf). Correctness never depends on scratch contents — every user
/// clears or overwrites before reading.
#[derive(Default)]
struct Scratch {
    /// Non-chain-start MVM result, added into the psum slab.
    mac: Vec<i32>,
    /// Activation / emit lane buffer (conv emit, FC output, res add).
    vals: Vec<i8>,
    /// Pixel-lane gathers for pool/res stages (and the res output).
    lanes_a: Vec<i8>,
    lanes_b: Vec<i8>,
    /// FC input-slice gather and column accumulator.
    fc_x: Vec<i8>,
    fc_acc: Vec<i32>,
    /// Skip-source stage tensors retained under [`CaptureMode::Final`]
    /// (indexed by stage; buffers reused across images).
    skip_store: Vec<Option<Tensor>>,
}

/// The owned runtime core of a cycle engine: per-tile state plus
/// aggregate statistics. Borrows nothing from the program — every run
/// method takes the program as a parameter — so one core can sit
/// behind a borrow ([`Simulator`]) or behind shared ownership
/// ([`PooledEngine`]) and stay alive across batches and requests.
///
/// Instrumentation is a type parameter: the core is monomorphized over
/// its [`Probe`]. With the default [`NullProbe`] every probe call
/// compiles to nothing (its callbacks are empty `#[inline(always)]`
/// bodies and `P::ENABLED` is a false constant), so the seam costs
/// zero on the hot path — the `engine_perf` frozen-baseline gate runs
/// against exactly this instantiation.
///
/// Fault injection is a second type parameter with the same contract
/// ([`crate::sim::fault`]): the default [`NoFaults`] compiles every
/// fault hook out, so the `EngineCore<NullProbe, NoFaults>`
/// instantiation — what every pre-existing constructor builds — is the
/// unchanged hot path. A [`FaultInjector`] corrupts psum *values* at
/// the tile-MVM and link-transfer sites; event structure, timing and
/// counters stay clean-run-identical (that is what makes the
/// corruption *silent* and the serve-plane canary necessary).
struct EngineCore<P: Probe = NullProbe, F: Faults = NoFaults> {
    /// Per-stage tile runtime state (indexed by stage; a `Res` stage's
    /// slot holds its projection's chains).
    state: Vec<Vec<ChainRt>>,
    /// Persistent pooling units for standalone `Pool` stages (indexed
    /// by stage), reset per image.
    pool_state: Vec<Option<PoolUnit>>,
    /// Stages whose output a later `Res` stage reads as its skip
    /// source (must be retained under [`CaptureMode::Final`]).
    skip_needed: Vec<bool>,
    /// Reused hot-loop scratch (taken out of `self` for the duration
    /// of a run so stage methods can borrow it alongside `self`).
    scratch: Scratch,
    capture: CaptureMode,
    stats: Counters,
    stage_stats: Vec<Counters>,
    /// The instrumentation sink (statically compiled out for
    /// [`NullProbe`]).
    probe: P,
    /// The fault seam (statically compiled out for [`NoFaults`]).
    faults: F,
}

impl EngineCore {
    fn new(program: &Program) -> Self {
        Self::with_probe(program, NullProbe)
    }
}

impl<P: Probe> EngineCore<P> {
    fn with_probe(program: &Program, probe: P) -> Self {
        Self::with_instruments(program, probe, NoFaults)
    }
}

impl<P: Probe, F: Faults> EngineCore<P, F> {
    fn with_instruments(program: &Program, probe: P, faults: F) -> Self {
        let n = program.stages.len();
        let mut skip_needed = vec![false; n];
        for stage in &program.stages {
            if let StageKind::Res(r) = &stage.kind {
                skip_needed[r.from_stage] = true;
            }
        }
        let pool_state = program
            .stages
            .iter()
            .map(|stage| match &stage.kind {
                StageKind::Pool(p) => Some(if p.max {
                    PoolUnit::new_max(p.kernel, p.stride)
                } else {
                    PoolUnit::new_avg(p.kernel, p.stride)
                }),
                _ => None,
            })
            .collect();
        Self {
            state: build_state(program),
            pool_state,
            skip_needed,
            scratch: Scratch {
                skip_store: (0..n).map(|_| None).collect(),
                ..Default::default()
            },
            capture: CaptureMode::default(),
            stats: Counters::new(),
            stage_stats: vec![Counters::new(); n],
            probe,
            faults,
        }
    }

    /// Zero the aggregate counters. Tile state needs no reset here — it
    /// is restored at the start of every image (and after errors).
    fn reset_stats(&mut self) {
        self.stats = Counters::new();
        for s in &mut self.stage_stats {
            *s = Counters::new();
        }
    }

    /// Simulate one inference on `program` (the program this core was
    /// built for; stage shapes are asserted).
    fn run_image(&mut self, program: &Program, input: &[i8]) -> Result<RunOutput> {
        // Scratch is taken out of `self` for the duration so the stage
        // methods can use it while `self` stays mutably borrowed for
        // state/probe; restored unconditionally (its capacity is
        // the point — contents carry nothing across calls).
        let mut scratch = std::mem::take(&mut self.scratch);
        let result = self.run_image_inner(program, input, &mut scratch);
        self.scratch = scratch;
        result
    }

    fn run_image_inner(
        &mut self,
        program: &Program,
        input: &[i8],
        scratch: &mut Scratch,
    ) -> Result<RunOutput> {
        if input.len() != program.net.input_len() {
            bail!(
                "input length {} != network input {}",
                input.len(),
                program.net.input_len()
            );
        }
        let capture = self.capture;
        let nstages = program.stages.len();
        let mut cur = Tensor::new(program.net.input, input.to_vec());
        let mut stage_outputs: Vec<Tensor> = Vec::with_capacity(match capture {
            CaptureMode::AllStages => nstages,
            CaptureMode::Final => 0,
        });
        let mut stage_slots: Vec<u64> = Vec::with_capacity(nstages);
        let mut total_cycles: u64 = 0;

        // Network input enters / final output leaves the package.
        self.stats.offchip_io_bits += 8 * input.len() as u64;

        let mut prev_exit_chip: Option<usize> = None;
        // The last stage's output: moved (never cloned) into the
        // result, so the final tensor is not copied twice any more.
        let mut final_out: Option<Tensor> = None;
        for (si, stage) in program.stages.iter().enumerate() {
            self.probe.stage_enter(si);
            let mut st = Counters::new();
            let (out, slots) = match &stage.kind {
                StageKind::Conv(c) => {
                    self.run_conv_stage(program, si, c, &cur, scratch, &mut st)?
                }
                StageKind::Fc(f) => {
                    self.run_fc_stage(program, si, f, &cur, scratch, &mut st)?
                }
                StageKind::Pool(p) => {
                    let unit = self.pool_state[si]
                        .as_mut()
                        .expect("pool unit built at engine construction");
                    unit.reset();
                    run_pool_stage(p, si, &cur, unit, scratch, &mut st, &mut self.probe)?
                }
                StageKind::Res(r) => {
                    // The skip source: the captured stage tensor
                    // (AllStages) or the retained copy (Final) — the
                    // latter is taken out of the scratch store for the
                    // duration so the projection conv / res loop can
                    // borrow the scratch buffers.
                    let taken: Option<Tensor> = match capture {
                        CaptureMode::AllStages => None,
                        CaptureMode::Final => Some(
                            scratch.skip_store[r.from_stage].take().with_context(|| {
                                format!(
                                    "stage {si}: skip source stage {} was not retained",
                                    r.from_stage
                                )
                            })?,
                        ),
                    };
                    let skip_src: &Tensor = match &taken {
                        Some(t) => t,
                        None => &stage_outputs[r.from_stage],
                    };
                    let projected: Option<Tensor> = match &r.proj {
                        Some(pstage) => {
                            let (t, s2) = self
                                .run_conv_stage(program, si, pstage, skip_src, scratch, &mut st)?;
                            total_cycles += s2 * CYCLES_PER_SLOT as u64;
                            Some(t)
                        }
                        None => None,
                    };
                    let skip: &Tensor = projected.as_ref().unwrap_or(skip_src);
                    let res =
                        run_res_stage(r, si, &cur, skip, scratch, &mut st, &mut self.probe)?;
                    // put the retained skip back (a later stage may
                    // also read it, and its buffer is reused next image)
                    if let Some(t) = taken {
                        scratch.skip_store[r.from_stage] = Some(t);
                    }
                    res
                }
                StageKind::Flatten => {
                    let t = Tensor::new(
                        TensorShape::new(cur.shape.len(), 1, 1),
                        cur.data.clone(),
                    );
                    (t, 0)
                }
            };
            // Stage hand-off across a chip boundary goes through the
            // 80 Gb/s transceivers (the OFM tensor crosses once).
            let entry = stage_entry_chip(stage);
            if let (Some(prev), Some(this)) = (prev_exit_chip, entry) {
                if prev != this {
                    let bits = 8 * cur.shape.len() as u64;
                    st.interchip_bits += bits;
                    self.probe.link(
                        si,
                        NO_TILE as usize,
                        NO_TILE as usize,
                        0,
                        LinkKind::InterChip,
                        bits,
                    );
                }
            }
            prev_exit_chip = stage_exit_chip(stage).or(prev_exit_chip);

            st.steps += slots * CYCLES_PER_SLOT as u64;
            st.tiles_used += stage.tile_count() as u64;
            total_cycles += slots * CYCLES_PER_SLOT as u64;
            self.probe.stage_exit(si, slots as usize);
            self.stage_stats[si].merge(&st);
            self.stats.merge(&st);
            stage_slots.push(slots);
            if si + 1 == nstages {
                final_out = Some(out);
            } else {
                match capture {
                    CaptureMode::AllStages => {
                        stage_outputs.push(out.clone());
                        cur = out;
                    }
                    CaptureMode::Final => {
                        if self.skip_needed[si] {
                            // retain a copy for the consuming Res
                            // stage, reusing the previous image's
                            // buffer when one exists
                            if let Some(t) = &mut scratch.skip_store[si] {
                                t.shape = out.shape;
                                t.data.clear();
                                t.data.extend_from_slice(&out.data);
                            } else {
                                scratch.skip_store[si] = Some(out.clone());
                            }
                        }
                        cur = out;
                    }
                }
            }
        }
        // `final_out` is None only for a stage-less program, where the
        // input passes through unchanged.
        let fin = final_out.unwrap_or(cur);
        self.stats.offchip_io_bits += 8 * fin.data.len() as u64;
        let scores = match capture {
            CaptureMode::AllStages => {
                let scores = fin.data.clone();
                stage_outputs.push(fin);
                scores
            }
            CaptureMode::Final => fin.data,
        };

        Ok(RunOutput {
            scores,
            stage_outputs,
            stage_slots,
            latency_cycles: total_cycles,
        })
    }

    /// Simulate one conv stage (also used for 1x1 residual projections).
    fn run_conv_stage(
        &mut self,
        program: &Program,
        si: usize,
        c: &ConvStage,
        input: &Tensor,
        scratch: &mut Scratch,
        st: &mut Counters,
    ) -> Result<(Tensor, u64)> {
        assert_eq!(input.shape, c.in_shape, "conv stage input shape");
        let g = ConvGeometry::new(c.k, c.stride, c.padding, c.in_shape.h, c.in_shape.w);
        let total_pixels = g.wp() * g.hp();

        // Output collection (pre-pool).
        let mut conv_out = Tensor::zeros(c.out_shape);
        // Fused pooling (block reuse): pool the OFM stream in flight.
        let mut pool_out_shape = c.out_shape;
        if let Some(p) = c.fused_pool {
            pool_out_shape = TensorShape::new(
                c.out_shape.c,
                (c.out_shape.h - p.kernel) / p.stride + 1,
                (c.out_shape.w - p.kernel) / p.stride + 1,
            );
        }
        let mut pooled = Tensor::zeros(pool_out_shape);

        // Mount this stage's persistent tile state (built once when the
        // engine was constructed, reset per image inside). Taken out of
        // `self` for the duration of the stage so the recorder can
        // still borrow `self` mutably; restored before any error
        // propagates so a caught simulation error cannot leave the
        // stage with silently-empty state.
        let mut chains_rt = std::mem::take(&mut self.state[si]);
        assert_eq!(chains_rt.len(), c.chains.len(), "stage state shape");
        let result = self.run_conv_chains(
            program, si, c, &g, input, scratch, st, &mut chains_rt, &mut conv_out, &mut pooled,
        );
        self.state[si] = chains_rt;
        result?;

        let out = if c.fused_pool.is_some() {
            pooled
        } else {
            conv_out
        };
        // With weight duplication each of the `dup` replica arrays
        // streams 1/dup of the pixels concurrently; the engine simulates
        // one replica over the full stream (identical events, identical
        // outputs) and reports the synchronized stage period.
        let n = c.chains.iter().map(|ch| ch.tiles.len()).max().unwrap_or(0) as u64;
        let slots = (total_pixels as u64).div_ceil(c.dup as u64) + n;
        Ok((out, slots))
    }

    /// The chain-by-chain event loop of a conv stage, over the stage's
    /// mounted runtime state. Separated from [`Self::run_conv_stage`]
    /// so the caller can unconditionally restore the state afterwards.
    ///
    /// §Perf: the loop is allocation-free. Partial sums live in the
    /// chain's psum slab arena and move between tiles as `Copy`
    /// handles; MVMs write into slab slots or reused scratch; emits
    /// requantize into reused scratch.
    #[allow(clippy::too_many_arguments)]
    fn run_conv_chains(
        &mut self,
        program: &Program,
        si: usize,
        c: &ConvStage,
        g: &ConvGeometry,
        input: &Tensor,
        scratch: &mut Scratch,
        st: &mut Counters,
        chains_rt: &mut [ChainRt],
        conv_out: &mut Tensor,
        pooled: &mut Tensor,
    ) -> Result<()> {
        let wp = g.wp();
        let hp = g.hp();
        let total_pixels = wp * hp;
        for (chain, chain_rt) in c.chains.iter().zip(chains_rt.iter_mut()) {
            let ChainRt {
                tiles,
                arena,
                pool,
                settled_grows,
            } = chain_rt;
            // Image-start state: queues empty, arena slots free, pool
            // windows recycled, counters at zero. All resets retain
            // capacity (no allocation in steady state).
            for t in tiles.iter_mut() {
                t.reset();
            }
            arena.reset();
            if let Some(unit) = pool.as_mut() {
                unit.reset();
            }
            let n = tiles.len();
            let m_lanes = chain.m_hi - chain.m_lo;
            let lanes = arena.lanes();
            scratch.mac.clear();
            scratch.mac.resize(lanes, 0);

            for slot in 0..(total_pixels + n) {
                for ci in 0..n {
                    let Some(p) = slot.checked_sub(ci) else { continue };
                    if p >= total_pixels {
                        continue;
                    }
                    let cfg = &chain.tiles[ci];
                    let (pr, u) = (p / wp, p % wp);

                    // ---- RIFM: receive the IFM beat (with in-buffer
                    // shift packing, several positions share one beat).
                    let pack = match cfg.rifm.shift_step {
                        64 => 4,
                        128 => 2,
                        _ => 1,
                    };
                    let bits = (cfg.rows * 8) as u64;
                    if p % pack == 0 {
                        // one physical beat received & forwarded
                        st.rifm_buffer_accesses += 1;
                        st.rifm_ctrl_steps += 1;
                        if cfg.rifm.forward {
                            let kind = if ci + 1 < n {
                                LinkKind::between(
                                    cfg.coord.chip,
                                    chain.tiles[ci + 1].coord.chip,
                                )
                            } else {
                                LinkKind::OnChip
                            };
                            match kind {
                                LinkKind::InterChip => st.interchip_bits += bits * pack as u64,
                                LinkKind::OnChip => st.onchip_link_bits += bits * pack as u64,
                            }
                            self.probe
                                .link(si, chain.mblock, ci, slot, kind, bits * pack as u64);
                        }
                    } else {
                        st.rifm_shifts += 1;
                    }
                    // ROFM schedule fetch + controller: live every
                    // cycle the stream occupies the tile.
                    st.sched_fetches += CYCLES_PER_SLOT as u64;
                    st.rofm_ctrl_steps += CYCLES_PER_SLOT as u64;

                    let c_lo = cfg.cb * program.arch.n_c;

                    // ---- validity: does this slot contribute?
                    let (Some(oy), Some(ox)) = (g.out_row(pr, cfg.kr), g.out_col(u, cfg.kc))
                    else {
                        continue;
                    };
                    let opos = (oy, ox);

                    // The RIFM-buffer read feeding the PE is the CIM
                    // array's wordline activation ("in-memory computing
                    // starts from the RIFM buffer", Section II-A) — its
                    // energy is inside the inherited CIM j/MAC, so it is
                    // not double-charged to the router here.
                    //
                    // ---- MVM micro-batch (§Perf): the stationary
                    // weight panel is streamed once per MICRO_BATCH
                    // valid pixels instead of once per pixel. Results
                    // are stashed and consumed in visit order, so every
                    // charge, probe event and fault site below still
                    // fires per-slot, exactly as before.
                    if tiles[ci].mb_drained() {
                        tiles[ci].refill_mvm_batch(
                            cfg,
                            g,
                            c.padding,
                            c_lo,
                            wp,
                            total_pixels,
                            input,
                            p,
                            st,
                        );
                    }
                    let mac_lo = tiles[ci].mb_take(p);

                    // ---- psum accumulation (COM) over the slab arena.
                    // `None` = single-tile chain: the sum completes in
                    // this slot, accumulate in scratch, no slot needed.
                    let sum_ref: Option<PsumRef> = if cfg.is_chain_start {
                        if cfg.is_last {
                            scratch
                                .mac
                                .copy_from_slice(&tiles[ci].mb_out[mac_lo..mac_lo + lanes]);
                            self.faults.tile_psum(si, cfg.coord, slot, &mut scratch.mac);
                            None
                        } else {
                            let r = arena.alloc(opos);
                            arena
                                .data_mut(r)
                                .copy_from_slice(&tiles[ci].mb_out[mac_lo..mac_lo + lanes]);
                            self.faults.tile_psum(si, cfg.coord, slot, arena.data_mut(r));
                            Some(r)
                        }
                    } else {
                        let prev = if cfg.is_row_head {
                            let popped = tiles[ci].rofm.pop_group(st);
                            self.probe.action(si, chain.mblock, ci, slot, ActionKind::Pop);
                            popped
                        } else {
                            tiles[ci].incoming.pop_front()
                        };
                        let Some(mut prev) = prev else {
                            bail!(
                                "stage {si} chain {} tile {ci} slot {slot}: no psum for {opos:?} \
                                 (schedule/placement bug)",
                                chain.mblock
                            );
                        };
                        if prev.opos != opos {
                            bail!(
                                "stage {si} chain {} tile {ci} slot {slot}: psum tag {:?} != {opos:?}",
                                chain.mblock,
                                prev.opos
                            );
                        }
                        prev.opos = opos;
                        scratch
                            .mac
                            .copy_from_slice(&tiles[ci].mb_out[mac_lo..mac_lo + lanes]);
                        // a faulty tile corrupts *its own* MVM
                        // contribution; the accumulated psum from
                        // upstream still passes through it intact
                        self.faults.tile_psum(si, cfg.coord, slot, &mut scratch.mac);
                        Rofm::add_psum_slices(arena.data_mut(prev), &scratch.mac, st);
                        Some(prev)
                    };

                    // ---- hand-off
                    if cfg.is_last {
                        // M-type: requantize (+ReLU), emit OFM
                        let sum: &[i32] = match sum_ref {
                            None => &scratch.mac,
                            Some(r) => arena.data(r),
                        };
                        if c.relu {
                            Rofm::act_into(sum, c.shift, &mut scratch.vals, st);
                        } else {
                            Rofm::quantize_into(sum, c.shift, &mut scratch.vals, st);
                        }
                        self.probe
                            .action(si, chain.mblock, ci, slot, ActionKind::Emit { opos });
                        for (lane, &v) in scratch.vals.iter().enumerate() {
                            conv_out.set(chain.m_lo + lane, oy, ox, v);
                        }
                        // fused pooling on the OFM stream
                        if let Some(unit) = pool.as_mut() {
                            unit.offer_each(opos, &scratch.vals, st, |(poy, pox), pv| {
                                for (lane, &v) in pv.iter().enumerate() {
                                    pooled.set(chain.m_lo + lane, poy, pox, v);
                                }
                            });
                        }
                        // OFM beat leaves through the output regs + link
                        let obits = (m_lanes * 8) as u64;
                        Rofm::charge_tx(obits, st);
                        st.onchip_link_bits += obits;
                        self.probe
                            .link(si, chain.mblock, ci, slot, LinkKind::OnChip, obits);
                        if let Some(r) = sum_ref {
                            arena.free(r);
                        }
                    } else {
                        // transmit the psum handle to the next chain tile
                        let r = sum_ref.expect("non-last tiles always carry a slab psum");
                        let pbits = (lanes * 32) as u64;
                        Rofm::charge_tx(pbits, st);
                        let kind =
                            LinkKind::between(cfg.coord.chip, chain.tiles[ci + 1].coord.chip);
                        match kind {
                            LinkKind::InterChip => st.interchip_bits += pbits,
                            LinkKind::OnChip => st.onchip_link_bits += pbits,
                        }
                        self.probe.link(si, chain.mblock, ci, slot, kind, pbits);
                        self.faults.link_psum(
                            si,
                            cfg.coord,
                            chain.tiles[ci + 1].coord,
                            slot,
                            kind,
                            arena.data_mut(r),
                        );
                        self.probe
                            .action(si, chain.mblock, ci, slot, ActionKind::Acc { opos });
                        let next_is_row_head = chain.tiles[ci + 1].is_row_head;
                        if next_is_row_head {
                            tiles[ci + 1].rofm.push_group(r, lanes, st);
                            self.probe
                                .action(si, chain.mblock, ci + 1, slot, ActionKind::Push);
                        } else {
                            Rofm::charge_rx(pbits, st);
                            tiles[ci + 1].incoming.push_back(r);
                        }
                    }
                }
                // End-of-slot occupancy samples (Fig. 6-style timelines):
                // group-sums queued per row-head FIFO + psum slab usage.
                // Guarded on the probe's static switch so the NullProbe
                // engine never even walks the tiles.
                if P::ENABLED {
                    for (ci, t) in tiles.iter().enumerate() {
                        if chain.tiles[ci].is_row_head {
                            self.probe.fifo_depth(
                                si,
                                chain.mblock,
                                ci,
                                slot,
                                t.rofm.fifo_len(),
                            );
                        }
                    }
                    let (in_use, cap) = arena.occupancy();
                    self.probe.arena_in_use(si, chain.mblock, slot, in_use, cap);
                }
            }

            // chain must drain completely — queues, FIFOs and the slab
            for (ci, t) in tiles.iter().enumerate() {
                if !t.incoming.is_empty() || t.rofm.fifo_len() != 0 {
                    bail!(
                        "conv chain {} tile {ci}: {} psums / {} group-sums undrained",
                        chain.mblock,
                        t.incoming.len(),
                        t.rofm.fifo_len()
                    );
                }
            }
            if arena.in_use() != 0 {
                bail!(
                    "conv chain {}: {} psum slab slots leaked",
                    chain.mblock,
                    arena.in_use()
                );
            }
            // §Perf: the slab settles after the first image — the conv
            // event stream is input-independent, so any later growth
            // means the pre-sizing estimate and the engine diverged.
            match settled_grows {
                None => *settled_grows = Some(arena.grows()),
                Some(g0) => debug_assert_eq!(
                    arena.grows(),
                    *g0,
                    "stage {si} chain {}: psum slab grew in steady state",
                    chain.mblock
                ),
            }
        }
        Ok(())
    }

    /// Simulate an FC stage (paper Fig. 2): input slices stream to each
    /// column; partial sums accumulate down the column; the bottom tile
    /// activates and emits its output slice. §Perf: the per-tile input
    /// gather and the column accumulator live in reused scratch — the
    /// loop allocates nothing.
    fn run_fc_stage(
        &mut self,
        program: &Program,
        si: usize,
        f: &FcStage,
        input: &Tensor,
        scratch: &mut Scratch,
        st: &mut Counters,
    ) -> Result<(Tensor, u64)> {
        if input.shape.len() != f.in_features {
            bail!(
                "fc stage: input {} != in_features {}",
                input.shape.len(),
                f.in_features
            );
        }
        let mut out = vec![0i8; f.out_features];
        let mut max_slot = 0u64;
        for (coli, col) in f.columns.iter().enumerate() {
            for (rb, t) in col.tiles.iter().enumerate() {
                // slice of the input vector this tile multiplies
                let i_lo = rb * program.arch.n_c;
                scratch.fc_x.clear();
                scratch
                    .fc_x
                    .extend((0..t.rows).map(|d| input.data[i_lo + d]));
                // RIFM receives the slice (one beat write; the PE-feed
                // read is the CIM wordline activation, charged in j/MAC)
                st.rifm_buffer_accesses += 1;
                st.rifm_ctrl_steps += 1;
                st.sched_fetches += 1;
                st.rofm_ctrl_steps += 1;
                let ibits = (t.rows * 8) as u64;
                st.onchip_link_bits += ibits;
                self.probe.link(si, coli, rb, rb, LinkKind::OnChip, ibits);
                // FC mounts run exactly one MVM per weight block, so a
                // packed copy would cost as much as it saves: the
                // zero-alloc borrow takes the blocked row-major kernel.
                let pe = Pe::borrowed(&t.weights, t.rows, t.cols);
                if rb == 0 {
                    // column head: the accumulator starts from this MVM
                    scratch.fc_acc.clear();
                    scratch.fc_acc.resize(t.cols, 0);
                    pe.mvm_into(&scratch.fc_x, &mut scratch.fc_acc, st);
                    self.faults.tile_psum(si, t.coord, rb, &mut scratch.fc_acc);
                } else {
                    scratch.mac.clear();
                    scratch.mac.resize(t.cols, 0);
                    pe.mvm_into(&scratch.fc_x, &mut scratch.mac, st);
                    self.faults.tile_psum(si, t.coord, rb, &mut scratch.mac);
                    // psum moved one hop down the column
                    let pbits = (scratch.fc_acc.len() * 32) as u64;
                    let kind =
                        LinkKind::between(col.tiles[rb - 1].coord.chip, t.coord.chip);
                    match kind {
                        LinkKind::InterChip => st.interchip_bits += pbits,
                        LinkKind::OnChip => st.onchip_link_bits += pbits,
                    }
                    self.probe.link(si, coli, rb, rb, kind, pbits);
                    // the column psum is in flight over this link
                    self.faults.link_psum(
                        si,
                        col.tiles[rb - 1].coord,
                        t.coord,
                        rb,
                        kind,
                        &mut scratch.fc_acc,
                    );
                    Rofm::charge_rx(pbits, st);
                    Rofm::add_psum_slices(&mut scratch.fc_acc, &scratch.mac, st);
                }
                max_slot = max_slot.max((rb + 1) as u64);
            }
            anyhow::ensure!(!col.tiles.is_empty(), "fc column has tiles");
            if f.relu {
                Rofm::act_into(&scratch.fc_acc, f.shift, &mut scratch.vals, st);
            } else {
                Rofm::quantize_into(&scratch.fc_acc, f.shift, &mut scratch.vals, st);
            }
            let obits = (scratch.vals.len() * 8) as u64;
            Rofm::charge_tx(obits, st);
            st.onchip_link_bits += obits;
            self.probe.link(
                si,
                coli,
                col.tiles.len() - 1,
                col.tiles.len(),
                LinkKind::OnChip,
                obits,
            );
            out[col.c_lo..col.c_hi].copy_from_slice(&scratch.vals);
        }
        Ok((
            Tensor::new(TensorShape::new(f.out_features, 1, 1), out),
            max_slot + 1,
        ))
    }
}

/// The simulator: a cycle engine borrowing its compiled program. Holds
/// the per-tile runtime state and aggregate statistics across all
/// images run, plus a pool of per-thread worker engines that
/// [`Self::run_batch_threads`] builds once and reuses across batch
/// calls (no per-batch state spin-up).
pub struct Simulator<'p, P: Probe = NullProbe, F: Faults = NoFaults> {
    program: &'p Program,
    core: EngineCore<P, F>,
    /// Reusable worker engines for the batched path: grown on first
    /// use, counters reset and tile state reused on every subsequent
    /// batch. Worker probes and fault injectors are forked from the
    /// main ones and merged back in chunk order after every batch.
    batch_workers: Vec<EngineCore<P, F>>,
}

impl<'p> Simulator<'p> {
    /// A simulator capturing every stage tensor
    /// ([`CaptureMode::AllStages`], the historical default — tests and
    /// tooling read intermediate tensors). Instrumentation is the
    /// zero-cost [`NullProbe`].
    pub fn new(program: &'p Program) -> Self {
        Self::with_probe(program, NullProbe)
    }

    /// A simulator with an explicit [`CaptureMode`] — use
    /// [`CaptureMode::Final`] on throughput paths to skip one tensor
    /// clone per stage per image.
    pub fn with_capture(program: &'p Program, capture: CaptureMode) -> Self {
        let mut s = Self::new(program);
        s.core.capture = capture;
        s
    }
}

impl<'p> Simulator<'p, FlightRecorder> {
    /// A simulator whose engine streams every instrumentation event
    /// (tile actions, link transfers, stage boundaries, occupancy
    /// samples) into a bounded flight-recorder ring — see
    /// [`crate::sim::flight`].
    pub fn with_recorder(program: &'p Program, cfg: RecorderConfig) -> Self {
        Self::with_probe(program, FlightRecorder::new(cfg))
    }
}

/// Recording accessors for *any* recorder-probed simulator — with or
/// without a fault injector, so a faulty run's event stream can be
/// diffed against a clean one's ([`crate::sim::flight::diff`]).
impl<'p, F: Faults> Simulator<'p, FlightRecorder, F> {
    /// Snapshot the recorded event stream. After a threaded batch the
    /// per-worker recordings are already merged in chunk order, so the
    /// stream is in sequential image order regardless of thread count.
    pub fn recording(&self) -> Recording {
        self.core.probe.recording()
    }

    /// Drop buffered events and restart the eviction counter.
    pub fn clear_recording(&mut self) {
        self.core.probe.clear();
    }
}

impl<'p, P: Probe> Simulator<'p, P> {
    /// A simulator over an explicit probe (see [`crate::sim::flight`]
    /// for the event seam; [`Simulator::with_recorder`] is the common
    /// instrumented constructor).
    pub fn with_probe(program: &'p Program, probe: P) -> Self {
        Self::with_instruments(program, probe, NoFaults)
    }
}

impl<'p> Simulator<'p, NullProbe, FaultInjector> {
    /// A simulator whose engine deterministically injects the given
    /// [`FaultPlan`] (see [`crate::sim::fault`]): matching tile MVM
    /// outputs and psum link transfers have their *values* corrupted in
    /// place, while event structure, timing and counters stay
    /// clean-run-identical. [`Self::fault_report`] says what fired.
    pub fn with_faults(program: &'p Program, plan: FaultPlan) -> Self {
        Self::with_instruments(program, NullProbe, FaultInjector::new(plan))
    }
}

/// Fault-report accessors for *any* injector-armed simulator — with
/// or without a probe, so an instrumented faulty run can both report
/// and be diffed.
impl<'p, P: Probe> Simulator<'p, P, FaultInjector> {
    /// Which sites fired so far, when, and their blast radius. After a
    /// threaded batch the per-worker fire counters are already merged,
    /// so the report is thread-count-invariant.
    pub fn fault_report(&self) -> FaultReport {
        self.core.faults.report()
    }

    /// The armed fault plan.
    pub fn fault_plan(&self) -> &FaultPlan {
        self.core.faults.plan()
    }
}

impl<'p, P: Probe, F: Faults> Simulator<'p, P, F> {
    /// The fully general constructor: an explicit probe *and* an
    /// explicit fault implementation. [`Simulator::with_probe`] /
    /// [`Simulator::with_faults`] are the common special cases.
    pub fn with_instruments(program: &'p Program, probe: P, faults: F) -> Self {
        Self {
            program,
            core: EngineCore::with_instruments(program, probe, faults),
            batch_workers: Vec::new(),
        }
    }

    /// Change the capture mode for subsequent runs (batch workers pick
    /// it up on their next batch).
    pub fn set_capture(&mut self, capture: CaptureMode) {
        self.core.capture = capture;
    }

    /// The current capture mode.
    pub fn capture(&self) -> CaptureMode {
        self.core.capture
    }

    /// Aggregate counters across all images simulated so far.
    pub fn stats(&self) -> &Counters {
        &self.core.stats
    }

    /// Per-stage counters.
    pub fn stage_stats(&self) -> &[Counters] {
        &self.core.stage_stats
    }

    /// Simulate one inference.
    pub fn run_image(&mut self, input: &[i8]) -> Result<RunOutput> {
        self.core.run_image(self.program, input)
    }

    /// Simulate a batch of images, data-parallel across up to
    /// `available_parallelism` threads. See [`Self::run_batch_threads`].
    pub fn run_batch<T: AsRef<[i8]> + Sync>(&mut self, inputs: &[T]) -> Result<BatchOutput> {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        self.run_batch_threads(inputs, threads)
    }

    /// Simulate a batch of images with at most `threads` worker
    /// threads.
    ///
    /// Each worker owns a persistent engine over the same shared
    /// program — built the first time a batch needs it, kept on this
    /// simulator and reused (counters reset) by every later batch —
    /// and simulates a contiguous chunk of the batch; per-image
    /// outputs come back in input order and are **bit-exact** with
    /// sequential [`Self::run_image`] calls. The per-thread
    /// [`Counters`] are merged (in chunk order, deterministically) into
    /// this simulator's aggregate stats, so `stats()` after a batch
    /// equals `stats()` after the same images run sequentially.
    ///
    /// The returned [`BatchOutput::pipeline`] carries the
    /// layer-synchronized steady-state timing of the batch; the
    /// measured per-stage busy slots and the measured steady-state
    /// period are asserted against the analytic `perfmodel` (an error
    /// here means the engine and the throughput model diverged, which
    /// Table IV numbers must never silently survive).
    ///
    /// Recording probes do **not** serialize the batch: each worker
    /// runs its own forked probe, and the per-worker event streams are
    /// absorbed back in chunk order, so the recorded stream equals the
    /// sequential-image-order stream for any thread count (as long as
    /// no single worker overflows its ring).
    pub fn run_batch_threads<T: AsRef<[i8]> + Sync>(
        &mut self,
        inputs: &[T],
        threads: usize,
    ) -> Result<BatchOutput> {
        if inputs.is_empty() {
            bail!("run_batch needs at least one image");
        }
        let threads = threads.clamp(1, inputs.len());
        let t0 = Instant::now();
        let program = self.program;
        let chunk_size = inputs.len().div_ceil(threads);
        // With contiguous chunking the spawned-worker count is the
        // chunk count, which can be below the requested thread count
        // (5 images / 4 threads -> 3 chunks of 2). Report what runs.
        let threads = inputs.len().div_ceil(chunk_size);

        let mut outputs: Vec<RunOutput> = Vec::with_capacity(inputs.len());
        if threads == 1 {
            // Run on *this* engine (its probe records directly).
            for input in inputs {
                outputs.push(self.core.run_image(program, input.as_ref())?);
            }
        } else {
            // Grow the persistent worker-engine pool to the spawned
            // worker count, then lend one engine to each scoped thread.
            // Worker probes are forked from the main probe (same
            // configuration, empty buffers).
            while self.batch_workers.len() < threads {
                self.batch_workers.push(EngineCore::with_instruments(
                    program,
                    self.core.probe.fork(),
                    self.core.faults.fork(),
                ));
            }
            let capture = self.core.capture;
            let workers = &mut self.batch_workers[..threads];
            for w in workers.iter_mut() {
                w.reset_stats();
                // workers inherit this simulator's capture mode; any
                // events or fault fires left from a previous (possibly
                // failed) batch are dropped
                w.capture = capture;
                w.probe.clear();
                w.faults.clear();
            }
            let joined: Vec<std::thread::Result<Result<Vec<RunOutput>>>> =
                std::thread::scope(|s| {
                    let handles: Vec<_> = inputs
                        .chunks(chunk_size)
                        .zip(workers.iter_mut())
                        .map(|(chunk, core)| {
                            s.spawn(move || -> Result<Vec<RunOutput>> {
                                let mut outs = Vec::with_capacity(chunk.len());
                                for input in chunk {
                                    outs.push(core.run_image(program, input.as_ref())?);
                                }
                                Ok(outs)
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join()).collect()
                });
            for res in joined {
                let outs =
                    res.map_err(|_| anyhow::anyhow!("batch worker thread panicked"))??;
                outputs.extend(outs);
            }
            // Merge per-worker counters and probe events in chunk
            // order (deterministic: concatenating contiguous chunks in
            // order reproduces the sequential image order). Reached
            // only when every chunk succeeded, so a failed batch never
            // pollutes the aggregate stats or the recording (workers
            // are reset at the top of the next batch either way).
            for w in &mut self.batch_workers[..threads] {
                self.core.stats.merge(&w.stats);
                for (agg, st) in self.core.stage_stats.iter_mut().zip(&w.stage_stats) {
                    agg.merge(st);
                }
                self.core.probe.absorb(&mut w.probe);
                self.core.faults.absorb(&mut w.faults);
            }
        }
        let wall = t0.elapsed();

        let pipeline = self.pipeline_report(&outputs)?;
        Ok(BatchOutput {
            outputs,
            threads,
            wall,
            pipeline,
        })
    }

    /// Pipelined steady-state timing for a set of simulated images:
    /// checks the measured per-stage busy slots against the analytic
    /// model, runs the layer-synchronized pipeline simulation, and
    /// asserts its measured steady-state period equals the analytic
    /// period (the quantity Table IV throughput is derived from).
    fn pipeline_report(&self, outputs: &[RunOutput]) -> Result<PipelineRun> {
        let est = crate::perfmodel::estimate(self.program)
            .context("analytic estimate for pipeline report")?;
        // Measured busy slots are input-independent: check image 0.
        // (`Res` stages book their projection conv separately from
        // their own slot count, so they are compared via total latency
        // instead — which covers every stage including projections.)
        if let Some(out) = outputs.first() {
            for (si, stage) in self.program.stages.iter().enumerate() {
                if matches!(stage.kind, StageKind::Res(_)) {
                    continue;
                }
                let measured = out.stage_slots[si];
                let analytic = est.stages[si].slots;
                if measured != analytic {
                    bail!(
                        "stage {si} ({}): measured {measured} busy slots != analytic {analytic} \
                         (engine/perfmodel divergence)",
                        stage.name
                    );
                }
            }
            if out.latency_cycles != est.latency_cycles {
                bail!(
                    "measured latency {} cycles != analytic {} (engine/perfmodel divergence)",
                    out.latency_cycles,
                    est.latency_cycles
                );
            }
        }
        let run = run_pipelined(self.program, &est, outputs.len().max(1))?;
        if run.steady_period_cycles != est.period_cycles {
            bail!(
                "measured steady-state period {} cycles != analytic {} \
                 (pipeline/perfmodel divergence)",
                run.steady_period_cycles,
                est.period_cycles
            );
        }
        Ok(run)
    }
}

/// A cycle engine that shares ownership of its compiled program, for
/// long-lived reuse: built once, kept in an [`EnginePool`], reset
/// between uses. Runs are bit-exact with a fresh [`Simulator`] over
/// the same program (property-tested in
/// `rust/tests/batch_properties.rs`).
///
/// Pooled engines default to [`CaptureMode::Final`] — they exist for
/// the serving hot path, which reads only `scores`. Use
/// [`Self::set_capture`] when intermediate tensors are needed.
pub struct PooledEngine {
    program: Arc<Program>,
    core: EngineCore,
}

impl PooledEngine {
    /// Build the per-tile runtime state once for `program`
    /// (capture defaults to [`CaptureMode::Final`]).
    pub fn new(program: Arc<Program>) -> Self {
        let mut core = EngineCore::new(&program);
        core.capture = CaptureMode::Final;
        Self { program, core }
    }

    /// [`Self::new`] with an explicit capture mode.
    pub fn with_capture(program: Arc<Program>, capture: CaptureMode) -> Self {
        let mut e = Self::new(program);
        e.core.capture = capture;
        e
    }

    /// Change the capture mode for subsequent runs.
    pub fn set_capture(&mut self, capture: CaptureMode) {
        self.core.capture = capture;
    }

    /// The current capture mode.
    pub fn capture(&self) -> CaptureMode {
        self.core.capture
    }

    /// The program this engine executes.
    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// Simulate one inference — identical semantics to
    /// [`Simulator::run_image`]. Counters accumulate across calls until
    /// [`Self::reset_stats`].
    pub fn run_image(&mut self, input: &[i8]) -> Result<RunOutput> {
        self.core.run_image(&self.program, input)
    }

    /// Aggregate counters across all images run since the last reset.
    pub fn stats(&self) -> &Counters {
        &self.core.stats
    }

    /// Per-stage counters.
    pub fn stage_stats(&self) -> &[Counters] {
        &self.core.stage_stats
    }

    /// Zero the counters (for callers that want per-run counters out of
    /// a reused engine). Tile state needs no reset — it is restored at
    /// the start of every image.
    pub fn reset_stats(&mut self) {
        self.core.reset_stats();
    }
}

/// A cache of reusable engines keyed by the caller's model key (the
/// serve layer keys it by registry version id): each engine is built
/// once per key and reused for every subsequent image, replacing the
/// per-batch / per-request state spin-up. One pool per worker thread —
/// the pool itself is not shared across threads.
#[derive(Default)]
pub struct EnginePool {
    engines: HashMap<u64, PooledEngine>,
}

impl EnginePool {
    pub fn new() -> Self {
        Self::default()
    }

    /// The engine for `key`, built from `program` on first use. The
    /// key must uniquely identify the program (e.g. a model-registry
    /// version id): an existing engine is returned as-is.
    pub fn engine(&mut self, key: u64, program: &Arc<Program>) -> &mut PooledEngine {
        self.engines
            .entry(key)
            .or_insert_with(|| PooledEngine::new(Arc::clone(program)))
    }

    /// Drop every engine whose key is not in `live` (its model was
    /// unloaded or swapped away). A key that comes back later — e.g. a
    /// still-queued request holding an unloaded model version — simply
    /// rebuilds its engine on demand.
    pub fn retain_keys(&mut self, live: &HashSet<u64>) {
        self.engines.retain(|k, _| live.contains(k));
    }

    /// Number of cached engines.
    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }
}

/// First chip a stage's tiles occupy (None for tile-less stages).
fn stage_entry_chip(stage: &Stage) -> Option<usize> {
    match &stage.kind {
        StageKind::Conv(c) => c.chains.first()?.tiles.first().map(|t| t.coord.chip),
        StageKind::Fc(f) => f.columns.first()?.tiles.first().map(|t| t.coord.chip),
        StageKind::Res(r) => r
            .proj
            .as_ref()
            .and_then(|p| p.chains.first()?.tiles.first().map(|t| t.coord.chip)),
        _ => None,
    }
}

/// Last chip a stage's tiles occupy.
fn stage_exit_chip(stage: &Stage) -> Option<usize> {
    match &stage.kind {
        StageKind::Conv(c) => c.chains.last()?.tiles.last().map(|t| t.coord.chip),
        StageKind::Fc(f) => f.columns.last()?.tiles.last().map(|t| t.coord.chip),
        StageKind::Res(r) => r
            .proj
            .as_ref()
            .and_then(|p| p.chains.last()?.tiles.last().map(|t| t.coord.chip)),
        _ => None,
    }
}

/// Standalone pooling stage: the OFM stream of the previous array is
/// pooled "during data transmission between arrays" (Section III-C).
/// The pooling unit persists on the engine (reset by the caller); the
/// per-pixel lane gather uses reused scratch (§Perf).
fn run_pool_stage<P: Probe>(
    p: &PoolStage,
    si: usize,
    input: &Tensor,
    unit: &mut PoolUnit,
    scratch: &mut Scratch,
    st: &mut Counters,
    probe: &mut P,
) -> Result<(Tensor, u64)> {
    assert_eq!(input.shape, p.in_shape, "pool stage input shape");
    let mut out = Tensor::zeros(p.out_shape);
    let mut slots = 0u64;
    for y in 0..input.shape.h {
        for x in 0..input.shape.w {
            scratch.lanes_a.clear();
            scratch
                .lanes_a
                .extend((0..input.shape.c).map(|ch| input.at(ch, y, x)));
            // stream hop between arrays
            let bits = (scratch.lanes_a.len() * 8) as u64;
            st.onchip_link_bits += bits;
            probe.link(
                si,
                NO_TILE as usize,
                NO_TILE as usize,
                slots as usize,
                LinkKind::OnChip,
                bits,
            );
            Rofm::charge_rx(bits, st);
            st.sched_fetches += 1;
            st.rofm_ctrl_steps += 1;
            unit.offer_each((y, x), &scratch.lanes_a, st, |(oy, ox), pv| {
                for (ch, &v) in pv.iter().enumerate() {
                    out.set(ch, oy, ox, v);
                }
            });
            slots += 1;
        }
    }
    Ok((out, slots.div_ceil(p.dup as u64)))
}

/// Residual-add stage: the skip stream arrives through the RIFM→ROFM
/// shortcut (Table II `Bp.`) and is added to the main stream, ReLU
/// fused. §Perf: pixel-lane gathers, the bypass copy and the add
/// result all live in reused scratch.
fn run_res_stage<P: Probe>(
    r: &ResStage,
    si: usize,
    main: &Tensor,
    skip: &Tensor,
    scratch: &mut Scratch,
    st: &mut Counters,
    probe: &mut P,
) -> Result<(Tensor, u64)> {
    if main.shape != skip.shape {
        bail!("res stage: main {} != skip {}", main.shape, skip.shape);
    }
    assert_eq!(main.shape, r.shape);
    let mut out = Tensor::zeros(main.shape);
    let mut slots = 0u64;
    for y in 0..main.shape.h {
        for x in 0..main.shape.w {
            scratch.lanes_a.clear();
            scratch
                .lanes_a
                .extend((0..main.shape.c).map(|ch| main.at(ch, y, x)));
            scratch.lanes_b.clear();
            scratch
                .lanes_b
                .extend((0..main.shape.c).map(|ch| skip.at(ch, y, x)));
            // skip beat bypasses through the shortcut: one link hop
            let bits = (scratch.lanes_b.len() * 8) as u64;
            st.onchip_link_bits += bits;
            probe.link(
                si,
                NO_TILE as usize,
                NO_TILE as usize,
                slots as usize,
                LinkKind::OnChip,
                bits,
            );
            Rofm::bypass_into(&scratch.lanes_b, &mut scratch.vals, st);
            st.sched_fetches += 1;
            st.rofm_ctrl_steps += 1;
            Rofm::res_add_into(&scratch.lanes_a, &scratch.vals, &mut scratch.lanes_b, st);
            for (ch, &vv) in scratch.lanes_b.iter().enumerate() {
                out.set(ch, y, x, vv);
            }
            slots += 1;
        }
    }
    Ok((out, slots.div_ceil(r.dup as u64)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ArchConfig, Compiler};
    use crate::model::refcompute::{forward_all, Weights};
    use crate::model::{zoo, NetworkBuilder};
    use crate::testutil::Rng;

    /// Compile + simulate + compare against refcompute.
    fn check_net(net: &crate::model::Network, arch: ArchConfig, seed: u64) {
        let compiler = Compiler::new(arch);
        let weights = Weights::random(net, compiler.weight_seed).unwrap();
        let program = compiler.compile_with_weights(net, &weights).unwrap();
        let mut sim = Simulator::new(&program);
        let mut rng = Rng::new(seed);
        let input = Tensor::new(net.input, rng.i8_vec(net.input_len(), 31));
        let got = sim.run_image(&input.data).unwrap();
        let want = forward_all(net, &weights, &input).unwrap();
        assert_eq!(
            got.scores,
            want.last().unwrap().data,
            "network output mismatch"
        );
    }

    #[test]
    fn conv_single_tile_matches_reference() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 6, 6))
            .conv(4, 3, 1, 1)
            .build();
        check_net(&net, ArchConfig::default(), 1);
    }

    #[test]
    fn conv_no_padding() {
        let net = NetworkBuilder::new("t", TensorShape::new(2, 5, 5))
            .conv(3, 3, 1, 0)
            .build();
        check_net(&net, ArchConfig::default(), 2);
    }

    #[test]
    fn conv_stride_two() {
        let net = NetworkBuilder::new("t", TensorShape::new(2, 8, 8))
            .conv(3, 3, 2, 1)
            .build();
        check_net(&net, ArchConfig::default(), 3);
    }

    #[test]
    fn conv_multiblock_channels() {
        // tiny crossbar (4x4) forces cblocks=2, mblocks=2
        let net = NetworkBuilder::new("t", TensorShape::new(6, 5, 5))
            .conv(7, 3, 1, 1)
            .build();
        check_net(&net, ArchConfig::tiny(4), 4);
    }

    #[test]
    fn conv_1x1_kernel() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 4, 4))
            .conv(5, 1, 1, 0)
            .build();
        check_net(&net, ArchConfig::default(), 5);
    }

    #[test]
    fn conv_linear_no_relu() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 4, 4))
            .conv_linear(4, 3, 1, 1)
            .build();
        check_net(&net, ArchConfig::default(), 6);
    }

    #[test]
    fn conv_with_fused_maxpool() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .max_pool(2, 2)
            .build();
        check_net(&net, ArchConfig::default(), 7);
    }

    #[test]
    fn conv_with_fused_avgpool() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .avg_pool(2, 2)
            .build();
        check_net(&net, ArchConfig::default(), 8);
    }

    #[test]
    fn fc_single_and_multi_block() {
        let net = NetworkBuilder::new("t", TensorShape::new(20, 1, 1))
            .fc(12)
            .fc_logits(5)
            .build();
        check_net(&net, ArchConfig::tiny(8), 9);
    }

    #[test]
    fn residual_identity_skip() {
        let net = NetworkBuilder::new("t", TensorShape::new(4, 6, 6))
            .conv(4, 3, 1, 1)
            .conv_linear(4, 3, 1, 1)
            .res_add(0)
            .build();
        check_net(&net, ArchConfig::default(), 10);
    }

    #[test]
    fn residual_projected_skip() {
        let net = NetworkBuilder::new("t", TensorShape::new(4, 8, 8))
            .conv(4, 3, 1, 1)
            .conv(8, 3, 2, 1)
            .conv_linear(8, 3, 1, 1)
            .res_add_proj(
                0,
                crate::model::Projection {
                    out_ch: 8,
                    stride: 2,
                },
            )
            .build();
        check_net(&net, ArchConfig::default(), 11);
    }

    #[test]
    fn tiny_cnn_end_to_end_matches_reference() {
        check_net(&zoo::tiny_cnn(), ArchConfig::default(), 12);
    }

    #[test]
    fn tiny_cnn_on_small_crossbars() {
        check_net(&zoo::tiny_cnn(), ArchConfig::tiny(16), 13);
    }

    #[test]
    fn latency_and_stats_populated() {
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let mut sim = Simulator::new(&program);
        let mut rng = Rng::new(14);
        let out = sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
        assert!(out.latency_cycles > 0);
        assert_eq!(out.stage_slots.len(), program.stages.len());
        let st = sim.stats();
        assert!(st.pe_macs >= net.total_macs().unwrap());
        assert!(st.onchip_link_bits > 0);
        assert!(st.adds_8b > 0);
        assert!(st.act_ops_8b > 0);
        assert!(st.pool_ops_8b > 0, "tiny_cnn has pooling");
    }

    #[test]
    fn mac_count_matches_theory_exactly() {
        // The engine fires PE MVMs only on valid window slots, so the
        // simulated MAC count equals the analytic conv MAC count.
        let net = NetworkBuilder::new("t", TensorShape::new(3, 6, 6))
            .conv(4, 3, 1, 1)
            .build();
        let program = Compiler::default().compile(&net).unwrap();
        let mut sim = Simulator::new(&program);
        let mut rng = Rng::new(15);
        sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
        assert_eq!(sim.stats().pe_macs, net.total_macs().unwrap());
    }

    #[test]
    fn rejects_wrong_input_length() {
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let mut sim = Simulator::new(&program);
        assert!(sim.run_image(&[0i8; 3]).is_err());
    }

    #[test]
    fn repeated_images_on_one_simulator_are_independent() {
        // Persistent tile state must be fully reset between images:
        // the same input yields the same output on every run, and a
        // different input in between does not perturb it.
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let mut sim = Simulator::new(&program);
        let mut rng = Rng::new(16);
        let a = rng.i8_vec(net.input_len(), 31);
        let b = rng.i8_vec(net.input_len(), 31);
        let first = sim.run_image(&a).unwrap();
        sim.run_image(&b).unwrap();
        let again = sim.run_image(&a).unwrap();
        assert_eq!(first.scores, again.scores);
        assert_eq!(first.latency_cycles, again.latency_cycles);
    }

    #[test]
    fn run_batch_matches_sequential_and_merges_counters() {
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(17);
        let inputs: Vec<Vec<i8>> =
            (0..5).map(|_| rng.i8_vec(net.input_len(), 31)).collect();

        let mut seq = Simulator::new(&program);
        let seq_outs: Vec<RunOutput> = inputs
            .iter()
            .map(|x| seq.run_image(x).unwrap())
            .collect();

        let mut batched = Simulator::new(&program);
        let batch = batched.run_batch_threads(&inputs, 3).unwrap();
        assert_eq!(batch.outputs.len(), seq_outs.len());
        for (b, s) in batch.outputs.iter().zip(&seq_outs) {
            assert_eq!(b.scores, s.scores);
            assert_eq!(b.stage_slots, s.stage_slots);
            assert_eq!(b.latency_cycles, s.latency_cycles);
        }
        // merged batch counters == counters of the sequential run
        assert_eq!(batched.stats(), seq.stats());
        // and the pipeline report agrees with the analytic model
        let est = crate::perfmodel::estimate(&program).unwrap();
        assert_eq!(batch.pipeline.steady_period_cycles, est.period_cycles);
    }

    #[test]
    fn run_batch_rejects_empty_batch() {
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let mut sim = Simulator::new(&program);
        let empty: Vec<Vec<i8>> = Vec::new();
        assert!(sim.run_batch(&empty).is_err());
    }

    #[test]
    fn run_batch_more_threads_than_images() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 6, 6))
            .conv(4, 3, 1, 1)
            .build();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(18);
        let inputs: Vec<Vec<i8>> =
            (0..2).map(|_| rng.i8_vec(net.input_len(), 31)).collect();
        let mut sim = Simulator::new(&program);
        let out = sim.run_batch_threads(&inputs, 16).unwrap();
        assert_eq!(out.outputs.len(), 2);
        assert_eq!(out.threads, 2, "reported threads == spawned workers");
    }

    #[test]
    fn run_batch_reports_spawned_worker_count() {
        // 5 images at 4 requested threads chunk into ceil(5/4)=2-image
        // chunks, i.e. 3 workers actually spawn.
        let net = NetworkBuilder::new("t", TensorShape::new(3, 6, 6))
            .conv(4, 3, 1, 1)
            .build();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(19);
        let inputs: Vec<Vec<i8>> =
            (0..5).map(|_| rng.i8_vec(net.input_len(), 31)).collect();
        let mut sim = Simulator::new(&program);
        let out = sim.run_batch_threads(&inputs, 4).unwrap();
        assert_eq!(out.threads, 3);
    }

    #[test]
    fn simulator_stays_usable_after_rejected_input() {
        // An error must not leave a stage's runtime state dismounted.
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let mut sim = Simulator::new(&program);
        assert!(sim.run_image(&[0i8; 3]).is_err());
        let mut rng = Rng::new(20);
        let a = rng.i8_vec(net.input_len(), 31);
        let ok = sim.run_image(&a).unwrap();
        let mut fresh = Simulator::new(&program);
        assert_eq!(ok.scores, fresh.run_image(&a).unwrap().scores);
    }

    #[test]
    fn run_batch_stays_usable_after_error() {
        // A failed batch (bad input in a worker's chunk) must leave the
        // persistent worker engines reusable, and must not pollute the
        // aggregate counters.
        let net = NetworkBuilder::new("t", TensorShape::new(3, 6, 6))
            .conv(4, 3, 1, 1)
            .build();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(21);
        let good: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(net.input_len(), 31)).collect();
        let mut bad = good.clone();
        bad[3] = vec![0i8; 3]; // wrong length, fails in the second chunk

        let mut sim = Simulator::new(&program);
        assert!(sim.run_batch_threads(&bad, 2).is_err());
        let batch = sim.run_batch_threads(&good, 2).unwrap();

        let mut fresh = Simulator::new(&program);
        let fresh_batch = fresh.run_batch_threads(&good, 2).unwrap();
        for (a, b) in batch.outputs.iter().zip(&fresh_batch.outputs) {
            assert_eq!(a.scores, b.scores);
        }
        assert_eq!(sim.stats(), fresh.stats(), "failed batch leaked counters");
    }

    #[test]
    fn pooled_engine_matches_fresh_simulator() {
        let net = zoo::tiny_cnn();
        let program = Arc::new(Compiler::default().compile(&net).unwrap());
        let mut engine = PooledEngine::new(Arc::clone(&program));
        assert_eq!(engine.capture(), CaptureMode::Final, "serving default");
        let mut rng = Rng::new(22);
        for _ in 0..3 {
            let img = rng.i8_vec(net.input_len(), 31);
            engine.reset_stats();
            let got = engine.run_image(&img).unwrap();
            // Final capture: no intermediate tensors, same everything else
            assert!(got.stage_outputs.is_empty());
            let mut fresh = Simulator::new(&program);
            let want = fresh.run_image(&img).unwrap();
            assert_eq!(got.scores, want.scores);
            assert_eq!(got.stage_slots, want.stage_slots);
            assert_eq!(got.latency_cycles, want.latency_cycles);
            assert_eq!(engine.stats(), fresh.stats());
            assert_eq!(engine.stage_stats(), fresh.stage_stats());
        }
    }

    #[test]
    fn capture_final_is_equivalent_to_all_stages() {
        // Capture is host-side only: scores, slots, latency and every
        // counter must be bit-identical across modes, over every stage
        // kind (conv, fused pool, standalone pool, res w/ and w/o
        // projection, flatten, fc).
        for net in [zoo::tiny_cnn(), zoo::tiny_mlp(), zoo::tiny_resnet()] {
            let program = Compiler::default().compile(&net).unwrap();
            let mut all = Simulator::new(&program);
            let mut fin = Simulator::with_capture(&program, CaptureMode::Final);
            assert_eq!(fin.capture(), CaptureMode::Final);
            let mut rng = Rng::new(30);
            for _ in 0..3 {
                let img = rng.i8_vec(net.input_len(), 31);
                let a = all.run_image(&img).unwrap();
                let f = fin.run_image(&img).unwrap();
                assert_eq!(a.scores, f.scores, "{}", net.name);
                assert_eq!(a.stage_slots, f.stage_slots, "{}", net.name);
                assert_eq!(a.latency_cycles, f.latency_cycles, "{}", net.name);
                assert_eq!(a.stage_outputs.len(), program.stages.len());
                assert!(f.stage_outputs.is_empty());
            }
            assert_eq!(all.stats(), fin.stats(), "{}: counters drifted", net.name);
            assert_eq!(all.stage_stats(), fin.stage_stats(), "{}", net.name);
        }
    }

    #[test]
    fn all_stages_final_tensor_is_not_cloned_twice() {
        // The last stage tensor is moved into stage_outputs; scores
        // must still match its data exactly.
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let mut sim = Simulator::new(&program);
        let mut rng = Rng::new(31);
        let out = sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
        assert_eq!(out.scores, out.stage_outputs.last().unwrap().data);
    }

    #[test]
    fn psum_arena_settles_after_first_image() {
        // The slab may grow during the warm-up image if the sizing
        // estimate was short, but never afterwards: the conv event
        // sequence is input-independent. Run several distinct images
        // and check every chain's growth count froze after image one.
        for net in [zoo::tiny_cnn(), zoo::tiny_resnet()] {
            let program = Compiler::default().compile(&net).unwrap();
            let mut sim = Simulator::with_capture(&program, CaptureMode::Final);
            let mut rng = Rng::new(32);
            sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
            let snapshot: Vec<Vec<u64>> = sim
                .core
                .state
                .iter()
                .map(|chains| chains.iter().map(|ch| ch.arena.grows()).collect())
                .collect();
            for _ in 0..3 {
                sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
            }
            let after: Vec<Vec<u64>> = sim
                .core
                .state
                .iter()
                .map(|chains| chains.iter().map(|ch| ch.arena.grows()).collect())
                .collect();
            assert_eq!(snapshot, after, "{}: arena grew in steady state", net.name);
            // and nothing is left allocated between images
            for chains in &sim.core.state {
                for ch in chains {
                    assert_eq!(ch.arena.in_use(), 0, "{}: slab leak", net.name);
                }
            }
        }
    }

    #[test]
    fn engine_pool_caches_builds_once_and_evicts() {
        let net_a = NetworkBuilder::new("a", TensorShape::new(2, 6, 6))
            .conv(4, 3, 1, 1)
            .build();
        let net_b = NetworkBuilder::new("b", TensorShape::new(3, 5, 5))
            .conv(3, 3, 1, 0)
            .build();
        let pa = Arc::new(Compiler::default().compile(&net_a).unwrap());
        let pb = Arc::new(Compiler::default().compile(&net_b).unwrap());
        let mut pool = EnginePool::new();
        assert!(pool.is_empty());
        let mut rng = Rng::new(23);
        let ia = rng.i8_vec(net_a.input_len(), 31);
        let ib = rng.i8_vec(net_b.input_len(), 31);
        // interleave the two models; one engine per key, reused
        for _ in 0..3 {
            pool.engine(1, &pa).run_image(&ia).unwrap();
            pool.engine(2, &pb).run_image(&ib).unwrap();
        }
        assert_eq!(pool.len(), 2);
        // evict key 1 (model unloaded); key 2 survives
        let live: HashSet<u64> = [2].into_iter().collect();
        pool.retain_keys(&live);
        assert_eq!(pool.len(), 1);
        // an evicted key rebuilds on demand and still answers correctly
        let out = pool.engine(1, &pa).run_image(&ia).unwrap();
        let want = Simulator::new(&pa).run_image(&ia).unwrap();
        assert_eq!(out.scores, want.scores);
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn repeated_batches_reuse_worker_engines_bit_exactly() {
        // run_batch_threads keeps its worker engines across calls; the
        // second batch must be bit-exact with the first and the
        // aggregate counters must be exactly the sum of both batches.
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let mut rng = Rng::new(24);
        let inputs: Vec<Vec<i8>> =
            (0..4).map(|_| rng.i8_vec(net.input_len(), 31)).collect();
        let mut sim = Simulator::new(&program);
        let first = sim.run_batch_threads(&inputs, 2).unwrap();
        let one_batch_stats = sim.stats().clone();
        let second = sim.run_batch_threads(&inputs, 2).unwrap();
        for (a, b) in first.outputs.iter().zip(&second.outputs) {
            assert_eq!(a.scores, b.scores);
            assert_eq!(a.latency_cycles, b.latency_cycles);
        }
        let mut twice = one_batch_stats.clone();
        twice.merge(&one_batch_stats);
        assert_eq!(sim.stats(), &twice);
    }
}
