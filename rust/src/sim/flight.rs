//! Flight recorder: the engine's pluggable observability plane.
//!
//! The paper's whole argument is *where data moves* — Fig. 3(b) and
//! Fig. 6 are timing/occupancy diagrams, not endpoint numbers. This
//! module turns the engine's instrumentation into a first-class
//! subsystem with three pieces:
//!
//! * [`Probe`] — the event seam threaded through `EngineCore`. Every
//!   architectural event the engine charges (tile actions, psum
//!   push/pop, link transfers with their [`LinkKind`], stage
//!   enter/exit, FIFO/arena occupancy samples) is also offered to the
//!   engine's probe. [`NullProbe`] is the statically zero-cost default:
//!   its callbacks are empty `#[inline(always)]` bodies and its
//!   [`Probe::ENABLED`] constant is `false`, so with the default
//!   `Simulator` the monomorphized hot path contains no probe code at
//!   all — the `engine_perf` frozen-baseline gate measures this.
//! * [`FlightRecorder`] — a probe that appends fixed-width binary
//!   event records ([`Event`], [`EVENT_BYTES`] bytes each) to a
//!   bounded ring buffer. Memory is capped by
//!   [`RecorderConfig::capacity`]; once full, the oldest events are
//!   evicted and counted in [`Recording::dropped`]. Recorders fork
//!   per batch worker and merge back in chunk order, so recording no
//!   longer serializes `run_batch_threads`.
//! * Analysis over a [`Recording`]: per-link/per-tile
//!   [`StageTimelines`], a terminal [`LinkHeatmap`] of link
//!   utilization over time, [`diff`] between two recordings (first
//!   divergent event + per-stage deltas — the frozen-baseline trick
//!   from the perf gate, generalized), and a [`Stepper`] with
//!   breakpoints on (tile, cycle, event kind) for `domino debug`.

use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

use crate::coordinator::schedule::CYCLES_PER_SLOT;
use crate::noc::link::LinkKind;
use crate::sim::engine::ActionKind;

/// Sentinel for events that are not scoped to one tile (stage
/// boundaries, arena samples).
pub const NO_TILE: u16 = u16::MAX;

/// The engine's instrumentation seam. One probe instance lives inside
/// each `EngineCore`; the engine invokes the callbacks at the exact
/// points where it charges the corresponding [`Counters`]
/// (crate::sim::stats::Counters) events, so a recording is a faithful
/// event-level expansion of the counters.
///
/// `ENABLED` is a `const`: call sites that do extra work to assemble
/// probe arguments guard on `P::ENABLED`, which constant-folds away
/// for [`NullProbe`]. Implementations must be cheap and infallible —
/// they run on the hot path when enabled.
pub trait Probe: Send {
    /// Statically `true` when this probe observes events. `false`
    /// compiles every probe call site out of the monomorphized engine.
    const ENABLED: bool;

    /// A tile action: psum accumulate/forward, group-sum push/pop, or
    /// output emit (the Fig. 3(b) vocabulary).
    fn action(&mut self, stage: usize, chain: usize, ci: usize, slot: usize, kind: ActionKind);

    /// `bits` moved over one link of `link` kind, leaving tile `ci`.
    fn link(
        &mut self,
        stage: usize,
        chain: usize,
        ci: usize,
        slot: usize,
        link: LinkKind,
        bits: u64,
    );

    /// Stage `stage` starts processing the current image.
    fn stage_enter(&mut self, stage: usize);

    /// Stage `stage` finished after `slots` pixel slots.
    fn stage_exit(&mut self, stage: usize, slots: usize);

    /// Row-head ROFM FIFO depth (group-sums queued) after slot `slot`.
    fn fifo_depth(&mut self, stage: usize, chain: usize, ci: usize, slot: usize, depth: usize);

    /// Psum arena occupancy after slot `slot`: `in_use` of `slots`
    /// slab slots allocated.
    fn arena_in_use(
        &mut self,
        stage: usize,
        chain: usize,
        slot: usize,
        in_use: usize,
        slots: usize,
    );

    /// A fresh probe of the same configuration for a batch worker
    /// (empty event buffer).
    fn fork(&self) -> Self
    where
        Self: Sized;

    /// Drop any buffered events (batch start for reused workers).
    fn clear(&mut self);

    /// Merge a worker probe's events into this one, in order. Called
    /// once per worker in chunk order after a threaded batch, so the
    /// merged stream is the sequential-image-order stream.
    fn absorb(&mut self, worker: &mut Self)
    where
        Self: Sized;
}

/// The default probe: observes nothing, costs nothing. Every callback
/// is an empty `#[inline(always)]` body and [`Probe::ENABLED`] is
/// `false`, so the `EngineCore<NullProbe>` instantiation — the one
/// every existing constructor produces — is bit-for-bit the
/// uninstrumented engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NullProbe;

impl Probe for NullProbe {
    const ENABLED: bool = false;
    #[inline(always)]
    fn action(&mut self, _: usize, _: usize, _: usize, _: usize, _: ActionKind) {}
    #[inline(always)]
    fn link(&mut self, _: usize, _: usize, _: usize, _: usize, _: LinkKind, _: u64) {}
    #[inline(always)]
    fn stage_enter(&mut self, _: usize) {}
    #[inline(always)]
    fn stage_exit(&mut self, _: usize, _: usize) {}
    #[inline(always)]
    fn fifo_depth(&mut self, _: usize, _: usize, _: usize, _: usize, _: usize) {}
    #[inline(always)]
    fn arena_in_use(&mut self, _: usize, _: usize, _: usize, _: usize, _: usize) {}
    #[inline(always)]
    fn fork(&self) -> Self {
        NullProbe
    }
    #[inline(always)]
    fn clear(&mut self) {}
    #[inline(always)]
    fn absorb(&mut self, _: &mut Self) {}
}

/// Event discriminant, stored as one byte in the fixed-width record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// Partial-sum accumulated in tile registers and forwarded
    /// (`a`/`b` = output position). Fig. 3(b)'s black circles.
    Acc = 0,
    /// Group-sum queued into a row-head ROFM FIFO (red circles).
    Push = 1,
    /// Group-sum popped to seed the next kernel row.
    Pop = 2,
    /// The last tile's activation emitted an output (`a`/`b` = opos).
    Emit = 3,
    /// Link transfer: `a` = bits, `b` = 1 for inter-chip, 0 on-chip.
    LinkTx = 4,
    /// Stage started processing the image.
    StageEnter = 5,
    /// Stage finished; `a` = pixel slots it ran.
    StageExit = 6,
    /// Row-head FIFO depth sample: `a` = group-sums queued.
    FifoDepth = 7,
    /// Psum arena sample: `a` = slab slots in use, `b` = capacity.
    ArenaInUse = 8,
}

impl EventKind {
    /// All kinds, in tag order.
    pub const ALL: [EventKind; 9] = [
        EventKind::Acc,
        EventKind::Push,
        EventKind::Pop,
        EventKind::Emit,
        EventKind::LinkTx,
        EventKind::StageEnter,
        EventKind::StageExit,
        EventKind::FifoDepth,
        EventKind::ArenaInUse,
    ];

    /// Decode the one-byte tag.
    pub fn from_u8(v: u8) -> Option<EventKind> {
        EventKind::ALL.get(v as usize).copied()
    }

    /// Short name, accepted back by [`EventKind::parse`] (CLI
    /// breakpoint specs).
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Acc => "acc",
            EventKind::Push => "push",
            EventKind::Pop => "pop",
            EventKind::Emit => "emit",
            EventKind::LinkTx => "link",
            EventKind::StageEnter => "enter",
            EventKind::StageExit => "exit",
            EventKind::FifoDepth => "fifo",
            EventKind::ArenaInUse => "arena",
        }
    }

    /// Parse a short name (case-insensitive).
    pub fn parse(s: &str) -> Option<EventKind> {
        let s = s.to_ascii_lowercase();
        EventKind::ALL.into_iter().find(|k| k.label() == s)
    }
}

/// Serialized size of one [`Event`] record.
pub const EVENT_BYTES: usize = 20;

/// One fixed-width flight-recorder record. `slot` is the stage-local
/// pixel slot ([`Event::cycle`] converts to cycles at the schedule's
/// [`CYCLES_PER_SLOT`]); `a`/`b` are the kind-specific payload (see
/// [`EventKind`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub kind: EventKind,
    pub stage: u16,
    /// Conv chain id (`mblock`) / FC column; [`NO_TILE`] when not
    /// chain-scoped.
    pub chain: u16,
    /// Tile position along the chain; [`NO_TILE`] when not tile-scoped.
    pub ci: u16,
    pub slot: u32,
    pub a: u32,
    pub b: u32,
}

impl Event {
    /// Stage-local cycle this event's slot starts at.
    pub fn cycle(&self) -> u64 {
        self.slot as u64 * CYCLES_PER_SLOT as u64
    }

    /// Link kind for [`EventKind::LinkTx`] events.
    pub fn link_kind(&self) -> Option<LinkKind> {
        match self.kind {
            EventKind::LinkTx if self.b == 1 => Some(LinkKind::InterChip),
            EventKind::LinkTx => Some(LinkKind::OnChip),
            _ => None,
        }
    }

    /// Fixed-width little-endian encoding (the "compact binary" form;
    /// determinism tests byte-compare whole streams).
    pub fn to_bytes(&self) -> [u8; EVENT_BYTES] {
        let mut out = [0u8; EVENT_BYTES];
        out[0] = self.kind as u8;
        // out[1] is a pad byte, kept zero
        out[2..4].copy_from_slice(&self.stage.to_le_bytes());
        out[4..6].copy_from_slice(&self.chain.to_le_bytes());
        out[6..8].copy_from_slice(&self.ci.to_le_bytes());
        out[8..12].copy_from_slice(&self.slot.to_le_bytes());
        out[12..16].copy_from_slice(&self.a.to_le_bytes());
        out[16..20].copy_from_slice(&self.b.to_le_bytes());
        out
    }

    /// Decode one fixed-width record.
    pub fn from_bytes(b: &[u8; EVENT_BYTES]) -> Result<Event> {
        let kind = EventKind::from_u8(b[0])
            .with_context(|| format!("unknown flight event tag {}", b[0]))?;
        Ok(Event {
            kind,
            stage: u16::from_le_bytes([b[2], b[3]]),
            chain: u16::from_le_bytes([b[4], b[5]]),
            ci: u16::from_le_bytes([b[6], b[7]]),
            slot: u32::from_le_bytes([b[8], b[9], b[10], b[11]]),
            a: u32::from_le_bytes([b[12], b[13], b[14], b[15]]),
            b: u32::from_le_bytes([b[16], b[17], b[18], b[19]]),
        })
    }

    /// One-line human rendering for the stepper/CLI.
    pub fn describe(&self) -> String {
        let loc = if self.ci == NO_TILE {
            format!("stage {}", self.stage)
        } else {
            format!("stage {} chain {} tile {}", self.stage, self.chain, self.ci)
        };
        match self.kind {
            EventKind::Acc => format!(
                "{loc} slot {} cycle {}: partial-sum acc -> opos ({}, {})",
                self.slot,
                self.cycle(),
                self.a,
                self.b
            ),
            EventKind::Push => format!(
                "{loc} slot {} cycle {}: group-sum queued (ROFM push)",
                self.slot,
                self.cycle()
            ),
            EventKind::Pop => format!(
                "{loc} slot {} cycle {}: group-sum popped (ROFM pop)",
                self.slot,
                self.cycle()
            ),
            EventKind::Emit => format!(
                "{loc} slot {} cycle {}: output emit -> opos ({}, {})",
                self.slot,
                self.cycle(),
                self.a,
                self.b
            ),
            EventKind::LinkTx => format!(
                "{loc} slot {} cycle {}: {} b over {} link",
                self.slot,
                self.cycle(),
                self.a,
                if self.b == 1 { "inter-chip" } else { "on-chip" }
            ),
            EventKind::StageEnter => format!("{loc}: enter"),
            EventKind::StageExit => format!("{loc}: exit after {} slots", self.a),
            EventKind::FifoDepth => format!(
                "{loc} slot {}: ROFM FIFO depth {}",
                self.slot, self.a
            ),
            EventKind::ArenaInUse => format!(
                "{loc} slot {}: psum arena {}/{} slots in use",
                self.slot, self.a, self.b
            ),
        }
    }
}

/// Recorder sizing. The ring holds at most `capacity` events
/// ([`EVENT_BYTES`] bytes each once serialized); the buffer itself
/// never exceeds `capacity` in-memory records, which is the bounded-
/// memory guarantee across arbitrarily long runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecorderConfig {
    /// Maximum events retained; oldest evicted first.
    pub capacity: usize,
}

impl RecorderConfig {
    /// A recorder keeping at most `capacity` events.
    pub fn with_capacity(capacity: usize) -> Self {
        Self { capacity }
    }
}

impl Default for RecorderConfig {
    fn default() -> Self {
        // ~20 MiB ceiling: comfortably one image of any zoo model, a
        // hard cap for long batches.
        Self { capacity: 1 << 20 }
    }
}

/// A probe that records every event into a bounded ring buffer.
#[derive(Clone, Debug)]
pub struct FlightRecorder {
    cap: usize,
    buf: VecDeque<Event>,
    dropped: u64,
}

impl FlightRecorder {
    pub fn new(cfg: RecorderConfig) -> Self {
        Self {
            cap: cfg.capacity.max(1),
            buf: VecDeque::new(),
            dropped: 0,
        }
    }

    fn push(&mut self, e: Event) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(e);
    }

    /// Events currently buffered (≤ capacity, always).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events evicted so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Snapshot the buffered stream (oldest first).
    pub fn recording(&self) -> Recording {
        Recording {
            events: self.buf.iter().copied().collect(),
            dropped: self.dropped,
        }
    }
}

impl Probe for FlightRecorder {
    const ENABLED: bool = true;

    fn action(&mut self, stage: usize, chain: usize, ci: usize, slot: usize, kind: ActionKind) {
        let (kind, a, b) = match kind {
            ActionKind::Acc { opos } => (EventKind::Acc, opos.0 as u32, opos.1 as u32),
            ActionKind::Push => (EventKind::Push, 0, 0),
            ActionKind::Pop => (EventKind::Pop, 0, 0),
            ActionKind::Emit { opos } => (EventKind::Emit, opos.0 as u32, opos.1 as u32),
        };
        self.push(Event {
            kind,
            stage: stage as u16,
            chain: chain as u16,
            ci: ci as u16,
            slot: slot as u32,
            a,
            b,
        });
    }

    fn link(
        &mut self,
        stage: usize,
        chain: usize,
        ci: usize,
        slot: usize,
        link: LinkKind,
        bits: u64,
    ) {
        self.push(Event {
            kind: EventKind::LinkTx,
            stage: stage as u16,
            chain: chain as u16,
            ci: ci as u16,
            slot: slot as u32,
            a: bits.min(u32::MAX as u64) as u32,
            b: (link == LinkKind::InterChip) as u32,
        });
    }

    fn stage_enter(&mut self, stage: usize) {
        self.push(Event {
            kind: EventKind::StageEnter,
            stage: stage as u16,
            chain: NO_TILE,
            ci: NO_TILE,
            slot: 0,
            a: 0,
            b: 0,
        });
    }

    fn stage_exit(&mut self, stage: usize, slots: usize) {
        self.push(Event {
            kind: EventKind::StageExit,
            stage: stage as u16,
            chain: NO_TILE,
            ci: NO_TILE,
            slot: 0,
            a: slots.min(u32::MAX as usize) as u32,
            b: 0,
        });
    }

    fn fifo_depth(&mut self, stage: usize, chain: usize, ci: usize, slot: usize, depth: usize) {
        self.push(Event {
            kind: EventKind::FifoDepth,
            stage: stage as u16,
            chain: chain as u16,
            ci: ci as u16,
            slot: slot as u32,
            a: depth.min(u32::MAX as usize) as u32,
            b: 0,
        });
    }

    fn arena_in_use(
        &mut self,
        stage: usize,
        chain: usize,
        slot: usize,
        in_use: usize,
        slots: usize,
    ) {
        self.push(Event {
            kind: EventKind::ArenaInUse,
            stage: stage as u16,
            chain: chain as u16,
            ci: NO_TILE,
            slot: slot as u32,
            a: in_use.min(u32::MAX as usize) as u32,
            b: slots.min(u32::MAX as usize) as u32,
        });
    }

    fn fork(&self) -> Self {
        Self {
            cap: self.cap,
            buf: VecDeque::new(),
            dropped: 0,
        }
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.dropped = 0;
    }

    fn absorb(&mut self, worker: &mut Self) {
        self.dropped += worker.dropped;
        worker.dropped = 0;
        for e in worker.buf.drain(..) {
            if self.buf.len() == self.cap {
                self.buf.pop_front();
                self.dropped += 1;
            }
            self.buf.push_back(e);
        }
    }
}

/// A linearized snapshot of a [`FlightRecorder`]'s ring: the event
/// stream in engine order, plus how many older events the ring
/// evicted.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Recording {
    pub events: Vec<Event>,
    pub dropped: u64,
}

impl Recording {
    /// Serialize the stream as fixed-width records behind a small
    /// header (magic, eviction count, event count). Two recordings of
    /// the same program + seed must byte-compare equal.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20 + self.events.len() * EVENT_BYTES);
        out.extend_from_slice(b"DFR1");
        out.extend_from_slice(&self.dropped.to_le_bytes());
        out.extend_from_slice(&(self.events.len() as u64).to_le_bytes());
        for e in &self.events {
            out.extend_from_slice(&e.to_bytes());
        }
        out
    }

    /// Decode [`Self::to_bytes`] output.
    pub fn from_bytes(bytes: &[u8]) -> Result<Recording> {
        if bytes.len() < 20 || &bytes[..4] != b"DFR1" {
            bail!("not a DFR1 flight recording");
        }
        let dropped = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
        let count = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        let body = &bytes[20..];
        if body.len() != count * EVENT_BYTES {
            bail!(
                "flight recording body is {} B, expected {} events x {} B",
                body.len(),
                count,
                EVENT_BYTES
            );
        }
        let mut events = Vec::with_capacity(count);
        for rec in body.chunks_exact(EVENT_BYTES) {
            events.push(Event::from_bytes(rec.try_into().unwrap())?);
        }
        Ok(Recording { events, dropped })
    }

    /// Highest stage index observed, plus one.
    pub fn stage_count(&self) -> usize {
        self.events
            .iter()
            .map(|e| e.stage as usize + 1)
            .max()
            .unwrap_or(0)
    }

    /// Events per stage.
    pub fn events_per_stage(&self) -> BTreeMap<u16, u64> {
        let mut out = BTreeMap::new();
        for e in &self.events {
            *out.entry(e.stage).or_insert(0u64) += 1;
        }
        out
    }
}

/// One link transfer in a timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkSample {
    pub slot: u32,
    pub bits: u64,
    pub interchip: bool,
}

/// One FIFO-depth sample in a timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DepthSample {
    pub slot: u32,
    pub depth: u32,
}

/// One psum-arena occupancy sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArenaSample {
    pub slot: u32,
    pub in_use: u32,
    pub slots: u32,
}

/// Per-link / per-tile time series for one stage, extracted from a
/// recording — the Fig. 6-style occupancy view.
#[derive(Clone, Debug, Default)]
pub struct StageTimelines {
    pub stage: usize,
    /// (chain, tile) -> transfers leaving that tile, in slot order.
    pub links: BTreeMap<(u16, u16), Vec<LinkSample>>,
    /// (chain, row-head tile) -> ROFM FIFO depth samples.
    pub fifo: BTreeMap<(u16, u16), Vec<DepthSample>>,
    /// chain -> psum arena occupancy samples.
    pub arena: BTreeMap<u16, Vec<ArenaSample>>,
}

impl StageTimelines {
    /// Build the stage's timelines from a recording.
    pub fn build(rec: &Recording, stage: usize) -> StageTimelines {
        let mut t = StageTimelines {
            stage,
            ..Default::default()
        };
        for e in rec.events.iter().filter(|e| e.stage as usize == stage) {
            match e.kind {
                EventKind::LinkTx if e.ci != NO_TILE => {
                    t.links.entry((e.chain, e.ci)).or_default().push(LinkSample {
                        slot: e.slot,
                        bits: e.a as u64,
                        interchip: e.b == 1,
                    });
                }
                EventKind::FifoDepth => {
                    t.fifo.entry((e.chain, e.ci)).or_default().push(DepthSample {
                        slot: e.slot,
                        depth: e.a,
                    });
                }
                EventKind::ArenaInUse => {
                    t.arena.entry(e.chain).or_default().push(ArenaSample {
                        slot: e.slot,
                        in_use: e.a,
                        slots: e.b,
                    });
                }
                _ => {}
            }
        }
        t
    }

    /// Total bits moved over links in this stage.
    pub fn total_link_bits(&self) -> u64 {
        self.links
            .values()
            .flatten()
            .map(|s| s.bits)
            .sum()
    }

    /// Peak group-sum FIFO depth across all row heads.
    pub fn peak_fifo_depth(&self) -> u32 {
        self.fifo
            .values()
            .flatten()
            .map(|s| s.depth)
            .max()
            .unwrap_or(0)
    }

    /// Peak psum arena occupancy across chains.
    pub fn peak_arena_in_use(&self) -> u32 {
        self.arena
            .values()
            .flatten()
            .map(|s| s.in_use)
            .max()
            .unwrap_or(0)
    }
}

/// Utilization shade ramp, darkest last.
const SHADES: &[u8] = b" .:-=+*#%@";

/// A tiles x time heatmap of link utilization for one stage: rows are
/// chain positions (the link each tile drives), columns are time
/// buckets over the stage's slot range, shade is bits moved relative
/// to the busiest cell.
#[derive(Clone, Debug)]
pub struct LinkHeatmap {
    pub stage: usize,
    /// Rows (tiles that moved bits; max chain position + 1).
    pub tiles: usize,
    /// Time buckets (columns).
    pub buckets: usize,
    pub max_slot: u32,
    pub total_bits: u64,
    pub interchip_bits: u64,
    /// Bits per (tile, bucket), row-major.
    cells: Vec<u64>,
    peak: u64,
}

impl LinkHeatmap {
    /// Build a heatmap of `stage` with `buckets` time columns. `None`
    /// when the recording holds no tile-scoped link events for the
    /// stage.
    pub fn build(rec: &Recording, stage: usize, buckets: usize) -> Option<LinkHeatmap> {
        let buckets = buckets.max(1);
        let evs: Vec<&Event> = rec
            .events
            .iter()
            .filter(|e| {
                e.kind == EventKind::LinkTx && e.stage as usize == stage && e.ci != NO_TILE
            })
            .collect();
        if evs.is_empty() {
            return None;
        }
        let tiles = evs.iter().map(|e| e.ci as usize + 1).max().unwrap();
        let max_slot = evs.iter().map(|e| e.slot).max().unwrap();
        let mut cells = vec![0u64; tiles * buckets];
        let mut total = 0u64;
        let mut inter = 0u64;
        for e in &evs {
            let bucket = (e.slot as usize * buckets) / (max_slot as usize + 1);
            cells[e.ci as usize * buckets + bucket] += e.a as u64;
            total += e.a as u64;
            if e.b == 1 {
                inter += e.a as u64;
            }
        }
        let peak = cells.iter().copied().max().unwrap_or(0);
        Some(LinkHeatmap {
            stage,
            tiles,
            buckets,
            max_slot,
            total_bits: total,
            interchip_bits: inter,
            cells,
            peak,
        })
    }

    /// The stage moving the most link bits in the recording.
    pub fn busiest_stage(rec: &Recording) -> Option<usize> {
        let mut per_stage: BTreeMap<u16, u64> = BTreeMap::new();
        for e in &rec.events {
            if e.kind == EventKind::LinkTx && e.ci != NO_TILE {
                *per_stage.entry(e.stage).or_insert(0) += e.a as u64;
            }
        }
        per_stage
            .into_iter()
            .max_by_key(|&(stage, bits)| (bits, std::cmp::Reverse(stage)))
            .map(|(stage, _)| stage as usize)
    }

    /// Bits moved from `tile` during time bucket `bucket`.
    pub fn cell_bits(&self, tile: usize, bucket: usize) -> u64 {
        self.cells[tile * self.buckets + bucket]
    }

    /// Render the terminal heatmap.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "link utilization, stage {} ({} tiles, slots 0..{}, {} b total, {} b inter-chip)",
            self.stage,
            self.tiles,
            self.max_slot + 1,
            self.total_bits,
            self.interchip_bits
        );
        let _ = writeln!(
            out,
            "shade ramp '{}' scales to the busiest cell ({} b)",
            std::str::from_utf8(SHADES).unwrap(),
            self.peak
        );
        for t in 0..self.tiles {
            let _ = write!(out, "{t:>4} |");
            for bkt in 0..self.buckets {
                let bits = self.cell_bits(t, bkt);
                let shade = if self.peak == 0 {
                    0
                } else {
                    (bits * (SHADES.len() as u64 - 1) / self.peak) as usize
                };
                out.push(SHADES[shade] as char);
            }
            out.push_str("|\n");
        }
        out
    }
}

/// Result of [`diff`]: where two event streams first diverge and how
/// their per-stage event populations compare.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RecordingDiff {
    pub len_a: usize,
    pub len_b: usize,
    /// Index of the first differing event (or of the end of the
    /// shorter stream when one is a prefix of the other).
    pub first_divergence: Option<usize>,
    /// The two events at the divergence point (`None` past the end of
    /// a stream).
    pub diverging: Option<(Option<Event>, Option<Event>)>,
    /// stage -> (events in a, events in b).
    pub stage_events: BTreeMap<u16, (u64, u64)>,
}

impl RecordingDiff {
    /// True when the streams are identical.
    pub fn identical(&self) -> bool {
        self.first_divergence.is_none()
    }

    /// Human-readable summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        match (self.first_divergence, &self.diverging) {
            (None, _) => {
                let _ = writeln!(out, "recordings identical ({} events)", self.len_a);
            }
            (Some(i), Some((a, b))) => {
                let _ = writeln!(
                    out,
                    "first divergence at event {i} ({} vs {} events)",
                    self.len_a, self.len_b
                );
                let _ = writeln!(
                    out,
                    "  a: {}",
                    a.map(|e| e.describe()).unwrap_or_else(|| "<end>".into())
                );
                let _ = writeln!(
                    out,
                    "  b: {}",
                    b.map(|e| e.describe()).unwrap_or_else(|| "<end>".into())
                );
            }
            _ => {}
        }
        for (stage, (na, nb)) in &self.stage_events {
            if na != nb {
                let _ = writeln!(out, "  stage {stage}: {na} events vs {nb}");
            }
        }
        out
    }
}

/// Compare two recordings: first divergent event and per-stage event
/// counts — the frozen-baseline comparison generalized to whole event
/// streams.
pub fn diff(a: &Recording, b: &Recording) -> RecordingDiff {
    let first = a
        .events
        .iter()
        .zip(&b.events)
        .position(|(x, y)| x != y)
        .or_else(|| {
            (a.events.len() != b.events.len()).then(|| a.events.len().min(b.events.len()))
        });
    let diverging = first.map(|i| {
        (
            a.events.get(i).copied(),
            b.events.get(i).copied(),
        )
    });
    let mut stage_events: BTreeMap<u16, (u64, u64)> = BTreeMap::new();
    for e in &a.events {
        stage_events.entry(e.stage).or_insert((0, 0)).0 += 1;
    }
    for e in &b.events {
        stage_events.entry(e.stage).or_insert((0, 0)).1 += 1;
    }
    RecordingDiff {
        len_a: a.events.len(),
        len_b: b.events.len(),
        first_divergence: first,
        diverging,
        stage_events,
    }
}

/// A breakpoint for the [`Stepper`]: matches events on any combination
/// of tile (chain position), cycle (the event's slot window), and
/// event kind. Unset fields match everything.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Breakpoint {
    pub tile: Option<usize>,
    pub cycle: Option<u64>,
    pub kind: Option<EventKind>,
}

impl Breakpoint {
    /// Parse a CLI spec `tile,cycle[,kind]` where either of the first
    /// two fields may be `*` (wildcard) and `kind` is an
    /// [`EventKind::label`] name, e.g. `3,120`, `*,40,push`,
    /// `6,*,pop`.
    pub fn parse(spec: &str) -> Result<Breakpoint> {
        let parts: Vec<&str> = spec.split(',').map(str::trim).collect();
        if parts.len() < 2 || parts.len() > 3 {
            bail!("breakpoint spec must be tile,cycle[,kind], got {spec:?}");
        }
        let field = |s: &str, what: &str| -> Result<Option<u64>> {
            if s == "*" {
                Ok(None)
            } else {
                Ok(Some(s.parse().with_context(|| {
                    format!("bad {what} {s:?} in breakpoint {spec:?}")
                })?))
            }
        };
        let tile = field(parts[0], "tile")?.map(|v| v as usize);
        let cycle = field(parts[1], "cycle")?;
        let kind = match parts.get(2) {
            None => None,
            Some(&"*") => None,
            Some(s) => Some(
                EventKind::parse(s)
                    .with_context(|| format!("unknown event kind {s:?} in breakpoint {spec:?}"))?,
            ),
        };
        Ok(Breakpoint { tile, cycle, kind })
    }

    /// Does `e` hit this breakpoint? A cycle condition hits when it
    /// falls inside the event's slot window (`CYCLES_PER_SLOT` cycles).
    pub fn matches(&self, e: &Event) -> bool {
        if let Some(t) = self.tile {
            if e.ci == NO_TILE || e.ci as usize != t {
                return false;
            }
        }
        if let Some(c) = self.cycle {
            let lo = e.cycle();
            if c < lo || c >= lo + CYCLES_PER_SLOT as u64 {
                return false;
            }
        }
        if let Some(k) = self.kind {
            if e.kind != k {
                return false;
            }
        }
        true
    }
}

/// Derived engine state at the stepper's current position, rebuilt
/// incrementally from the event stream.
#[derive(Clone, Debug, Default)]
pub struct DebugState {
    /// Stage currently executing (last StageEnter not yet exited).
    pub stage: Option<u16>,
    /// (stage, chain, tile) -> last observed ROFM FIFO depth.
    pub fifo_depth: BTreeMap<(u16, u16, u16), u32>,
    /// (stage, chain) -> last observed psum arena (in_use, slots).
    pub arena: BTreeMap<(u16, u16), (u32, u32)>,
    /// Events consumed per kind, indexed by the kind tag.
    pub counts: [u64; EventKind::ALL.len()],
    pub onchip_bits: u64,
    pub interchip_bits: u64,
}

impl DebugState {
    fn apply(&mut self, e: &Event) {
        self.counts[e.kind as usize] += 1;
        match e.kind {
            EventKind::StageEnter => self.stage = Some(e.stage),
            EventKind::StageExit => {
                if self.stage == Some(e.stage) {
                    self.stage = None;
                }
            }
            EventKind::FifoDepth => {
                self.fifo_depth.insert((e.stage, e.chain, e.ci), e.a);
            }
            EventKind::ArenaInUse => {
                self.arena.insert((e.stage, e.chain), (e.a, e.b));
            }
            EventKind::LinkTx => {
                if e.b == 1 {
                    self.interchip_bits += e.a as u64;
                } else {
                    self.onchip_bits += e.a as u64;
                }
            }
            _ => {}
        }
    }

    /// Events of `kind` consumed so far.
    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind as usize]
    }

    /// Render the inspection summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "stage: {}   links: {} b on-chip / {} b inter-chip",
            self.stage
                .map(|s| s.to_string())
                .unwrap_or_else(|| "-".into()),
            self.onchip_bits,
            self.interchip_bits
        );
        let _ = write!(out, "events:");
        for k in EventKind::ALL {
            if self.count(k) > 0 {
                let _ = write!(out, " {}={}", k.label(), self.count(k));
            }
        }
        let _ = writeln!(out);
        let queued: Vec<String> = self
            .fifo_depth
            .iter()
            .filter(|(_, &d)| d > 0)
            .map(|(&(s, c, t), d)| format!("s{s}/c{c}/t{t}:{d}"))
            .collect();
        if !queued.is_empty() {
            let _ = writeln!(out, "group-sum FIFOs: {}", queued.join(" "));
        }
        let busy: Vec<String> = self
            .arena
            .iter()
            .filter(|(_, &(u, _))| u > 0)
            .map(|(&(s, c), &(u, n))| format!("s{s}/c{c}:{u}/{n}"))
            .collect();
        if !busy.is_empty() {
            let _ = writeln!(out, "psum arenas: {}", busy.join(" "));
        }
        out
    }
}

/// A domino debug stepper: walk a recording event by event, stop at
/// breakpoints, inspect derived engine state at any point.
#[derive(Clone, Debug)]
pub struct Stepper {
    rec: Recording,
    pos: usize,
    breakpoints: Vec<Breakpoint>,
    state: DebugState,
}

impl Stepper {
    pub fn new(rec: Recording) -> Self {
        Self {
            rec,
            pos: 0,
            breakpoints: Vec::new(),
            state: DebugState::default(),
        }
    }

    pub fn add_breakpoint(&mut self, bp: Breakpoint) {
        self.breakpoints.push(bp);
    }

    /// Index of the next event to consume.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Total events in the recording.
    pub fn len(&self) -> usize {
        self.rec.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rec.events.is_empty()
    }

    /// All events consumed?
    pub fn done(&self) -> bool {
        self.pos >= self.rec.events.len()
    }

    /// Derived state after every consumed event.
    pub fn state(&self) -> &DebugState {
        &self.state
    }

    /// Consume one event; `None` at end of stream.
    pub fn step(&mut self) -> Option<Event> {
        let e = *self.rec.events.get(self.pos)?;
        self.pos += 1;
        self.state.apply(&e);
        Some(e)
    }

    /// Run until an event hits a breakpoint (that event is consumed
    /// and returned with its index); `None` when the stream ends with
    /// no hit.
    pub fn run_to_break(&mut self) -> Option<(usize, Event)> {
        while let Some(e) = self.step() {
            if self.breakpoints.iter().any(|bp| bp.matches(&e)) {
                return Some((self.pos - 1, e));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, stage: u16, ci: u16, slot: u32, a: u32, b: u32) -> Event {
        Event {
            kind,
            stage,
            chain: 0,
            ci,
            slot,
            a,
            b,
        }
    }

    #[test]
    fn event_bytes_round_trip_every_kind() {
        for (i, kind) in EventKind::ALL.into_iter().enumerate() {
            assert_eq!(EventKind::from_u8(i as u8), Some(kind));
            assert_eq!(EventKind::parse(kind.label()), Some(kind));
            let e = Event {
                kind,
                stage: 3,
                chain: 1,
                ci: NO_TILE,
                slot: 0xDEAD_BEEF,
                a: 7,
                b: 9,
            };
            let bytes = e.to_bytes();
            assert_eq!(bytes.len(), EVENT_BYTES);
            assert_eq!(Event::from_bytes(&bytes).unwrap(), e);
        }
        assert_eq!(EventKind::from_u8(200), None);
        assert!(Event::from_bytes(&[200u8; EVENT_BYTES]).is_err());
    }

    #[test]
    fn ring_caps_length_and_counts_drops() {
        let mut r = FlightRecorder::new(RecorderConfig::with_capacity(4));
        for slot in 0..10usize {
            r.stage_enter(slot);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6);
        let rec = r.recording();
        assert_eq!(rec.events.len(), 4);
        // oldest evicted: stages 6..10 remain
        assert_eq!(rec.events[0].stage, 6);
        assert_eq!(rec.events[3].stage, 9);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn fork_and_absorb_concatenate_in_order() {
        let mut main = FlightRecorder::new(RecorderConfig::with_capacity(100));
        main.stage_enter(0);
        let mut w1 = main.fork();
        let mut w2 = main.fork();
        assert!(w1.is_empty() && w2.capacity() == 100);
        w1.stage_enter(1);
        w2.stage_enter(2);
        main.absorb(&mut w1);
        main.absorb(&mut w2);
        assert!(w1.is_empty());
        let stages: Vec<u16> = main.recording().events.iter().map(|e| e.stage).collect();
        assert_eq!(stages, vec![0, 1, 2]);
    }

    #[test]
    fn recording_bytes_round_trip() {
        let rec = Recording {
            events: vec![
                ev(EventKind::Acc, 0, 1, 5, 2, 3),
                ev(EventKind::LinkTx, 1, 2, 6, 512, 1),
            ],
            dropped: 42,
        };
        let bytes = rec.to_bytes();
        assert_eq!(bytes.len(), 20 + 2 * EVENT_BYTES);
        assert_eq!(Recording::from_bytes(&bytes).unwrap(), rec);
        assert!(Recording::from_bytes(&bytes[..10]).is_err());
        assert!(Recording::from_bytes(b"XXXX0000000000000000").is_err());
    }

    #[test]
    fn diff_finds_first_divergence_and_stage_deltas() {
        let a = Recording {
            events: vec![
                ev(EventKind::Acc, 0, 0, 0, 0, 0),
                ev(EventKind::Push, 0, 3, 1, 0, 0),
            ],
            dropped: 0,
        };
        assert!(diff(&a, &a).identical());
        assert!(diff(&a, &a).render().contains("identical"));

        let mut b = a.clone();
        b.events[1] = ev(EventKind::Pop, 0, 3, 1, 0, 0);
        let d = diff(&a, &b);
        assert_eq!(d.first_divergence, Some(1));
        let (ea, eb) = d.diverging.unwrap();
        assert_eq!(ea.unwrap().kind, EventKind::Push);
        assert_eq!(eb.unwrap().kind, EventKind::Pop);

        // prefix relationship: divergence at the shorter stream's end
        let mut c = a.clone();
        c.events.push(ev(EventKind::Emit, 1, 8, 2, 0, 0));
        let d = diff(&a, &c);
        assert_eq!(d.first_divergence, Some(2));
        assert_eq!(d.diverging.unwrap().0, None);
        assert_eq!(d.stage_events[&1], (0, 1));
        assert!(d.render().contains("stage 1"));
    }

    #[test]
    fn heatmap_buckets_and_shades() {
        let rec = Recording {
            events: vec![
                ev(EventKind::LinkTx, 0, 0, 0, 100, 0),
                ev(EventKind::LinkTx, 0, 1, 5, 300, 1),
                ev(EventKind::LinkTx, 0, 1, 9, 300, 0),
                // other stage, ignored by build(0)
                ev(EventKind::LinkTx, 1, 0, 0, 999, 0),
            ],
            dropped: 0,
        };
        let h = LinkHeatmap::build(&rec, 0, 2).unwrap();
        assert_eq!((h.tiles, h.buckets, h.max_slot), (2, 2, 9));
        assert_eq!(h.total_bits, 700);
        assert_eq!(h.interchip_bits, 300);
        assert_eq!(h.cell_bits(0, 0), 100);
        assert_eq!(h.cell_bits(1, 1), 600);
        let s = h.render();
        assert_eq!(s.lines().count(), 2 + h.tiles);
        assert!(s.contains("700 b total"));
        assert_eq!(LinkHeatmap::busiest_stage(&rec), Some(1));
        assert!(LinkHeatmap::build(&rec, 7, 2).is_none());
    }

    #[test]
    fn breakpoint_parse_and_match() {
        let bp = Breakpoint::parse("3,120").unwrap();
        assert_eq!(bp.tile, Some(3));
        assert_eq!(bp.cycle, Some(120));
        assert_eq!(bp.kind, None);
        // slot 60 covers cycles 120..122 at CYCLES_PER_SLOT = 2
        assert!(bp.matches(&ev(EventKind::Acc, 0, 3, 60, 0, 0)));
        assert!(!bp.matches(&ev(EventKind::Acc, 0, 4, 60, 0, 0)));
        assert!(!bp.matches(&ev(EventKind::Acc, 0, 3, 61, 0, 0)));

        let bp = Breakpoint::parse("*,*,push").unwrap();
        assert!(bp.matches(&ev(EventKind::Push, 0, 3, 1, 0, 0)));
        assert!(!bp.matches(&ev(EventKind::Pop, 0, 3, 1, 0, 0)));

        let bp = Breakpoint::parse(" 6 , * , pop ").unwrap();
        assert_eq!((bp.tile, bp.cycle, bp.kind), (Some(6), None, Some(EventKind::Pop)));

        assert!(Breakpoint::parse("3").is_err());
        assert!(Breakpoint::parse("a,b").is_err());
        assert!(Breakpoint::parse("1,2,teleport").is_err());
        assert!(Breakpoint::parse("1,2,3,4").is_err());
    }

    #[test]
    fn stepper_runs_to_breakpoints_and_tracks_state() {
        let rec = Recording {
            events: vec![
                ev(EventKind::StageEnter, 0, NO_TILE, 0, 0, 0),
                ev(EventKind::Acc, 0, 1, 0, 0, 0),
                ev(EventKind::Push, 0, 3, 1, 0, 0),
                ev(EventKind::FifoDepth, 0, 3, 1, 2, 0),
                ev(EventKind::LinkTx, 0, 1, 1, 64, 1),
                ev(EventKind::Pop, 0, 3, 4, 0, 0),
                ev(EventKind::StageExit, 0, NO_TILE, 0, 9, 0),
            ],
            dropped: 0,
        };
        let mut st = Stepper::new(rec.clone());
        st.add_breakpoint(Breakpoint::parse("3,*,push").unwrap());
        st.add_breakpoint(Breakpoint::parse("3,*,pop").unwrap());
        let (i, e) = st.run_to_break().unwrap();
        assert_eq!((i, e.kind), (2, EventKind::Push));
        assert_eq!(st.state().stage, Some(0));
        let (i, e) = st.run_to_break().unwrap();
        assert_eq!((i, e.kind), (5, EventKind::Pop));
        assert_eq!(st.state().fifo_depth[&(0, 0, 3)], 2);
        assert_eq!(st.state().interchip_bits, 64);
        assert!(st.run_to_break().is_none());
        assert!(st.done());
        assert_eq!(st.state().stage, None);
        assert_eq!(st.state().count(EventKind::Acc), 1);
        let r = st.state().render();
        assert!(r.contains("inter-chip"));

        // plain stepping visits every event once
        let mut st = Stepper::new(rec);
        let mut n = 0;
        while st.step().is_some() {
            n += 1;
        }
        assert_eq!(n, st.len());
    }

    #[test]
    fn timelines_split_by_link_fifo_and_arena() {
        let mut r = FlightRecorder::new(RecorderConfig::with_capacity(64));
        r.link(0, 0, 1, 3, LinkKind::OnChip, 128);
        r.link(0, 0, 1, 4, LinkKind::InterChip, 256);
        r.fifo_depth(0, 0, 3, 4, 2);
        r.arena_in_use(0, 0, 4, 5, 12);
        r.link(2, 0, 0, 0, LinkKind::OnChip, 8);
        let rec = r.recording();
        let t = StageTimelines::build(&rec, 0);
        assert_eq!(t.links[&(0, 1)].len(), 2);
        assert!(t.links[&(0, 1)][1].interchip);
        assert_eq!(t.total_link_bits(), 384);
        assert_eq!(t.peak_fifo_depth(), 2);
        assert_eq!(t.peak_arena_in_use(), 5);
        assert_eq!(t.arena[&0][0].slots, 12);
        assert_eq!(rec.stage_count(), 3);
        assert_eq!(rec.events_per_stage()[&0], 4);
    }
}
