//! Raw event counters collected during simulation.
//!
//! The simulator counts *architectural events* (buffer accesses, adds,
//! schedule fetches, link bits, MACs); the `energy` module converts the
//! counts into joules using the paper's Table III per-event energies.
//! Keeping counts and energy separate lets the same run be re-priced
//! under different technology assumptions (the Table IV normalization).

/// Event counters (one instance per simulation run; `merge` combines
/// per-stage counters).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Counters {
    /// Instruction steps simulated (10 MHz domain).
    pub steps: u64,
    /// PE multiply-accumulate operations (8b x 8b -> 32b each).
    pub pe_macs: u64,
    /// PE array activations (one per streamed input vector).
    pub pe_mvms: u64,
    /// RIFM 256 B buffer accesses (read or write of one beat).
    pub rifm_buffer_accesses: u64,
    /// RIFM in-buffer shift operations (step 64 / multiple of 128).
    pub rifm_shifts: u64,
    /// Steps in which a RIFM controller was active.
    pub rifm_ctrl_steps: u64,
    /// ROFM schedule-table fetches (16 b each).
    pub sched_fetches: u64,
    /// ROFM 16 KiB data-buffer accesses (group-sum push/pop).
    pub rofm_buffer_accesses: u64,
    /// ROFM input/output register accesses, in 64 b words.
    pub rofm_reg_accesses: u64,
    /// 8-bit adder-equivalent operations (an i32 add counts as 4).
    pub adds_8b: u64,
    /// Pooling comparisons/scales, in 8-bit units.
    pub pool_ops_8b: u64,
    /// Activation operations, in 8-bit units.
    pub act_ops_8b: u64,
    /// Steps in which an ROFM controller was active.
    pub rofm_ctrl_steps: u64,
    /// Bits moved over on-chip mesh links (per hop).
    pub onchip_link_bits: u64,
    /// Bits moved over inter-chip transceivers.
    pub interchip_bits: u64,
    /// Bits moved on/off package (DRAM or host I/O; network input and
    /// final output only under COM dataflow).
    pub offchip_io_bits: u64,
    /// Peak ROFM group-sum buffer occupancy observed (bytes), for the
    /// 16 KiB capacity fidelity check.
    pub peak_rofm_buffer_bytes: u64,
    /// Number of tiles that were configured (for ctrl/idle accounting).
    pub tiles_used: u64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate another counter set (e.g. per-layer into per-network).
    pub fn merge(&mut self, other: &Counters) {
        self.steps += other.steps;
        self.pe_macs += other.pe_macs;
        self.pe_mvms += other.pe_mvms;
        self.rifm_buffer_accesses += other.rifm_buffer_accesses;
        self.rifm_shifts += other.rifm_shifts;
        self.rifm_ctrl_steps += other.rifm_ctrl_steps;
        self.sched_fetches += other.sched_fetches;
        self.rofm_buffer_accesses += other.rofm_buffer_accesses;
        self.rofm_reg_accesses += other.rofm_reg_accesses;
        self.adds_8b += other.adds_8b;
        self.pool_ops_8b += other.pool_ops_8b;
        self.act_ops_8b += other.act_ops_8b;
        self.rofm_ctrl_steps += other.rofm_ctrl_steps;
        self.onchip_link_bits += other.onchip_link_bits;
        self.interchip_bits += other.interchip_bits;
        self.offchip_io_bits += other.offchip_io_bits;
        self.peak_rofm_buffer_bytes = self.peak_rofm_buffer_bytes.max(other.peak_rofm_buffer_bytes);
        self.tiles_used += other.tiles_used;
    }

    /// Wall-clock seconds at the paper's 10 MHz step frequency — note
    /// that for latency purposes `steps` of *pipelined* stages overlap;
    /// the engine reports per-stage steps and the critical path
    /// separately.
    pub fn seconds(&self) -> f64 {
        self.steps as f64 / crate::consts::STEP_HZ
    }

    /// Events per simulated second for an arbitrary count, at the
    /// 10 MHz step clock. Returns 0 for a run with no simulated steps
    /// (instead of dividing by zero).
    pub fn rate_per_s(&self, count: u64) -> f64 {
        safe_rate(count as f64, self.seconds())
    }

    /// Simulated MAC throughput (MACs per simulated second); 0 when
    /// nothing was simulated.
    pub fn macs_per_second(&self) -> f64 {
        self.rate_per_s(self.pe_macs)
    }
}

/// `count / seconds`, with every degenerate denominator (zero,
/// negative, NaN) mapped to 0.0 instead of NaN/inf — rates derived
/// from empty runs must stay plottable and comparable.
pub fn safe_rate(count: f64, seconds: f64) -> f64 {
    if seconds > 0.0 && seconds.is_finite() {
        count / seconds
    } else {
        0.0
    }
}

impl std::fmt::Display for Counters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "steps:               {}", self.steps)?;
        writeln!(f, "pe_macs:             {}", self.pe_macs)?;
        writeln!(f, "pe_mvms:             {}", self.pe_mvms)?;
        writeln!(f, "rifm_buffer_access:  {}", self.rifm_buffer_accesses)?;
        writeln!(f, "rifm_shifts:         {}", self.rifm_shifts)?;
        writeln!(f, "sched_fetches:       {}", self.sched_fetches)?;
        writeln!(f, "rofm_buffer_access:  {}", self.rofm_buffer_accesses)?;
        writeln!(f, "rofm_reg_accesses:      {}", self.rofm_reg_accesses)?;
        writeln!(f, "adds_8b:             {}", self.adds_8b)?;
        writeln!(f, "pool_ops_8b:         {}", self.pool_ops_8b)?;
        writeln!(f, "act_ops_8b:          {}", self.act_ops_8b)?;
        writeln!(f, "onchip_link_bits:    {}", self.onchip_link_bits)?;
        writeln!(f, "interchip_bits:      {}", self.interchip_bits)?;
        writeln!(f, "offchip_io_bits:     {}", self.offchip_io_bits)?;
        writeln!(f, "peak_rofm_buf_bytes: {}", self.peak_rofm_buffer_bytes)?;
        write!(f, "tiles_used:          {}", self.tiles_used)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_counts_and_maxes_peaks() {
        let mut a = Counters {
            steps: 10,
            pe_macs: 100,
            peak_rofm_buffer_bytes: 64,
            ..Default::default()
        };
        let b = Counters {
            steps: 5,
            pe_macs: 50,
            peak_rofm_buffer_bytes: 128,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.steps, 15);
        assert_eq!(a.pe_macs, 150);
        assert_eq!(a.peak_rofm_buffer_bytes, 128);
    }

    #[test]
    fn seconds_at_10mhz() {
        let c = Counters {
            steps: 10_000_000,
            ..Default::default()
        };
        assert!((c.seconds() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rates_guard_zero_denominators() {
        // An empty run must produce 0, not NaN/inf.
        let empty = Counters::new();
        assert_eq!(empty.macs_per_second(), 0.0);
        assert_eq!(empty.rate_per_s(123), 0.0);
        assert_eq!(safe_rate(5.0, 0.0), 0.0);
        assert_eq!(safe_rate(5.0, -1.0), 0.0);
        assert_eq!(safe_rate(5.0, f64::NAN), 0.0);
        // ... and a real run produces the plain ratio.
        let c = Counters {
            steps: 10_000_000, // 1 simulated second
            pe_macs: 42,
            ..Default::default()
        };
        assert!((c.macs_per_second() - 42.0).abs() < 1e-9);
    }
}
