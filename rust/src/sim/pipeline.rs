//! Steady-state pipeline simulation (paper Section IV-B-2, "layer
//! synchronization").
//!
//! Table IV's throughput comes from all layers streaming concurrently:
//! while stage *i* processes image *n*, stage *i−1* is already on image
//! *n+1*. This module simulates that overlap at stage granularity —
//! each stage is busy for its slot count per image, may not start an
//! image before its predecessor has streamed the first outputs (the
//! chain-fill lead), and may not run ahead of its own previous image —
//! and measures the steady-state inter-completion time, which must
//! equal the analytic `perfmodel` period. It also reports per-stage
//! utilization (the fraction of the pipeline period each tile array is
//! busy), which is what the duplication water-filler equalizes.

use anyhow::Result;

use crate::coordinator::program::{Program, StageKind};
use crate::coordinator::schedule::CYCLES_PER_SLOT;
use crate::perfmodel::NetworkEstimate;

/// Timing of one stage across the simulated image batch.
#[derive(Clone, Debug)]
pub struct StageTiming {
    pub name: String,
    /// Busy slots per image (latency, incl. chain fill).
    pub slots: u64,
    /// Steady-state period slots (excl. fill).
    pub period_slots: u64,
    /// First-output lead: slots from stage start until the next stage
    /// can begin (chain fill for convs, full pass for pool/fc).
    pub lead_slots: u64,
    /// Busy fraction of the pipeline period in steady state.
    pub utilization: f64,
}

/// Result of a pipelined batch run.
#[derive(Clone, Debug)]
pub struct PipelineRun {
    pub stages: Vec<StageTiming>,
    /// Completion cycle of every image.
    pub completions: Vec<u64>,
    /// First-image latency in cycles.
    pub first_latency_cycles: u64,
    /// Steady-state inter-completion gap (cycles) measured over the
    /// last half of the batch.
    pub steady_period_cycles: u64,
    pub images_per_s: f64,
}

/// Per-stage first-output lead in slots.
fn lead_slots(stage: &StageKind) -> u64 {
    match stage {
        // a conv chain emits its first output after the chain fills
        StageKind::Conv(c) => c
            .chains
            .iter()
            .map(|ch| ch.tiles.len() as u64)
            .max()
            .unwrap_or(0),
        StageKind::Res(r) => r
            .proj
            .as_ref()
            .map(|p| {
                p.chains
                    .iter()
                    .map(|ch| ch.tiles.len() as u64)
                    .max()
                    .unwrap_or(0)
            })
            .unwrap_or(1),
        // pooling emits once a window row completes; FC once the column
        // drains — model both as one slot of lead (they stream)
        StageKind::Pool(_) | StageKind::Fc(_) => 1,
        StageKind::Flatten => 0,
    }
}

/// Simulate `images` through the stage pipeline.
///
/// Recurrence (slots):
///   start[i][n] = max(start[i-1][n] + lead[i-1],    // first data in
///                     start[i][n-1] + period[i])    // stage busy
///   done[i][n]  = max(start[i][n] + slots[i],       // own work
///                     done[i-1][n] + lead[i])       // input stream end
///
/// The second `done` term models streaming: a stage cannot finish
/// before its input finishes arriving plus its drain time.
pub fn run_pipelined(
    program: &Program,
    est: &NetworkEstimate,
    images: usize,
) -> Result<PipelineRun> {
    anyhow::ensure!(images >= 1, "need at least one image");
    let n_stages = program.stages.len();
    let mut leads = Vec::with_capacity(n_stages);
    for s in &program.stages {
        leads.push(lead_slots(&s.kind));
    }

    let mut start = vec![vec![0u64; images]; n_stages];
    let mut done = vec![vec![0u64; images]; n_stages];
    let mut done_last = vec![0u64; images];
    for n in 0..images {
        for i in 0..n_stages {
            let data_ready = if i == 0 {
                // images enter back-to-back at the first stage's period
                (n as u64) * est.stages[0].period_slots
            } else {
                start[i - 1][n] + leads[i - 1]
            };
            let stage_free = if n == 0 {
                0
            } else {
                start[i][n - 1] + est.stages[i].period_slots
            };
            start[i][n] = data_ready.max(stage_free);
            let own = start[i][n] + est.stages[i].slots;
            done[i][n] = if i == 0 {
                own
            } else {
                own.max(done[i - 1][n] + leads[i])
            };
        }
        done_last[n] = done[n_stages - 1][n];
    }

    let completions: Vec<u64> = done_last
        .iter()
        .map(|s| s * CYCLES_PER_SLOT as u64)
        .collect();
    let first_latency_cycles = completions[0];
    // steady state: average gap over the last half
    let steady_period_cycles = if images >= 4 {
        let half = images / 2;
        (completions[images - 1] - completions[half]) / (images - 1 - half) as u64
    } else {
        est.period_cycles
    };

    let period = steady_period_cycles.max(1);
    let stages = program
        .stages
        .iter()
        .zip(&est.stages)
        .zip(&leads)
        .map(|((s, e), &lead)| StageTiming {
            name: s.name.clone(),
            slots: e.slots,
            period_slots: e.period_slots,
            lead_slots: lead,
            utilization: (e.period_slots * CYCLES_PER_SLOT as u64) as f64 / period as f64,
        })
        .collect();

    Ok(PipelineRun {
        stages,
        completions,
        first_latency_cycles,
        steady_period_cycles,
        images_per_s: images_per_s_for_period(steady_period_cycles),
    })
}

/// Throughput at the 10 MHz step clock for a steady-state period in
/// cycles; 0 for a degenerate (zero-cycle) period instead of NaN/inf.
pub fn images_per_s_for_period(period_cycles: u64) -> f64 {
    crate::sim::stats::safe_rate(1.0, period_cycles as f64 / crate::consts::STEP_HZ)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ArchConfig, Compiler};
    use crate::model::zoo;
    use crate::perfmodel::estimate;

    fn run(net: &crate::model::Network, arch: ArchConfig, images: usize) -> PipelineRun {
        let program = Compiler::new(arch).compile(net).unwrap();
        let est = estimate(&program).unwrap();
        run_pipelined(&program, &est, images).unwrap()
    }

    #[test]
    fn steady_state_matches_analytic_period() {
        // the central claim of the perfmodel: the pipelined simulation's
        // measured inter-completion time equals max-stage-period
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let est = estimate(&program).unwrap();
        let r = run_pipelined(&program, &est, 32).unwrap();
        assert_eq!(r.steady_period_cycles, est.period_cycles);
    }

    #[test]
    fn steady_state_matches_under_duplication() {
        let net = zoo::vgg11_cifar();
        let program = Compiler::new(ArchConfig::table4(5)).compile(&net).unwrap();
        let est = estimate(&program).unwrap();
        let r = run_pipelined(&program, &est, 32).unwrap();
        assert_eq!(r.steady_period_cycles, est.period_cycles);
        // throughput equals the analytic figure
        assert!((r.images_per_s - est.images_per_s()).abs() / est.images_per_s() < 1e-9);
    }

    #[test]
    fn first_image_latency_bounded_by_sum_of_stages() {
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let est = estimate(&program).unwrap();
        let r = run_pipelined(&program, &est, 8).unwrap();
        // pipelined first-image latency <= back-to-back latency (leads
        // overlap downstream work), and >= the longest stage
        assert!(r.first_latency_cycles <= est.latency_cycles);
        assert!(r.first_latency_cycles >= est.period_cycles);
    }

    #[test]
    fn completions_are_monotonic() {
        let net = zoo::tiny_cnn();
        let r = run(&net, ArchConfig::default(), 16);
        for w in r.completions.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn bottleneck_stage_is_saturated() {
        let net = zoo::vgg11_cifar();
        let r = run(&net, ArchConfig::default(), 16);
        let max_util = r
            .stages
            .iter()
            .map(|s| s.utilization)
            .fold(0.0f64, f64::max);
        assert!((max_util - 1.0).abs() < 1e-9, "bottleneck util {max_util}");
        // water-filling lifts the minimum utilization
        let filled = run(&net, ArchConfig::table4(5), 16);
        let conv_min = |r: &PipelineRun| {
            r.stages
                .iter()
                .filter(|s| s.name.starts_with("conv"))
                .map(|s| s.utilization)
                .fold(1.0f64, f64::min)
        };
        assert!(conv_min(&filled) > conv_min(&r));
    }

    #[test]
    fn zero_period_yields_zero_throughput() {
        assert_eq!(images_per_s_for_period(0), 0.0);
        assert!((images_per_s_for_period(10) - 1.0e6).abs() < 1e-6);
    }

    #[test]
    fn rejects_zero_images() {
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let est = estimate(&program).unwrap();
        assert!(run_pipelined(&program, &est, 0).is_err());
    }
}
