//! Deterministic CIM/NoC fault injection.
//!
//! CIM crossbars are exactly where stuck-at cells and drift live, and
//! the NoC's psum links are where upsets flip bits in flight — yet a
//! cycle simulator normally assumes both are perfect. This module is
//! the engine-side half of the fault plane: a seeded, fully
//! deterministic [`FaultPlan`] describing *which* physical resources
//! misbehave and *when*, threaded through the engine as a monomorphized
//! type parameter exactly like the probe layer
//! ([`crate::sim::flight::Probe`]).
//!
//! * [`NoFaults`] is the default: `const ENABLED = false`, every hook
//!   an empty `#[inline(always)]` body. The zero-allocation hot path
//!   and the `engine_perf` frozen-baseline gate compile bit-for-bit
//!   unchanged — the seam costs nothing when unused.
//! * [`FaultInjector`] is the live implementation: it matches every
//!   tile MVM and psum link transfer against the plan's sites and
//!   corrupts the payload **values** in place. Event structure and
//!   timing are never touched — a faulty run produces the same event
//!   sequence, the same latency and the same energy counters as a
//!   clean one, only wrong numbers. That is precisely the
//!   silent-corruption failure mode the serve plane's canary checks
//!   exist to catch, and it keeps the engine's schedule tag-checks and
//!   the `perfmodel` cross-assertions valid under injection.
//!
//! Fault sites are keyed by physical [`Coord`] (chip, row, col) — the
//! same coordinates the mapping plane places chains onto and the same
//! link sites the probe layer instruments — so a detected fault maps
//! directly to a [`crate::coordinator::TileMask`] entry and the model
//! can be re-placed around the bad resource.
//!
//! Determinism: the engine's event sequence is a pure function of
//! (program, input), so for a fixed plan the set of fires, the
//! [`FaultReport`] and the corrupted outputs are byte-identical across
//! runs *and across batch thread counts* — per-worker reports merge by
//! order-invariant sums/mins/maxes (property-tested in
//! `rust/tests/fault_properties.rs`).

use std::collections::BTreeSet;
use std::fmt;

use anyhow::{bail, Context, Result};

use crate::noc::link::LinkKind;
use crate::noc::Coord;

/// What a fault site does to the values that pass through it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Dead CIM tile: its MVM output reads all-zero (the array never
    /// discharges).
    DeadTile,
    /// Stuck-at CIM tile: every output lane of its MVM latches the
    /// given value.
    StuckAt(i8),
    /// Link upset: XOR one bit (0..=31) of the first lane of every
    /// psum payload leaving this tile.
    LinkFlip { bit: u8 },
    /// Dropped flit: the psum payload leaving this tile is re-assembled
    /// as zeros at the receiver (values lost, event structure intact).
    LinkDrop,
}

impl FaultKind {
    /// Whether this kind fires on tile MVM outputs (vs link transfers).
    pub fn is_tile(self) -> bool {
        matches!(self, FaultKind::DeadTile | FaultKind::StuckAt(_))
    }
}

/// When a fault site is live, in engine pixel slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultWindow {
    /// Live for the whole run (a hard fault).
    Permanent,
    /// Live for slots in `[from, to)` of every stage (a transient).
    Transient { from: u32, to: u32 },
}

impl FaultWindow {
    fn contains(self, slot: usize) -> bool {
        match self {
            FaultWindow::Permanent => true,
            FaultWindow::Transient { from, to } => {
                (slot as u64) >= from as u64 && (slot as u64) < to as u64
            }
        }
    }
}

/// One faulty physical resource: the tile (or link source tile) at
/// `coord` misbehaves per `kind` whenever `window` is live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSite {
    pub coord: Coord,
    pub kind: FaultKind,
    pub window: FaultWindow,
}

impl fmt::Display for FaultSite {
    /// Canonical spec string — the wire/CLI format, parsed back by
    /// [`FaultSite::parse`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let c = self.coord;
        match self.kind {
            FaultKind::DeadTile => write!(f, "tile:{}:{}:{}:dead", c.chip, c.row, c.col)?,
            FaultKind::StuckAt(v) => {
                write!(f, "tile:{}:{}:{}:stuck:{}", c.chip, c.row, c.col, v)?
            }
            FaultKind::LinkFlip { bit } => {
                write!(f, "link:{}:{}:{}:flip:{}", c.chip, c.row, c.col, bit)?
            }
            FaultKind::LinkDrop => write!(f, "link:{}:{}:{}:drop", c.chip, c.row, c.col)?,
        }
        if let FaultWindow::Transient { from, to } = self.window {
            write!(f, "@{from}-{to}")?;
        }
        Ok(())
    }
}

impl FaultSite {
    /// Parse one site spec:
    /// `tile:<chip>:<row>:<col>:dead`,
    /// `tile:<chip>:<row>:<col>:stuck:<v>`,
    /// `link:<chip>:<row>:<col>:flip:<bit>`,
    /// `link:<chip>:<row>:<col>:drop`,
    /// each optionally suffixed `@<from>-<to>` (slot window, else
    /// permanent).
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        let (body, window) = match spec.split_once('@') {
            Some((b, w)) => {
                let (from, to) = w
                    .split_once('-')
                    .with_context(|| format!("fault window {w:?}: expected <from>-<to>"))?;
                let from: u32 = from
                    .parse()
                    .with_context(|| format!("fault window start {from:?}"))?;
                let to: u32 = to
                    .parse()
                    .with_context(|| format!("fault window end {to:?}"))?;
                if from >= to {
                    bail!("fault window {w:?} is empty (from >= to)");
                }
                (b, FaultWindow::Transient { from, to })
            }
            None => (spec, FaultWindow::Permanent),
        };
        let parts: Vec<&str> = body.split(':').collect();
        if parts.len() < 5 {
            bail!(
                "fault spec {spec:?}: expected \
                 tile:<chip>:<row>:<col>:dead|stuck:<v> or \
                 link:<chip>:<row>:<col>:flip:<bit>|drop"
            );
        }
        let coord = Coord::new(
            parts[1].parse().with_context(|| format!("chip {:?}", parts[1]))?,
            parts[2].parse().with_context(|| format!("row {:?}", parts[2]))?,
            parts[3].parse().with_context(|| format!("col {:?}", parts[3]))?,
        );
        let kind = match (parts[0], parts[4]) {
            ("tile", "dead") => FaultKind::DeadTile,
            ("tile", "stuck") => {
                let v = parts
                    .get(5)
                    .with_context(|| format!("fault spec {spec:?}: stuck needs a value"))?;
                FaultKind::StuckAt(v.parse().with_context(|| format!("stuck value {v:?}"))?)
            }
            ("link", "flip") => {
                let b = parts
                    .get(5)
                    .with_context(|| format!("fault spec {spec:?}: flip needs a bit"))?;
                let bit: u8 = b.parse().with_context(|| format!("flip bit {b:?}"))?;
                if bit > 31 {
                    bail!("flip bit {bit} out of range (psum lanes are 32-bit)");
                }
                FaultKind::LinkFlip { bit }
            }
            ("link", "drop") => FaultKind::LinkDrop,
            (site, kind) => bail!("unknown fault {site}:{kind} in spec {spec:?}"),
        };
        Ok(FaultSite {
            coord,
            kind,
            window,
        })
    }
}

/// A deterministic set of fault sites. Built programmatically or parsed
/// from a `;`-separated spec string (the CLI/wire format).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub sites: Vec<FaultSite>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a `;`-separated list of site specs (see
    /// [`FaultSite::parse`]). An empty string is the empty plan.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut sites = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            sites.push(FaultSite::parse(part)?);
        }
        Ok(Self { sites })
    }

    /// The canonical `;`-separated spec string (round-trips through
    /// [`Self::parse`]).
    pub fn spec(&self) -> String {
        self.sites
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join(";")
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    pub fn len(&self) -> usize {
        self.sites.len()
    }

    /// Every distinct physical coordinate named by the plan — the tile
    /// set a recovery re-mapping must avoid (link faults are dodged by
    /// avoiding their source tile).
    pub fn coords(&self) -> BTreeSet<Coord> {
        self.sites.iter().map(|s| s.coord).collect()
    }

    fn push(mut self, site: FaultSite) -> Self {
        self.sites.push(site);
        self
    }

    /// Builder: a permanently dead tile.
    pub fn dead_tile(self, coord: Coord) -> Self {
        self.push(FaultSite {
            coord,
            kind: FaultKind::DeadTile,
            window: FaultWindow::Permanent,
        })
    }

    /// Builder: a permanently stuck tile.
    pub fn stuck_tile(self, coord: Coord, v: i8) -> Self {
        self.push(FaultSite {
            coord,
            kind: FaultKind::StuckAt(v),
            window: FaultWindow::Permanent,
        })
    }

    /// Builder: a permanent single-bit upset on psums leaving `coord`.
    pub fn link_flip(self, coord: Coord, bit: u8) -> Self {
        self.push(FaultSite {
            coord,
            kind: FaultKind::LinkFlip { bit },
            window: FaultWindow::Permanent,
        })
    }

    /// Builder: psum payloads leaving `coord` dropped (zeroed).
    pub fn link_drop(self, coord: Coord) -> Self {
        self.push(FaultSite {
            coord,
            kind: FaultKind::LinkDrop,
            window: FaultWindow::Permanent,
        })
    }

    /// Builder: restrict the most recently added site to a slot window.
    pub fn during(mut self, from: u32, to: u32) -> Self {
        if let Some(last) = self.sites.last_mut() {
            last.window = FaultWindow::Transient { from, to };
        }
        self
    }
}

/// The engine's fault seam, mirroring [`crate::sim::flight::Probe`]:
/// monomorphized, forked per batch worker, merged back in chunk order.
/// Hooks receive the payload *after* the clean computation and may
/// corrupt values in place; they must never change payload length.
pub trait Faults: Send {
    /// Statically `true` when this implementation can fire. `false`
    /// compiles every hook call site out of the monomorphized engine.
    const ENABLED: bool;

    /// A tile at `coord` produced an MVM psum row (`data`, one `i32`
    /// per output lane) in stage `stage`, pixel slot `slot`.
    fn tile_psum(&mut self, stage: usize, coord: Coord, slot: usize, data: &mut [i32]);

    /// A psum payload (`data`) is in flight over the `kind` link
    /// leaving tile `from` toward tile `to`.
    fn link_psum(
        &mut self,
        stage: usize,
        from: Coord,
        to: Coord,
        slot: usize,
        kind: LinkKind,
        data: &mut [i32],
    );

    /// A fresh instance of the same plan for a batch worker (zeroed
    /// fire counters).
    fn fork(&self) -> Self
    where
        Self: Sized;

    /// Zero the fire counters (batch start for reused workers).
    fn clear(&mut self);

    /// Merge a worker's fire counters into this one. Sums, mins and
    /// maxes only, so merging in any order — and any thread count —
    /// produces the identical report.
    fn absorb(&mut self, worker: &mut Self)
    where
        Self: Sized;
}

/// The default: no faults, no cost. The `EngineCore<_, NoFaults>`
/// instantiation — the one every pre-existing constructor produces —
/// is bit-for-bit the unparameterized engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NoFaults;

impl Faults for NoFaults {
    const ENABLED: bool = false;
    #[inline(always)]
    fn tile_psum(&mut self, _: usize, _: Coord, _: usize, _: &mut [i32]) {}
    #[inline(always)]
    fn link_psum(&mut self, _: usize, _: Coord, _: Coord, _: usize, _: LinkKind, _: &mut [i32]) {}
    #[inline(always)]
    fn fork(&self) -> Self {
        NoFaults
    }
    #[inline(always)]
    fn clear(&mut self) {}
    #[inline(always)]
    fn absorb(&mut self, _: &mut Self) {}
}

/// Per-site fire counters (order-invariant under merge).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct SiteFires {
    fires: u64,
    lanes_corrupted: u64,
    first_slot: Option<u32>,
    last_slot: Option<u32>,
    /// Bitmask of the first 64 stages the site fired in (blast radius).
    stage_mask: u64,
}

impl SiteFires {
    fn record(&mut self, stage: usize, slot: usize, lanes: u64) {
        self.fires += 1;
        self.lanes_corrupted += lanes;
        let s = slot.min(u32::MAX as usize) as u32;
        self.first_slot = Some(self.first_slot.map_or(s, |f| f.min(s)));
        self.last_slot = Some(self.last_slot.map_or(s, |l| l.max(s)));
        if stage < 64 {
            self.stage_mask |= 1 << stage;
        }
    }

    fn merge(&mut self, other: &SiteFires) {
        self.fires += other.fires;
        self.lanes_corrupted += other.lanes_corrupted;
        self.first_slot = match (self.first_slot, other.first_slot) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.last_slot = match (self.last_slot, other.last_slot) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
        self.stage_mask |= other.stage_mask;
    }
}

/// The live [`Faults`] implementation: matches engine events against a
/// [`FaultPlan`] and corrupts payload values in place, counting every
/// fire per site.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    fires: Vec<SiteFires>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        let n = plan.sites.len();
        Self {
            plan,
            fires: vec![SiteFires::default(); n],
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Snapshot the per-site fire counters as a typed report.
    pub fn report(&self) -> FaultReport {
        FaultReport {
            sites: self
                .plan
                .sites
                .iter()
                .zip(&self.fires)
                .map(|(site, f)| SiteReport {
                    site: *site,
                    fires: f.fires,
                    lanes_corrupted: f.lanes_corrupted,
                    first_slot: f.first_slot,
                    last_slot: f.last_slot,
                    stages: (0..64u16).filter(|s| f.stage_mask & (1 << s) != 0).collect(),
                })
                .collect(),
        }
    }

    fn apply(kind: FaultKind, data: &mut [i32]) -> u64 {
        match kind {
            FaultKind::DeadTile | FaultKind::LinkDrop => {
                data.fill(0);
                data.len() as u64
            }
            FaultKind::StuckAt(v) => {
                data.fill(v as i32);
                data.len() as u64
            }
            FaultKind::LinkFlip { bit } => {
                if let Some(lane) = data.first_mut() {
                    *lane ^= 1i32 << bit;
                    1
                } else {
                    0
                }
            }
        }
    }
}

impl Faults for FaultInjector {
    const ENABLED: bool = true;

    fn tile_psum(&mut self, stage: usize, coord: Coord, slot: usize, data: &mut [i32]) {
        for (site, f) in self.plan.sites.iter().zip(self.fires.iter_mut()) {
            if site.kind.is_tile() && site.coord == coord && site.window.contains(slot) {
                let lanes = Self::apply(site.kind, data);
                f.record(stage, slot, lanes);
            }
        }
    }

    fn link_psum(
        &mut self,
        stage: usize,
        from: Coord,
        _to: Coord,
        slot: usize,
        _kind: LinkKind,
        data: &mut [i32],
    ) {
        for (site, f) in self.plan.sites.iter().zip(self.fires.iter_mut()) {
            if !site.kind.is_tile() && site.coord == from && site.window.contains(slot) {
                let lanes = Self::apply(site.kind, data);
                f.record(stage, slot, lanes);
            }
        }
    }

    fn fork(&self) -> Self {
        Self::new(self.plan.clone())
    }

    fn clear(&mut self) {
        self.fires.fill(SiteFires::default());
    }

    fn absorb(&mut self, worker: &mut Self) {
        debug_assert_eq!(self.plan, worker.plan, "absorbing a different plan");
        for (a, b) in self.fires.iter_mut().zip(&worker.fires) {
            a.merge(b);
        }
        worker.clear();
    }
}

/// One site's fire record in a [`FaultReport`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SiteReport {
    pub site: FaultSite,
    /// Events the site corrupted.
    pub fires: u64,
    /// Total payload lanes (i32 values) modified — the blast radius in
    /// corrupted numbers.
    pub lanes_corrupted: u64,
    /// Earliest pixel slot the site fired in (None: never fired).
    pub first_slot: Option<u32>,
    /// Latest pixel slot the site fired in.
    pub last_slot: Option<u32>,
    /// Stages the site fired in, ascending (stages >= 64 not tracked).
    pub stages: Vec<u16>,
}

/// Typed summary of what a faulty run actually did: which sites fired,
/// when, and how many values they touched. Byte-identical for a given
/// (program, inputs, plan) across runs and batch thread counts.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultReport {
    pub sites: Vec<SiteReport>,
}

impl FaultReport {
    /// Total fires across all sites.
    pub fn total_fires(&self) -> u64 {
        self.sites.iter().map(|s| s.fires).sum()
    }

    /// Total corrupted payload lanes across all sites.
    pub fn total_lanes(&self) -> u64 {
        self.sites.iter().map(|s| s.lanes_corrupted).sum()
    }

    /// Sites that fired at least once.
    pub fn fired_sites(&self) -> impl Iterator<Item = &SiteReport> {
        self.sites.iter().filter(|s| s.fires > 0)
    }

    /// Human-readable multi-line summary (CLI `domino fault inject`).
    pub fn render(&self) -> String {
        if self.sites.is_empty() {
            return "no fault sites armed".to_string();
        }
        let mut out = String::new();
        for s in &self.sites {
            let when = match (s.first_slot, s.last_slot) {
                (Some(a), Some(b)) => format!("slots {a}..={b}"),
                _ => "never fired".to_string(),
            };
            let stages = if s.stages.is_empty() {
                "-".to_string()
            } else {
                s.stages
                    .iter()
                    .map(|x| x.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            };
            out.push_str(&format!(
                "{:<34} fires {:>8}  lanes {:>10}  {when:<22} stages {stages}\n",
                s.site.to_string(),
                s.fires,
                s.lanes_corrupted
            ));
        }
        out.push_str(&format!(
            "total: {} fires, {} corrupted lanes\n",
            self.total_fires(),
            self.total_lanes()
        ));
        out
    }
}

/// The output-corruption verdict of a faulty run against the
/// refcompute oracle.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CorruptionVerdict {
    /// Any score diverged from the oracle.
    pub corrupted: bool,
    /// Scores that diverged.
    pub mismatched: usize,
    /// Total scores compared.
    pub outputs: usize,
}

/// Compare simulated scores against the oracle's. A length mismatch is
/// full corruption (every output counted mismatched).
pub fn corruption_verdict(scores: &[i8], oracle: &[i8]) -> CorruptionVerdict {
    if scores.len() != oracle.len() {
        let outputs = scores.len().max(oracle.len());
        return CorruptionVerdict {
            corrupted: true,
            mismatched: outputs,
            outputs,
        };
    }
    let mismatched = scores
        .iter()
        .zip(oracle)
        .filter(|(a, b)| a != b)
        .count();
    CorruptionVerdict {
        corrupted: mismatched > 0,
        mismatched,
        outputs: scores.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(chip: usize, row: usize, col: usize) -> Coord {
        Coord::new(chip, row, col)
    }

    #[test]
    fn spec_round_trips() {
        let plan = FaultPlan::new()
            .dead_tile(c(0, 1, 2))
            .stuck_tile(c(1, 0, 3), -7)
            .link_flip(c(0, 2, 2), 13)
            .during(4, 96)
            .link_drop(c(2, 0, 0));
        let spec = plan.spec();
        assert_eq!(
            spec,
            "tile:0:1:2:dead;tile:1:0:3:stuck:-7;link:0:2:2:flip:13@4-96;link:2:0:0:drop"
        );
        assert_eq!(FaultPlan::parse(&spec).unwrap(), plan);
        // empty and whitespace specs are the empty plan
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ;").unwrap().is_empty());
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        for bad in [
            "tile:0:0:0",
            "tile:0:0:0:melt",
            "tile:0:0:0:stuck",
            "link:0:0:0:flip:32",
            "link:0:0:0:flip",
            "tile:x:0:0:dead",
            "tile:0:0:0:dead@9-3",
            "tile:0:0:0:dead@5",
        ] {
            assert!(FaultSite::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn injector_fires_only_matching_sites_in_window() {
        let plan = FaultPlan::new()
            .dead_tile(c(0, 0, 0))
            .link_flip(c(0, 0, 1), 0)
            .during(10, 20);
        let mut inj = FaultInjector::new(plan);
        let mut data = [5i32, 6, 7];

        // tile fault fires at its coord, any slot
        inj.tile_psum(0, c(0, 0, 0), 3, &mut data);
        assert_eq!(data, [0, 0, 0]);
        // wrong coord: untouched
        let mut other = [5i32];
        inj.tile_psum(0, c(0, 0, 1), 3, &mut other);
        assert_eq!(other, [5]);
        // link fault respects its window
        let mut lane = [8i32];
        inj.link_psum(1, c(0, 0, 1), c(0, 0, 2), 5, LinkKind::OnChip, &mut lane);
        assert_eq!(lane, [8], "slot 5 outside 10..20");
        inj.link_psum(1, c(0, 0, 1), c(0, 0, 2), 12, LinkKind::OnChip, &mut lane);
        assert_eq!(lane, [9], "bit 0 flipped");

        let report = inj.report();
        assert_eq!(report.sites[0].fires, 1);
        assert_eq!(report.sites[0].lanes_corrupted, 3);
        assert_eq!(report.sites[0].stages, vec![0]);
        assert_eq!(report.sites[1].fires, 1);
        assert_eq!(report.sites[1].first_slot, Some(12));
        assert_eq!(report.total_fires(), 2);
    }

    #[test]
    fn fork_absorb_is_order_invariant() {
        let plan = FaultPlan::new().dead_tile(c(0, 0, 0));
        let mut a = FaultInjector::new(plan.clone());
        let mut w1 = a.fork();
        let mut w2 = a.fork();
        let mut d = [1i32, 2];
        w1.tile_psum(0, c(0, 0, 0), 7, &mut d);
        let mut d2 = [3i32, 4];
        w2.tile_psum(1, c(0, 0, 0), 2, &mut d2);

        let mut b = FaultInjector::new(plan);
        let mut w1b = w1.clone();
        let mut w2b = w2.clone();
        a.absorb(&mut w1);
        a.absorb(&mut w2);
        b.absorb(&mut w2b);
        b.absorb(&mut w1b);
        assert_eq!(a.report(), b.report(), "merge order must not matter");
        let r = a.report();
        assert_eq!(r.sites[0].fires, 2);
        assert_eq!(r.sites[0].first_slot, Some(2));
        assert_eq!(r.sites[0].last_slot, Some(7));
        assert_eq!(r.sites[0].stages, vec![0, 1]);
        // absorbed workers are drained
        assert_eq!(w1.report().total_fires(), 0);
    }

    #[test]
    fn verdict_counts_mismatches() {
        let v = corruption_verdict(&[1, 2, 3], &[1, 2, 3]);
        assert!(!v.corrupted);
        let v = corruption_verdict(&[1, 9, 3], &[1, 2, 3]);
        assert!(v.corrupted);
        assert_eq!((v.mismatched, v.outputs), (1, 3));
        let v = corruption_verdict(&[1], &[1, 2]);
        assert!(v.corrupted);
        assert_eq!(v.outputs, 2);
    }
}
