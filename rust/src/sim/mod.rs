//! Cycle-level simulation: engine, statistics, dataflow trace.

pub mod engine;
pub mod pipeline;
pub mod stats;
pub mod trace;

pub use engine::Simulator;
pub use stats::Counters;
