//! Cycle-level simulation: engine, probe layer, flight recorder,
//! statistics, pipeline timing, dataflow trace.
//!
//! * [`engine`] — the cycle-accurate COM engine. Per-tile runtime
//!   state is built once per engine and reset between images;
//!   [`Simulator::run_image`] simulates one inference back-to-back,
//!   [`Simulator::run_batch`] data-parallelizes a batch across
//!   persistent worker engines (bit-exact with sequential runs,
//!   per-thread [`Counters`] merged) and reports the pipelined
//!   steady-state timing asserted against `perfmodel`. [`PooledEngine`]
//!   is the same engine behind an `Arc<Program>`; [`EnginePool`] caches
//!   one per model so multi-model serve workers never rebuild state
//!   per request.
//!
//!   The steady-state loop is free of **per-event** allocation (§Perf;
//!   what remains is a handful of per-stage output tensors per image):
//!   partial sums live in per-chain psum slab arenas and move between
//!   tiles as `Copy` handles, MVMs/activations write into reused
//!   scratch, and pooling units recycle their window buffers. [`CaptureMode`]
//!   selects what `run_image` copies out: `AllStages` (every stage
//!   tensor — tests, tracing) or `Final` (scores only — the serving
//!   path; one tensor clone per stage per image saved). Capture is
//!   host-side only: scores and counters are bit-identical across
//!   modes. `cargo bench --bench engine_perf` gates the speedup of
//!   this design against a frozen copy of the pre-arena hot path.
//!
//!   The MVMs themselves run as blocked kernels: each conv tile owns
//!   a panel-packed [`Pe`](crate::tile::Pe) and drains a small pixel
//!   micro-batch per tile visit
//!   ([`mvm_many_into`](crate::tile::Pe::mvm_many_into)), with every
//!   counter charge, probe event and fault-injection site still
//!   applied per slot — the observable event stream is 1:1 with
//!   per-pixel draining, and `cargo bench --bench bench_kernels`
//!   gates the kernel-level speedup against frozen scalar copies.
//! * [`flight`] — the observability plane. The engine is generic over a
//!   [`Probe`]: every tile action, psum push/pop, link transfer
//!   (with [`LinkKind`](crate::noc::link::LinkKind)), stage boundary,
//!   and FIFO/arena occupancy sample flows through it. The default
//!   [`NullProbe`] monomorphizes every callback to an empty inline
//!   body guarded by a `const ENABLED = false`, so the serving hot
//!   path compiles exactly as if the seam did not exist — scores and
//!   [`Counters`] are bit-identical probe-on vs. probe-off, and the
//!   `engine_perf` frozen-baseline gate still holds. [`FlightRecorder`]
//!   is the real probe: a bounded binary ring of fixed-width 20-byte
//!   [`flight::Event`] records (oldest dropped under pressure, never
//!   unbounded growth). Batches record too: each worker forks an empty
//!   recorder and the chunks are absorbed back in image order, so
//!   recordings are thread-count invariant. On top of a
//!   [`Recording`] the module builds per-link/per-tile occupancy
//!   timelines ([`flight::StageTimelines`]), a terminal link-utilization
//!   heatmap ([`flight::LinkHeatmap`]), recording diffs
//!   ([`flight::diff`] — first divergent event, per-stage deltas), and
//!   a breakpointing [`flight::Stepper`] for `domino debug`.
//! * [`fault`] — the fault plane's engine half. The engine is generic
//!   over a second seam, [`Faults`], with the same zero-cost contract
//!   as the probe: the default [`NoFaults`] compiles every hook out,
//!   while a [`FaultInjector`] executes a deterministic [`FaultPlan`]
//!   (dead/stuck-at CIM tiles, link bit-flips and dropped flits keyed
//!   to the same tile/link sites the probe instruments, permanent or
//!   slot-windowed transients). Faults corrupt psum *values* only —
//!   event structure, timing and counters stay clean-run-identical,
//!   which is exactly the silent-corruption failure mode the serve
//!   plane's canary checks detect. Faulty runs yield a typed
//!   [`FaultReport`] (fires, blast radius, slot windows, stages) and
//!   an output verdict against refcompute
//!   ([`fault::corruption_verdict`]); reports and outputs are
//!   byte-identical across batch thread counts.
//! * [`pipeline`] — the stage-granularity layer-synchronization model
//!   ([`run_pipelined`]): while stage *i* processes image *n*, stage
//!   *i−1* streams image *n+1*; its measured steady-state period is
//!   the quantity Table IV throughput derives from.
//! * [`stats`] — raw architectural event counters; the `energy` module
//!   prices them.
//! * [`trace`] — the Fig. 3(b) COM dataflow trace, rendered from a
//!   flight recording.

pub mod engine;
pub mod fault;
pub mod flight;
pub mod pipeline;
pub mod stats;
pub mod trace;

pub use engine::{BatchOutput, CaptureMode, EnginePool, PooledEngine, RunOutput, Simulator};
pub use fault::{
    corruption_verdict, CorruptionVerdict, FaultInjector, FaultKind, FaultPlan, FaultReport,
    FaultSite, FaultWindow, Faults, NoFaults,
};
pub use flight::{FlightRecorder, NullProbe, Probe, RecorderConfig, Recording};
pub use pipeline::{run_pipelined, PipelineRun};
pub use stats::Counters;
