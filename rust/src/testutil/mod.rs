//! Deterministic test utilities: a small PRNG and a property-testing
//! helper.
//!
//! The build environment has no network access and only the `xla` crate's
//! vendored dependency closure, so `proptest`/`quickcheck` are not
//! available. This module provides the minimal equivalent we need:
//! a seeded xorshift64* generator and [`for_all`], which runs a property
//! over `n` generated cases and reports the failing seed for reproduction.

/// Deterministic xorshift64* PRNG.
///
/// Not cryptographic; used for test-case generation and synthetic
/// workloads. The same seed always yields the same sequence on every
/// platform.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a new generator from a seed (0 is mapped to a fixed
    /// non-zero value since xorshift requires non-zero state).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "Rng::below(0)");
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform i8 over the full range.
    pub fn i8(&mut self) -> i8 {
        (self.next_u64() & 0xFF) as u8 as i8
    }

    /// Uniform i8 in `[-bound, bound]` (useful to avoid accumulator
    /// saturation in long reductions).
    pub fn i8_bounded(&mut self, bound: i8) -> i8 {
        let b = bound as i64;
        ((self.next_u64() as i64).rem_euclid(2 * b + 1) - b) as i8
    }

    /// f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bool with probability `p` of `true`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// A vector of `len` random i8 values bounded by `bound`.
    ///
    /// Batched: one xorshift draw yields eight bounded bytes (weight
    /// generation for VGG-scale networks draws 10⁸ values — §Perf).
    pub fn i8_vec(&mut self, len: usize, bound: i8) -> Vec<i8> {
        let b = bound as i64;
        let m = (2 * b + 1) as u64;
        let mut out = Vec::with_capacity(len);
        while out.len() < len {
            let mut v = self.next_u64();
            for _ in 0..8 {
                if out.len() == len {
                    break;
                }
                out.push((((v & 0xff) % m) as i64 - b) as i8);
                v >>= 8;
            }
        }
        out
    }
}

/// Run `prop` over `n` cases, each with a fresh deterministic [`Rng`].
///
/// On failure the panic message includes the case index and seed so the
/// exact case can be replayed with `Rng::new(seed)`.
pub fn for_all<F: FnMut(&mut Rng)>(name: &str, n: usize, mut prop: F) {
    for case in 0..n {
        let seed = 0xD0A11A0_u64
            .wrapping_mul(case as u64 + 1)
            .wrapping_add(0x5EED);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            assert!(rng.below(10) < 10);
            let v = rng.range(3, 5);
            assert!((3..=5).contains(&v));
        }
    }

    #[test]
    fn rng_i8_bounded_stays_in_bound() {
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            let v = rng.i8_bounded(4);
            assert!((-4..=4).contains(&v), "{v}");
        }
    }

    #[test]
    fn rng_f64_unit_interval() {
        let mut rng = Rng::new(11);
        for _ in 0..1000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed at case 0")]
    fn for_all_reports_failing_case() {
        for_all("always_fails", 5, |_| panic!("boom"));
    }

    #[test]
    fn rng_zero_seed_is_usable() {
        let mut rng = Rng::new(0);
        // must not loop or return all-zero
        let vals: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
        assert!(vals.iter().any(|&v| v != 0));
    }
}
