//! Area model (paper Table III areas + substituted CIM arrays).
//!
//! A tile's "active area" is the RIFM + ROFM router area (Table III,
//! fixed) plus the area of the 256x256 CIM array it hosts — the latter
//! depends on which counterpart's array Domino adopts for a given
//! comparison (`energy::CimModel::array_area_mm2`). Chip area adds the
//! inter-chip transceivers.

use crate::energy::CimModel;

/// Table III component areas in µm².
pub mod table3_um2 {
    pub const RIFM_BUFFER: f64 = 826.5;
    pub const RIFM_CTRL: f64 = 1400.6;
    /// RIFM total (as printed in Table III).
    pub const RIFM_TOTAL: f64 = 2227.1;
    pub const ADDER: f64 = 0.07;
    pub const POOL: f64 = 34.06;
    pub const ACT: f64 = 7.07;
    pub const ROFM_DATA_BUFFER: f64 = 52896.0;
    pub const SCHED_TABLE: f64 = 826.5;
    pub const INPUT_BUFFER: f64 = 878.9;
    pub const OUTPUT_BUFFER: f64 = 878.9;
    pub const ROFM_CTRL: f64 = 2451.2;
    /// ROFM total (as printed in Table III).
    pub const ROFM_TOTAL: f64 = 57972.7;
    /// Eight 80 Gb/s transceivers.
    pub const INTERCHIP: f64 = 8e5;
}

/// Router (RIFM + ROFM) area per tile in mm².
pub fn router_area_mm2() -> f64 {
    (table3_um2::RIFM_TOTAL + table3_um2::ROFM_TOTAL) / 1e6
}

/// Active area of one tile hosting the given CIM array (mm²).
pub fn tile_area_mm2(cim: &CimModel) -> f64 {
    router_area_mm2() + cim.array_area_mm2
}

/// Active area of a deployment (mm²): `tiles` tiles plus one set of
/// inter-chip transceivers per chip.
pub fn active_area_mm2(tiles: usize, chips: usize, cim: &CimModel) -> f64 {
    tiles as f64 * tile_area_mm2(cim) + chips as f64 * table3_um2::INTERCHIP / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_totals_are_consistent() {
        // RIFM total = buffer + control (Table III prints 2227.1).
        let rifm = table3_um2::RIFM_BUFFER + table3_um2::RIFM_CTRL;
        assert!((rifm - table3_um2::RIFM_TOTAL).abs() < 1.0);
        // ROFM total ≈ sum of its components.
        let rofm = table3_um2::ADDER
            + table3_um2::POOL
            + table3_um2::ACT
            + table3_um2::ROFM_DATA_BUFFER
            + table3_um2::SCHED_TABLE
            + table3_um2::INPUT_BUFFER
            + table3_um2::OUTPUT_BUFFER
            + table3_um2::ROFM_CTRL;
        assert!(
            (rofm - table3_um2::ROFM_TOTAL).abs() / table3_um2::ROFM_TOTAL < 0.01,
            "rofm parts sum to {rofm}"
        );
    }

    #[test]
    fn router_area_is_small_vs_cim() {
        // The routers are ~0.06 mm²: an order below a typical SRAM
        // 256x256 array, as the paper's throughput argument requires.
        let r = router_area_mm2();
        assert!((r - 0.0602).abs() < 0.001, "router = {r}");
        assert!(r < CimModel::generic_sram().array_area_mm2);
    }

    #[test]
    fn active_area_scales_with_tiles_and_chips() {
        let cim = CimModel::generic_sram();
        let one = active_area_mm2(240, 1, &cim);
        let five = active_area_mm2(1200, 5, &cim);
        assert!((five - 5.0 * one).abs() < 1e-9);
    }
}
