//! Technology / voltage / precision normalization (paper Section IV-A).
//!
//! "To make a fair energy efficiency comparison, we further normalize
//! technology nodes and supply voltage using equations given in [13]"
//! (Stillmaker & Baas, "Scaling equations for the accurate prediction of
//! CMOS device performance from 180 nm to 7 nm").
//!
//! * **Precision**: the scaling factor is `(B_wd·B_ad)/(B_wt·B_at)` for
//!   MAC energy and `B_ad/B_at` for everything else (data movement and
//!   non-MAC ops) — quoted verbatim from the paper.
//! * **Technology**: per-op energy ratios at nominal voltage from the
//!   Stillmaker-Baas fits (their Table 7 aggregate energy/op data,
//!   normalized here to 45 nm = 1.0).
//! * **Voltage**: dynamic energy `∝ V²`.
//!
//! The paper's own "Normalized CE" row is not exactly recoverable from
//! these rules for every counterpart (see EXPERIMENTS.md §T4 notes);
//! the harness therefore reports both the paper's normalized values and
//! ours, computed uniformly with this module.

/// Relative energy per operation at nominal VDD, normalized to
/// 45 nm = 1.0 (Stillmaker-Baas fits, interpolated).
const ENERGY_VS_NODE: &[(u32, f64)] = &[
    (7, 0.23),
    (10, 0.28),
    (14, 0.34),
    (16, 0.39),
    (20, 0.47),
    (22, 0.52),
    (28, 0.62),
    (32, 0.71),
    (40, 0.92),
    (45, 1.00),
    (65, 1.60),
    (90, 2.00),
    (130, 3.60),
    (180, 5.50),
];

/// Energy-per-op factor of a node relative to 45 nm (log-linear
/// interpolation between tabulated points).
pub fn node_energy_factor(tech_nm: u32) -> f64 {
    let t = tech_nm as f64;
    let pts = ENERGY_VS_NODE;
    if t <= pts[0].0 as f64 {
        return pts[0].1;
    }
    if t >= pts[pts.len() - 1].0 as f64 {
        return pts[pts.len() - 1].1;
    }
    for w in pts.windows(2) {
        let (n0, e0) = (w[0].0 as f64, w[0].1);
        let (n1, e1) = (w[1].0 as f64, w[1].1);
        if t >= n0 && t <= n1 {
            let f = (t.ln() - n0.ln()) / (n1.ln() - n0.ln());
            return (e0.ln() + f * (e1.ln() - e0.ln())).exp();
        }
    }
    unreachable!("interpolation covers the table range")
}

/// A design point to normalize.
#[derive(Clone, Copy, Debug)]
pub struct DesignPoint {
    pub tech_nm: u32,
    pub vdd: f64,
    /// Weight precision (bits).
    pub b_w: u32,
    /// Activation precision (bits).
    pub b_a: u32,
}

/// Domino's evaluation point: 45 nm, 1 V, 8 b / 8 b.
pub const DOMINO_POINT: DesignPoint = DesignPoint {
    tech_nm: 45,
    vdd: 1.0,
    b_w: 8,
    b_a: 8,
};

/// Energy multiplier taking a value measured at `from` to the reference
/// point `to` (tech + voltage only — precision handled separately
/// because MAC and non-MAC ops scale differently).
pub fn tech_voltage_energy_factor(from: &DesignPoint, to: &DesignPoint) -> f64 {
    let node = node_energy_factor(to.tech_nm) / node_energy_factor(from.tech_nm);
    let volt = (to.vdd / from.vdd).powi(2);
    node * volt
}

/// Precision scaling factor for MAC energy: `(B_wd·B_ad)/(B_wt·B_at)`
/// (paper Section IV-A; `d` = Domino/reference, `t` = target).
pub fn mac_precision_factor(target: &DesignPoint, reference: &DesignPoint) -> f64 {
    (reference.b_w as f64 * reference.b_a as f64) / (target.b_w as f64 * target.b_a as f64)
}

/// Precision scaling for non-MAC ops and data movement: `B_ad/B_at`.
pub fn data_precision_factor(target: &DesignPoint, reference: &DesignPoint) -> f64 {
    reference.b_a as f64 / target.b_a as f64
}

/// Normalize a computational-efficiency value (TOPS/W) measured at
/// `from` to the reference point (Domino's 8 b / 1 V / 45 nm), assuming
/// MAC-dominated energy (the paper's normalization; CE is an op/energy
/// ratio, so CE divides by the energy factors).
pub fn normalize_ce(ce: f64, from: &DesignPoint) -> f64 {
    let e_factor = tech_voltage_energy_factor(from, &DOMINO_POINT)
        * mac_precision_factor(from, &DOMINO_POINT);
    ce / e_factor
}

/// Normalize an areal throughput (TOPS/mm²) measured at `from` to
/// 8-bit, 45 nm: area scales with the node squared, and op width
/// linearly with the precision product.
pub fn normalize_throughput(tops_mm2: f64, from: &DesignPoint) -> f64 {
    let area_factor = (45.0 / from.tech_nm as f64).powi(2); // 45nm area / target area
    let prec = mac_precision_factor(from, &DOMINO_POINT);
    tops_mm2 / area_factor / prec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_factor_is_monotonic_and_anchored() {
        assert!((node_energy_factor(45) - 1.0).abs() < 1e-12);
        assert!(node_energy_factor(16) < node_energy_factor(45));
        assert!(node_energy_factor(65) > node_energy_factor(45));
        let mut prev = 0.0;
        for n in [7u32, 16, 22, 32, 45, 65, 90, 180] {
            let f = node_energy_factor(n);
            assert!(f > prev, "not monotonic at {n}");
            prev = f;
        }
    }

    #[test]
    fn interpolation_between_points() {
        let f = node_energy_factor(50);
        assert!(f > 1.0 && f < 1.6, "f = {f}");
    }

    #[test]
    fn out_of_range_clamps() {
        assert_eq!(node_energy_factor(5), 0.23);
        assert_eq!(node_energy_factor(250), 5.50);
    }

    #[test]
    fn voltage_scaling_is_quadratic() {
        let from = DesignPoint {
            tech_nm: 45,
            vdd: 0.5,
            b_w: 8,
            b_a: 8,
        };
        let f = tech_voltage_energy_factor(&from, &DOMINO_POINT);
        assert!((f - 4.0).abs() < 1e-12);
    }

    #[test]
    fn precision_factors_match_paper_formulas() {
        let four_bit = DesignPoint {
            tech_nm: 45,
            vdd: 1.0,
            b_w: 4,
            b_a: 4,
        };
        assert_eq!(mac_precision_factor(&four_bit, &DOMINO_POINT), 4.0);
        assert_eq!(data_precision_factor(&four_bit, &DOMINO_POINT), 2.0);
        let sixteen = DesignPoint {
            tech_nm: 45,
            vdd: 1.0,
            b_w: 16,
            b_a: 16,
        };
        assert_eq!(mac_precision_factor(&sixteen, &DOMINO_POINT), 0.25);
    }

    #[test]
    fn normalize_ce_direction() {
        // A 4-bit 16 nm 0.8 V design's CE must drop substantially when
        // normalized to 8-bit 45 nm 1 V (more energy per op there).
        let from = DesignPoint {
            tech_nm: 16,
            vdd: 0.8,
            b_w: 4,
            b_a: 4,
        };
        let norm = normalize_ce(71.39, &from);
        assert!(norm < 71.39 / 4.0, "precision alone gives /4; got {norm}");
        // and an old-node 16-bit design gains from precision but loses
        // from nothing else at 1 V / coarser node:
        let from2 = DesignPoint {
            tech_nm: 32,
            vdd: 1.0,
            b_w: 16,
            b_a: 16,
        };
        let norm2 = normalize_ce(0.68, &from2);
        assert!(norm2 > 0.68, "16-bit design gains when normalized to 8 b");
    }

    #[test]
    fn identity_normalization() {
        assert!((normalize_ce(5.0, &DOMINO_POINT) - 5.0).abs() < 1e-12);
        assert!((normalize_throughput(0.5, &DOMINO_POINT) - 0.5).abs() < 1e-12);
    }
}
