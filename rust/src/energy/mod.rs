//! Energy model: the paper's Table III per-component energies applied to
//! the simulator's event counters.
//!
//! "Buffer parameters are based on the silicon-proven SRAM array in [15].
//! On-chip data transmission energy is simulated by Noxim, and the rest
//! is analyzed by PrimeTime with a 45 nm CMOS process." We charge the
//! published per-event energies directly; the Noxim role (per-bit link
//! energy x hops) is a single calibrated constant, and CIM array energy
//! is a pluggable per-MAC parameter because Domino "adopts existing CIM
//! arrays" — each Table IV comparison substitutes the counterpart's
//! array (see `counterparts`).

pub mod area;
pub mod scaling;

use crate::sim::stats::Counters;

/// Table III: energy per architectural event (joules).
pub mod table3 {
    /// RIFM buffer (256 B x 1): per access.
    pub const RIFM_BUFFER_J: f64 = 281.3e-12;
    /// RIFM control circuits: per active step.
    pub const RIFM_CTRL_J: f64 = 10.4e-12;
    /// ROFM adder (8 b x 8 x 2): per 8-bit add.
    pub const ADDER_8B_J: f64 = 0.02e-12;
    /// ROFM pooling unit (8 b x 8): per 8-bit op.
    pub const POOL_8B_J: f64 = 7.7e-15;
    /// ROFM activation unit (8 b x 8): per 8-bit op.
    pub const ACT_8B_J: f64 = 0.9e-15;
    /// ROFM data buffer (16 KiB): per access.
    pub const ROFM_BUFFER_J: f64 = 281.3e-12;
    /// ROFM schedule table (16 b x 128): per 16-bit fetch.
    pub const SCHED_16B_J: f64 = 2.2e-12;
    /// ROFM input/output buffers (64 b x 2): per 64-bit word.
    pub const IOBUF_64B_J: f64 = 42.1e-12;
    /// ROFM control circuits: per active step.
    pub const ROFM_CTRL_J: f64 = 28.5e-12;
    /// Inter-chip connection (80 Gb/s x 8): per bit.
    pub const INTERCHIP_J_PER_BIT: f64 = 0.55e-12;
    /// In-buffer shift: a local lane move inside the 256 B buffer (step
    /// 64 b), charged at 1/32 of a full-buffer access — below Table III
    /// resolution but non-zero.
    pub const RIFM_SHIFT_J: f64 = 281.3e-12 / 32.0;
}

/// On-chip mesh link energy per bit per hop. This is the constant the
/// paper obtains from Noxim; 0.05 pJ/b/hop corresponds to a sub-mm
/// abutted-tile hop at 45 nm (Noxim wire+crossbar energy for ~0.5 mm
/// links) and reproduces the paper's on-chip data power share (8-32%,
/// Section IV-B-3) — see EXPERIMENTS.md §Calibration for the fit.
pub const ONCHIP_LINK_J_PER_BIT: f64 = 0.05e-12;

/// Off-package I/O energy per bit (network input / final output DMA);
/// conservative DDR-class figure. Under COM dataflow this traffic is
/// tiny (Section IV-B-3: 0.1-3%).
pub const OFFCHIP_IO_J_PER_BIT: f64 = 15.0e-12;

/// Energy breakdown of a simulated run (joules).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub rifm_buffer: f64,
    pub rifm_ctrl: f64,
    pub rifm_shift: f64,
    pub adders: f64,
    pub pooling: f64,
    pub activation: f64,
    pub rofm_buffer: f64,
    pub sched_table: f64,
    pub io_regs: f64,
    pub rofm_ctrl: f64,
    pub onchip_links: f64,
    pub interchip: f64,
    pub offchip_io: f64,
    pub cim: f64,
}

impl EnergyBreakdown {
    /// "On-chip data power" in the paper's taxonomy: everything that
    /// moves or routes data on chip, including the routers' buffers and
    /// control and the in-network computation, but excluding the CIM
    /// arrays themselves.
    pub fn onchip_data(&self) -> f64 {
        self.rifm_buffer
            + self.rifm_ctrl
            + self.rifm_shift
            + self.adders
            + self.pooling
            + self.activation
            + self.rofm_buffer
            + self.sched_table
            + self.io_regs
            + self.rofm_ctrl
            + self.onchip_links
    }

    /// "Off-chip data power": inter-chip transceivers plus package I/O.
    pub fn offchip_data(&self) -> f64 {
        self.interchip + self.offchip_io
    }

    pub fn total(&self) -> f64 {
        self.onchip_data() + self.offchip_data() + self.cim
    }
}

/// The pluggable CIM-array energy/area model (per 256x256 array).
/// Calibrated per comparison from the counterpart's published numbers —
/// see `counterparts` for the values and their derivation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CimModel {
    /// Energy per 8b x 8b MAC (joules).
    pub j_per_mac: f64,
    /// Area of one 256x256 array (mm²).
    pub array_area_mm2: f64,
    /// Human-readable label ("SRAM [9]", "ReRAM [16]", ...).
    pub label: &'static str,
}

impl CimModel {
    /// A generic silicon-proven SRAM CIM macro (≈ 22 TOPS/W at 8 b —
    /// between [5]'s 89 TOPS/W 22 nm macro and 45 nm scaling).
    pub const fn generic_sram() -> Self {
        Self {
            j_per_mac: 0.09e-12,
            array_area_mm2: 0.25,
            label: "SRAM (generic 45nm)",
        }
    }

    /// A generic ReRAM CIM macro.
    pub const fn generic_reram() -> Self {
        Self {
            j_per_mac: 0.18e-12,
            array_area_mm2: 0.10,
            label: "ReRAM (generic)",
        }
    }
}

/// Convert event counters into an energy breakdown.
pub fn energy_of(c: &Counters, cim: &CimModel) -> EnergyBreakdown {
    use table3::*;
    EnergyBreakdown {
        rifm_buffer: c.rifm_buffer_accesses as f64 * RIFM_BUFFER_J,
        rifm_ctrl: c.rifm_ctrl_steps as f64 * RIFM_CTRL_J,
        rifm_shift: c.rifm_shifts as f64 * RIFM_SHIFT_J,
        adders: c.adds_8b as f64 * ADDER_8B_J,
        pooling: c.pool_ops_8b as f64 * POOL_8B_J,
        activation: c.act_ops_8b as f64 * ACT_8B_J,
        rofm_buffer: c.rofm_buffer_accesses as f64 * ROFM_BUFFER_J,
        sched_table: c.sched_fetches as f64 * SCHED_16B_J,
        io_regs: c.rofm_reg_accesses as f64 * IOBUF_64B_J,
        rofm_ctrl: c.rofm_ctrl_steps as f64 * ROFM_CTRL_J,
        onchip_links: c.onchip_link_bits as f64 * ONCHIP_LINK_J_PER_BIT,
        interchip: c.interchip_bits as f64 * INTERCHIP_J_PER_BIT,
        offchip_io: c.offchip_io_bits as f64 * OFFCHIP_IO_J_PER_BIT,
        cim: c.pe_macs as f64 * cim.j_per_mac,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_counters_zero_energy() {
        let e = energy_of(&Counters::new(), &CimModel::generic_sram());
        assert_eq!(e.total(), 0.0);
    }

    #[test]
    fn breakdown_partitions_total() {
        let c = Counters {
            rifm_buffer_accesses: 10,
            adds_8b: 100,
            pe_macs: 1000,
            onchip_link_bits: 4096,
            interchip_bits: 128,
            offchip_io_bits: 64,
            ..Default::default()
        };
        let e = energy_of(&c, &CimModel::generic_sram());
        let sum = e.onchip_data() + e.offchip_data() + e.cim;
        assert!((sum - e.total()).abs() < 1e-18);
        assert!(e.cim > 0.0 && e.onchip_links > 0.0 && e.interchip > 0.0);
    }

    #[test]
    fn table3_magnitudes() {
        // One ROFM ctrl step at 10 MHz continuous = 0.285 mW.
        let p = table3::ROFM_CTRL_J * crate::consts::STEP_HZ;
        assert!((p - 0.285e-3).abs() < 1e-6);
        // A 256-lane i32 psum hop: 8192 b x 0.05 pJ/b ≈ 410 pJ.
        let e = 8192.0 * ONCHIP_LINK_J_PER_BIT;
        assert!((e - 409.6e-12).abs() < 1e-15);
    }

    #[test]
    fn cim_energy_scales_with_macs() {
        let mut c = Counters::new();
        c.pe_macs = 1_000_000;
        let sram = energy_of(&c, &CimModel::generic_sram());
        let reram = energy_of(&c, &CimModel::generic_reram());
        assert!(reram.cim > sram.cim);
        assert_eq!(sram.total(), sram.cim, "only CIM events charged");
    }
}
