//! `domino` — the CLI leader. Every paper experiment is reachable from
//! here; benches and examples share the same `eval` drivers.

use anyhow::{bail, Result};

use domino::cli::{Args, USAGE};
use domino::coordinator::{ArchConfig, Compiler};
use domino::counterparts::all_comparisons;
use domino::energy::{energy_of, CimModel};
use domino::model::zoo;
use domino::sim::{CaptureMode, Simulator};
use domino::testutil::Rng;
use domino::{baselines, eval};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table4" => table4(),
        "breakdown" => breakdown(),
        "accuracy" => accuracy(args),
        "map" => map(args),
        "run" => run(args),
        "trace" => trace(args),
        "debug" => debug_cmd(args),
        "pipeline" => pipeline(args),
        "ablate" => ablate(),
        "sweep" => sweep(args),
        "golden" => golden(args),
        "serve" => serve(args),
        "client" => client_cmd(args),
        "traffic" => traffic_cmd(args),
        "cluster" => cluster_cmd(args),
        "fault" => fault_cmd(args),
        "models" => models_cmd(args),
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn table4() -> Result<()> {
    let entries = eval::table4::run()?;
    print!("{}", eval::table4::render(&entries));
    Ok(())
}

fn breakdown() -> Result<()> {
    let rows = eval::breakdown::run()?;
    print!("{}", eval::breakdown::render(&rows));
    Ok(())
}

fn accuracy(args: &Args) -> Result<()> {
    let dir = domino::runtime::artifacts_dir();
    let r = eval::accuracy::run(&dir, args.get_usize("limit", 0))?;
    print!("{}", eval::accuracy::render(&r));
    Ok(())
}

fn config_from(args: &Args) -> Result<Option<domino::config::Config>> {
    match args.get("config") {
        Some(p) => Ok(Some(domino::config::Config::load(std::path::Path::new(p))?)),
        None => Ok(None),
    }
}

fn arch_from(args: &Args) -> ArchConfig {
    // --config [arch] first, --chips overrides
    let mut a = config_from(args)
        .ok()
        .flatten()
        .and_then(|c| c.arch().ok())
        .unwrap_or_default();
    if let Some(c) = args.get("chips") {
        a.sync_chips = Some(c.parse().unwrap_or(1));
    }
    a
}

/// Build an optional per-model [`MappingSpec`] from the CLI mapping
/// flags (`--pooling`, `--placement`, `--mesh-cols`, `--chip-aligned`,
/// `--sync-chips`). Returns `None` when no mapping flag was given, so
/// the server applies its service-wide defaults.
fn mapping_from(args: &Args) -> Result<Option<domino::serve::api::MappingSpec>> {
    use domino::coordinator::{Placement, PoolingScheme};
    let mut spec = domino::serve::api::MappingSpec::default();
    if let Some(p) = args.get("pooling") {
        spec.pooling = Some(PoolingScheme::parse(p)?);
    }
    if let Some(p) = args.get("placement") {
        spec.placement = Some(Placement::parse(p)?);
    }
    if let Some(m) = args.get("mesh-cols") {
        spec.mesh_cols = Some(
            m.parse()
                .map_err(|_| anyhow::anyhow!("--mesh-cols must be a positive integer"))?,
        );
    }
    if let Some(v) = args.get("chip-aligned") {
        // bare `--chip-aligned` parses as "true"; an explicit value
        // lets the flag also express *disabling* alignment against a
        // chip-aligned server default
        spec.chip_aligned = Some(match v {
            "true" => true,
            "false" => false,
            other => bail!("--chip-aligned takes true|false (got {other:?})"),
        });
    }
    if let Some(s) = args.get("sync-chips") {
        spec.sync_chips = Some(
            s.parse()
                .map_err(|_| anyhow::anyhow!("--sync-chips must be a non-negative integer"))?,
        );
    }
    Ok((!spec.is_empty()).then_some(spec))
}

fn net_arg(args: &Args) -> Result<domino::model::Network> {
    let from_cfg = config_from(args)?
        .and_then(|c| c.get_str("run", "model").map(String::from));
    let name = args
        .positional
        .first()
        .cloned()
        .or(from_cfg)
        .unwrap_or_else(|| "tiny-cnn".to_string());
    zoo::lookup(&name)
}

/// `domino models [list | info <model>] [--json]`. `--json` emits the
/// same `ModelDesc` representation the wire protocol speaks (via the
/// `serve::wire` encoder), so scripts can parse one format for local
/// listings and remote `client models` alike (`id`/`version` are 0
/// for zoo entries that are not loaded anywhere).
fn models_cmd(args: &Args) -> Result<()> {
    use domino::serve::api::ModelDesc;
    use domino::serve::wire;
    let json = args.get("json").is_some();
    match args.positional.first().map(String::as_str) {
        None | Some("list") => {
            if json {
                let descs = zoo::MODEL_NAMES
                    .iter()
                    .map(|name| {
                        let net = zoo::lookup(name)?;
                        Ok(wire::desc_to_json(&ModelDesc::of_network(&net)?))
                    })
                    .collect::<Result<Vec<_>>>()?;
                println!("{}", wire::encode(&wire::Json::Arr(descs)));
                return Ok(());
            }
            println!(
                "{:<18} {:>12} {:>16} {:>12} {:>8}",
                "model", "params", "macs", "input", "classes"
            );
            for name in zoo::MODEL_NAMES {
                let net = zoo::lookup(name)?;
                let input = net.input.to_string();
                println!(
                    "{:<18} {:>12} {:>16} {:>12} {:>8}",
                    name,
                    net.total_params()?,
                    net.total_macs()?,
                    input,
                    net.output_shape()?.c
                );
            }
            Ok(())
        }
        Some("info") => {
            let name = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: domino models info <model> [--json]"))?;
            let net = zoo::lookup(name)?;
            // mapping/placement stats at the requested (or default)
            // arch: analysis-only compile, no weights
            let desc = ModelDesc::of_network_mapped(&net, arch_from(args))?;
            if json {
                println!("{}", wire::encode(&wire::desc_to_json(&desc)));
                return Ok(());
            }
            println!(
                "{}: input {}, output {}, {} layers, {} params, {} MACs",
                net.name,
                net.input,
                net.output_shape()?,
                net.layers.len(),
                net.total_params()?,
                net.total_macs()?
            );
            print_mapping(&desc.mapping);
            for (i, shape) in net.shapes()?.iter().enumerate() {
                println!("  layer {i:>2}: {shape}");
            }
            Ok(())
        }
        Some(other) => bail!("unknown models subcommand {other:?} (use `list` or `info <model>`)"),
    }
}

/// Render the mapping/placement stats block shared by `models info`
/// and `client info`.
fn print_mapping(mapping: &Option<domino::serve::api::MappingDesc>) {
    if let Some(m) = mapping {
        println!(
            "mapping: {} pooling, {} placement, {} mesh cols{}{}",
            m.pooling,
            m.placement,
            m.mesh_cols,
            if m.chip_aligned { ", chip-aligned" } else { "" },
            m.sync_chips
                .map(|c| format!(", sync budget {c} chips"))
                .unwrap_or_default()
        );
        println!(
            "  {} tiles on {} chip(s), worst link {:.1}%, est {} img/s, {} pJ/image",
            m.tiles,
            m.chips,
            m.worst_link_permille as f64 / 10.0,
            m.images_per_s,
            m.pj_per_image
        );
    }
}

/// `domino map explore <model> [--objective latency|energy|tiles]
/// [--top N] [--verify] [--load-into ADDR]` — rank candidate mappings
/// analytically; optionally prove the winner end-to-end (compile,
/// serve one refcompute-verified inference) or feed it straight into a
/// running `serve --listen` endpoint.
fn map_explore(args: &Args) -> Result<()> {
    use domino::coordinator::explore::{self, ExploreBounds, Objective};

    let name = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "tiny-cnn".to_string());
    let net = zoo::lookup(&name)?;
    let objective = Objective::parse(args.get("objective").unwrap_or("latency"))?;
    let base = arch_from(args);
    let cands = explore::explore(&net, &base, &ExploreBounds::default(), objective)?;
    anyhow::ensure!(!cands.is_empty(), "explorer produced no candidates");

    println!(
        "{}: {} candidate mappings ranked by {} (analytic: perfmodel + energy + worst-link)",
        net.name,
        cands.len(),
        objective.name()
    );
    println!(
        "{:>4} {:<18} {:<13} {:>4} {:>7} {:>7} {:>5} {:>12} {:>8} {:>10} {:>6} {:>3}",
        "rank", "pooling", "placement", "mesh", "aligned", "tiles", "chips", "latency cyc",
        "img/s", "pJ/img", "link%", "ok"
    );
    let top = args.get_usize("top", cands.len());
    for (i, c) in cands.iter().take(top).enumerate() {
        println!(
            "{:>4} {:<18} {:<13} {:>4} {:>7} {:>7} {:>5} {:>12} {:>8.0} {:>10.0} {:>6.1} {:>3}",
            i + 1,
            c.choice.pooling.name(),
            c.choice.placement.name(),
            c.choice.mesh_cols,
            if c.choice.chip_aligned { "yes" } else { "no" },
            c.tiles,
            c.chips,
            c.latency_cycles,
            c.images_per_s,
            c.energy_per_image_j * 1e12,
            c.worst_link_utilization * 100.0,
            if c.feasible { "yes" } else { "NO" }
        );
    }

    let best = &cands[0];
    anyhow::ensure!(
        best.feasible,
        "no feasible mapping candidate for {} (every choice oversubscribes the links \
         or overflows the schedule table)",
        net.name
    );
    // print every mapping knob explicitly (incl. chip_aligned false
    // and the base sync budget), so the command reproduces the scored
    // winner even against a server whose defaults differ
    println!(
        "winner: domino client load {} --pooling {} --placement {} --mesh-cols {} \
         --chip-aligned {}{}",
        net.name,
        best.choice.pooling.name(),
        best.choice.placement.name(),
        best.choice.mesh_cols,
        best.choice.chip_aligned,
        best.arch
            .sync_chips
            .map(|c| format!(" --sync-chips {c}"))
            .unwrap_or_default()
    );

    if args.flag("verify") {
        // prove the winner end-to-end: compile it with weights, serve
        // one request through the real server, cross-check refcompute
        use domino::serve::{ModelRegistry, ServeConfig, Server};
        use std::sync::Arc;
        let registry = Arc::new(ModelRegistry::new());
        let mv = registry.load(&net.name, &net, best.arch)?;
        let server = Server::start_multi(
            ServeConfig {
                workers: 1,
                max_batch: 4,
                queue_cap: 16,
                ..ServeConfig::default()
            },
            Arc::clone(&registry),
        )?;
        let mut rng = Rng::new(args.get_u64("seed", 42));
        let img = rng.i8_vec(net.input_len(), 31);
        let r = server.infer_on(&net.name, img.clone())?;
        anyhow::ensure!(
            r.logits == mv.refcompute(&img)?,
            "winner mapping diverged from refcompute"
        );
        server.shutdown()?;
        println!("winner verified: served one inference bit-exact vs refcompute");
    }

    if let Some(addr) = args.get("load-into") {
        // feed the winner straight into a running serve --listen; the
        // spec carries the scored base's sync budget too, so the
        // remote load reproduces exactly the mapping that was ranked
        let mut client = domino::serve::client::Client::connect(addr)?;
        let mut spec = domino::serve::api::MappingSpec::of_choice(&best.choice);
        spec.sync_chips = best.arch.sync_chips.map(|c| c as u64);
        let seed = args.get("seed").map(|s| s.parse::<u64>()).transpose()
            .map_err(|_| anyhow::anyhow!("--seed must be a u64"))?;
        let st = client.load_mapped(&net.name, seed, Some(spec))?;
        println!(
            "loaded {} v{} at the winning mapping via {addr}",
            st.name, st.version
        );
    }
    Ok(())
}

fn map(args: &Args) -> Result<()> {
    if args.positional.first().map(String::as_str) == Some("explore") {
        return map_explore(args);
    }
    let net = net_arg(args)?;
    let program = Compiler::new(arch_from(args)).compile_analysis(&net)?;
    println!(
        "{}: {} stages, {} tiles, {} chips",
        net.name,
        program.stages.len(),
        program.total_tiles,
        program.chips
    );
    let est = domino::perfmodel::estimate(&program)?;
    println!(
        "{:<12} {:>8} {:>8} {:>10} {:>10}",
        "stage", "tiles", "dup", "period", "latency"
    );
    for (s, e) in program.stages.iter().zip(&est.stages) {
        let dup = match &s.kind {
            domino::coordinator::program::StageKind::Conv(c) => c.dup,
            domino::coordinator::program::StageKind::Res(r) => r.dup,
            domino::coordinator::program::StageKind::Pool(p) => p.dup,
            _ => 1,
        };
        println!(
            "{:<12} {:>8} {:>8} {:>10} {:>10}",
            s.name, e.tiles, dup, e.period_slots, e.slots
        );
    }
    println!(
        "pipeline: period {} cycles ({:.1} us), latency {} cycles ({:.1} us), {:.0} img/s",
        est.period_cycles,
        1e6 * est.period_cycles as f64 / domino::consts::STEP_HZ,
        est.latency_cycles,
        1e6 * est.latency_cycles as f64 / domino::consts::STEP_HZ,
        est.images_per_s()
    );
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    let net = net_arg(args)?;
    let program = Compiler::new(arch_from(args)).compile(&net)?;
    // the CLI prints scores and counters only — skip per-stage tensor
    // capture on this throughput path
    let mut sim = Simulator::with_capture(&program, CaptureMode::Final);
    let images = args.get_usize("images", 1);
    let mut rng = Rng::new(args.get_u64("seed", 42));
    let threads = args.get_usize("threads", 1);
    if threads > 1 && images > 1 {
        // batched, data-parallel path
        let inputs: Vec<Vec<i8>> = (0..images)
            .map(|_| rng.i8_vec(net.input_len(), 31))
            .collect();
        let batch = sim.run_batch_threads(&inputs, threads)?;
        for (i, out) in batch.outputs.iter().enumerate() {
            println!(
                "image {i}: latency {} cycles ({:.1} us), scores {:?}",
                out.latency_cycles,
                1e6 * out.latency_cycles as f64 / domino::consts::STEP_HZ,
                out.scores
            );
        }
        println!(
            "\nbatch: {} images on {} threads in {:.3} s ({:.1} img/s simulated); \
             pipelined steady period {} cycles -> {:.0} img/s modeled",
            batch.outputs.len(),
            batch.threads,
            batch.wall.as_secs_f64(),
            batch.images_per_s_wall(),
            batch.pipeline.steady_period_cycles,
            batch.pipeline.images_per_s
        );
    } else {
        for i in 0..images {
            let out = sim.run_image(&rng.i8_vec(net.input_len(), 31))?;
            println!(
                "image {i}: latency {} cycles ({:.1} us), scores {:?}",
                out.latency_cycles,
                1e6 * out.latency_cycles as f64 / domino::consts::STEP_HZ,
                out.scores
            );
        }
    }
    println!("\ncounters over {images} image(s):\n{}", sim.stats());
    println!(
        "hardware MAC rate over busy steps: {:.2} GMAC/s",
        sim.stats().macs_per_second() / 1e9
    );
    let e = energy_of(sim.stats(), &CimModel::generic_sram());
    println!(
        "\nenergy: total {:.3} uJ (cim {:.3}, on-chip data {:.3}, off-chip {:.3})",
        1e6 * e.total(),
        1e6 * e.cim,
        1e6 * e.onchip_data(),
        1e6 * e.offchip_data()
    );
    Ok(())
}

fn trace(args: &Args) -> Result<()> {
    // a small K=3 conv reproduces Fig. 3(b)'s geometry
    let net = domino::model::NetworkBuilder::new(
        "fig3",
        domino::model::TensorShape::new(2, 5, 5),
    )
    .conv(3, 3, 1, 1)
    .build();
    let program = Compiler::default().compile(&net)?;
    let tr = domino::sim::trace::trace_stage(&program, args.get_usize("stage", 0), 7)?;
    print!("{}", tr.render(0, args.get_usize("slots", 26)));
    Ok(())
}

/// `domino debug <model> [--seed S] [--break tile,cycle[,kind][;…]]
/// [--steps N] [--heatmap] [--stage S] [--buckets N]` — record one
/// seeded image under the flight recorder, then walk the event stream:
/// stop at breakpoints, single-step, and inspect the derived engine
/// state (current stage, FIFO depths, psum arena occupancy, link
/// bits). Non-interactive by design so CI can smoke it; a breakpoint
/// that never hits is a normal outcome (exit 0), not an error.
fn debug_cmd(args: &Args) -> Result<()> {
    use domino::sim::flight::{Breakpoint, LinkHeatmap, RecorderConfig, Stepper};

    let net = net_arg(args)?;
    let program = Compiler::new(arch_from(args)).compile(&net)?;
    let mut sim = Simulator::with_recorder(&program, RecorderConfig::default());
    let seed = args.get_u64("seed", 7);
    let mut rng = Rng::new(seed);
    sim.run_image(&rng.i8_vec(net.input_len(), 31))?;
    let rec = sim.recording();
    println!(
        "{}: recorded 1 image (seed {seed}) -> {} events over {} stage(s), {} dropped",
        net.name,
        rec.events.len(),
        rec.stage_count(),
        rec.dropped
    );

    let mut stepper = Stepper::new(rec.clone());
    let breaks: Vec<Breakpoint> = match args.get("break") {
        Some(specs) => specs
            .split(';')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(Breakpoint::parse)
            .collect::<Result<_>>()?,
        None => Vec::new(),
    };
    for bp in &breaks {
        stepper.add_breakpoint(*bp);
    }

    if !breaks.is_empty() {
        match stepper.run_to_break() {
            Some((i, e)) => {
                println!("break at event #{i}: {}", e.describe());
                print!("{}", stepper.state().render());
            }
            None => println!(
                "no breakpoint hit in {} events (stream fully consumed)",
                stepper.len()
            ),
        }
    }

    let steps = args.get_usize("steps", 0);
    for _ in 0..steps {
        match stepper.step() {
            Some(e) => println!("#{}: {}", stepper.pos() - 1, e.describe()),
            None => {
                println!("end of stream at event {}", stepper.len());
                break;
            }
        }
    }
    if steps > 0 {
        print!("{}", stepper.state().render());
    }

    if breaks.is_empty() && steps == 0 {
        // no navigation requested: consume the whole stream and show
        // the end-state inspection (a one-shot post-mortem view)
        while stepper.step().is_some() {}
        print!("{}", stepper.state().render());
    }

    if args.flag("heatmap") {
        let stage = match args.get("stage") {
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--stage must be a stage index"))?,
            None => LinkHeatmap::busiest_stage(&rec)
                .ok_or_else(|| anyhow::anyhow!("recording holds no link events"))?,
        };
        match LinkHeatmap::build(&rec, stage, args.get_usize("buckets", 40)) {
            Some(h) => print!("{}", h.render()),
            None => println!("stage {stage} moved no tile-scoped link bits"),
        }
    }
    Ok(())
}

fn pipeline(args: &Args) -> Result<()> {
    let net = net_arg(args)?;
    let program = Compiler::new(arch_from(args)).compile_analysis(&net)?;
    let est = domino::perfmodel::estimate(&program)?;
    let images = args.get_usize("images", 32);
    let r = domino::sim::pipeline::run_pipelined(&program, &est, images)?;
    println!(
        "{}: {} images pipelined; first latency {:.1} us, steady period {} cycles, {:.0} img/s",
        net.name,
        images,
        1e6 * r.first_latency_cycles as f64 / domino::consts::STEP_HZ,
        r.steady_period_cycles,
        r.images_per_s
    );
    println!("
{:<12} {:>8} {:>10} {:>8} {:>8}", "stage", "slots", "period", "lead", "util %");
    for s in &r.stages {
        println!(
            "{:<12} {:>8} {:>10} {:>8} {:>8.1}",
            s.name, s.slots, s.period_slots, s.lead_slots, 100.0 * s.utilization
        );
    }
    Ok(())
}

fn ablate() -> Result<()> {
    println!("A1 — COM vs WS+im2col data movement (per Table IV workload):\n");
    for comp in all_comparisons() {
        let program = eval::compile_comparison(&comp)?;
        let cim = comp.domino_cim_model();
        let ab = baselines::ws_im2col::ablate(&program, &cim)?;
        println!(
            "{:<18} on-chip data energy x{:.1}, total energy x{:.2} (baseline/COM)",
            comp.counterpart.model,
            ab.movement_ratio(),
            ab.total_ratio()
        );
    }
    println!("\nFig. 4 — pooling schemes (block reuse vs weight duplication):\n");
    for (net, _) in zoo::table4_workloads() {
        let ab = baselines::pooling::ablate(&net, &CimModel::generic_sram())?;
        println!(
            "{:<18} dup: {:.2}x tiles -> {:.2}x throughput (period {} -> {})",
            net.name,
            ab.tile_ratio(),
            ab.speedup(),
            ab.block_reuse.period_cycles,
            ab.weight_dup.period_cycles
        );
    }
    Ok(())
}

fn sweep(args: &Args) -> Result<()> {
    let models: Vec<String> = args
        .get("models")
        .map(|s| s.split(',').map(String::from).collect())
        .unwrap_or_else(|| vec!["vgg11-cifar10".into(), "resnet18-cifar10".into()]);
    println!(
        "{:<18} {:>6} {:>8} {:>8} {:>12} {:>10}",
        "model", "Nc=Nm", "tiles", "chips", "period cyc", "img/s"
    );
    for name in &models {
        let net = zoo::lookup(name)?;
        for n in [64usize, 128, 256, 512] {
            let mut arch = ArchConfig::default();
            arch.n_c = n;
            arch.n_m = n;
            let program = Compiler::new(arch).compile_analysis(&net)?;
            let est = domino::perfmodel::estimate(&program)?;
            println!(
                "{:<18} {:>6} {:>8} {:>8} {:>12} {:>10.0}",
                name,
                n,
                program.total_tiles,
                program.chips,
                est.period_cycles,
                est.images_per_s()
            );
        }
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    match args.get("backend").unwrap_or("pjrt") {
        "pjrt" => {
            // reject loudly rather than silently ignore: the typed
            // API endpoint and registry persistence are sim-only
            anyhow::ensure!(
                args.get("listen").is_none() && args.get("registry-file").is_none(),
                "--listen and --registry-file are only supported on the sim backend \
                 (run with --backend sim)"
            );
            serve_pjrt(args)
        }
        "sim" => serve_sim(args),
        other => bail!("unknown serve backend {other:?} (use `pjrt` or `sim`)"),
    }
}

/// Serve the cycle-accurate simulator: load one or more models into a
/// registry (optionally restored from / persisted to a manifest),
/// then either expose the typed service API over TCP (`--listen`) or
/// drive a local closed loop through the same `Service::dispatch` the
/// network path uses — hot-swapping a model mid-traffic on request,
/// and cross-checking every response against the int8 reference of
/// the exact model version stamped on it.
fn serve_sim(args: &Args) -> Result<()> {
    use domino::serve::api::{self, RegistryManifest};
    use domino::serve::net::{NetConfig, NetServer};
    use domino::serve::{LatencyStats, ModelRegistry, ServeConfig, Server, Service};
    use std::sync::Arc;

    let names: Vec<String> = match args.get("models") {
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect(),
        None => vec![args.get("model").unwrap_or("tiny-cnn").to_string()],
    };
    // An empty model set is only meaningful with --listen, where the
    // admin plane (a client, or a cluster router) loads models later.
    anyhow::ensure!(
        !names.is_empty() || args.get("listen").is_some(),
        "--models needs at least one model name (an empty list needs --listen)"
    );
    let arch = arch_from(args);
    let cfg = ServeConfig {
        workers: args.get_usize("workers", 2),
        max_batch: args.get_usize("batch", 8),
        queue_cap: args.get_usize("queue", 256),
        dispatchers: args.get_usize("dispatchers", ServeConfig::default().dispatchers),
    };
    let n = args.get_usize("requests", 64);

    // Registry, optionally persistent: `--registry-file` reloads the
    // model set a previous run recorded (exact versions and weight
    // seeds), then every API-plane mutation rewrites the manifest.
    let manifest = match args.get("registry-file") {
        Some(p) => Some(Arc::new(RegistryManifest::open(std::path::Path::new(p))?)),
        None => None,
    };
    let registry = Arc::new(ModelRegistry::new());
    if let Some(man) = &manifest {
        let restored = man.restore(&registry, arch)?;
        if restored > 0 {
            println!(
                "restored {restored} model(s) from {}",
                man.path().display()
            );
        }
    }
    // Compile the requested models into the shared registry (registry
    // key = the network's canonical name, so `--models tiny,TINY_MLP`
    // works); names already restored from the manifest stay as-is.
    for raw in &names {
        let net = zoo::lookup(raw)?;
        if registry.get(&net.name).is_none() {
            let mv = registry.load(&net.name, &net, arch)?;
            if let Some(man) = &manifest {
                man.record(&net.name, &net.name, None, mv.version(), Some(arch));
            }
        }
    }
    if let Some(man) = &manifest {
        man.save()?;
    }
    let mut models = registry.list();

    println!(
        "{} model(s) on the cycle simulator ({} workers, micro-batch {})",
        models.len(),
        cfg.workers,
        cfg.max_batch
    );
    for mv in &models {
        let est = domino::perfmodel::estimate(mv.program())?;
        println!(
            "  {} v{}: {} tiles, modeled {:.0} img/s (pipeline period {} cycles)",
            mv.name(),
            mv.version(),
            mv.program().total_tiles,
            est.images_per_s(),
            est.period_cycles
        );
    }

    let server = Server::start_multi(cfg, Arc::clone(&registry))?;
    let service = match &manifest {
        Some(man) => Service::with_manifest(server, arch, Arc::clone(man)),
        None => Service::new(server, arch),
    };

    // --listen: expose the typed API (data/admin/observability planes)
    // over TCP instead of driving local traffic. Flags that only make
    // sense for the local closed loop are rejected loudly rather than
    // silently ignored.
    if let Some(addr) = args.get("listen") {
        anyhow::ensure!(
            args.get("swap").is_none() && args.get("swap-after").is_none(),
            "--swap/--swap-after drive the local closed loop and do nothing with \
             --listen; use `domino client swap <model> --addr <addr>` against the \
             endpoint instead"
        );
        anyhow::ensure!(
            args.get("requests").is_none(),
            "--requests drives the local closed loop and does nothing with --listen; \
             use `domino client infer <model> --requests N --addr <addr>` instead"
        );
        let service = Arc::new(service);
        let net = NetServer::bind_with(
            addr,
            Arc::clone(&service),
            NetConfig {
                dispatchers: cfg.dispatchers,
                ..NetConfig::default()
            },
        )?;
        // port 0 resolves to the actually-bound ephemeral port here
        println!("listening on {addr_real} (length-prefixed JSON frames; drive with `domino client <op> --addr {addr_real}`)",
            addr_real = net.local_addr());
        let secs = args.get_u64("serve-secs", 0);
        if secs == 0 {
            println!("serving until killed (pass --serve-secs N for a bounded run)");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        std::thread::sleep(std::time::Duration::from_secs(secs));
        net.shutdown()?;
        let service = Arc::try_unwrap(service)
            .map_err(|_| anyhow::anyhow!("service still referenced after net shutdown"))?;
        print_stats(&service.dispatch(api::Request::Stats))?;
        service.shutdown()?;
        return Ok(());
    }

    // Local closed loop. Per model: a small pool of distinct images
    // with precomputed refcompute references (recomputed when the
    // model is swapped).
    let mut rng = Rng::new(args.get_u64("seed", 42));
    let pool_sz = 16.min(n.max(1));
    let expected_of = |mv: &domino::serve::ModelVersion,
                       images: &[Vec<i8>]|
     -> Result<Vec<Vec<i8>>> {
        images.iter().map(|img| mv.refcompute(img)).collect()
    };
    let mut pools: Vec<Vec<Vec<i8>>> = Vec::new();
    let mut expected: Vec<Vec<Vec<i8>>> = Vec::new();
    for mv in &models {
        let images: Vec<Vec<i8>> = (0..pool_sz)
            .map(|_| rng.i8_vec(mv.input_len(), 31))
            .collect();
        expected.push(expected_of(mv, &images)?);
        pools.push(images);
    }

    // Optional admin op: hot-swap a model (fresh weights) mid-traffic.
    // Validated up front so a typo'd name or an out-of-range
    // `--swap-after` fails loudly instead of silently never swapping.
    let swap_name: Option<String> = args
        .get("swap")
        .map(|s| zoo::lookup(s).map(|net| net.name))
        .transpose()?;
    let swap_after = args.get_usize("swap-after", n / 2);
    if let Some(sn) = &swap_name {
        anyhow::ensure!(
            models.iter().any(|m| m.name() == sn.as_str()),
            "--swap {sn:?} is not among the served models"
        );
        anyhow::ensure!(
            swap_after < n,
            "--swap-after {swap_after} is past the last request (--requests {n})"
        );
    }

    println!("driving {n} requests through the typed service API (local dispatch)");
    let t0 = std::time::Instant::now();
    let mut lat = LatencyStats::default();
    let mut served_per_model = vec![0u64; models.len()];
    for i in 0..n {
        if let Some(sn) = &swap_name {
            if i == swap_after {
                let mi = models
                    .iter()
                    .position(|m| m.name() == sn.as_str())
                    .expect("swap target validated before the loop");
                // the same typed request a remote admin client sends
                let stamp = match service.dispatch(api::Request::Swap {
                    model: sn.clone(),
                    seed: Some(0xD0_31_10 ^ (i as u64 + 1)),
                }) {
                    api::Response::Swapped(st) => st,
                    api::Response::Error { message } => bail!("swap failed: {message}"),
                    other => bail!("unexpected response to swap: {other:?}"),
                };
                println!(
                    "hot-swapped {} -> v{} after {i} requests (new weights; traffic uninterrupted)",
                    sn, stamp.version
                );
                let new_mv = registry.get(sn).expect("just swapped");
                expected[mi] = expected_of(&new_mv, &pools[mi])?;
                models[mi] = new_mv;
            }
        }
        let mi = i % models.len();
        let idx = (i / models.len()) % pools[mi].len();
        let t = std::time::Instant::now();
        let reply = match service.dispatch(api::Request::Infer {
            model: Some(models[mi].name().to_string()),
            image: pools[mi][idx].clone(),
        }) {
            api::Response::Infer(r) => r,
            api::Response::Error { message } => bail!("request {i} failed: {message}"),
            other => bail!("unexpected response to infer: {other:?}"),
        };
        lat.record(t.elapsed());
        let stamp = reply.model.as_ref().expect("sim responses carry a stamp");
        anyhow::ensure!(
            stamp.id == models[mi].id(),
            "request for {} answered by {} v{} (routing bug)",
            models[mi].name(),
            stamp.name,
            stamp.version
        );
        anyhow::ensure!(
            reply.logits == expected[mi][idx],
            "response for {} image {idx} diverged from refcompute",
            models[mi].name()
        );
        served_per_model[mi] += 1;
    }
    let wall = t0.elapsed();
    println!(
        "{} req in {:.2} s -> {:.0} req/s served; latency {}",
        n,
        wall.as_secs_f64(),
        domino::sim::stats::safe_rate(n as f64, wall.as_secs_f64()),
        lat.summary()
    );
    for (mv, count) in models.iter().zip(&served_per_model) {
        println!("  {} v{}: {count} responses", mv.name(), mv.version());
    }
    println!(
        "all responses bit-exact vs refcompute for the model version that served them \
         (served {}, rejected {}, failed {})",
        service.server().served(),
        service.server().rejected(),
        service.server().failed()
    );
    print_stats(&service.dispatch(api::Request::Stats))?;
    service.shutdown()?;
    Ok(())
}

/// Render a `Stats` response: the aggregate counters plus the
/// per-model split (counts, live queue depth, latency percentiles).
fn print_stats(resp: &domino::serve::api::Response) -> Result<()> {
    use domino::serve::api::Response;
    let stats = match resp {
        Response::Stats(s) => s,
        Response::Error { message } => bail!("stats failed: {message}"),
        other => bail!("unexpected response to stats: {other:?}"),
    };
    println!(
        "stats: served {}, rejected {}, failed {}, conns refused {}, traces rejected {}",
        stats.served, stats.rejected, stats.failed, stats.conns_refused, stats.trace_rejected
    );
    println!(
        "  {:<18} {:>8} {:>8} {:>9} {:>7} {:>7} {:>9} {:>9} {:>9}",
        "model", "served", "failed", "rejected", "traced", "queued", "p50 us", "p95 us",
        "p99 us"
    );
    let fmt = |v: Option<u64>| v.map(|x| x.to_string()).unwrap_or_else(|| "-".into());
    for m in &stats.models {
        println!(
            "  {:<18} {:>8} {:>8} {:>9} {:>7} {:>7} {:>9} {:>9} {:>9}{}",
            m.model,
            m.served,
            m.failed,
            m.rejected,
            m.traced,
            m.queue_depth,
            fmt(m.p50_us),
            fmt(m.p95_us),
            fmt(m.p99_us),
            if m.degraded { "  DEGRADED" } else { "" }
        );
    }
    Ok(())
}

/// `domino client <op> --addr HOST:PORT` — drive a `serve --listen`
/// endpoint over TCP through the in-crate typed client. Ops: `infer
/// <model>`, `load <model> [--seed S]`, `swap <model> [--seed S]`,
/// `unload <model>`, `models`, `info <model>`, `stats`, `trace
/// <model> [--seed S] [--window N]`; `--json` prints the raw wire
/// representation.
fn client_cmd(args: &Args) -> Result<()> {
    use domino::serve::client::Client;
    use domino::serve::{api, wire};

    let addr = args.get("addr").ok_or_else(|| {
        anyhow::anyhow!("client needs --addr HOST:PORT (the address `serve --listen` printed)")
    })?;
    let op = args.positional.first().map(String::as_str).unwrap_or("stats");
    let json = args.get("json").is_some();
    fn second_positional<'a>(args: &'a Args, what: &str, addr: &str) -> Result<&'a str> {
        args.positional.get(1).map(String::as_str).ok_or_else(|| {
            anyhow::anyhow!("usage: domino client {what} <model> --addr {addr}")
        })
    }
    let mut client = Client::connect(addr)?;
    client.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
    match op {
        "infer" => {
            let model = second_positional(args, "infer", addr)?;
            let info = client.model_info(model)?;
            let reqs = args.get_usize("requests", 1);
            let mut rng = Rng::new(args.get_u64("seed", 42));
            // --verify-seed S: reconstruct the weights locally (they
            // are a pure function of the network and the seed the
            // model was loaded/swapped with) and cross-check every
            // remote response bit-for-bit against refcompute.
            let verify = match args.get("verify-seed") {
                Some(v) => {
                    let seed: u64 = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--verify-seed must be a u64"))?;
                    let net = zoo::lookup(model)?;
                    let weights = domino::model::refcompute::Weights::random(&net, seed)?;
                    Some((net, weights))
                }
                None => None,
            };
            let mut lat = domino::serve::LatencyStats::default();
            for i in 0..reqs {
                let image = rng.i8_vec(info.input_len as usize, 31);
                let t = std::time::Instant::now();
                let r = client.infer(Some(model), image.clone())?;
                lat.record(t.elapsed());
                let stamp = r
                    .model
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("response carried no model stamp"))?;
                if let Some((net, weights)) = &verify {
                    let want = domino::model::refcompute::forward(
                        net,
                        weights,
                        &domino::model::refcompute::Tensor::new(net.input, image),
                    )?;
                    anyhow::ensure!(
                        r.logits == want.data,
                        "response {i} diverged from refcompute under --verify-seed"
                    );
                }
                println!(
                    "#{i}: {} v{} -> {:?} (queue {} us, exec {} us)",
                    stamp.name, stamp.version, r.logits, r.queue_us, r.exec_us
                );
            }
            if reqs > 1 {
                println!("latency over {reqs} requests: {}", lat.summary());
            }
            if verify.is_some() {
                println!("all {reqs} response(s) bit-exact vs refcompute (seed-verified)");
            }
            Ok(())
        }
        "load" => {
            let model = second_positional(args, "load", addr)?;
            let seed = match args.get("seed") {
                Some(s) => Some(
                    s.parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("--seed must be a u64"))?,
                ),
                None => None,
            };
            let mapping = mapping_from(args)?;
            let st = client.load_mapped(model, seed, mapping)?;
            println!(
                "loaded {} v{} (id {}){}",
                st.name,
                st.version,
                st.id,
                if mapping.is_some() {
                    " at the requested mapping"
                } else {
                    ""
                }
            );
            Ok(())
        }
        "swap" => {
            let model = second_positional(args, "swap", addr)?;
            let seed = match args.get("seed") {
                Some(s) => Some(
                    s.parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("--seed must be a u64"))?,
                ),
                None => None,
            };
            let st = client.swap(model, seed)?;
            println!("swapped {} -> v{} (id {})", st.name, st.version, st.id);
            Ok(())
        }
        "unload" => {
            let model = second_positional(args, "unload", addr)?;
            let st = client.unload(model)?;
            println!("unloaded {} v{} (id {})", st.name, st.version, st.id);
            Ok(())
        }
        "models" => {
            let models = client.models()?;
            if json {
                let arr = wire::Json::Arr(models.iter().map(wire::desc_to_json).collect());
                println!("{}", wire::encode(&arr));
                return Ok(());
            }
            println!(
                "{:<18} {:>4} {:>8} {:>12} {:>16} {:>10} {:>8}",
                "model", "ver", "id", "params", "macs", "input", "classes"
            );
            for d in &models {
                println!(
                    "{:<18} {:>4} {:>8} {:>12} {:>16} {:>10} {:>8}",
                    d.name, d.version, d.id, d.params, d.macs, d.input_len, d.classes
                );
            }
            Ok(())
        }
        "info" => {
            let model = second_positional(args, "info", addr)?;
            let d = client.model_info(model)?;
            if json {
                println!("{}", wire::encode(&wire::desc_to_json(&d)));
                return Ok(());
            }
            println!(
                "{} v{} (id {}): input {} values, {} classes, {} layers, {} params, {} MACs",
                d.name, d.version, d.id, d.input_len, d.classes, d.layers, d.params, d.macs
            );
            print_mapping(&d.mapping);
            Ok(())
        }
        "stats" => {
            let stats = client.stats()?;
            if json {
                let resp = api::Response::Stats(stats);
                println!("{}", String::from_utf8(wire::encode_response(&resp))?);
                return Ok(());
            }
            print_stats(&api::Response::Stats(stats))
        }
        "trace" => {
            let model = second_positional(args, "trace", addr)?;
            let t = client.trace(
                model,
                args.get_u64("seed", 7),
                args.get_u64("window", 32),
            )?;
            if json {
                let resp = api::Response::Trace(t);
                println!("{}", String::from_utf8(wire::encode_response(&resp))?);
                return Ok(());
            }
            println!(
                "{} v{} (image seed {}): {} events recorded ({} dropped), {} returned",
                t.model.name,
                t.model.version,
                t.image_seed,
                t.events_total,
                t.dropped,
                t.events.len()
            );
            for (i, e) in t.events.iter().enumerate() {
                println!("  #{i}: {}", e.describe());
            }
            if !t.heatmap.is_empty() {
                print!("{}", t.heatmap);
            }
            println!("scores: {:?}", t.scores);
            Ok(())
        }
        other => bail!(
            "unknown client op {other:?} (use infer | load | swap | unload | models | info \
             | stats | trace)"
        ),
    }
}

/// `domino traffic record|replay|scenario` — the hostile-reality
/// plane: capture a timestamped request log off a live service,
/// re-issue it deterministically at a chosen speed, or run the
/// scenario suite (overload, bursts, admin storms, slow-loris, SLO
/// search). See `serve::traffic`.
fn traffic_cmd(args: &Args) -> Result<()> {
    let op = args.positional.first().map(String::as_str).unwrap_or("");
    match op {
        "record" => traffic_record(args),
        "replay" => traffic_replay(args),
        "scenario" => traffic_scenario(args),
        other => bail!("unknown traffic op {other:?} (use record | replay | scenario)"),
    }
}

fn traffic_models(args: &Args) -> Vec<String> {
    args.get("models")
        .unwrap_or("tiny-mlp,tiny-cnn")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

fn traffic_record(args: &Args) -> Result<()> {
    use domino::serve::api::{Request, Response};
    use domino::serve::traffic::{arrival_offsets_us, Arrival, TrafficRecorder};
    use domino::serve::{ModelRegistry, ServeConfig, Server, Service};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("traffic record needs --out FILE"))?;
    let models = traffic_models(args);
    let n = args.get_usize("requests", 64);
    let seed = args.get_u64("seed", 42);

    // Start from an *empty* registry and load the models through
    // dispatch while the recorder is armed: the log then begins with
    // its own `load_seeded` requests, so replaying it into a fresh
    // empty service reconstructs the exact versions (weights are a
    // pure function of network + seed) — the log is self-contained.
    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start_multi(
        ServeConfig {
            workers: 2,
            max_batch: 4,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        registry,
    )?;
    let service = Service::new(server, arch_from(args));
    let recorder = TrafficRecorder::arm(&service);

    let mut loaded: Vec<(String, usize)> = Vec::new();
    for (i, m) in models.iter().enumerate() {
        match service.dispatch(Request::LoadSeeded {
            model: m.clone(),
            seed: seed.wrapping_add(i as u64),
            mapping: None,
        }) {
            Response::Loaded(stamp) => {
                let reg = service
                    .server()
                    .registry()
                    .ok_or_else(|| anyhow::anyhow!("sim backend has no registry"))?;
                let mv = reg
                    .get(&stamp.name)
                    .ok_or_else(|| anyhow::anyhow!("{} vanished after load", stamp.name))?;
                loaded.push((stamp.name.to_string(), mv.input_len()));
            }
            Response::Error { message } => bail!("load {m}: {message}"),
            other => bail!("unexpected response to load {m}: {other:?}"),
        }
    }

    let arrival = match args.get("burst") {
        Some(b) => Arrival::Bursty {
            burst: b
                .parse()
                .map_err(|_| anyhow::anyhow!("--burst must be a positive integer"))?,
            gap_us: args.get_u64("gap-us", 20_000),
        },
        None => Arrival::Uniform {
            rate: args.get_u64("rate", 200),
        },
    };
    let offsets = arrival_offsets_us(arrival, n);
    let start = Instant::now();
    let mut rng = Rng::new(seed);
    let (mut ok, mut rejected, mut failed) = (0u64, 0u64, 0u64);
    for (i, off) in offsets.iter().enumerate() {
        let due = Duration::from_micros(*off);
        let now = start.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        let (name, input_len) = &loaded[i % loaded.len()];
        let image = rng.i8_vec(*input_len, 31);
        match service.dispatch(Request::Infer {
            model: Some(name.clone()),
            image,
        }) {
            Response::Infer(_) => ok += 1,
            Response::Error { message } if message.contains("backpressure") => rejected += 1,
            _ => failed += 1,
        }
    }
    service.clear_tap();
    let log = recorder.finish();
    log.save(std::path::Path::new(out))?;
    println!(
        "recorded {} entries ({} loads; {} infers ok, {} rejected, {} failed) \
         over {:.2}s -> {}",
        log.len(),
        loaded.len(),
        ok,
        rejected,
        failed,
        start.elapsed().as_secs_f64(),
        out
    );
    if rejected > 0 {
        println!(
            "note: the recording includes backpressure rejections; replay with \
             `--admission recorded` to re-apply them byte-identically at any speed"
        );
    }
    service.shutdown()?;
    Ok(())
}

fn traffic_replay(args: &Args) -> Result<()> {
    use domino::serve::api::Response;
    use domino::serve::traffic::{
        replay_admission, replay_with_admission, AdmissionMode, ReplaySpeed, TrafficLog,
    };
    use domino::serve::{ModelRegistry, ServeConfig, Server, Service};
    use std::sync::Arc;

    let file = args.positional.get(1).ok_or_else(|| {
        anyhow::anyhow!(
            "usage: domino traffic replay FILE [--speed 1x|max|Nx] [--addr HOST:PORT] \
             [--admission live|recorded]"
        )
    })?;
    let log = TrafficLog::load(std::path::Path::new(file))?;
    let speed = ReplaySpeed::parse(args.get("speed").unwrap_or("max"))?;
    let admission = AdmissionMode::parse(args.get("admission").unwrap_or("live"))?;
    let report = match args.get("addr") {
        Some(addr) => {
            // against a live endpoint: a transport failure becomes a
            // typed error response, which the diff then reports
            let mut client = domino::serve::client::Client::connect(addr)?;
            replay_with_admission(&log, speed, admission, |req| {
                client.call(&req).unwrap_or_else(|e| Response::Error {
                    message: format!("transport: {e:#}"),
                })
            })
        }
        None => {
            // against a fresh local service: the log's own load
            // requests reconstruct the models, same seeds, same bytes
            let registry = Arc::new(ModelRegistry::new());
            let server = Server::start_multi(
                ServeConfig {
                    workers: 2,
                    max_batch: 4,
                    queue_cap: 64,
                    ..ServeConfig::default()
                },
                registry,
            )?;
            let service = Service::new(server, arch_from(args));
            let r = replay_admission(&log, &service, speed, admission);
            service.shutdown()?;
            r
        }
    };
    println!(
        "replayed {} entries ({} admission) in {:.2}s: {} matched, {} mismatched, \
         {} skipped (stats)",
        report.total,
        admission.name(),
        report.elapsed.as_secs_f64(),
        report.matched,
        report.mismatched,
        report.skipped
    );
    if report.rejections_reapplied > 0 || report.backpressure_retries > 0 {
        println!(
            "  admission: {} recorded rejections re-applied, {} live backpressure retries",
            report.rejections_reapplied, report.backpressure_retries
        );
    }
    if let Some(m) = &report.first_mismatch {
        println!("first mismatch: {m}");
    }
    anyhow::ensure!(
        report.is_identical(),
        "{} responses diverged from the recording",
        report.mismatched
    );
    println!("every comparable response was byte-identical to the recording");
    Ok(())
}

fn traffic_scenario(args: &Args) -> Result<()> {
    use domino::serve::{traffic, wire};

    let models = traffic_models(args);
    let smoke = args.flag("smoke");
    let seed = args.get_u64("seed", 42);
    let report = traffic::scenario_suite(&models, smoke, seed)?;
    println!(
        "scenario suite ({}) on {} (queue_cap {}):",
        if smoke { "smoke" } else { "full" },
        models.join(","),
        report.queue_cap
    );
    println!(
        "  overload: {} submitted -> {} accepted, {} rejected (typed), {} failed, {} dropped",
        report.overload.submitted,
        report.overload.accepted,
        report.overload.rejected,
        report.overload.failed,
        report.overload.dropped
    );
    let fmt = |v: Option<u64>| v.map(|x| x.to_string()).unwrap_or_else(|| "-".into());
    println!(
        "  burst:    {} submitted -> {} accepted, {} rejected; p50 {} us, p99 {} us",
        report.burst.submitted,
        report.burst.accepted,
        report.burst.rejected,
        fmt(report.burst.p50_us),
        fmt(report.burst.p99_us)
    );
    println!(
        "  storm:    {} infers ok across {} version(s); {} swaps, {} side loads, \
         {} admin failures",
        report.storm.infers_ok,
        report.storm.versions_seen,
        report.storm.swaps_ok,
        report.storm.loads_ok,
        report.storm.admin_failed
    );
    if let Some(l) = &report.loris {
        println!(
            "  loris:    {} well-behaved infers served during a {} ms dribble; \
             dribbled frame answered: {}",
            l.wellbehaved_ok, l.dribble_ms, l.loris_answered
        );
    }
    println!(
        "  slo:      max sustained rate {}/s at p99 {} us (bound {} us, {} probes)",
        report.slo.max_rate_per_s,
        report.slo.p99_at_max_us,
        report.slo.slo_p99_us,
        report.slo.probes.len()
    );
    if let Some(path) = args.get("out") {
        std::fs::write(path, wire::encode(&report.to_json()))?;
        println!("wrote {path}");
    }
    println!("all scenario invariants held");
    Ok(())
}

/// Serve the AOT artifact through PJRT over the held-out test set.
fn serve_pjrt(args: &Args) -> Result<()> {
    use domino::serve::{LatencyStats, ServeConfig, Server};
    let dir = domino::runtime::artifacts_dir();
    let ts = domino::eval::accuracy::TestSet::load(
        &dir.join(domino::runtime::artifact::TESTSET_BIN),
    )?;
    let cfg = ServeConfig {
        workers: args.get_usize("workers", 2),
        max_batch: args.get_usize("batch", 8),
        queue_cap: args.get_usize("queue", 256),
        ..ServeConfig::default()
    };
    let n = args.get_usize("requests", 256);
    println!(
        "serving {} requests ({} workers, micro-batch {})",
        n, cfg.workers, cfg.max_batch
    );
    let server = Server::start(cfg)?;
    let t0 = std::time::Instant::now();
    let mut lat = LatencyStats::default();
    let mut correct = 0usize;
    for i in 0..n {
        let idx = i % ts.images.len();
        let t = std::time::Instant::now();
        let r = server.infer(ts.images[idx].clone())?;
        lat.record(t.elapsed());
        let pred = r
            .logits
            .iter()
            .enumerate()
            .max_by_key(|&(j, &v)| (v, std::cmp::Reverse(j)))
            .map(|(j, _)| j)
            .unwrap();
        if pred == ts.labels[idx] as usize {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "{} req in {:.2} s -> {:.0} req/s; latency {}; accuracy {:.4}",
        n,
        wall.as_secs_f64(),
        domino::sim::stats::safe_rate(n as f64, wall.as_secs_f64()),
        lat.summary(),
        domino::sim::stats::safe_rate(correct as f64, n as f64)
    );
    server.shutdown()?;
    Ok(())
}

fn golden(args: &Args) -> Result<()> {
    let rt = domino::runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let n = args.get_usize("images", 5);
    let checked = domino::runtime::golden::check_golden_vs_reference(&rt, n, 1234)?;
    println!("golden HLO == rust reference on {checked} image(s) [bit-exact]");
    Ok(())
}

// ------------------------------------------------------------------ cluster

fn cluster_cmd(args: &Args) -> Result<()> {
    let op = args.positional.first().map(String::as_str).unwrap_or("");
    match op {
        "serve" => cluster_serve(args),
        "status" => cluster_status(args),
        other => bail!("unknown cluster op {other:?} (use serve | status)"),
    }
}

/// Backend processes spawned by `cluster serve --spawn N`. Killed on
/// drop — including every error path — so a failed router start never
/// orphans children. The stdout pipes are held open for the children's
/// lifetime: a spawned `serve` prints a line or two after we stop
/// reading, and a closed pipe would make its `println!` panic.
struct SpawnedBackends {
    children: Vec<std::process::Child>,
    // held, never read: keeping the pipes open is the point
    #[allow(dead_code)]
    stdouts: Vec<std::io::BufReader<std::process::ChildStdout>>,
}

impl Drop for SpawnedBackends {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Spawn `n` empty sim-serve backends on ephemeral ports and collect
/// the bound address each prints. The backends carry no models — the
/// router's admin plane loads (and on failover re-loads) them.
fn spawn_backends(n: usize, workers: usize) -> Result<(SpawnedBackends, Vec<String>)> {
    use std::io::BufRead;

    let exe = std::env::current_exe()?;
    let mut guard = SpawnedBackends {
        children: Vec::new(),
        stdouts: Vec::new(),
    };
    let mut addrs = Vec::new();
    for _ in 0..n {
        let mut child = std::process::Command::new(&exe)
            .args([
                "serve",
                "--backend",
                "sim",
                "--models",
                "",
                "--workers",
                &workers.to_string(),
                "--listen",
                "127.0.0.1:0",
            ])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::inherit())
            .spawn()?;
        let stdout = child.stdout.take().expect("stdout was piped");
        guard.children.push(child);
        let mut reader = std::io::BufReader::new(stdout);
        let mut addr = None;
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break; // child exited without listening
            }
            if let Some(rest) = line.strip_prefix("listening on ") {
                addr = rest.split_whitespace().next().map(String::from);
                break;
            }
        }
        guard.stdouts.push(reader);
        let addr = addr.ok_or_else(|| {
            anyhow::anyhow!("spawned backend exited before printing its listen address")
        })?;
        addrs.push(addr);
    }
    Ok((guard, addrs))
}

fn cluster_models(args: &Args) -> Vec<String> {
    args.get("models")
        .unwrap_or("tiny-mlp,tiny-cnn")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect()
}

/// `domino cluster serve`: spawn (or attach to) backend serve
/// processes, shard the requested models over them with replication,
/// and expose the same typed API on `--listen` — a router endpoint is
/// indistinguishable from a single serve endpoint to any client.
fn cluster_serve(args: &Args) -> Result<()> {
    use domino::serve::api::{Dispatcher, Request, Response};
    use domino::serve::net::{NetConfig, NetServer};
    use domino::serve::{ClusterConfig, Router};
    use std::sync::Arc;
    use std::time::Duration;

    let listen = args
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("cluster serve needs --listen ADDR"))?;
    let (_guard, backend_addrs) = match (args.get("spawn"), args.get("backends")) {
        (Some(_), Some(_)) => bail!("pass --spawn N or --backends a,b,c, not both"),
        (Some(n), None) => {
            let n: usize = n
                .parse()
                .ok()
                .filter(|&n| n > 0)
                .ok_or_else(|| anyhow::anyhow!("--spawn must be a positive integer"))?;
            let (g, addrs) = spawn_backends(n, args.get_usize("workers", 2))?;
            println!(
                "spawned {} backend process(es): {}",
                addrs.len(),
                addrs.join(", ")
            );
            (Some(g), addrs)
        }
        (None, Some(list)) => {
            let addrs: Vec<String> = list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect();
            (None, addrs)
        }
        (None, None) => bail!("cluster serve needs --spawn N or --backends a,b,c"),
    };

    let cfg = ClusterConfig {
        replication: args.get_usize("replication", 2),
        ..ClusterConfig::default()
    };
    let router = Router::new(backend_addrs, cfg)?;

    // Load the models through the router's own admin plane: rendezvous
    // hashing picks each model's owners, and the router records the
    // (seed, mapping) spec it will re-load from during failover.
    let seed = args.get_u64("seed", 42);
    for (i, m) in cluster_models(args).iter().enumerate() {
        match router.dispatch(Request::LoadSeeded {
            model: m.clone(),
            seed: seed.wrapping_add(i as u64),
            mapping: None,
        }) {
            Response::Loaded(stamp) => {
                println!("loaded {} v{} across the cluster", stamp.name, stamp.version)
            }
            Response::Error { message } => bail!("load {m}: {message}"),
            other => bail!("unexpected response to load {m}: {other:?}"),
        }
    }
    router.start_health();
    print!("{}", router.status().render());

    let router = Arc::new(router);
    let net = NetServer::bind_with(
        listen,
        Arc::clone(&router),
        NetConfig {
            dispatchers: args
                .get_usize("dispatchers", domino::serve::ServeConfig::default().dispatchers),
            ..NetConfig::default()
        },
    )?;
    println!(
        "router listening on {addr_real} (length-prefixed JSON frames; drive with \
         `domino client <op> --addr {addr_real}`)",
        addr_real = net.local_addr()
    );
    println!(
        "note: the wire protocol is plaintext and unauthenticated; bind to trusted \
         networks only"
    );
    let secs = args.get_u64("serve-secs", 0);
    if secs == 0 {
        println!("serving until killed (pass --serve-secs N for a bounded run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));
    net.shutdown()?;
    print!("{}", router.status().render());
    Ok(())
}

/// `domino cluster status`: probe each backend once (read-only — no
/// loads, no repairs) and print liveness, loaded models, and the
/// owner assignments a router over these backends would use.
fn cluster_status(args: &Args) -> Result<()> {
    use domino::serve::{ClusterConfig, Router};
    use std::collections::BTreeSet;

    let backends: Vec<String> = args
        .get("backends")
        .ok_or_else(|| anyhow::anyhow!("cluster status needs --backends a,b,c"))?
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    let cfg = ClusterConfig {
        replication: args.get_usize("replication", 2),
        ..ClusterConfig::default()
    };
    let router = Router::new(backends, cfg)?;
    // First probe-only pass (no repair loop, nothing is loaded
    // anywhere): discover liveness and each backend's loaded set.
    router.probe_pass();
    let probed = router.status();
    let mut names: BTreeSet<String> = probed
        .backends
        .iter()
        .flat_map(|b| b.loaded.iter().cloned())
        .collect();
    if let Some(list) = args.get("models") {
        names.extend(
            list.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from),
        );
    }
    router.assume_models(&names.into_iter().collect::<Vec<_>>());
    // Second pass now that the table is populated: canary every
    // discovered model, so the rendered state distinguishes a dead
    // socket (DEAD) from silently-wrong outputs (canary-failed).
    router.probe_pass();
    print!("{}", router.status().render());
    Ok(())
}

/// `domino fault inject|canary|storm` — the fault plane's CLI: arm a
/// deterministic fault plan on a model (local one-shot service or a
/// live `serve --listen` endpoint via --addr), run canary checks
/// against the refcompute oracle, and heal by re-mapping around the
/// faulted tiles. `storm` is the end-to-end drill over several
/// models at once.
fn fault_cmd(args: &Args) -> Result<()> {
    let op = args.positional.first().map(String::as_str).unwrap_or("");
    match op {
        "inject" => fault_inject_cmd(args),
        "canary" => fault_canary_cmd(args),
        "storm" => fault_storm(args),
        other => bail!("unknown fault op {other:?} (use inject | canary | storm)"),
    }
}

fn print_fault_reply(r: &domino::serve::api::FaultReply) {
    if !r.armed {
        println!("{} v{}: fault plan disarmed", r.model.name, r.model.version);
        return;
    }
    println!(
        "{} v{}: armed {} fault site(s)",
        r.model.name, r.model.version, r.sites
    );
    println!(
        "diagnostic run (image seed {:#x}): {} fire(s), {} psum lane(s) corrupted, \
         {}/{} outputs wrong -> {}",
        domino::serve::api::FAULT_DIAG_SEED,
        r.fires,
        r.lanes,
        r.mismatched,
        r.outputs,
        if r.corrupted {
            "SILENTLY CORRUPT (structure and timing stay clean; only a canary catches this)"
        } else {
            "outputs unaffected (sites never exercised or corruption masked)"
        }
    );
    for line in r.report.lines() {
        println!("  {line}");
    }
}

fn print_canary_reply(c: &domino::serve::api::CanaryReply) {
    println!(
        "canary on {} v{}: {} ({}/{} outputs wrong vs refcompute)",
        c.model.name,
        c.model.version,
        if c.ok { "PASS" } else { "FAIL" },
        c.mismatched,
        c.outputs
    );
    if c.remapped {
        println!(
            "re-mapped around the armed fault sites -> v{} ({})",
            c.version,
            if c.healed {
                "healed: post-remap canary is bit-exact"
            } else {
                "NOT healed: post-remap canary still corrupt"
            }
        );
    }
}

/// Build a one-shot local sim service with `model` loaded at `--seed`
/// — the offline venue for fault drills when no --addr is given.
fn fault_local_service(model: &str, args: &Args) -> Result<(domino::serve::Service, String)> {
    use domino::serve::api::{Dispatcher, Request, Response};
    use domino::serve::{ModelRegistry, ServeConfig, Server, Service};
    use std::sync::Arc;

    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start_multi(
        ServeConfig {
            workers: 1,
            max_batch: 2,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        registry,
    )?;
    let service = Service::new(server, arch_from(args));
    match service.dispatch(Request::LoadSeeded {
        model: model.to_string(),
        seed: args.get_u64("seed", 42),
        mapping: None,
    }) {
        Response::Loaded(stamp) => Ok((service, stamp.name.to_string())),
        Response::Error { message } => bail!("load {model}: {message}"),
        other => bail!("unexpected response to load {model}: {other:?}"),
    }
}

/// `domino fault inject <model> --plan SPEC [--addr HOST:PORT]
/// [--heal]`: arm (empty SPEC disarms) a deterministic fault plan and
/// print the diagnostic report; --heal follows up with a healing
/// canary. Without --addr a local one-shot service hosts the drill.
fn fault_inject_cmd(args: &Args) -> Result<()> {
    use domino::serve::api::{Dispatcher, Request, Response};
    use domino::serve::client::Client;

    let model = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("usage: domino fault inject <model> --plan SPEC"))?;
    let plan = args.get("plan").ok_or_else(|| {
        anyhow::anyhow!(
            "fault inject needs --plan SPEC — `;`-joined sites like \
             tile:0:1:2:stuck:7, tile:0:1:2:dead, link:0:0:3:flip:5, link:0:0:3:drop, \
             each optionally windowed @from-to; an empty spec disarms"
        )
    })?;

    if let Some(addr) = args.get("addr") {
        let mut client = Client::connect(addr)?;
        client.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
        let rep = client.fault_inject(model, plan)?;
        print_fault_reply(&rep);
        if args.flag("heal") {
            let c = client.canary(model, args.get_u64("canary-seed", 0xCA11A2), true)?;
            print_canary_reply(&c);
        }
        return Ok(());
    }

    let (service, name) = fault_local_service(model, args)?;
    match service.dispatch(Request::FaultInject {
        model: name.clone(),
        plan: plan.to_string(),
    }) {
        Response::Fault(rep) => print_fault_reply(&rep),
        Response::Error { message } => bail!("fault inject: {message}"),
        other => bail!("unexpected response to fault inject: {other:?}"),
    }
    if args.flag("heal") {
        match service.dispatch(Request::Canary {
            model: name,
            seed: args.get_u64("canary-seed", 0xCA11A2),
            heal: true,
        }) {
            Response::Canary(c) => print_canary_reply(&c),
            Response::Error { message } => bail!("canary: {message}"),
            other => bail!("unexpected response to canary: {other:?}"),
        }
    }
    service.shutdown()?;
    Ok(())
}

/// `domino fault canary <model> [--heal] [--addr HOST:PORT]`: one
/// seeded sentinel inference checked bit-for-bit against refcompute;
/// --heal re-maps around armed fault sites when the check fails.
fn fault_canary_cmd(args: &Args) -> Result<()> {
    use domino::serve::api::{Dispatcher, Request, Response};
    use domino::serve::client::Client;

    let model = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("usage: domino fault canary <model> [--heal]"))?;
    let seed = args.get_u64("canary-seed", 0xCA11A2);
    let heal = args.flag("heal");

    if let Some(addr) = args.get("addr") {
        let mut client = Client::connect(addr)?;
        client.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
        print_canary_reply(&client.canary(model, seed, heal)?);
        return Ok(());
    }
    let (service, name) = fault_local_service(model, args)?;
    match service.dispatch(Request::Canary {
        model: name,
        seed,
        heal,
    }) {
        Response::Canary(c) => print_canary_reply(&c),
        Response::Error { message } => bail!("canary: {message}"),
        other => bail!("unexpected response to canary: {other:?}"),
    }
    service.shutdown()?;
    Ok(())
}

/// `domino fault storm [--models a,b,c] [--seed S]`: the end-to-end
/// drill. For each model: load seeded, arm a stuck-at fault on a
/// real tile of its placement, prove the corruption is silent
/// (diagnostic fires, outputs wrong), then detect + heal via the
/// canary path and report per-model detection/recovery wall time.
/// Exits non-zero if any model fails to heal.
fn fault_storm(args: &Args) -> Result<()> {
    use domino::serve::api::{Dispatcher, Request, Response};
    use domino::serve::{ModelRegistry, ServeConfig, Server, Service};
    use std::sync::Arc;
    use std::time::Instant;

    let models: Vec<String> = args
        .get("models")
        .unwrap_or("tiny-mlp,tiny-cnn,tiny-resnet")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    let seed = args.get_u64("seed", 42);

    let registry = Arc::new(ModelRegistry::new());
    let server = Server::start_multi(
        ServeConfig {
            workers: 2,
            max_batch: 4,
            queue_cap: 64,
            ..ServeConfig::default()
        },
        registry,
    )?;
    let service = Service::new(server, arch_from(args));

    let mut unhealed = Vec::new();
    for (i, m) in models.iter().enumerate() {
        let stamp = match service.dispatch(Request::LoadSeeded {
            model: m.clone(),
            seed: seed.wrapping_add(i as u64),
            mapping: None,
        }) {
            Response::Loaded(stamp) => stamp,
            Response::Error { message } => bail!("load {m}: {message}"),
            other => bail!("unexpected response to load {m}: {other:?}"),
        };
        // a real tile of this model's placement — the fault must hit
        let reg = service
            .server()
            .registry()
            .ok_or_else(|| anyhow::anyhow!("sim backend has no registry"))?;
        let mv = reg
            .get(&stamp.name)
            .ok_or_else(|| anyhow::anyhow!("{} vanished after load", stamp.name))?;
        let coords = mv.program().tile_coords();
        let bad = coords[i % coords.len()];
        let plan = domino::sim::FaultPlan::new().stuck_tile(bad, 7).spec();

        let t0 = Instant::now();
        let rep = match service.dispatch(Request::FaultInject {
            model: stamp.name.to_string(),
            plan,
        }) {
            Response::Fault(rep) => rep,
            Response::Error { message } => bail!("fault inject {m}: {message}"),
            other => bail!("unexpected response to fault inject {m}: {other:?}"),
        };
        let detect_us = t0.elapsed().as_micros();
        println!(
            "{}: stuck-at fault on tile {bad} -> diagnostic {} fire(s), {}/{} outputs wrong \
             ({} us to detect)",
            stamp.name, rep.fires, rep.mismatched, rep.outputs, detect_us
        );

        let t1 = Instant::now();
        let c = match service.dispatch(Request::Canary {
            model: stamp.name.to_string(),
            seed: args.get_u64("canary-seed", 0xCA11A2),
            heal: true,
        }) {
            Response::Canary(c) => c,
            Response::Error { message } => bail!("canary {m}: {message}"),
            other => bail!("unexpected response to canary {m}: {other:?}"),
        };
        let heal_us = t1.elapsed().as_micros();
        print_canary_reply(&c);
        if rep.corrupted && c.remapped && c.healed {
            println!("  recovered in {heal_us} us (re-map + verifying canary)");
        } else if !rep.corrupted {
            println!("  fault site never exercised on the diagnostic image; nothing to heal");
        } else {
            unhealed.push(stamp.name.to_string());
        }
    }
    print_stats(&service.dispatch(Request::Stats))?;
    service.shutdown()?;
    if !unhealed.is_empty() {
        bail!("models left unhealed: {}", unhealed.join(", "));
    }
    println!("storm complete: every corrupted model detected and healed");
    Ok(())
}
