//! One Domino tile (paper Fig. 1(b)): a CIM crossbar [`pe::Pe`], an
//! input-feature-map router [`rifm::Rifm`] and an output/partial-sum
//! router [`rofm::Rofm`].
//!
//! The dual-router structure is the paper's first stated contribution:
//! IFM traffic (streamed activations) and OFM/partial-sum traffic move on
//! disjoint router networks, so input streaming and computing-on-the-move
//! accumulation never contend.
//!
//! The arithmetic inside both components is written as blocked,
//! autovectorization-friendly kernels: [`pe::Pe`] packs its weights
//! into cache-tiled column panels at construction and drains several
//! pixels' MVMs per panel pass ([`pe::Pe::mvm_many_into`]), and the
//! [`rofm::Rofm`] scratch datapaths (psum adds, activation,
//! requantization, pooling) walk fixed-width `chunks_exact` blocks
//! with scalar remainder lanes. All of it is bit-exact with the
//! scalar reference by construction — i32 accumulation of i8
//! products is order-independent — and `cargo bench --bench
//! bench_kernels` gates the speedup against frozen scalar copies.

pub mod pe;
pub mod rifm;
pub mod rofm;

pub use pe::Pe;
pub use rifm::Rifm;
pub use rofm::Rofm;

/// A fully assembled tile.
#[derive(Clone, Debug)]
pub struct Tile<'w> {
    pub pe: Pe<'w>,
    pub rifm: Rifm,
    pub rofm: Rofm,
}

impl<'w> Tile<'w> {
    pub fn new(pe: Pe<'w>, rifm: Rifm, rofm: Rofm) -> Self {
        Self { pe, rifm, rofm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::isa::Schedule;

    #[test]
    fn tile_assembles_all_three_components() {
        // Fig. 1(b): a tile contains an RIFM, an ROFM and a PE.
        let tile = Tile::new(
            Pe::new(vec![1, 2, 3, 4], 2, 2),
            Rifm::new(2),
            Rofm::new(Schedule::idle()),
        );
        assert_eq!(tile.pe.rows(), 2);
        assert_eq!(tile.pe.cols(), 2);
    }
}
