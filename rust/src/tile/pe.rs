//! The Processing Element: a behavioral CIM crossbar array.
//!
//! The paper deliberately treats the PE as substitutable ("Domino adopts
//! existing CIM arrays to enable flexible substitution", Section II-D):
//! an `N_c x N_m` crossbar holding stationary int8 weights; streaming an
//! input vector down the rows yields `N_m` analog column sums, digitised
//! by ADCs into 32-bit partial sums. This model computes the same
//! function digitally and bit-exactly (quantization error is the only
//! error source the paper's accuracy evaluation considers).
//!
//! Weight layout: row-major `[row(=input channel)][col(=output channel)]`,
//! i.e. `w[c * cols + m]` — the transpose of the `[M][C]` layout used by
//! `model::refcompute`, reflecting how a crossbar is physically loaded
//! (inputs enter rows, outputs leave columns).

use crate::sim::stats::Counters;

/// A weight-loaded CIM crossbar block (≤ 256 x 256). Weights are held
/// by copy-on-write so the simulator can mount a compiled tile's block
/// without cloning 64 KiB per tile per image (§Perf).
#[derive(Clone, Debug)]
pub struct Pe<'w> {
    weights: std::borrow::Cow<'w, [i8]>,
    rows: usize,
    cols: usize,
}

impl<'w> Pe<'w> {
    /// `weights[c * cols + m]`, `rows` input channels, `cols` output
    /// channels.
    pub fn new(weights: Vec<i8>, rows: usize, cols: usize) -> Pe<'static> {
        Pe::check(&weights, rows, cols);
        Pe { weights: std::borrow::Cow::Owned(weights), rows, cols }
    }

    /// Mount a stationary weight block without copying.
    pub fn borrowed(weights: &'w [i8], rows: usize, cols: usize) -> Pe<'w> {
        Pe::check(weights, rows, cols);
        Pe { weights: std::borrow::Cow::Borrowed(weights), rows, cols }
    }

    fn check(weights: &[i8], rows: usize, cols: usize) {
        assert_eq!(weights.len(), rows * cols, "PE weight block size");
        assert!(
            rows <= crate::consts::N_C && cols <= crate::consts::N_M,
            "PE block exceeds crossbar dimensions"
        );
    }

    /// An unloaded (all-zero) block.
    pub fn zeros(rows: usize, cols: usize) -> Pe<'static> {
        Pe::new(vec![0; rows * cols], rows, cols)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// In-memory matrix-vector multiply: `out[m] = Σ_c x[c] * w[c][m]`.
    ///
    /// `x` may be shorter than `rows` (the tail rows see zero input —
    /// e.g. the last channel block of a layer whose C is not a multiple
    /// of 256). Allocates the result; the steady-state engine path uses
    /// [`Self::mvm_into`] with caller scratch instead.
    pub fn mvm(&self, x: &[i8], stats: &mut Counters) -> Vec<i32> {
        let mut out = vec![0i32; self.cols];
        self.mvm_into(x, &mut out, stats);
        out
    }

    /// [`Self::mvm`] writing into caller-owned scratch (`out.len()`
    /// must equal `cols`); the hot path of the cycle engine, which
    /// points `out` at a psum-arena slot or a reused scratch buffer so
    /// no MVM allocates (§Perf).
    pub fn mvm_into(&self, x: &[i8], out: &mut [i32], stats: &mut Counters) {
        assert!(x.len() <= self.rows, "input vector exceeds crossbar rows");
        assert_eq!(out.len(), self.cols, "MVM output width");
        // MACs are charged uniformly per row activation — analog CIM
        // drives the wordline regardless of value — so the zero-skip
        // below is a pure simulator-speed optimization (§Perf), not an
        // energy model change.
        stats.pe_mvms += 1;
        stats.pe_macs += (x.len() * self.cols) as u64;
        out.fill(0);
        for (c, &xv) in x.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i32;
            let row = &self.weights[c * self.cols..(c + 1) * self.cols];
            // zip keeps the loop free of bounds checks => SIMD
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xv * wv as i32;
            }
        }
    }

    /// Weight of cell (row c, col m) — used by tests and the trace tool.
    pub fn weight(&self, c: usize, m: usize) -> i8 {
        self.weights[c * self.cols + m]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{for_all, Rng};

    #[test]
    fn mvm_known_values() {
        // w = [[1, 2], [3, 4]] (c-major): out = x0*[1,2] + x1*[3,4]
        let pe = Pe::new(vec![1, 2, 3, 4], 2, 2);
        let mut stats = Counters::new();
        let out = pe.mvm(&[1, 1], &mut stats);
        assert_eq!(out, vec![4, 6]);
        assert_eq!(stats.pe_mvms, 1);
        assert_eq!(stats.pe_macs, 4);
    }

    #[test]
    fn mvm_short_input_treats_tail_as_zero() {
        let pe = Pe::new(vec![1, 2, 3, 4], 2, 2);
        let mut stats = Counters::new();
        let out = pe.mvm(&[2], &mut stats);
        assert_eq!(out, vec![2, 4]);
        assert_eq!(stats.pe_macs, 2);
    }

    #[test]
    #[should_panic(expected = "exceeds crossbar rows")]
    fn mvm_rejects_oversized_input() {
        let pe = Pe::new(vec![0; 4], 2, 2);
        pe.mvm(&[1, 2, 3], &mut Counters::new());
    }

    #[test]
    #[should_panic(expected = "exceeds crossbar dimensions")]
    fn pe_rejects_oversized_block() {
        Pe::zeros(257, 1);
    }

    #[test]
    fn mvm_into_matches_mvm_and_overwrites_scratch() {
        let pe = Pe::new(vec![1, 2, 3, 4], 2, 2);
        let mut s1 = Counters::new();
        let want = pe.mvm(&[3, -1], &mut s1);
        // dirty scratch must be fully overwritten, charges identical
        let mut out = vec![i32::MIN; 2];
        let mut s2 = Counters::new();
        pe.mvm_into(&[3, -1], &mut out, &mut s2);
        assert_eq!(out, want);
        assert_eq!(s1, s2);
    }

    #[test]
    #[should_panic(expected = "MVM output width")]
    fn mvm_into_rejects_wrong_width_scratch() {
        let pe = Pe::new(vec![0; 4], 2, 2);
        pe.mvm_into(&[1], &mut [0i32; 3], &mut Counters::new());
    }

    #[test]
    fn prop_mvm_matches_naive_dot() {
        for_all("pe_mvm_vs_naive", 30, |rng: &mut Rng| {
            let rows = rng.range(1, 64);
            let cols = rng.range(1, 64);
            let w = rng.i8_vec(rows * cols, 15);
            let x = rng.i8_vec(rows, 15);
            let pe = Pe::new(w.clone(), rows, cols);
            let out = pe.mvm(&x, &mut Counters::new());
            for m in 0..cols {
                let want: i32 = (0..rows)
                    .map(|c| x[c] as i32 * w[c * cols + m] as i32)
                    .sum();
                assert_eq!(out[m], want);
            }
        });
    }

    #[test]
    fn prop_mvm_is_linear() {
        for_all("pe_mvm_linear", 20, |rng: &mut Rng| {
            let rows = rng.range(1, 32);
            let cols = rng.range(1, 32);
            let pe = Pe::new(rng.i8_vec(rows * cols, 10), rows, cols);
            let a = rng.i8_vec(rows, 5);
            let b = rng.i8_vec(rows, 5);
            let sum: Vec<i8> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
            let mut s = Counters::new();
            let oa = pe.mvm(&a, &mut s);
            let ob = pe.mvm(&b, &mut s);
            let os = pe.mvm(&sum, &mut s);
            for m in 0..cols {
                assert_eq!(os[m], oa[m] + ob[m]);
            }
        });
    }
}
