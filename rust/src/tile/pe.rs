//! The Processing Element: a behavioral CIM crossbar array.
//!
//! The paper deliberately treats the PE as substitutable ("Domino adopts
//! existing CIM arrays to enable flexible substitution", Section II-D):
//! an `N_c x N_m` crossbar holding stationary int8 weights; streaming an
//! input vector down the rows yields `N_m` analog column sums, digitised
//! by ADCs into 32-bit partial sums. This model computes the same
//! function digitally and bit-exactly (quantization error is the only
//! error source the paper's accuracy evaluation considers).
//!
//! Weight layout: row-major `[row(=input channel)][col(=output channel)]`,
//! i.e. `w[c * cols + m]` — the transpose of the `[M][C]` layout used by
//! `model::refcompute`, reflecting how a crossbar is physically loaded
//! (inputs enter rows, outputs leave columns).
//!
//! ## Blocked kernels (§Perf)
//!
//! Owned constructions ([`Pe::new`], [`Pe::zeros`]) additionally pack
//! the block into a **lane-blocked panel layout** once, at
//! construction: the columns are split into [`LANE`]-wide panels and
//! each panel stores its rows contiguously, so the register-blocked
//! inner kernel streams 64-byte lines of weights into a fixed
//! `[i32; LANE]` accumulator that lives in registers instead of
//! re-loading the output slice per row. [`Pe::borrowed`] stays a
//! zero-alloc mount (no packing — the FC path runs one MVM per mount,
//! where packing would cost as much as it saves) and takes a
//! `chunks_exact`-blocked row-major path instead. Both paths, and the
//! multi-input [`Pe::mvm_many_into`], are **bit-exact by construction**
//! with the retained scalar reference [`Pe::mvm_scalar_into`]: i32
//! accumulation of i8×i8 products is order-independent (|x·w| ≤ 2¹⁴
//! and ≤ 256 terms, so no i32 overflow is reachable), which makes the
//! reordering safe and assertable — `rust/tests/kernel_properties.rs`
//! sweeps every remainder-lane case, and `rust/benches/bench_kernels.rs`
//! gates the speedup against a frozen copy of the scalar kernels.

use crate::sim::stats::Counters;

/// Accumulator lanes per blocked panel: 16 i32 lanes are one 64-byte
/// line of accumulators, and `LANE` i8 weights per row × [`QUAD`] rows
/// is one 64-byte line of packed weights.
pub const LANE: usize = 16;

/// Input rows walked per blocked step of the panel kernel.
const QUAD: usize = 4;

/// Upper bound on the pixel micro-batch [`Pe::mvm_many_into`] accepts
/// (the engine's conv chains drain up to this many pixels' MVMs per
/// tile visit against one mounted weight panel).
pub const MICRO_BATCH: usize = 4;

/// A weight-loaded CIM crossbar block (≤ 256 x 256). Weights are held
/// by copy-on-write so the simulator can mount a compiled tile's block
/// without cloning 64 KiB per tile per image (§Perf).
#[derive(Clone, Debug)]
pub struct Pe<'w> {
    weights: std::borrow::Cow<'w, [i8]>,
    rows: usize,
    cols: usize,
    /// Lane-blocked panels, precomputed once at owned construction
    /// (`⌈cols/LANE⌉` panels of `rows * LANE` weights each; remainder
    /// panels are zero-padded, which is bit-exact — the padding lanes
    /// accumulate `x·0` and are never copied out). Empty for
    /// [`Pe::borrowed`] mounts.
    panels: Vec<i8>,
}

impl<'w> Pe<'w> {
    /// `weights[c * cols + m]`, `rows` input channels, `cols` output
    /// channels. Packs the lane-blocked panel layout once, here.
    pub fn new(weights: Vec<i8>, rows: usize, cols: usize) -> Pe<'static> {
        Pe::check(&weights, rows, cols);
        let panels = pack_panels(&weights, rows, cols);
        Pe {
            weights: std::borrow::Cow::Owned(weights),
            rows,
            cols,
            panels,
        }
    }

    /// Mount a stationary weight block without copying (and without
    /// packing — one-shot mounts like the FC path run a single MVM,
    /// for which the blocked row-major kernel is the right trade).
    pub fn borrowed(weights: &'w [i8], rows: usize, cols: usize) -> Pe<'w> {
        Pe::check(weights, rows, cols);
        Pe {
            weights: std::borrow::Cow::Borrowed(weights),
            rows,
            cols,
            panels: Vec::new(),
        }
    }

    fn check(weights: &[i8], rows: usize, cols: usize) {
        assert_eq!(weights.len(), rows * cols, "PE weight block size");
        assert!(
            rows <= crate::consts::N_C && cols <= crate::consts::N_M,
            "PE block exceeds crossbar dimensions"
        );
    }

    /// An unloaded (all-zero) block.
    pub fn zeros(rows: usize, cols: usize) -> Pe<'static> {
        Pe::new(vec![0; rows * cols], rows, cols)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Whether this block carries the precomputed lane-blocked panels
    /// (owned constructions do; [`Pe::borrowed`] mounts do not).
    pub fn is_packed(&self) -> bool {
        !self.panels.is_empty() || self.cols == 0
    }

    /// In-memory matrix-vector multiply: `out[m] = Σ_c x[c] * w[c][m]`.
    ///
    /// `x` may be shorter than `rows` (the tail rows see zero input —
    /// e.g. the last channel block of a layer whose C is not a multiple
    /// of 256). Allocates the result; **hot-path callers should use
    /// [`Self::mvm_into`]** (or [`Self::mvm_many_into`]) with caller
    /// scratch instead — this wrapper exists for tests and tools.
    pub fn mvm(&self, x: &[i8], stats: &mut Counters) -> Vec<i32> {
        let mut out = vec![0i32; self.cols];
        self.mvm_into(x, &mut out, stats);
        out
    }

    /// [`Self::mvm`] writing into caller-owned scratch (`out.len()`
    /// must equal `cols`); the hot path of the cycle engine, which
    /// points `out` at a psum-arena slot or a reused scratch buffer so
    /// no MVM allocates (§Perf). Dispatches to the panel kernel when
    /// the block was packed at construction, else to the blocked
    /// row-major kernel; both are bit-exact with
    /// [`Self::mvm_scalar_into`].
    pub fn mvm_into(&self, x: &[i8], out: &mut [i32], stats: &mut Counters) {
        assert!(x.len() <= self.rows, "input vector exceeds crossbar rows");
        assert_eq!(out.len(), self.cols, "MVM output width");
        // MACs are charged uniformly per row activation — analog CIM
        // drives the wordline regardless of value — so the zero-skips
        // below are pure simulator-speed optimizations (§Perf), not an
        // energy model change.
        stats.pe_mvms += 1;
        stats.pe_macs += (x.len() * self.cols) as u64;
        if self.panels.is_empty() {
            out.fill(0);
            self.mvm_rowmajor(x, out);
        } else {
            self.mvm_panels(x, out);
        }
    }

    /// Drain several inputs' MVMs against this one mounted weight
    /// block: `out` receives `xs.len()` consecutive `cols`-wide result
    /// slices (`out.len() == xs.len() * cols`). Each packed panel is
    /// streamed through the cache once per micro-batch instead of once
    /// per input, which is where the conv chains' weight-bandwidth win
    /// comes from. Charges exactly `xs.len()` single-MVM charges and
    /// is bit-exact with `xs.len()` separate [`Self::mvm_into`] calls.
    pub fn mvm_many_into(&self, xs: &[&[i8]], out: &mut [i32], stats: &mut Counters) {
        assert!(xs.len() <= MICRO_BATCH, "micro-batch exceeds MICRO_BATCH");
        assert_eq!(
            out.len(),
            xs.len() * self.cols,
            "micro-batch output width"
        );
        stats.pe_mvms += xs.len() as u64;
        for x in xs {
            assert!(x.len() <= self.rows, "input vector exceeds crossbar rows");
            stats.pe_macs += (x.len() * self.cols) as u64;
        }
        if self.panels.is_empty() {
            for (b, x) in xs.iter().enumerate() {
                let o = &mut out[b * self.cols..(b + 1) * self.cols];
                o.fill(0);
                self.mvm_rowmajor(x, o);
            }
            return;
        }
        let np = self.cols.div_ceil(LANE);
        for p in 0..np {
            let c_lo = p * LANE;
            let width = LANE.min(self.cols - c_lo);
            let panel = &self.panels[p * self.rows * LANE..(p + 1) * self.rows * LANE];
            for (b, x) in xs.iter().enumerate() {
                let mut acc = [0i32; LANE];
                panel_dot(panel, x, &mut acc);
                out[b * self.cols + c_lo..b * self.cols + c_lo + width]
                    .copy_from_slice(&acc[..width]);
            }
        }
    }

    /// The retained scalar reference kernel (the pre-blocking PR-9
    /// `mvm_into` body): the bit-exactness oracle the kernel property
    /// sweep compares the blocked paths against. Charges identically
    /// to [`Self::mvm_into`]. Not a hot-path API.
    pub fn mvm_scalar_into(&self, x: &[i8], out: &mut [i32], stats: &mut Counters) {
        assert!(x.len() <= self.rows, "input vector exceeds crossbar rows");
        assert_eq!(out.len(), self.cols, "MVM output width");
        stats.pe_mvms += 1;
        stats.pe_macs += (x.len() * self.cols) as u64;
        out.fill(0);
        for (c, &xv) in x.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i32;
            let row = &self.weights[c * self.cols..(c + 1) * self.cols];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xv * wv as i32;
            }
        }
    }

    /// Panel path: every output lane is computed in the fixed-size
    /// accumulator and copied out once, so `out` needs no pre-zeroing.
    fn mvm_panels(&self, x: &[i8], out: &mut [i32]) {
        let np = self.cols.div_ceil(LANE);
        for p in 0..np {
            let c_lo = p * LANE;
            let width = LANE.min(self.cols - c_lo);
            let panel = &self.panels[p * self.rows * LANE..(p + 1) * self.rows * LANE];
            let mut acc = [0i32; LANE];
            panel_dot(panel, x, &mut acc);
            out[c_lo..c_lo + width].copy_from_slice(&acc[..width]);
        }
    }

    /// Blocked row-major path for unpacked (borrowed) mounts: per-row
    /// zero skip as before, with the inner accumulation walked in
    /// `LANE`-wide `chunks_exact` blocks plus a scalar remainder lane.
    fn mvm_rowmajor(&self, x: &[i8], out: &mut [i32]) {
        for (c, &xv) in x.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i32;
            let row = &self.weights[c * self.cols..(c + 1) * self.cols];
            let mut oi = out.chunks_exact_mut(LANE);
            let mut wi = row.chunks_exact(LANE);
            for (o, w) in oi.by_ref().zip(wi.by_ref()) {
                let o: &mut [i32; LANE] = o.try_into().unwrap();
                let w: &[i8; LANE] = w.try_into().unwrap();
                for l in 0..LANE {
                    o[l] += xv * w[l] as i32;
                }
            }
            for (o, &wv) in oi.into_remainder().iter_mut().zip(wi.remainder()) {
                *o += xv * wv as i32;
            }
        }
    }

    /// Weight of cell (row c, col m) — used by tests and the trace tool.
    pub fn weight(&self, c: usize, m: usize) -> i8 {
        self.weights[c * self.cols + m]
    }
}

/// Pack `weights` (row-major `[rows][cols]`) into the lane-blocked
/// panel layout: panel `p` holds cols `p*LANE..` and stores row `r`'s
/// `LANE` weights at `p*rows*LANE + r*LANE` (remainder panel
/// zero-padded to `LANE`).
fn pack_panels(weights: &[i8], rows: usize, cols: usize) -> Vec<i8> {
    let np = cols.div_ceil(LANE);
    let mut panels = vec![0i8; np * rows * LANE];
    for p in 0..np {
        let c_lo = p * LANE;
        let width = LANE.min(cols - c_lo);
        let base = p * rows * LANE;
        for r in 0..rows {
            panels[base + r * LANE..base + r * LANE + width]
                .copy_from_slice(&weights[r * cols + c_lo..r * cols + c_lo + width]);
        }
    }
    panels
}

/// `acc[l] += Σ_r x[r] * panel[r][l]` — the register-blocked inner
/// kernel: quads of rows stream 64-byte lines of packed weights with a
/// quad-granular zero skip; remainder rows take the scalar lane.
/// Bit-exact with the scalar reference in any grouping (i32 adds are
/// order-independent and cannot overflow at crossbar scale).
#[inline]
fn panel_dot(panel: &[i8], x: &[i8], acc: &mut [i32; LANE]) {
    let mut quads = x.chunks_exact(QUAD);
    let mut r = 0;
    for q in quads.by_ref() {
        let [x0, x1, x2, x3]: [i8; QUAD] = q.try_into().unwrap();
        // bit-OR of the quad is zero iff every input is zero
        if (x0 | x1 | x2 | x3) != 0 {
            let w: &[i8; QUAD * LANE] = panel[r * LANE..(r + QUAD) * LANE].try_into().unwrap();
            let (x0, x1, x2, x3) = (x0 as i32, x1 as i32, x2 as i32, x3 as i32);
            for l in 0..LANE {
                acc[l] += x0 * w[l] as i32
                    + x1 * w[LANE + l] as i32
                    + x2 * w[2 * LANE + l] as i32
                    + x3 * w[3 * LANE + l] as i32;
            }
        }
        r += QUAD;
    }
    for (i, &xv) in quads.remainder().iter().enumerate() {
        if xv != 0 {
            let xv = xv as i32;
            let w: &[i8; LANE] = panel[(r + i) * LANE..(r + i + 1) * LANE].try_into().unwrap();
            for l in 0..LANE {
                acc[l] += xv * w[l] as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{for_all, Rng};

    #[test]
    fn mvm_known_values() {
        // w = [[1, 2], [3, 4]] (c-major): out = x0*[1,2] + x1*[3,4]
        let pe = Pe::new(vec![1, 2, 3, 4], 2, 2);
        let mut stats = Counters::new();
        let out = pe.mvm(&[1, 1], &mut stats);
        assert_eq!(out, vec![4, 6]);
        assert_eq!(stats.pe_mvms, 1);
        assert_eq!(stats.pe_macs, 4);
    }

    #[test]
    fn mvm_short_input_treats_tail_as_zero() {
        let pe = Pe::new(vec![1, 2, 3, 4], 2, 2);
        let mut stats = Counters::new();
        let out = pe.mvm(&[2], &mut stats);
        assert_eq!(out, vec![2, 4]);
        assert_eq!(stats.pe_macs, 2);
    }

    #[test]
    #[should_panic(expected = "exceeds crossbar rows")]
    fn mvm_rejects_oversized_input() {
        let pe = Pe::new(vec![0; 4], 2, 2);
        pe.mvm(&[1, 2, 3], &mut Counters::new());
    }

    #[test]
    #[should_panic(expected = "exceeds crossbar dimensions")]
    fn pe_rejects_oversized_block() {
        Pe::zeros(257, 1);
    }

    #[test]
    fn mvm_into_matches_mvm_and_overwrites_scratch() {
        let pe = Pe::new(vec![1, 2, 3, 4], 2, 2);
        let mut s1 = Counters::new();
        let want = pe.mvm(&[3, -1], &mut s1);
        // dirty scratch must be fully overwritten, charges identical
        let mut out = vec![i32::MIN; 2];
        let mut s2 = Counters::new();
        pe.mvm_into(&[3, -1], &mut out, &mut s2);
        assert_eq!(out, want);
        assert_eq!(s1, s2);
    }

    #[test]
    #[should_panic(expected = "MVM output width")]
    fn mvm_into_rejects_wrong_width_scratch() {
        let pe = Pe::new(vec![0; 4], 2, 2);
        pe.mvm_into(&[1], &mut [0i32; 3], &mut Counters::new());
    }

    #[test]
    fn packed_and_borrowed_paths_agree_with_scalar_reference() {
        for_all("pe_blocked_vs_scalar", 40, |rng: &mut Rng| {
            // widths crossing every remainder-lane and quad case
            let dims = [1, 3, QUAD, LANE - 1, LANE, LANE + 1, 2 * LANE + 5, 100];
            let rows = dims[rng.below(dims.len())];
            let cols = dims[rng.below(dims.len())];
            let w = rng.i8_vec(rows * cols, 127);
            let x: Vec<i8> = (0..rows)
                .map(|_| if rng.chance(0.4) { 0 } else { rng.i8() })
                .collect();
            let packed = Pe::new(w.clone(), rows, cols);
            let borrowed = Pe::borrowed(&w, rows, cols);
            assert!(packed.is_packed());
            assert!(!borrowed.is_packed() || cols == 0);
            let (mut s0, mut s1, mut s2) =
                (Counters::new(), Counters::new(), Counters::new());
            let mut want = vec![0i32; cols];
            packed.mvm_scalar_into(&x, &mut want, &mut s0);
            let mut got_p = vec![i32::MIN; cols];
            packed.mvm_into(&x, &mut got_p, &mut s1);
            let mut got_b = vec![i32::MIN; cols];
            borrowed.mvm_into(&x, &mut got_b, &mut s2);
            assert_eq!(got_p, want, "panel kernel diverged ({rows}x{cols})");
            assert_eq!(got_b, want, "row-major kernel diverged ({rows}x{cols})");
            assert_eq!(s0, s1);
            assert_eq!(s0, s2);
        });
    }

    #[test]
    fn mvm_many_matches_single_calls_and_charges() {
        for_all("pe_mvm_many", 30, |rng: &mut Rng| {
            let rows = rng.range(1, 70);
            let cols = rng.range(1, 70);
            let pe = Pe::new(rng.i8_vec(rows * cols, 127), rows, cols);
            let n = rng.range(1, MICRO_BATCH);
            let xs_own: Vec<Vec<i8>> = (0..n).map(|_| rng.i8_vec(rows, 127)).collect();
            let xs: Vec<&[i8]> = xs_own.iter().map(|v| v.as_slice()).collect();
            let mut s1 = Counters::new();
            let mut many = vec![i32::MIN; n * cols];
            pe.mvm_many_into(&xs, &mut many, &mut s1);
            let mut s2 = Counters::new();
            for (b, x) in xs.iter().enumerate() {
                let mut one = vec![0i32; cols];
                pe.mvm_scalar_into(x, &mut one, &mut s2);
                assert_eq!(&many[b * cols..(b + 1) * cols], &one[..]);
            }
            assert_eq!(s1, s2, "micro-batch must charge as n single MVMs");
        });
    }

    #[test]
    fn prop_mvm_matches_naive_dot() {
        for_all("pe_mvm_vs_naive", 30, |rng: &mut Rng| {
            let rows = rng.range(1, 64);
            let cols = rng.range(1, 64);
            let w = rng.i8_vec(rows * cols, 15);
            let x = rng.i8_vec(rows, 15);
            let pe = Pe::new(w.clone(), rows, cols);
            let out = pe.mvm(&x, &mut Counters::new());
            for m in 0..cols {
                let want: i32 = (0..rows)
                    .map(|c| x[c] as i32 * w[c * cols + m] as i32)
                    .sum();
                assert_eq!(out[m], want);
            }
        });
    }

    #[test]
    fn prop_mvm_is_linear() {
        for_all("pe_mvm_linear", 20, |rng: &mut Rng| {
            let rows = rng.range(1, 32);
            let cols = rng.range(1, 32);
            let pe = Pe::new(rng.i8_vec(rows * cols, 10), rows, cols);
            let a = rng.i8_vec(rows, 5);
            let b = rng.i8_vec(rows, 5);
            let sum: Vec<i8> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
            let mut s = Counters::new();
            let oa = pe.mvm(&a, &mut s);
            let ob = pe.mvm(&b, &mut s);
            let os = pe.mvm(&sum, &mut s);
            for m in 0..cols {
                assert_eq!(os[m], oa[m] + ob[m]);
            }
        });
    }
}
