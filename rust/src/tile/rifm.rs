//! The RIFM: router for Input Feature Maps (paper Section II-B).
//!
//! Each RIFM owns a 256 B buffer holding the current input beat, a
//! counter + controller that steer the stream, and three outgoing paths:
//! to the next tile's RIFM (stream forwarding), to the local PE (MAC
//! input), and a *shortcut* straight into the local ROFM (used when MAC
//! is skipped — the ResNet skip connection).
//!
//! The in-buffer shifting operation ("a step size of 64 or a multiple of
//! 128") maximises in-tile reuse for early layers whose channel count is
//! far below 256: several spatial positions share one 256 B beat, and the
//! PE consumes them by shifting the buffer rather than re-receiving.

use crate::sim::stats::Counters;

/// RIFM configuration decided by the compiler at mapping time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RifmConfig {
    /// How many channel values of each beat this tile's PE consumes.
    pub channels: usize,
    /// Whether the stream is forwarded to a next tile.
    pub forward: bool,
    /// Whether beats are also delivered to the ROFM via the shortcut
    /// (skip-connection source).
    pub shortcut: bool,
    /// In-buffer shift step (0 = no shifting; otherwise 64 or k*128 —
    /// enforced by [`Rifm::new_with_config`]).
    pub shift_step: usize,
}

impl Default for RifmConfig {
    fn default() -> Self {
        Self {
            channels: crate::consts::N_C,
            forward: true,
            shortcut: false,
            shift_step: 0,
        }
    }
}

/// One RIFM instance.
#[derive(Clone, Debug)]
pub struct Rifm {
    cfg: RifmConfig,
    /// Current buffered beat (≤ 256 i8 values = 256 B).
    buffer: Vec<i8>,
    /// Beats received since configuration (the paper's counter).
    pub counter: u64,
    /// Current shift offset within the buffer.
    shift_offset: usize,
}

impl Rifm {
    pub fn new(channels: usize) -> Self {
        Self::new_with_config(RifmConfig {
            channels,
            ..RifmConfig::default()
        })
    }

    pub fn new_with_config(cfg: RifmConfig) -> Self {
        assert!(
            cfg.channels <= crate::consts::RIFM_BUFFER_BYTES,
            "RIFM beat exceeds 256 B buffer"
        );
        assert!(
            cfg.shift_step == 0 || cfg.shift_step == 64 || cfg.shift_step % 128 == 0,
            "in-buffer shift step must be 64 or a multiple of 128 (got {})",
            cfg.shift_step
        );
        Self {
            cfg,
            buffer: Vec::new(),
            counter: 0,
            shift_offset: 0,
        }
    }

    pub fn config(&self) -> RifmConfig {
        self.cfg
    }

    /// Restore the configuration-time state (empty buffer, counter at
    /// zero, no shift offset). Used by the engine to reuse one RIFM
    /// instance across images. Performs no allocation: `Vec::clear`
    /// retains the buffer's capacity.
    pub fn reset(&mut self) {
        let cap = self.buffer.capacity();
        self.buffer.clear();
        debug_assert_eq!(self.buffer.capacity(), cap, "reset must retain capacity");
        self.counter = 0;
        self.shift_offset = 0;
    }

    /// Receive one beat into the buffer. Charges one buffer access and
    /// one active-controller step. Returns `true` if the beat should be
    /// forwarded to the next tile (the engine moves the actual packet and
    /// charges link energy).
    pub fn receive(&mut self, data: &[i8], stats: &mut Counters) -> bool {
        assert!(
            data.len() <= crate::consts::RIFM_BUFFER_BYTES,
            "RIFM beat exceeds 256 B buffer"
        );
        self.buffer.clear();
        self.buffer.extend_from_slice(data);
        self.shift_offset = 0;
        self.counter += 1;
        stats.rifm_buffer_accesses += 1; // write
        stats.rifm_ctrl_steps += 1;
        self.cfg.forward
    }

    /// The slice the PE consumes this step (after any shifting). Charges
    /// a buffer read.
    pub fn pe_view(&self, stats: &mut Counters) -> &[i8] {
        stats.rifm_buffer_accesses += 1; // read
        let start = self.shift_offset;
        let end = (start + self.cfg.channels).min(self.buffer.len());
        &self.buffer[start.min(self.buffer.len())..end]
    }

    /// Apply one in-buffer shift; returns `false` when the buffer is
    /// exhausted (no more positions to expose).
    pub fn shift(&mut self, stats: &mut Counters) -> bool {
        if self.cfg.shift_step == 0 {
            return false;
        }
        self.shift_offset += self.cfg.shift_step;
        stats.rifm_shifts += 1;
        self.shift_offset < self.buffer.len()
    }

    /// Whether the shortcut path to the ROFM is active.
    pub fn shortcut_active(&self) -> bool {
        self.cfg.shortcut
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn receive_buffers_and_counts() {
        let mut r = Rifm::new(4);
        let mut s = Counters::new();
        assert!(r.receive(&[1, 2, 3, 4], &mut s));
        assert_eq!(r.counter, 1);
        assert_eq!(s.rifm_buffer_accesses, 1);
        assert_eq!(r.pe_view(&mut s), &[1, 2, 3, 4]);
        assert_eq!(s.rifm_buffer_accesses, 2);
    }

    #[test]
    fn pe_view_respects_channel_count() {
        let mut r = Rifm::new(2);
        let mut s = Counters::new();
        r.receive(&[9, 8, 7, 6], &mut s);
        assert_eq!(r.pe_view(&mut s), &[9, 8]);
    }

    #[test]
    fn in_buffer_shift_walks_positions() {
        // 64-channel beats holding 4 spatial positions of a 64-channel
        // layer: shift step 64 exposes each in turn.
        let mut r = Rifm::new_with_config(RifmConfig {
            channels: 64,
            forward: false,
            shortcut: false,
            shift_step: 64,
        });
        let mut s = Counters::new();
        let beat: Vec<i8> = (0..256).map(|i| (i / 64) as i8).collect();
        assert!(!r.receive(&beat, &mut s));
        assert_eq!(r.pe_view(&mut s)[0], 0);
        assert!(r.shift(&mut s));
        assert_eq!(r.pe_view(&mut s)[0], 1);
        assert!(r.shift(&mut s));
        assert_eq!(r.pe_view(&mut s)[0], 2);
        assert!(r.shift(&mut s));
        assert_eq!(r.pe_view(&mut s)[0], 3);
        assert!(!r.shift(&mut s), "buffer exhausted after 4 positions");
        assert_eq!(s.rifm_shifts, 4);
    }

    #[test]
    #[should_panic(expected = "shift step must be 64 or a multiple of 128")]
    fn invalid_shift_step_rejected() {
        Rifm::new_with_config(RifmConfig {
            channels: 64,
            forward: false,
            shortcut: false,
            shift_step: 32,
        });
    }

    #[test]
    fn receive_resets_shift() {
        let mut r = Rifm::new_with_config(RifmConfig {
            channels: 64,
            forward: false,
            shortcut: false,
            shift_step: 64,
        });
        let mut s = Counters::new();
        r.receive(&vec![1i8; 256], &mut s);
        r.shift(&mut s);
        r.receive(&vec![2i8; 256], &mut s);
        assert_eq!(r.pe_view(&mut s)[0], 2);
        assert_eq!(r.pe_view(&mut s).len(), 64);
    }

    #[test]
    fn reset_restores_configuration_state() {
        let mut r = Rifm::new_with_config(RifmConfig {
            channels: 64,
            forward: false,
            shortcut: false,
            shift_step: 64,
        });
        let mut s = Counters::new();
        r.receive(&vec![7i8; 256], &mut s);
        r.shift(&mut s);
        r.reset();
        assert_eq!(r.counter, 0);
        assert!(r.pe_view(&mut s).is_empty(), "buffer cleared");
        // behaves like a fresh instance after reset
        r.receive(&[1, 2], &mut s);
        assert_eq!(r.counter, 1);
        assert_eq!(r.pe_view(&mut s), &[1, 2]);
    }

    #[test]
    #[should_panic(expected = "exceeds 256 B buffer")]
    fn oversized_beat_rejected() {
        let mut r = Rifm::new(256);
        r.receive(&vec![0i8; 257], &mut Counters::new());
    }
}
