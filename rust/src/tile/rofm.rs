//! The ROFM: router for Output Feature Maps and partial sums — "the key
//! component for COM dataflow" (paper Section II-C).
//!
//! Microarchitecture (Fig. 1(b)): four-direction I/O ports, input/output
//! registers, an instruction **schedule table** (16 b x 128) indexed by a
//! counter, a 16 KiB **data buffer** queueing group-sums, reusable
//! adders, and a computation unit implementing Table II's functions
//! (Add / Act / Cmp / Mul / Bp) plus explicit requantization.
//!
//! The engine (`sim::engine`) orchestrates which method runs in which
//! cycle according to the compiled schedule; every method charges its
//! architectural events so the energy model sees exactly what the
//! hardware would do.

use std::collections::VecDeque;

use crate::coordinator::isa::{Instr, Schedule};
use crate::model::refcompute::{clamp_i8, requant};
use crate::noc::packet::{PsumPacket, PsumRef};
use crate::sim::stats::Counters;

/// One ROFM instance.
///
/// The group-sum FIFO queues [`PsumRef`] handles: the lane values live
/// in the owning chain's `PsumArena` slab, so a push/pop moves a small
/// `Copy` header while the byte-occupancy model (the 16 KiB capacity
/// check) is tracked from the lane count passed at push time (§Perf).
#[derive(Clone, Debug)]
pub struct Rofm {
    /// The periodic instruction schedule written at configuration time.
    pub schedule: Schedule,
    /// Cycle counter generating instruction indices.
    pub counter: u64,
    /// Group-sum FIFO modelling the 16 KiB data buffer: (handle, lane
    /// count) — lanes are carried per entry for byte accounting.
    fifo: VecDeque<(PsumRef, u32)>,
    fifo_bytes: usize,
    peak_fifo_bytes: usize,
}

impl Rofm {
    pub fn new(schedule: Schedule) -> Self {
        Self {
            schedule,
            counter: 0,
            fifo: VecDeque::new(),
            fifo_bytes: 0,
            peak_fifo_bytes: 0,
        }
    }

    /// Restore the configuration-time state: counter at zero, FIFO
    /// empty. Used by the engine to reuse one ROFM instance across
    /// images (the schedule itself is immutable after configuration).
    /// Performs no allocation: `VecDeque::clear` retains the FIFO's
    /// grown capacity, so steady-state images never re-grow it.
    pub fn reset(&mut self) {
        let cap = self.fifo.capacity();
        self.fifo.clear();
        debug_assert_eq!(self.fifo.capacity(), cap, "reset must retain capacity");
        self.counter = 0;
        self.fifo_bytes = 0;
        self.peak_fifo_bytes = 0;
    }

    /// Fetch the instruction for the current cycle and advance the
    /// counter. Charges the schedule-table fetch (2.2 pJ/16 b) and an
    /// active-controller step.
    pub fn fetch(&mut self, stats: &mut Counters) -> Instr {
        let i = self.schedule.at(self.counter as usize);
        self.counter += 1;
        stats.sched_fetches += 1;
        stats.rofm_ctrl_steps += 1;
        i
    }

    /// Receive a beat through the input registers. The 64 b x 2
    /// double-buffer latches the head word of each beat while the
    /// 160 MHz FDM link serialises the payload; Table III prices one
    /// access of the structure per beat.
    pub fn charge_rx(_bits: u64, stats: &mut Counters) {
        stats.rofm_reg_accesses += 1;
    }

    /// Transmit a beat through the output registers.
    pub fn charge_tx(_bits: u64, stats: &mut Counters) {
        stats.rofm_reg_accesses += 1;
    }

    /// Add `incoming` into `acc` element-wise (the reusable adders).
    /// Both packets must target the same output position — a mismatch is
    /// a compiler/schedule bug, caught here.
    pub fn add_psum(acc: &mut PsumPacket, incoming: &PsumPacket, stats: &mut Counters) {
        assert_eq!(
            acc.opos, incoming.opos,
            "ROFM adder: partial sums for different outputs met (schedule misalignment)"
        );
        Self::add_psum_slices(&mut acc.data, &incoming.data, stats);
    }

    /// The adder datapath of [`Self::add_psum`] over raw lane slices —
    /// the engine's arena path (tags are checked by the engine before
    /// the lanes meet; this charges the adds). Blocked in fixed-width
    /// `chunks_exact` steps with a scalar remainder lane (§Perf);
    /// bit-exact — i32 adds are order-independent.
    pub fn add_psum_slices(acc: &mut [i32], incoming: &[i32], stats: &mut Counters) {
        assert_eq!(acc.len(), incoming.len(), "psum width mismatch");
        let mut ai = acc.chunks_exact_mut(VEC_CHUNK);
        let mut bi = incoming.chunks_exact(VEC_CHUNK);
        for (a, b) in ai.by_ref().zip(bi.by_ref()) {
            let a: &mut [i32; VEC_CHUNK] = a.try_into().unwrap();
            let b: &[i32; VEC_CHUNK] = b.try_into().unwrap();
            for l in 0..VEC_CHUNK {
                a[l] += b[l];
            }
        }
        for (a, b) in ai.into_remainder().iter_mut().zip(bi.remainder()) {
            *a += b;
        }
        // i32 adds = 4 x 8-bit adder-equivalents each (Table III prices
        // the adder per 8 b).
        stats.adds_8b += 4 * acc.len() as u64;
    }

    /// Push a group-sum handle into the data buffer (FIFO). `lanes` is
    /// the psum's lane count in the owning arena (byte accounting).
    pub fn push_group(&mut self, p: PsumRef, lanes: usize, stats: &mut Counters) {
        self.fifo_bytes += 4 * lanes;
        self.peak_fifo_bytes = self.peak_fifo_bytes.max(self.fifo_bytes);
        stats.rofm_buffer_accesses += 1;
        stats.peak_rofm_buffer_bytes = stats
            .peak_rofm_buffer_bytes
            .max(self.peak_fifo_bytes as u64);
        self.fifo.push_back((p, lanes as u32));
    }

    /// Pop the oldest group-sum handle.
    pub fn pop_group(&mut self, stats: &mut Counters) -> Option<PsumRef> {
        let (p, lanes) = self.fifo.pop_front()?;
        self.fifo_bytes -= 4 * lanes as usize;
        stats.rofm_buffer_accesses += 1;
        Some(p)
    }

    /// Front of the FIFO without popping (engine look-ahead).
    pub fn peek_group(&self) -> Option<&PsumRef> {
        self.fifo.front().map(|(p, _)| p)
    }

    pub fn fifo_len(&self) -> usize {
        self.fifo.len()
    }

    /// Peak buffer occupancy (bytes) for the 16 KiB capacity check.
    pub fn peak_fifo_bytes(&self) -> usize {
        self.peak_fifo_bytes
    }

    /// Whether this ROFM ever exceeded the hardware buffer (Table III:
    /// 16 KiB). Reported as a fidelity statistic, not a hard failure.
    pub fn exceeded_hw_buffer(&self) -> bool {
        self.peak_fifo_bytes > crate::consts::ROFM_BUFFER_BYTES
    }

    // ---- computation unit (Table II) ----

    /// `Act.`: requantize + ReLU a finished sum to i8 (non-linear
    /// function applied "in the last tile", Section III-B). Allocates;
    /// **hot-path callers should use [`Self::act_into`]** with reused
    /// scratch — this wrapper exists for tests and tools.
    pub fn act(sum: &[i32], shift: u32, stats: &mut Counters) -> Vec<i8> {
        let mut out = Vec::with_capacity(sum.len());
        Self::act_into(sum, shift, &mut out, stats);
        out
    }

    /// [`Self::act`] into reused caller scratch (cleared first) — the
    /// engine's zero-alloc emit path, blocked in `chunks_exact` steps.
    pub fn act_into(sum: &[i32], shift: u32, out: &mut Vec<i8>, stats: &mut Counters) {
        stats.act_ops_8b += sum.len() as u64;
        out.clear();
        out.resize(sum.len(), 0);
        requant_slice(sum, shift, true, out);
    }

    /// Requantize without activation (linear conv output, e.g. before a
    /// residual add). Allocates; hot-path callers should use
    /// [`Self::quantize_into`].
    pub fn quantize(sum: &[i32], shift: u32, stats: &mut Counters) -> Vec<i8> {
        let mut out = Vec::with_capacity(sum.len());
        Self::quantize_into(sum, shift, &mut out, stats);
        out
    }

    /// [`Self::quantize`] into reused caller scratch (cleared first),
    /// blocked in `chunks_exact` steps.
    pub fn quantize_into(sum: &[i32], shift: u32, out: &mut Vec<i8>, stats: &mut Counters) {
        stats.act_ops_8b += sum.len() as u64;
        out.clear();
        out.resize(sum.len(), 0);
        requant_slice(sum, shift, false, out);
    }

    /// `Cmp.`: element-wise max (max pooling step), blocked in
    /// `chunks_exact` steps with a scalar remainder lane.
    pub fn cmp_max(acc: &mut [i8], incoming: &[i8], stats: &mut Counters) {
        assert_eq!(acc.len(), incoming.len());
        stats.pool_ops_8b += acc.len() as u64;
        let mut ai = acc.chunks_exact_mut(VEC_CHUNK);
        let mut bi = incoming.chunks_exact(VEC_CHUNK);
        for (a, b) in ai.by_ref().zip(bi.by_ref()) {
            let a: &mut [i8; VEC_CHUNK] = a.try_into().unwrap();
            let b: &[i8; VEC_CHUNK] = b.try_into().unwrap();
            for l in 0..VEC_CHUNK {
                a[l] = a[l].max(b[l]);
            }
        }
        for (a, b) in ai.into_remainder().iter_mut().zip(bi.remainder()) {
            *a = (*a).max(*b);
        }
    }

    /// `Mul.`: scale by `1/divisor` with floor division (average
    /// pooling's "multiplication with a scaling factor"). Allocates;
    /// hot-path callers should use [`Self::mul_scale_into`].
    pub fn mul_scale(sum: &[i32], divisor: i32, stats: &mut Counters) -> Vec<i8> {
        let mut out = Vec::with_capacity(sum.len());
        Self::mul_scale_into(sum, divisor, &mut out, stats);
        out
    }

    /// [`Self::mul_scale`] into reused caller scratch (cleared first).
    pub fn mul_scale_into(sum: &[i32], divisor: i32, out: &mut Vec<i8>, stats: &mut Counters) {
        stats.pool_ops_8b += sum.len() as u64;
        out.clear();
        out.extend(sum.iter().map(|&v| clamp_i8(v.div_euclid(divisor))));
    }

    /// `Bp.`: direct transmission (skip connections). Only charges
    /// register traffic — no compute.
    pub fn bypass(data: &[i8], stats: &mut Counters) -> Vec<i8> {
        let mut out = Vec::with_capacity(data.len());
        Self::bypass_into(data, &mut out, stats);
        out
    }

    /// [`Self::bypass`] into reused caller scratch (cleared first).
    pub fn bypass_into(data: &[i8], out: &mut Vec<i8>, stats: &mut Counters) {
        Self::charge_tx(8 * data.len() as u64, stats);
        out.clear();
        out.extend_from_slice(data);
    }

    /// Residual add of two i8 streams (skip + main), ReLU fused —
    /// executed with the reusable adders + Act unit. Allocates;
    /// hot-path callers should use [`Self::res_add_into`].
    pub fn res_add(main: &[i8], skip: &[i8], stats: &mut Counters) -> Vec<i8> {
        let mut out = Vec::with_capacity(main.len());
        Self::res_add_into(main, skip, &mut out, stats);
        out
    }

    /// [`Self::res_add`] into reused caller scratch (cleared first;
    /// must not alias either input), blocked in `chunks_exact` steps
    /// with a scalar remainder lane.
    pub fn res_add_into(main: &[i8], skip: &[i8], out: &mut Vec<i8>, stats: &mut Counters) {
        assert_eq!(main.len(), skip.len());
        stats.adds_8b += main.len() as u64;
        stats.act_ops_8b += main.len() as u64;
        out.clear();
        out.resize(main.len(), 0);
        let mut ai = main.chunks_exact(VEC_CHUNK);
        let mut bi = skip.chunks_exact(VEC_CHUNK);
        let mut oi = out.chunks_exact_mut(VEC_CHUNK);
        for ((a, b), o) in ai.by_ref().zip(bi.by_ref()).zip(oi.by_ref()) {
            let a: &[i8; VEC_CHUNK] = a.try_into().unwrap();
            let b: &[i8; VEC_CHUNK] = b.try_into().unwrap();
            let o: &mut [i8; VEC_CHUNK] = o.try_into().unwrap();
            for l in 0..VEC_CHUNK {
                o[l] = crate::model::refcompute::res_add(a[l], b[l]);
            }
        }
        for ((a, b), o) in ai
            .remainder()
            .iter()
            .zip(bi.remainder())
            .zip(oi.into_remainder())
        {
            *o = crate::model::refcompute::res_add(*a, *b);
        }
    }
}

/// Fixed block width of the vectorized ROFM datapaths: wide enough to
/// fill a SIMD register file, small enough that the scalar remainder
/// lane stays cheap at the engine's narrow lane counts.
const VEC_CHUNK: usize = 16;

/// `out[i] = requant(sum[i], shift, relu)` blocked in [`VEC_CHUNK`]
/// steps with a scalar remainder lane. `relu` is a call-site constant
/// at both callers, so the branch is hoisted when inlined.
#[inline]
fn requant_slice(sum: &[i32], shift: u32, relu: bool, out: &mut [i8]) {
    let mut si = sum.chunks_exact(VEC_CHUNK);
    let mut oi = out.chunks_exact_mut(VEC_CHUNK);
    for (s, o) in si.by_ref().zip(oi.by_ref()) {
        let s: &[i32; VEC_CHUNK] = s.try_into().unwrap();
        let o: &mut [i8; VEC_CHUNK] = o.try_into().unwrap();
        for l in 0..VEC_CHUNK {
            o[l] = requant(s[l], shift, relu);
        }
    }
    for (s, o) in si.remainder().iter().zip(oi.into_remainder()) {
        *o = requant(*s, shift, relu);
    }
}

/// Pooling unit state for the *block reuse* scheme (paper Fig. 4(c)):
/// activation results are produced in the last tile; a comparison (or
/// accumulation, for average pooling) is taken as each new result
/// arrives, and a pooling result is emitted once its window completes.
///
/// The unit is built once per chain/stage and [`Self::reset`] between
/// images: window buffers are recycled through spare lists and the
/// window maps keep their capacity, so the steady-state pooling path
/// performs no allocation (§Perf).
#[derive(Clone, Debug, Default)]
pub struct PoolUnit {
    kernel: usize,
    stride: usize,
    /// In-flight windows keyed by output position.
    max_partial: std::collections::HashMap<(usize, usize), (Vec<i8>, usize)>,
    sum_partial: std::collections::HashMap<(usize, usize), (Vec<i32>, usize)>,
    /// Recycled window buffers (completed windows return theirs here).
    spare8: Vec<Vec<i8>>,
    spare32: Vec<Vec<i32>>,
    /// Reused output buffer for average-pool scaling.
    scaled: Vec<i8>,
    is_max: bool,
}

impl PoolUnit {
    pub fn new_max(kernel: usize, stride: usize) -> Self {
        Self {
            kernel,
            stride,
            is_max: true,
            ..Default::default()
        }
    }

    pub fn new_avg(kernel: usize, stride: usize) -> Self {
        Self {
            kernel,
            stride,
            is_max: false,
            ..Default::default()
        }
    }

    /// Restore the image-start state. In-flight window buffers are
    /// recycled (not dropped) and the maps keep their capacity, so a
    /// steady-state reset allocates nothing.
    pub fn reset(&mut self) {
        for (_, (b, _)) in self.max_partial.drain() {
            self.spare8.push(b);
        }
        for (_, (b, _)) in self.sum_partial.drain() {
            self.spare32.push(b);
        }
    }

    /// Offer one activation result at input position (y, x). Returns any
    /// completed pooling outputs `(opos, values)`. Allocates the result
    /// list; the engine's zero-alloc path is [`Self::offer_each`].
    pub fn offer(
        &mut self,
        pos: (usize, usize),
        values: &[i8],
        stats: &mut Counters,
    ) -> Vec<((usize, usize), Vec<i8>)> {
        let mut done = Vec::new();
        self.offer_each(pos, values, stats, |opos, v| done.push((opos, v.to_vec())));
        done
    }

    /// [`Self::offer`] with a completion callback instead of an
    /// allocated result list: `emit(opos, values)` is called for each
    /// window that completes, and the window's buffer is recycled
    /// afterwards.
    pub fn offer_each(
        &mut self,
        (y, x): (usize, usize),
        values: &[i8],
        stats: &mut Counters,
        mut emit: impl FnMut((usize, usize), &[i8]),
    ) {
        // Which windows does (y, x) belong to?
        let oy_min = y.saturating_sub(self.kernel - 1).div_ceil(self.stride);
        let ox_min = x.saturating_sub(self.kernel - 1).div_ceil(self.stride);
        let oy_max = y / self.stride;
        let ox_max = x / self.stride;
        for oy in oy_min..=oy_max {
            for ox in ox_min..=ox_max {
                // window (oy, ox) covers rows oy*s .. oy*s+k-1
                if y < oy * self.stride
                    || y >= oy * self.stride + self.kernel
                    || x < ox * self.stride
                    || x >= ox * self.stride + self.kernel
                {
                    continue;
                }
                let full = self.kernel * self.kernel;
                if self.is_max {
                    let spare8 = &mut self.spare8;
                    let entry = self.max_partial.entry((oy, ox)).or_insert_with(|| {
                        let mut b = spare8.pop().unwrap_or_default();
                        b.clear();
                        b.resize(values.len(), i8::MIN);
                        (b, 0)
                    });
                    Rofm::cmp_max(&mut entry.0, values, stats);
                    entry.1 += 1;
                    if entry.1 == full {
                        let (v, _) = self.max_partial.remove(&(oy, ox)).unwrap();
                        emit((oy, ox), &v);
                        self.spare8.push(v);
                    }
                } else {
                    let spare32 = &mut self.spare32;
                    let entry = self.sum_partial.entry((oy, ox)).or_insert_with(|| {
                        let mut b = spare32.pop().unwrap_or_default();
                        b.clear();
                        b.resize(values.len(), 0);
                        (b, 0)
                    });
                    // widening accumulate, blocked like the other
                    // datapaths (§Perf; bit-exact in any order)
                    let mut ai = entry.0.chunks_exact_mut(VEC_CHUNK);
                    let mut bi = values.chunks_exact(VEC_CHUNK);
                    for (a, b) in ai.by_ref().zip(bi.by_ref()) {
                        let a: &mut [i32; VEC_CHUNK] = a.try_into().unwrap();
                        let b: &[i8; VEC_CHUNK] = b.try_into().unwrap();
                        for l in 0..VEC_CHUNK {
                            a[l] += b[l] as i32;
                        }
                    }
                    for (a, &b) in ai.into_remainder().iter_mut().zip(bi.remainder()) {
                        *a += b as i32;
                    }
                    stats.adds_8b += values.len() as u64;
                    entry.1 += 1;
                    if entry.1 == full {
                        let (v, _) = self.sum_partial.remove(&(oy, ox)).unwrap();
                        Rofm::mul_scale_into(&v, full as i32, &mut self.scaled, stats);
                        emit((oy, ox), &self.scaled);
                        self.spare32.push(v);
                    }
                }
            }
        }
    }

    /// Number of in-flight (incomplete) windows — buffer-occupancy proxy.
    pub fn in_flight(&self) -> usize {
        self.max_partial.len() + self.sum_partial.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::for_all;

    fn pkt(opos: (usize, usize), data: Vec<i32>) -> PsumPacket {
        PsumPacket { opos, data }
    }

    #[test]
    fn fetch_walks_schedule_and_charges() {
        let mut r = Rofm::new(Schedule::idle());
        let mut s = Counters::new();
        let i = r.fetch(&mut s);
        assert!(i.is_nop());
        assert_eq!(r.counter, 1);
        assert_eq!(s.sched_fetches, 1);
        assert_eq!(s.rofm_ctrl_steps, 1);
    }

    #[test]
    fn add_psum_accumulates() {
        let mut s = Counters::new();
        let mut a = pkt((0, 0), vec![1, 2]);
        Rofm::add_psum(&mut a, &pkt((0, 0), vec![10, 20]), &mut s);
        assert_eq!(a.data, vec![11, 22]);
        assert_eq!(s.adds_8b, 8);
    }

    #[test]
    #[should_panic(expected = "schedule misalignment")]
    fn add_psum_rejects_mismatched_outputs() {
        let mut a = pkt((0, 0), vec![1]);
        Rofm::add_psum(&mut a, &pkt((0, 1), vec![1]), &mut Counters::new());
    }

    fn pref(opos: (usize, usize), slot: u32) -> PsumRef {
        PsumRef { opos, slot }
    }

    #[test]
    fn fifo_tracks_occupancy_and_peak() {
        let mut r = Rofm::new(Schedule::idle());
        let mut s = Counters::new();
        r.push_group(pref((0, 0), 0), 8, &mut s);
        r.push_group(pref((0, 1), 1), 8, &mut s);
        assert_eq!(r.fifo_len(), 2);
        assert_eq!(r.peak_fifo_bytes(), 64);
        assert_eq!(r.peek_group().unwrap().opos, (0, 0));
        let p = r.pop_group(&mut s).unwrap();
        assert_eq!(p.opos, (0, 0), "FIFO order");
        assert_eq!(p.slot, 0);
        assert_eq!(r.peak_fifo_bytes(), 64, "peak is sticky");
        assert_eq!(s.rofm_buffer_accesses, 3);
        assert_eq!(s.peak_rofm_buffer_bytes, 64);
        assert!(!r.exceeded_hw_buffer());
    }

    #[test]
    fn hw_buffer_overflow_detected() {
        let mut r = Rofm::new(Schedule::idle());
        let mut s = Counters::new();
        // 17 pushes x 256 lanes x 4 B = 17 KiB > 16 KiB
        for i in 0..17 {
            r.push_group(pref((0, i), i as u32), 256, &mut s);
        }
        assert!(r.exceeded_hw_buffer());
    }

    #[test]
    fn reset_retains_fifo_capacity_and_clears_occupancy() {
        let mut r = Rofm::new(Schedule::idle());
        let mut s = Counters::new();
        for i in 0..8 {
            r.push_group(pref((0, i), i as u32), 4, &mut s);
        }
        r.reset();
        assert_eq!(r.fifo_len(), 0);
        assert_eq!(r.peak_fifo_bytes(), 0);
        assert_eq!(r.counter, 0);
        // usable again after reset
        r.push_group(pref((1, 0), 9), 4, &mut s);
        assert_eq!(r.pop_group(&mut s).unwrap().slot, 9);
    }

    #[test]
    fn act_and_quantize_semantics() {
        let mut s = Counters::new();
        assert_eq!(Rofm::act(&[-256, 256, 100000], 7, &mut s), vec![0, 2, 127]);
        assert_eq!(
            Rofm::quantize(&[-256, 256, -100000], 7, &mut s),
            vec![-2, 2, -128]
        );
        assert_eq!(s.act_ops_8b, 6);
    }

    #[test]
    fn cmp_and_mul_semantics() {
        let mut s = Counters::new();
        let mut acc = vec![1i8, -5, 7];
        Rofm::cmp_max(&mut acc, &[2, -9, 7], &mut s);
        assert_eq!(acc, vec![2, -5, 7]);
        // floor(-3/4) = -1
        assert_eq!(Rofm::mul_scale(&[-3, 9], 4, &mut s), vec![-1, 2]);
        assert_eq!(s.pool_ops_8b, 5);
    }

    #[test]
    fn res_add_fuses_relu() {
        let mut s = Counters::new();
        assert_eq!(Rofm::res_add(&[100, -3], &[100, 1], &mut s), vec![127, 0]);
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        // Each scratch-writing variant must produce the same bytes and
        // charge the same counters as its allocating wrapper, and must
        // fully overwrite dirty scratch.
        let mut buf8 = vec![99i8; 7];
        let mut s1 = Counters::new();
        let mut s2 = Counters::new();
        Rofm::act_into(&[-256, 256, 100000], 7, &mut buf8, &mut s1);
        assert_eq!(buf8, Rofm::act(&[-256, 256, 100000], 7, &mut s2));
        Rofm::quantize_into(&[-256, 256, -100000], 7, &mut buf8, &mut s1);
        assert_eq!(buf8, Rofm::quantize(&[-256, 256, -100000], 7, &mut s2));
        Rofm::mul_scale_into(&[-3, 9], 4, &mut buf8, &mut s1);
        assert_eq!(buf8, Rofm::mul_scale(&[-3, 9], 4, &mut s2));
        Rofm::bypass_into(&[1, 2, 3], &mut buf8, &mut s1);
        assert_eq!(buf8, Rofm::bypass(&[1, 2, 3], &mut s2));
        Rofm::res_add_into(&[100, -3], &[100, 1], &mut buf8, &mut s1);
        assert_eq!(buf8, Rofm::res_add(&[100, -3], &[100, 1], &mut s2));
        assert_eq!(s1, s2, "scratch variants must charge identically");
    }

    #[test]
    fn pool_unit_reset_recycles_buffers_and_stays_correct() {
        use crate::model::refcompute::{max_pool, Tensor};
        use crate::model::TensorShape;
        let mut rng = crate::testutil::Rng::new(11);
        let mut unit = PoolUnit::new_max(2, 2);
        let mut s = Counters::new();
        for _ in 0..3 {
            let data = rng.i8_vec(16, 100);
            let t = Tensor::new(TensorShape::new(1, 4, 4), data);
            let want = max_pool(&t, 2, 2);
            let mut got = vec![0i8; 4];
            for y in 0..4 {
                for x in 0..4 {
                    unit.offer_each((y, x), &[t.at(0, y, x)], &mut s, |(oy, ox), v| {
                        got[oy * 2 + ox] = v[0];
                    });
                }
            }
            assert_eq!(got, want.data);
            assert_eq!(unit.in_flight(), 0);
            unit.reset();
        }
    }

    #[test]
    fn pool_unit_max_2x2_matches_reference() {
        // Stream a 4x4 single-channel map through the unit in raster
        // order; compare against refcompute::max_pool.
        use crate::model::refcompute::{max_pool, Tensor};
        use crate::model::TensorShape;
        let mut rng = crate::testutil::Rng::new(5);
        let data = rng.i8_vec(16, 100);
        let t = Tensor::new(TensorShape::new(1, 4, 4), data.clone());
        let want = max_pool(&t, 2, 2);
        let mut unit = PoolUnit::new_max(2, 2);
        let mut s = Counters::new();
        let mut got = vec![0i8; 4];
        for y in 0..4 {
            for x in 0..4 {
                for ((oy, ox), v) in unit.offer((y, x), &[t.at(0, y, x)], &mut s) {
                    got[oy * 2 + ox] = v[0];
                }
            }
        }
        assert_eq!(got, want.data);
        assert_eq!(unit.in_flight(), 0);
    }

    #[test]
    fn prop_pool_unit_avg_matches_reference() {
        use crate::model::refcompute::{avg_pool, Tensor};
        use crate::model::TensorShape;
        for_all("pool_unit_avg", 20, |rng| {
            let k = rng.range(2, 3);
            let stride = k; // non-overlapping (the paper's case)
            let out = rng.range(1, 4);
            let n = out * stride;
            let c = rng.range(1, 3);
            let data = rng.i8_vec(c * n * n, 50);
            let t = Tensor::new(TensorShape::new(c, n, n), data);
            let want = avg_pool(&t, k, stride);
            let mut unit = PoolUnit::new_avg(k, stride);
            let mut s = Counters::new();
            let mut got = Tensor::zeros(want.shape);
            for y in 0..n {
                for x in 0..n {
                    let vals: Vec<i8> = (0..c).map(|ch| t.at(ch, y, x)).collect();
                    for ((oy, ox), v) in unit.offer((y, x), &vals, &mut s) {
                        for (ch, &vv) in v.iter().enumerate() {
                            got.set(ch, oy, ox, vv);
                        }
                    }
                }
            }
            assert_eq!(got.data, want.data);
        });
    }

    #[test]
    fn prop_pool_unit_overlapping_windows() {
        // kernel 3 stride 2 (overlapping) still matches the reference.
        use crate::model::refcompute::{max_pool, Tensor};
        use crate::model::TensorShape;
        for_all("pool_unit_overlap", 10, |rng| {
            let n = 5; // output = 2x2 for k=3 s=2
            let data = rng.i8_vec(n * n, 100);
            let t = Tensor::new(TensorShape::new(1, n, n), data);
            let want = max_pool(&t, 3, 2);
            let mut unit = PoolUnit::new_max(3, 2);
            let mut s = Counters::new();
            let mut got = Tensor::zeros(want.shape);
            for y in 0..n {
                for x in 0..n {
                    for ((oy, ox), v) in unit.offer((y, x), &[t.at(0, y, x)], &mut s) {
                        got.set(0, oy, ox, v[0]);
                    }
                }
            }
            assert_eq!(got.data, want.data);
        });
    }
}
