//! The ROFM: router for Output Feature Maps and partial sums — "the key
//! component for COM dataflow" (paper Section II-C).
//!
//! Microarchitecture (Fig. 1(b)): four-direction I/O ports, input/output
//! registers, an instruction **schedule table** (16 b x 128) indexed by a
//! counter, a 16 KiB **data buffer** queueing group-sums, reusable
//! adders, and a computation unit implementing Table II's functions
//! (Add / Act / Cmp / Mul / Bp) plus explicit requantization.
//!
//! The engine (`sim::engine`) orchestrates which method runs in which
//! cycle according to the compiled schedule; every method charges its
//! architectural events so the energy model sees exactly what the
//! hardware would do.

use std::collections::VecDeque;

use crate::coordinator::isa::{Instr, Schedule};
use crate::model::refcompute::{clamp_i8, requant};
use crate::noc::packet::PsumPacket;
use crate::sim::stats::Counters;

/// One ROFM instance.
#[derive(Clone, Debug)]
pub struct Rofm {
    /// The periodic instruction schedule written at configuration time.
    pub schedule: Schedule,
    /// Cycle counter generating instruction indices.
    pub counter: u64,
    /// Group-sum FIFO modelling the 16 KiB data buffer.
    fifo: VecDeque<PsumPacket>,
    fifo_bytes: usize,
    peak_fifo_bytes: usize,
}

impl Rofm {
    pub fn new(schedule: Schedule) -> Self {
        Self {
            schedule,
            counter: 0,
            fifo: VecDeque::new(),
            fifo_bytes: 0,
            peak_fifo_bytes: 0,
        }
    }

    /// Restore the configuration-time state: counter at zero, FIFO
    /// empty. Used by the engine to reuse one ROFM instance across
    /// images (the schedule itself is immutable after configuration).
    pub fn reset(&mut self) {
        self.counter = 0;
        self.fifo.clear();
        self.fifo_bytes = 0;
        self.peak_fifo_bytes = 0;
    }

    /// Fetch the instruction for the current cycle and advance the
    /// counter. Charges the schedule-table fetch (2.2 pJ/16 b) and an
    /// active-controller step.
    pub fn fetch(&mut self, stats: &mut Counters) -> Instr {
        let i = self.schedule.at(self.counter as usize);
        self.counter += 1;
        stats.sched_fetches += 1;
        stats.rofm_ctrl_steps += 1;
        i
    }

    /// Receive a beat through the input registers. The 64 b x 2
    /// double-buffer latches the head word of each beat while the
    /// 160 MHz FDM link serialises the payload; Table III prices one
    /// access of the structure per beat.
    pub fn charge_rx(_bits: u64, stats: &mut Counters) {
        stats.rofm_reg_accesses += 1;
    }

    /// Transmit a beat through the output registers.
    pub fn charge_tx(_bits: u64, stats: &mut Counters) {
        stats.rofm_reg_accesses += 1;
    }

    /// Add `incoming` into `acc` element-wise (the reusable adders).
    /// Both packets must target the same output position — a mismatch is
    /// a compiler/schedule bug, caught here.
    pub fn add_psum(acc: &mut PsumPacket, incoming: &PsumPacket, stats: &mut Counters) {
        assert_eq!(
            acc.opos, incoming.opos,
            "ROFM adder: partial sums for different outputs met (schedule misalignment)"
        );
        assert_eq!(acc.data.len(), incoming.data.len(), "psum width mismatch");
        for (a, b) in acc.data.iter_mut().zip(incoming.data.iter()) {
            *a += b;
        }
        // i32 adds = 4 x 8-bit adder-equivalents each (Table III prices
        // the adder per 8 b).
        stats.adds_8b += 4 * acc.data.len() as u64;
    }

    /// Push a group-sum into the data buffer (FIFO).
    pub fn push_group(&mut self, p: PsumPacket, stats: &mut Counters) {
        self.fifo_bytes += 4 * p.data.len();
        self.peak_fifo_bytes = self.peak_fifo_bytes.max(self.fifo_bytes);
        stats.rofm_buffer_accesses += 1;
        stats.peak_rofm_buffer_bytes = stats
            .peak_rofm_buffer_bytes
            .max(self.peak_fifo_bytes as u64);
        self.fifo.push_back(p);
    }

    /// Pop the oldest group-sum.
    pub fn pop_group(&mut self, stats: &mut Counters) -> Option<PsumPacket> {
        let p = self.fifo.pop_front()?;
        self.fifo_bytes -= 4 * p.data.len();
        stats.rofm_buffer_accesses += 1;
        Some(p)
    }

    /// Front of the FIFO without popping (engine look-ahead).
    pub fn peek_group(&self) -> Option<&PsumPacket> {
        self.fifo.front()
    }

    pub fn fifo_len(&self) -> usize {
        self.fifo.len()
    }

    /// Peak buffer occupancy (bytes) for the 16 KiB capacity check.
    pub fn peak_fifo_bytes(&self) -> usize {
        self.peak_fifo_bytes
    }

    /// Whether this ROFM ever exceeded the hardware buffer (Table III:
    /// 16 KiB). Reported as a fidelity statistic, not a hard failure.
    pub fn exceeded_hw_buffer(&self) -> bool {
        self.peak_fifo_bytes > crate::consts::ROFM_BUFFER_BYTES
    }

    // ---- computation unit (Table II) ----

    /// `Act.`: requantize + ReLU a finished sum to i8 (non-linear
    /// function applied "in the last tile", Section III-B).
    pub fn act(sum: &[i32], shift: u32, stats: &mut Counters) -> Vec<i8> {
        stats.act_ops_8b += sum.len() as u64;
        sum.iter().map(|&v| requant(v, shift, true)).collect()
    }

    /// Requantize without activation (linear conv output, e.g. before a
    /// residual add).
    pub fn quantize(sum: &[i32], shift: u32, stats: &mut Counters) -> Vec<i8> {
        stats.act_ops_8b += sum.len() as u64;
        sum.iter().map(|&v| requant(v, shift, false)).collect()
    }

    /// `Cmp.`: element-wise max (max pooling step).
    pub fn cmp_max(acc: &mut [i8], incoming: &[i8], stats: &mut Counters) {
        assert_eq!(acc.len(), incoming.len());
        stats.pool_ops_8b += acc.len() as u64;
        for (a, b) in acc.iter_mut().zip(incoming.iter()) {
            *a = (*a).max(*b);
        }
    }

    /// `Mul.`: scale by `1/divisor` with floor division (average
    /// pooling's "multiplication with a scaling factor").
    pub fn mul_scale(sum: &[i32], divisor: i32, stats: &mut Counters) -> Vec<i8> {
        stats.pool_ops_8b += sum.len() as u64;
        sum.iter()
            .map(|&v| clamp_i8(v.div_euclid(divisor)))
            .collect()
    }

    /// `Bp.`: direct transmission (skip connections). Only charges
    /// register traffic — no compute.
    pub fn bypass(data: &[i8], stats: &mut Counters) -> Vec<i8> {
        Self::charge_tx(8 * data.len() as u64, stats);
        data.to_vec()
    }

    /// Residual add of two i8 streams (skip + main), ReLU fused —
    /// executed with the reusable adders + Act unit.
    pub fn res_add(main: &[i8], skip: &[i8], stats: &mut Counters) -> Vec<i8> {
        assert_eq!(main.len(), skip.len());
        stats.adds_8b += main.len() as u64;
        stats.act_ops_8b += main.len() as u64;
        main.iter()
            .zip(skip.iter())
            .map(|(&a, &b)| crate::model::refcompute::res_add(a, b))
            .collect()
    }
}

/// Pooling unit state for the *block reuse* scheme (paper Fig. 4(c)):
/// activation results are produced in the last tile; a comparison (or
/// accumulation, for average pooling) is taken as each new result
/// arrives, and a pooling result is emitted once its window completes.
#[derive(Clone, Debug)]
pub struct PoolUnit {
    kernel: usize,
    stride: usize,
    /// In-flight windows keyed by output position.
    max_partial: std::collections::HashMap<(usize, usize), (Vec<i8>, usize)>,
    sum_partial: std::collections::HashMap<(usize, usize), (Vec<i32>, usize)>,
    is_max: bool,
}

impl PoolUnit {
    pub fn new_max(kernel: usize, stride: usize) -> Self {
        Self {
            kernel,
            stride,
            max_partial: Default::default(),
            sum_partial: Default::default(),
            is_max: true,
        }
    }

    pub fn new_avg(kernel: usize, stride: usize) -> Self {
        Self {
            kernel,
            stride,
            max_partial: Default::default(),
            sum_partial: Default::default(),
            is_max: false,
        }
    }

    /// Offer one activation result at input position (y, x). Returns any
    /// completed pooling outputs `(opos, values)`.
    pub fn offer(
        &mut self,
        (y, x): (usize, usize),
        values: &[i8],
        stats: &mut Counters,
    ) -> Vec<((usize, usize), Vec<i8>)> {
        let mut done = Vec::new();
        // Which windows does (y, x) belong to?
        let oy_min = y.saturating_sub(self.kernel - 1).div_ceil(self.stride);
        let ox_min = x.saturating_sub(self.kernel - 1).div_ceil(self.stride);
        let oy_max = y / self.stride;
        let ox_max = x / self.stride;
        for oy in oy_min..=oy_max {
            for ox in ox_min..=ox_max {
                // window (oy, ox) covers rows oy*s .. oy*s+k-1
                if y < oy * self.stride
                    || y >= oy * self.stride + self.kernel
                    || x < ox * self.stride
                    || x >= ox * self.stride + self.kernel
                {
                    continue;
                }
                let full = self.kernel * self.kernel;
                if self.is_max {
                    let entry = self
                        .max_partial
                        .entry((oy, ox))
                        .or_insert_with(|| (vec![i8::MIN; values.len()], 0));
                    let mut buf = std::mem::take(&mut entry.0);
                    Rofm::cmp_max(&mut buf, values, stats);
                    entry.0 = buf;
                    entry.1 += 1;
                    if entry.1 == full {
                        let (v, _) = self.max_partial.remove(&(oy, ox)).unwrap();
                        done.push(((oy, ox), v));
                    }
                } else {
                    let entry = self
                        .sum_partial
                        .entry((oy, ox))
                        .or_insert_with(|| (vec![0i32; values.len()], 0));
                    for (a, &b) in entry.0.iter_mut().zip(values.iter()) {
                        *a += b as i32;
                    }
                    stats.adds_8b += values.len() as u64;
                    entry.1 += 1;
                    if entry.1 == full {
                        let (v, _) = self.sum_partial.remove(&(oy, ox)).unwrap();
                        let scaled = Rofm::mul_scale(&v, full as i32, stats);
                        done.push(((oy, ox), scaled));
                    }
                }
            }
        }
        done
    }

    /// Number of in-flight (incomplete) windows — buffer-occupancy proxy.
    pub fn in_flight(&self) -> usize {
        self.max_partial.len() + self.sum_partial.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::for_all;

    fn pkt(opos: (usize, usize), data: Vec<i32>) -> PsumPacket {
        PsumPacket { opos, data }
    }

    #[test]
    fn fetch_walks_schedule_and_charges() {
        let mut r = Rofm::new(Schedule::idle());
        let mut s = Counters::new();
        let i = r.fetch(&mut s);
        assert!(i.is_nop());
        assert_eq!(r.counter, 1);
        assert_eq!(s.sched_fetches, 1);
        assert_eq!(s.rofm_ctrl_steps, 1);
    }

    #[test]
    fn add_psum_accumulates() {
        let mut s = Counters::new();
        let mut a = pkt((0, 0), vec![1, 2]);
        Rofm::add_psum(&mut a, &pkt((0, 0), vec![10, 20]), &mut s);
        assert_eq!(a.data, vec![11, 22]);
        assert_eq!(s.adds_8b, 8);
    }

    #[test]
    #[should_panic(expected = "schedule misalignment")]
    fn add_psum_rejects_mismatched_outputs() {
        let mut a = pkt((0, 0), vec![1]);
        Rofm::add_psum(&mut a, &pkt((0, 1), vec![1]), &mut Counters::new());
    }

    #[test]
    fn fifo_tracks_occupancy_and_peak() {
        let mut r = Rofm::new(Schedule::idle());
        let mut s = Counters::new();
        r.push_group(pkt((0, 0), vec![0; 8]), &mut s);
        r.push_group(pkt((0, 1), vec![0; 8]), &mut s);
        assert_eq!(r.fifo_len(), 2);
        assert_eq!(r.peak_fifo_bytes(), 64);
        let p = r.pop_group(&mut s).unwrap();
        assert_eq!(p.opos, (0, 0), "FIFO order");
        assert_eq!(r.peak_fifo_bytes(), 64, "peak is sticky");
        assert_eq!(s.rofm_buffer_accesses, 3);
        assert_eq!(s.peak_rofm_buffer_bytes, 64);
        assert!(!r.exceeded_hw_buffer());
    }

    #[test]
    fn hw_buffer_overflow_detected() {
        let mut r = Rofm::new(Schedule::idle());
        let mut s = Counters::new();
        // 17 pushes x 256 lanes x 4 B = 17 KiB > 16 KiB
        for i in 0..17 {
            r.push_group(pkt((0, i), vec![0; 256]), &mut s);
        }
        assert!(r.exceeded_hw_buffer());
    }

    #[test]
    fn act_and_quantize_semantics() {
        let mut s = Counters::new();
        assert_eq!(Rofm::act(&[-256, 256, 100000], 7, &mut s), vec![0, 2, 127]);
        assert_eq!(
            Rofm::quantize(&[-256, 256, -100000], 7, &mut s),
            vec![-2, 2, -128]
        );
        assert_eq!(s.act_ops_8b, 6);
    }

    #[test]
    fn cmp_and_mul_semantics() {
        let mut s = Counters::new();
        let mut acc = vec![1i8, -5, 7];
        Rofm::cmp_max(&mut acc, &[2, -9, 7], &mut s);
        assert_eq!(acc, vec![2, -5, 7]);
        // floor(-3/4) = -1
        assert_eq!(Rofm::mul_scale(&[-3, 9], 4, &mut s), vec![-1, 2]);
        assert_eq!(s.pool_ops_8b, 5);
    }

    #[test]
    fn res_add_fuses_relu() {
        let mut s = Counters::new();
        assert_eq!(Rofm::res_add(&[100, -3], &[100, 1], &mut s), vec![127, 0]);
    }

    #[test]
    fn pool_unit_max_2x2_matches_reference() {
        // Stream a 4x4 single-channel map through the unit in raster
        // order; compare against refcompute::max_pool.
        use crate::model::refcompute::{max_pool, Tensor};
        use crate::model::TensorShape;
        let mut rng = crate::testutil::Rng::new(5);
        let data = rng.i8_vec(16, 100);
        let t = Tensor::new(TensorShape::new(1, 4, 4), data.clone());
        let want = max_pool(&t, 2, 2);
        let mut unit = PoolUnit::new_max(2, 2);
        let mut s = Counters::new();
        let mut got = vec![0i8; 4];
        for y in 0..4 {
            for x in 0..4 {
                for ((oy, ox), v) in unit.offer((y, x), &[t.at(0, y, x)], &mut s) {
                    got[oy * 2 + ox] = v[0];
                }
            }
        }
        assert_eq!(got, want.data);
        assert_eq!(unit.in_flight(), 0);
    }

    #[test]
    fn prop_pool_unit_avg_matches_reference() {
        use crate::model::refcompute::{avg_pool, Tensor};
        use crate::model::TensorShape;
        for_all("pool_unit_avg", 20, |rng| {
            let k = rng.range(2, 3);
            let stride = k; // non-overlapping (the paper's case)
            let out = rng.range(1, 4);
            let n = out * stride;
            let c = rng.range(1, 3);
            let data = rng.i8_vec(c * n * n, 50);
            let t = Tensor::new(TensorShape::new(c, n, n), data);
            let want = avg_pool(&t, k, stride);
            let mut unit = PoolUnit::new_avg(k, stride);
            let mut s = Counters::new();
            let mut got = Tensor::zeros(want.shape);
            for y in 0..n {
                for x in 0..n {
                    let vals: Vec<i8> = (0..c).map(|ch| t.at(ch, y, x)).collect();
                    for ((oy, ox), v) in unit.offer((y, x), &vals, &mut s) {
                        for (ch, &vv) in v.iter().enumerate() {
                            got.set(ch, oy, ox, vv);
                        }
                    }
                }
            }
            assert_eq!(got.data, want.data);
        });
    }

    #[test]
    fn prop_pool_unit_overlapping_windows() {
        // kernel 3 stride 2 (overlapping) still matches the reference.
        use crate::model::refcompute::{max_pool, Tensor};
        use crate::model::TensorShape;
        for_all("pool_unit_overlap", 10, |rng| {
            let n = 5; // output = 2x2 for k=3 s=2
            let data = rng.i8_vec(n * n, 100);
            let t = Tensor::new(TensorShape::new(1, n, n), data);
            let want = max_pool(&t, 3, 2);
            let mut unit = PoolUnit::new_max(3, 2);
            let mut s = Counters::new();
            let mut got = Tensor::zeros(want.shape);
            for y in 0..n {
                for x in 0..n {
                    for ((oy, ox), v) in unit.offer((y, x), &[t.at(0, y, x)], &mut s) {
                        got.set(0, oy, ox, v[0]);
                    }
                }
            }
            assert_eq!(got.data, want.data);
        });
    }
}
