//! Closed-form layer-level performance/event model.
//!
//! The cycle simulator is exact but costs O(MACs) per image — fine for
//! validation networks, prohibitive for VGG-16 at 224x224 x thousands of
//! images. This module computes the *same* event counters analytically
//! (the engine's loops have closed forms) plus the pipelined timing the
//! paper's Table IV execution times are built on:
//!
//! * **latency** (one image, layers back-to-back) = Σ stage busy slots —
//!   matches `Simulator::run_image` exactly;
//! * **pipeline period** = the slowest stage's busy slots — with every
//!   layer's tile array streaming concurrently ("layer synchronization",
//!   Section IV-B-2), a new image enters every period;
//! * **throughput** = STEP_HZ / (period x 2 cycles/slot).
//!
//! `validated_against_engine` in the tests (and the
//! `perfmodel_validation` bench, experiment A3) assert exact counter
//! equality on small networks, so extrapolation to Table IV sizes is a
//! matter of arithmetic, not modeling error.

use anyhow::Result;

use crate::coordinator::program::*;
use crate::coordinator::schedule::{ConvGeometry, CYCLES_PER_SLOT};
use crate::sim::stats::Counters;

/// Analytic result for one stage.
#[derive(Clone, Debug)]
pub struct StageEstimate {
    pub name: String,
    /// Busy pixel slots per image (latency: includes chain fill).
    pub slots: u64,
    /// Steady-state pipeline period in pixel slots: with consecutive
    /// images streaming back-to-back the chain never drains, so the
    /// image period excludes the fill term.
    pub period_slots: u64,
    /// Event counters per image.
    pub counters: Counters,
    pub tiles: usize,
}

/// Analytic result for a whole network.
#[derive(Clone, Debug)]
pub struct NetworkEstimate {
    pub stages: Vec<StageEstimate>,
    /// Per-image counters (all stages merged).
    pub counters: Counters,
    /// One-image latency in cycles (stages back-to-back).
    pub latency_cycles: u64,
    /// Pipeline period in cycles (slowest stage).
    pub period_cycles: u64,
    pub total_tiles: usize,
    pub chips: usize,
}

impl NetworkEstimate {
    /// One-image latency in seconds.
    pub fn latency_s(&self) -> f64 {
        self.latency_cycles as f64 / crate::consts::STEP_HZ
    }

    /// Pipelined throughput in images per second (0 for a degenerate
    /// zero-cycle period instead of dividing by zero).
    pub fn images_per_s(&self) -> f64 {
        crate::sim::pipeline::images_per_s_for_period(self.period_cycles)
    }

    /// Paper's per-core inference speed (images/s/CIM core); 0 when no
    /// tiles were allocated.
    pub fn images_per_s_per_core(&self) -> f64 {
        crate::sim::stats::safe_rate(self.images_per_s(), self.total_tiles as f64)
    }
}

/// Estimate a compiled program analytically.
pub fn estimate(program: &Program) -> Result<NetworkEstimate> {
    let mut stages = Vec::new();
    let mut total = Counters::new();
    let mut latency_slots: u64 = 0;
    let mut period_slots: u64 = 0;

    // package I/O (mirrors engine)
    total.offchip_io_bits += 8 * program.net.input_len() as u64;
    let out_shape = program.net.output_shape()?;
    total.offchip_io_bits += 8 * out_shape.len() as u64;

    let mut prev_exit_chip: Option<usize> = None;
    let mut cur_shape = program.net.input;

    for stage in &program.stages {
        let mut st = Counters::new();
        let mut period = None; // set where it differs from `slots`
        let slots = match &stage.kind {
            StageKind::Conv(c) => {
                let s = conv_counters(c, &mut st);
                period = Some(conv_period_slots(c));
                cur_shape = match c.fused_pool {
                    Some(p) => crate::model::TensorShape::new(
                        c.out_shape.c,
                        (c.out_shape.h - p.kernel) / p.stride + 1,
                        (c.out_shape.w - p.kernel) / p.stride + 1,
                    ),
                    None => c.out_shape,
                };
                s
            }
            StageKind::Fc(f) => {
                let s = fc_counters(f, program.arch.n_c, &mut st);
                cur_shape = crate::model::TensorShape::new(f.out_features, 1, 1);
                s
            }
            StageKind::Pool(p) => {
                let s = pool_counters(p, &mut st);
                cur_shape = p.out_shape;
                s
            }
            StageKind::Res(r) => {
                let mut s = 0;
                let mut per = 0;
                if let Some(proj) = &r.proj {
                    s += conv_counters(proj, &mut st);
                    per = per.max(conv_period_slots(proj));
                }
                s += res_counters(r, &mut st);
                per = per.max(res_period_slots(r));
                period = Some(per);
                cur_shape = r.shape;
                s
            }
            StageKind::Flatten => {
                cur_shape = crate::model::TensorShape::new(cur_shape.len(), 1, 1);
                0
            }
        };

        // stage hand-off across chips (mirrors engine)
        let entry = entry_chip(stage);
        if let (Some(prev), Some(this)) = (prev_exit_chip, entry) {
            if prev != this {
                st.interchip_bits += 8 * cur_shape.len() as u64;
            }
        }
        prev_exit_chip = exit_chip(stage).or(prev_exit_chip);

        let stage_period = period.unwrap_or(slots);
        st.steps = slots * CYCLES_PER_SLOT as u64;
        st.tiles_used = stage.tile_count() as u64;
        latency_slots += slots;
        period_slots = period_slots.max(stage_period);
        total.merge(&st);
        stages.push(StageEstimate {
            name: stage.name.clone(),
            slots,
            period_slots: stage_period,
            counters: st,
            tiles: stage.tile_count(),
        });
    }

    Ok(NetworkEstimate {
        stages,
        counters: total,
        latency_cycles: latency_slots * CYCLES_PER_SLOT as u64,
        period_cycles: (period_slots * CYCLES_PER_SLOT as u64).max(1),
        total_tiles: program.total_tiles,
        chips: program.chips,
    })
}

fn entry_chip(stage: &Stage) -> Option<usize> {
    match &stage.kind {
        StageKind::Conv(c) => c.chains.first()?.tiles.first().map(|t| t.coord.chip),
        StageKind::Fc(f) => f.columns.first()?.tiles.first().map(|t| t.coord.chip),
        StageKind::Res(r) => r
            .proj
            .as_ref()
            .and_then(|p| p.chains.first()?.tiles.first().map(|t| t.coord.chip)),
        _ => None,
    }
}

fn exit_chip(stage: &Stage) -> Option<usize> {
    match &stage.kind {
        StageKind::Conv(c) => c.chains.last()?.tiles.last().map(|t| t.coord.chip),
        StageKind::Fc(f) => f.columns.last()?.tiles.last().map(|t| t.coord.chip),
        StageKind::Res(r) => r
            .proj
            .as_ref()
            .and_then(|p| p.chains.last()?.tiles.last().map(|t| t.coord.chip)),
        _ => None,
    }
}

/// Steady-state pipeline period of a conv stage in pixel slots.
fn conv_period_slots(c: &ConvStage) -> u64 {
    let g = ConvGeometry::new(c.k, c.stride, c.padding, c.in_shape.h, c.in_shape.w);
    (g.stream_slots() as u64).div_ceil(c.dup as u64)
}

/// Steady-state period of the residual add junction.
fn res_period_slots(r: &ResStage) -> u64 {
    ((r.shape.h * r.shape.w) as u64).div_ceil(r.dup as u64)
}

/// Closed-form counters for a conv stage (mirrors
/// `Simulator::run_conv_stage` term by term).
fn conv_counters(c: &ConvStage, st: &mut Counters) -> u64 {
    let g = ConvGeometry::new(c.k, c.stride, c.padding, c.in_shape.h, c.in_shape.w);
    let (wp, hp) = (g.wp(), g.hp());
    let total_pixels = (wp * hp) as u64;
    let outs = (g.out_h * g.out_w) as u64;

    let mut max_chain_len = 0u64;
    for chain in &c.chains {
        let n = chain.tiles.len() as u64;
        max_chain_len = max_chain_len.max(n);
        let m_lanes = (chain.m_hi - chain.m_lo) as u64;
        for (ci, cfg) in chain.tiles.iter().enumerate() {
            let pack = match cfg.rifm.shift_step {
                64 => 4u64,
                128 => 2,
                _ => 1,
            };
            let beats = total_pixels.div_ceil(pack);
            let bits = (cfg.rows * 8) as u64;
            // RIFM stream
            st.rifm_buffer_accesses += beats;
            st.rifm_ctrl_steps += beats;
            st.rifm_shifts += total_pixels - beats;
            st.sched_fetches += 2 * total_pixels;
            st.rofm_ctrl_steps += 2 * total_pixels;
            if cfg.rifm.forward {
                let cross = ci + 1 < chain.tiles.len()
                    && chain.tiles[ci + 1].coord.chip != cfg.coord.chip;
                let fwd_bits = bits * pack * beats;
                if cross {
                    st.interchip_bits += fwd_bits;
                } else {
                    st.onchip_link_bits += fwd_bits;
                }
            }
            // valid slots (PE-feed reads are charged inside CIM j/MAC)
            st.pe_mvms += outs;
            st.pe_macs += (cfg.rows * cfg.cols) as u64 * outs;
            if !cfg.is_chain_start {
                // add of incoming psum (4 8b-adds per i32 lane)
                st.adds_8b += 4 * cfg.cols as u64 * outs;
                if cfg.is_row_head {
                    st.rofm_buffer_accesses += outs; // pops
                }
            }
            if cfg.is_last {
                st.act_ops_8b += cfg.cols as u64 * outs;
                let obits = m_lanes * 8;
                st.rofm_reg_accesses += outs;
                st.onchip_link_bits += obits * outs;
            } else {
                let pbits = (cfg.cols * 32) as u64;
                st.rofm_reg_accesses += outs; // tx
                let next = &chain.tiles[ci + 1];
                if next.coord.chip != cfg.coord.chip {
                    st.interchip_bits += pbits * outs;
                } else {
                    st.onchip_link_bits += pbits * outs;
                }
                if next.is_row_head {
                    st.rofm_buffer_accesses += outs; // pushes
                } else {
                    st.rofm_reg_accesses += outs; // rx
                }
            }
        }
        // fused pooling on the OFM stream (block reuse; kernel == stride
        // in every Table IV network)
        if let Some(p) = c.fused_pool {
            let win = (p.kernel * p.kernel) as u64;
            let pooled = outs / win;
            if p.max {
                st.pool_ops_8b += m_lanes * outs; // one cmp per activation
            } else {
                st.adds_8b += m_lanes * outs;
                st.pool_ops_8b += m_lanes * pooled; // scale at completion
            }
        }
    }
    // weight duplication: `dup` replica arrays each stream 1/dup of
    // the pixels concurrently; chain fill is not divided
    total_pixels.div_ceil(c.dup as u64) + max_chain_len
}

/// Closed-form counters for an FC stage (mirrors
/// `Simulator::run_fc_stage`).
fn fc_counters(f: &FcStage, _n_c: usize, st: &mut Counters) -> u64 {
    let mut max_col = 0u64;
    for col in &f.columns {
        max_col = max_col.max(col.tiles.len() as u64);
        for (rb, t) in col.tiles.iter().enumerate() {
            st.rifm_buffer_accesses += 1;
            st.rifm_ctrl_steps += 1;
            st.sched_fetches += 1;
            st.rofm_ctrl_steps += 1;
            st.onchip_link_bits += (t.rows * 8) as u64;
            st.pe_mvms += 1;
            st.pe_macs += (t.rows * t.cols) as u64;
            if rb > 0 {
                let pbits = (t.cols * 32) as u64;
                if col.tiles[rb - 1].coord.chip != t.coord.chip {
                    st.interchip_bits += pbits;
                } else {
                    st.onchip_link_bits += pbits;
                }
                st.rofm_reg_accesses += 1;
                st.adds_8b += 4 * t.cols as u64;
            }
        }
        let cols = col.c_hi - col.c_lo;
        st.act_ops_8b += cols as u64;
        let obits = (cols * 8) as u64;
        st.rofm_reg_accesses += 1;
        st.onchip_link_bits += obits;
    }
    max_col + 1
}

/// Closed-form counters for a standalone pooling stage.
fn pool_counters(p: &PoolStage, st: &mut Counters) -> u64 {
    let c = p.in_shape.c as u64;
    let pixels = (p.in_shape.h * p.in_shape.w) as u64;
    let outs = (p.out_shape.h * p.out_shape.w) as u64;
    let bits = c * 8;
    st.onchip_link_bits += bits * pixels;
    st.rofm_reg_accesses += pixels;
    st.sched_fetches += pixels;
    st.rofm_ctrl_steps += pixels;
    if p.max {
        st.pool_ops_8b += c * pixels;
    } else {
        st.adds_8b += c * pixels;
        st.pool_ops_8b += c * outs;
    }
    pixels.div_ceil(p.dup as u64)
}

/// Closed-form counters for a residual-add stage (excluding its
/// projection, which is a conv).
fn res_counters(r: &ResStage, st: &mut Counters) -> u64 {
    let c = r.shape.c as u64;
    let pixels = (r.shape.h * r.shape.w) as u64;
    let bits = c * 8;
    st.onchip_link_bits += bits * pixels;
    st.rofm_reg_accesses += pixels; // bypass tx
    st.sched_fetches += pixels;
    st.rofm_ctrl_steps += pixels;
    st.adds_8b += c * pixels;
    st.act_ops_8b += c * pixels;
    pixels.div_ceil(r.dup as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ArchConfig, Compiler};
    use crate::model::{zoo, NetworkBuilder, TensorShape};
    use crate::sim::Simulator;
    use crate::testutil::Rng;

    /// The heart of experiment A3: analytic counters must equal the
    /// cycle simulator's counters exactly.
    fn assert_model_matches_engine(net: &crate::model::Network, arch: ArchConfig) {
        let program = Compiler::new(arch).compile(net).unwrap();
        let est = estimate(&program).unwrap();
        let mut sim = Simulator::new(&program);
        let mut rng = Rng::new(42);
        let out = sim.run_image(&rng.i8_vec(net.input_len(), 31)).unwrap();
        let sim_stats = sim.stats();

        assert_eq!(est.counters.pe_macs, sim_stats.pe_macs, "pe_macs");
        assert_eq!(est.counters.pe_mvms, sim_stats.pe_mvms, "pe_mvms");
        assert_eq!(
            est.counters.rifm_buffer_accesses, sim_stats.rifm_buffer_accesses,
            "rifm_buffer"
        );
        assert_eq!(est.counters.rifm_shifts, sim_stats.rifm_shifts, "shifts");
        assert_eq!(est.counters.adds_8b, sim_stats.adds_8b, "adds");
        assert_eq!(est.counters.act_ops_8b, sim_stats.act_ops_8b, "acts");
        assert_eq!(est.counters.pool_ops_8b, sim_stats.pool_ops_8b, "pools");
        assert_eq!(
            est.counters.rofm_buffer_accesses, sim_stats.rofm_buffer_accesses,
            "rofm_buffer"
        );
        assert_eq!(
            est.counters.rofm_reg_accesses, sim_stats.rofm_reg_accesses,
            "reg_words"
        );
        assert_eq!(
            est.counters.onchip_link_bits, sim_stats.onchip_link_bits,
            "onchip_bits"
        );
        assert_eq!(
            est.counters.interchip_bits, sim_stats.interchip_bits,
            "interchip_bits"
        );
        assert_eq!(
            est.counters.offchip_io_bits, sim_stats.offchip_io_bits,
            "offchip_bits"
        );
        assert_eq!(est.latency_cycles, out.latency_cycles, "latency");
    }

    #[test]
    fn model_matches_engine_simple_conv() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 6, 6))
            .conv(4, 3, 1, 1)
            .build();
        assert_model_matches_engine(&net, ArchConfig::default());
    }

    #[test]
    fn model_matches_engine_tiny_cnn() {
        assert_model_matches_engine(&zoo::tiny_cnn(), ArchConfig::default());
    }

    #[test]
    fn model_matches_engine_multiblock() {
        let net = NetworkBuilder::new("t", TensorShape::new(6, 5, 5))
            .conv(7, 3, 1, 1)
            .max_pool(2, 2)
            .flatten()
            .fc(9)
            .fc_logits(5)
            .build();
        assert_model_matches_engine(&net, ArchConfig::tiny(4));
    }

    #[test]
    fn model_matches_engine_resnet_block() {
        let net = NetworkBuilder::new("t", TensorShape::new(4, 8, 8))
            .conv(4, 3, 1, 1)
            .conv(8, 3, 2, 1)
            .conv_linear(8, 3, 1, 1)
            .res_add_proj(
                0,
                crate::model::Projection {
                    out_ch: 8,
                    stride: 2,
                },
            )
            .build();
        assert_model_matches_engine(&net, ArchConfig::default());
    }

    #[test]
    fn pipeline_period_is_slowest_stage() {
        let net = zoo::tiny_cnn();
        let program = Compiler::default().compile(&net).unwrap();
        let est = estimate(&program).unwrap();
        let max = est.stages.iter().map(|s| s.period_slots).max().unwrap();
        assert_eq!(est.period_cycles, max * CYCLES_PER_SLOT as u64);
        // steady-state period excludes chain fill, so it never exceeds
        // the per-stage latency
        assert!(est.stages.iter().all(|s| s.period_slots <= s.slots));
        assert!(est.latency_cycles >= est.period_cycles);
    }

    #[test]
    fn vgg16_estimate_is_sane() {
        let net = zoo::vgg16_imagenet();
        let program = Compiler::default().compile(&net).unwrap();
        let est = estimate(&program).unwrap();
        // 15.5 GMACs must be preserved exactly.
        assert_eq!(est.counters.pe_macs, net.total_macs().unwrap());
        // The bottleneck stage is the 224x224 input layer: ~51k slots.
        let period_slots = est.period_cycles / CYCLES_PER_SLOT as u64;
        assert!(
            period_slots >= (224 * 224) as u64,
            "period {period_slots} slots"
        );
        assert!(est.images_per_s() > 10.0);
        assert!(est.chips >= 9, "VGG-16 spans ~10 chips, got {}", est.chips);
    }

    #[test]
    fn weight_duplication_shortens_period() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 8, 8))
            .conv(8, 3, 1, 1)
            .max_pool(2, 2)
            .build();
        let block = Compiler::default().compile(&net).unwrap();
        let mut arch = ArchConfig::default();
        arch.pooling = crate::coordinator::PoolingScheme::WeightDuplication;
        let dup = Compiler::new(arch).compile(&net).unwrap();
        let e_block = estimate(&block).unwrap();
        let e_dup = estimate(&dup).unwrap();
        assert!(e_dup.period_cycles < e_block.period_cycles);
        assert!(dup.total_tiles > block.total_tiles);
    }
}
