//! Int8 functional reference ("what the hardware must compute").
//!
//! This module fixes the exact arithmetic semantics shared by all three
//! implementations of the network:
//!
//! 1. this direct Rust reference (the oracle for unit/property tests),
//! 2. the cycle-accurate Domino simulator (`crate::sim`), and
//! 3. the JAX/Pallas golden model (python/compile/model.py, loaded through
//!    `crate::runtime` as AOT-compiled HLO).
//!
//! Semantics (all shared, bit-exact):
//! * activations and weights are `i8`; accumulation is `i32`;
//! * conv/fc requantization: `y = clamp_i8(relu?(acc >> shift))` with an
//!   arithmetic right shift (`shift` = `Layer::requant_shift`), ReLU
//!   applied *after* the shift, then saturation to `[-128, 127]`;
//! * residual add: `y = clamp_i8(max(a + b, 0))` (ReLU always follows the
//!   add, as in ResNet); a projected skip path is first convolved 1x1 and
//!   requantized like a conv;
//! * max pool: plain i8 max; average pool: `floor(sum / k²)` (floor
//!   division, matching `jnp.floor_divide`).

use super::{Layer, LayerKind, Network, Projection, ShapeError, TensorShape};
use crate::testutil::Rng;

/// Saturate an i32 accumulator to i8.
#[inline]
pub fn clamp_i8(v: i32) -> i8 {
    v.clamp(i8::MIN as i32, i8::MAX as i32) as i8
}

/// The shared conv/fc requantization function.
#[inline]
pub fn requant(acc: i32, shift: u32, relu: bool) -> i8 {
    let mut v = acc >> shift; // arithmetic shift (i32)
    if relu {
        v = v.max(0);
    }
    clamp_i8(v)
}

/// The shared residual-add function (ReLU fused).
#[inline]
pub fn res_add(a: i8, b: i8) -> i8 {
    clamp_i8((a as i32 + b as i32).max(0))
}

/// Weights for one layer.
#[derive(Clone, Debug)]
pub enum LayerWeights {
    /// Conv2d weights laid out `[M][C][K][K]` row-major.
    Conv { w: Vec<i8> },
    /// FC weights laid out `[out][in]` row-major.
    Fc { w: Vec<i8> },
    /// Projection weights for a ResAdd skip path, laid out `[M][C]`.
    Proj { w: Vec<i8> },
    /// Layer holds no weights.
    None,
}

impl LayerWeights {
    pub fn as_slice(&self) -> &[i8] {
        match self {
            LayerWeights::Conv { w } | LayerWeights::Fc { w } | LayerWeights::Proj { w } => w,
            LayerWeights::None => &[],
        }
    }
}

/// All weights of a network, indexed by layer.
#[derive(Clone, Debug)]
pub struct Weights {
    pub per_layer: Vec<LayerWeights>,
}

impl Weights {
    /// A weight-less placeholder (one `None` per layer) for skeleton
    /// (analysis-only) compilation.
    pub fn empty(net: &Network) -> Self {
        Self {
            per_layer: vec![LayerWeights::None; net.layers.len()],
        }
    }

    /// Seeded synthetic weights, bounded to avoid permanent saturation in
    /// deep accumulations (|w| <= 15). Geometry follows the network.
    pub fn random(net: &Network, seed: u64) -> Result<Self, ShapeError> {
        let shapes = net.shapes()?;
        let mut rng = Rng::new(seed);
        let mut per_layer = Vec::with_capacity(net.layers.len());
        let mut in_shape = net.input;
        for (i, layer) in net.layers.iter().enumerate() {
            let lw = match &layer.kind {
                LayerKind::Conv2d { out_ch, kernel, .. } => LayerWeights::Conv {
                    w: rng.i8_vec(out_ch * in_shape.c * kernel * kernel, 15),
                },
                LayerKind::Fc { out_features, .. } => LayerWeights::Fc {
                    w: rng.i8_vec(out_features * in_shape.c, 15),
                },
                LayerKind::ResAdd {
                    from,
                    proj: Some(p),
                } => LayerWeights::Proj {
                    w: rng.i8_vec(p.out_ch * shapes[*from].c, 15),
                },
                _ => LayerWeights::None,
            };
            per_layer.push(lw);
            in_shape = shapes[i];
        }
        Ok(Self { per_layer })
    }
}

/// An i8 CHW tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor {
    pub shape: TensorShape,
    pub data: Vec<i8>,
}

impl Tensor {
    pub fn new(shape: TensorShape, data: Vec<i8>) -> Self {
        assert_eq!(shape.len(), data.len(), "tensor data/shape mismatch");
        Self { shape, data }
    }

    pub fn zeros(shape: TensorShape) -> Self {
        Self {
            data: vec![0; shape.len()],
            shape,
        }
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> i8 {
        self.data[(c * self.shape.h + y) * self.shape.w + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: i8) {
        self.data[(c * self.shape.h + y) * self.shape.w + x] = v;
    }

    /// Zero-padded read (used by convolution).
    #[inline]
    pub fn at_padded(&self, c: usize, y: isize, x: isize) -> i8 {
        if y < 0 || x < 0 || y >= self.shape.h as isize || x >= self.shape.w as isize {
            0
        } else {
            self.at(c, y as usize, x as usize)
        }
    }
}

/// Direct (sliding-window) conv2d with the shared requantization.
pub fn conv2d(
    input: &Tensor,
    w: &[i8],
    out_ch: usize,
    kernel: usize,
    stride: usize,
    padding: usize,
    shift: u32,
    relu: bool,
) -> Tensor {
    let c_in = input.shape.c;
    let oh = super::conv_out(input.shape.h, kernel, stride, padding).expect("conv2d shape");
    let ow = super::conv_out(input.shape.w, kernel, stride, padding).expect("conv2d shape");
    assert_eq!(w.len(), out_ch * c_in * kernel * kernel, "conv weight size");
    let mut out = Tensor::zeros(TensorShape::new(out_ch, oh, ow));
    for m in 0..out_ch {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc: i32 = 0;
                for c in 0..c_in {
                    for kr in 0..kernel {
                        for kc in 0..kernel {
                            let iy = (oy * stride + kr) as isize - padding as isize;
                            let ix = (ox * stride + kc) as isize - padding as isize;
                            let xv = input.at_padded(c, iy, ix) as i32;
                            let wv = w[((m * c_in + c) * kernel + kr) * kernel + kc] as i32;
                            acc += xv * wv;
                        }
                    }
                }
                out.set(m, oy, ox, requant(acc, shift, relu));
            }
        }
    }
    out
}

/// FC layer `y = xW` with the shared requantization.
pub fn fc(input: &[i8], w: &[i8], out_features: usize, shift: u32, relu: bool) -> Vec<i8> {
    let in_features = input.len();
    assert_eq!(w.len(), out_features * in_features, "fc weight size");
    (0..out_features)
        .map(|o| {
            let acc: i32 = (0..in_features)
                .map(|i| input[i] as i32 * w[o * in_features + i] as i32)
                .sum();
            requant(acc, shift, relu)
        })
        .collect()
}

/// Max pooling.
pub fn max_pool(input: &Tensor, kernel: usize, stride: usize) -> Tensor {
    let oh = super::conv_out(input.shape.h, kernel, stride, 0).expect("pool shape");
    let ow = super::conv_out(input.shape.w, kernel, stride, 0).expect("pool shape");
    let mut out = Tensor::zeros(TensorShape::new(input.shape.c, oh, ow));
    for c in 0..input.shape.c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = i8::MIN;
                for kr in 0..kernel {
                    for kc in 0..kernel {
                        m = m.max(input.at(c, oy * stride + kr, ox * stride + kc));
                    }
                }
                out.set(c, oy, ox, m);
            }
        }
    }
    out
}

/// Average pooling with floor division (matches `jnp.floor_divide`).
pub fn avg_pool(input: &Tensor, kernel: usize, stride: usize) -> Tensor {
    let oh = super::conv_out(input.shape.h, kernel, stride, 0).expect("pool shape");
    let ow = super::conv_out(input.shape.w, kernel, stride, 0).expect("pool shape");
    let n = (kernel * kernel) as i32;
    let mut out = Tensor::zeros(TensorShape::new(input.shape.c, oh, ow));
    for c in 0..input.shape.c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut sum: i32 = 0;
                for kr in 0..kernel {
                    for kc in 0..kernel {
                        sum += input.at(c, oy * stride + kr, ox * stride + kc) as i32;
                    }
                }
                out.set(c, oy, ox, clamp_i8(sum.div_euclid(n)));
            }
        }
    }
    out
}

/// 1x1 strided projection conv (ResNet skip path).
pub fn project(input: &Tensor, w: &[i8], proj: &Projection, shift: u32) -> Tensor {
    let c_in = input.shape.c;
    let shape = proj.out_shape(input.shape).expect("projection shape");
    assert_eq!(w.len(), proj.out_ch * c_in, "projection weight size");
    let mut out = Tensor::zeros(shape);
    for m in 0..proj.out_ch {
        for oy in 0..shape.h {
            for ox in 0..shape.w {
                let acc: i32 = (0..c_in)
                    .map(|c| {
                        input.at(c, oy * proj.stride, ox * proj.stride) as i32
                            * w[m * c_in + c] as i32
                    })
                    .sum();
                out.set(m, oy, ox, requant(acc, shift, false));
            }
        }
    }
    out
}

/// Full-network forward pass. Returns the output of every layer (the last
/// entry is the network output); intermediate outputs feed residual skips
/// and let tests compare the simulator layer by layer.
pub fn forward_all(
    net: &Network,
    weights: &Weights,
    input: &Tensor,
) -> Result<Vec<Tensor>, ShapeError> {
    assert_eq!(input.shape, net.input, "input shape mismatch");
    net.shapes()?; // validate
    let mut outs: Vec<Tensor> = Vec::with_capacity(net.layers.len());
    let mut cur = input.clone();
    for (i, layer) in net.layers.iter().enumerate() {
        let Layer {
            kind, requant_shift, ..
        } = layer;
        let next = match kind {
            LayerKind::Conv2d {
                out_ch,
                kernel,
                stride,
                padding,
                relu,
            } => conv2d(
                &cur,
                weights.per_layer[i].as_slice(),
                *out_ch,
                *kernel,
                *stride,
                *padding,
                *requant_shift,
                *relu,
            ),
            LayerKind::Fc { out_features, relu } => {
                let y = fc(
                    &cur.data,
                    weights.per_layer[i].as_slice(),
                    *out_features,
                    *requant_shift,
                    *relu,
                );
                Tensor::new(TensorShape::new(*out_features, 1, 1), y)
            }
            LayerKind::MaxPool2d { kernel, stride } => max_pool(&cur, *kernel, *stride),
            LayerKind::AvgPool2d { kernel, stride } => avg_pool(&cur, *kernel, *stride),
            LayerKind::ResAdd { from, proj } => {
                let skip_src = &outs[*from];
                let skip = match proj {
                    Some(p) => project(
                        skip_src,
                        weights.per_layer[i].as_slice(),
                        p,
                        *requant_shift,
                    ),
                    None => skip_src.clone(),
                };
                assert_eq!(skip.shape, cur.shape, "residual shape");
                let data = cur
                    .data
                    .iter()
                    .zip(skip.data.iter())
                    .map(|(&a, &b)| res_add(a, b))
                    .collect();
                Tensor::new(cur.shape, data)
            }
            LayerKind::Flatten => Tensor::new(TensorShape::new(cur.shape.len(), 1, 1), cur.data.clone()),
        };
        outs.push(next.clone());
        cur = next;
    }
    Ok(outs)
}

/// Forward pass returning only the final output.
pub fn forward(
    net: &Network,
    weights: &Weights,
    input: &Tensor,
) -> Result<Tensor, ShapeError> {
    Ok(forward_all(net, weights, input)?
        .pop()
        .unwrap_or_else(|| input.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::testutil::for_all;

    #[test]
    fn requant_semantics() {
        assert_eq!(requant(255, 0, false), 127); // saturate high
        assert_eq!(requant(-300, 0, false), -128); // saturate low
        assert_eq!(requant(-300, 0, true), 0); // relu after shift
        assert_eq!(requant(256, 7, false), 2);
        assert_eq!(requant(-1, 7, false), -1); // arithmetic shift: -1>>7 = -1
        assert_eq!(requant(-1, 7, true), 0);
    }

    #[test]
    fn res_add_saturates_and_relus() {
        assert_eq!(res_add(100, 100), 127);
        assert_eq!(res_add(-100, 50), 0);
        assert_eq!(res_add(3, 4), 7);
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 kernel, single channel, weight=1, shift 0: identity + relu.
        let input = Tensor::new(
            TensorShape::new(1, 2, 2),
            vec![1, -2, 3, -4],
        );
        let out = conv2d(&input, &[1], 1, 1, 1, 0, 0, true);
        assert_eq!(out.data, vec![1, 0, 3, 0]);
    }

    #[test]
    fn conv2d_known_3x3() {
        // Single channel 3x3 input, 3x3 all-ones kernel, padding 1:
        // centre output = sum of all inputs.
        let input = Tensor::new(
            TensorShape::new(1, 3, 3),
            vec![1, 2, 3, 4, 5, 6, 7, 8, 9],
        );
        let w = vec![1i8; 9];
        let out = conv2d(&input, &w, 1, 3, 1, 1, 0, false);
        assert_eq!(out.shape, TensorShape::new(1, 3, 3));
        assert_eq!(out.at(0, 1, 1), 45);
        // corner (0,0): window covers (0..1, 0..1) => 1+2+4+5 = 12
        assert_eq!(out.at(0, 0, 0), 12);
    }

    #[test]
    fn conv2d_stride_two() {
        let input = Tensor::new(
            TensorShape::new(1, 4, 4),
            (0..16).map(|v| v as i8).collect(),
        );
        let out = conv2d(&input, &[1], 1, 1, 2, 0, 0, false);
        assert_eq!(out.shape, TensorShape::new(1, 2, 2));
        assert_eq!(out.data, vec![0, 2, 8, 10]);
    }

    #[test]
    fn fc_known_values() {
        // y0 = 1*1 + 2*2 = 5; y1 = 1*(-1) + 2*3 = 5 -> shift 1 -> 2
        let y = fc(&[1, 2], &[1, 2, -1, 3], 2, 1, false);
        assert_eq!(y, vec![2, 2]);
    }

    #[test]
    fn max_pool_2x2() {
        let input = Tensor::new(
            TensorShape::new(1, 2, 4),
            vec![1, 5, -3, -7, 2, 0, -1, -9],
        );
        let out = max_pool(&input, 2, 2);
        assert_eq!(out.data, vec![5, -1]);
    }

    #[test]
    fn avg_pool_floor_division() {
        // sum = 1+2+3+(-9) = -3; floor(-3/4) = -1 (floor, not trunc)
        let input = Tensor::new(TensorShape::new(1, 2, 2), vec![1, 2, 3, -9]);
        let out = avg_pool(&input, 2, 2);
        assert_eq!(out.data, vec![-1]);
    }

    #[test]
    fn projection_downsamples() {
        let input = Tensor::new(
            TensorShape::new(1, 2, 2),
            vec![10, 20, 30, 40],
        );
        let p = Projection { out_ch: 2, stride: 2 };
        let out = project(&input, &[2, -2], &p, 0);
        assert_eq!(out.shape, TensorShape::new(2, 1, 1));
        assert_eq!(out.data, vec![20, -20]);
    }

    #[test]
    fn forward_tiny_cnn_runs_and_is_deterministic() {
        let net = zoo::tiny_cnn();
        let weights = Weights::random(&net, 1).unwrap();
        let mut rng = crate::testutil::Rng::new(2);
        let input = Tensor::new(net.input, rng.i8_vec(net.input_len(), 31));
        let a = forward(&net, &weights, &input).unwrap();
        let b = forward(&net, &weights, &input).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.shape.c, 10);
    }

    #[test]
    fn forward_resnet_block_uses_skip() {
        // conv_linear + res_add: zero conv weights make output = relu(skip).
        let net = crate::model::NetworkBuilder::new("t", TensorShape::new(2, 4, 4))
            .conv(2, 3, 1, 1)
            .conv_shift(2, 3, 1, 1, false, 0)
            .res_add(0)
            .build();
        let mut weights = Weights::random(&net, 3).unwrap();
        // zero the second conv
        if let LayerWeights::Conv { w } = &mut weights.per_layer[1] {
            w.iter_mut().for_each(|v| *v = 0);
        }
        let mut rng = crate::testutil::Rng::new(4);
        let input = Tensor::new(net.input, rng.i8_vec(net.input_len(), 31));
        let outs = forward_all(&net, &weights, &input).unwrap();
        let skip = &outs[0];
        let out = &outs[2];
        for (a, b) in out.data.iter().zip(skip.data.iter()) {
            assert_eq!(*a, (*b).max(0));
        }
    }

    #[test]
    fn prop_conv_linearity_in_weights() {
        // conv(x, w) with shift 0 no relu is linear in w for small values:
        // conv(x, 2w) == 2*conv(x, w) when nothing saturates.
        for_all("conv_linearity", 20, |rng| {
            let c = rng.range(1, 3);
            let m = rng.range(1, 3);
            let h = rng.range(3, 6);
            let input = Tensor::new(
                TensorShape::new(c, h, h),
                rng.i8_vec(c * h * h, 3),
            );
            let w: Vec<i8> = rng.i8_vec(m * c * 9, 2);
            let w2: Vec<i8> = w.iter().map(|&v| v * 2).collect();
            let a = conv2d(&input, &w, m, 3, 1, 1, 0, false);
            let b = conv2d(&input, &w2, m, 3, 1, 1, 0, false);
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                // guard: skip saturated positions
                if (*x as i32 * 2).abs() <= 127 {
                    assert_eq!(*y as i32, *x as i32 * 2);
                }
            }
        });
    }

    #[test]
    fn prop_maxpool_upper_bounds_avgpool() {
        for_all("max_ge_avg", 20, |rng| {
            let c = rng.range(1, 3);
            let h = rng.range(2, 5) * 2;
            let input = Tensor::new(
                TensorShape::new(c, h, h),
                rng.i8_vec(c * h * h, 100),
            );
            let mx = max_pool(&input, 2, 2);
            let av = avg_pool(&input, 2, 2);
            for (m, a) in mx.data.iter().zip(av.data.iter()) {
                assert!(m >= a, "max {m} < avg {a}");
            }
        });
    }
}
