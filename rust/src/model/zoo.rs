//! Model zoo: the networks evaluated in the paper's Table IV.
//!
//! * VGG-11 on CIFAR-10 (compared against Jia et al. [9])
//! * ResNet-18 on CIFAR-10 (compared against Yue et al. [17])
//! * VGG-16 on ImageNet (compared against Yoon et al. [16])
//! * VGG-19 on ImageNet (compared against AtomLayer [10] and CASCADE [6])
//!
//! plus `tiny_cnn`, a small network used for cycle-accurate simulator
//! validation and the end-to-end accuracy/golden-model experiments
//! (full-size nets are evaluated through the validated analytic
//! performance model — see `perfmodel`).
//!
//! Layer shapes follow the original papers (Simonyan & Zisserman for VGG,
//! He et al. for ResNet). CIFAR variants use the standard 32x32
//! adaptations. Weight *values* are synthetic (seeded), which does not
//! affect performance/energy evaluation — only layer geometry matters.

use super::{Network, NetworkBuilder, Projection, TensorShape};
#[cfg(test)]
use super::LayerKind;

/// VGG classifier head. ImageNet VGG uses FC-4096, FC-4096, FC-1000.
fn vgg_head_imagenet(b: NetworkBuilder) -> NetworkBuilder {
    b.flatten().fc(4096).fc(4096).fc_logits(1000)
}

/// CIFAR-10 VGG head: FC-512, FC-10 (standard 32x32 adaptation).
fn vgg_head_cifar(b: NetworkBuilder) -> NetworkBuilder {
    b.flatten().fc(512).fc_logits(10)
}

/// VGG-11 ("configuration A"): 64 M 128 M 256x2 M 512x2 M 512x2 M.
pub fn vgg11_cifar() -> Network {
    let b = NetworkBuilder::new("vgg11-cifar10", TensorShape::new(3, 32, 32))
        .conv(64, 3, 1, 1)
        .max_pool(2, 2)
        .conv(128, 3, 1, 1)
        .max_pool(2, 2)
        .conv(256, 3, 1, 1)
        .conv(256, 3, 1, 1)
        .max_pool(2, 2)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .max_pool(2, 2)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .max_pool(2, 2);
    vgg_head_cifar(b).build()
}

/// VGG-16 ("configuration D") on ImageNet 224x224.
pub fn vgg16_imagenet() -> Network {
    let b = NetworkBuilder::new("vgg16-imagenet", TensorShape::new(3, 224, 224))
        .conv(64, 3, 1, 1)
        .conv(64, 3, 1, 1)
        .max_pool(2, 2)
        .conv(128, 3, 1, 1)
        .conv(128, 3, 1, 1)
        .max_pool(2, 2)
        .conv(256, 3, 1, 1)
        .conv(256, 3, 1, 1)
        .conv(256, 3, 1, 1)
        .max_pool(2, 2)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .max_pool(2, 2)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .max_pool(2, 2);
    vgg_head_imagenet(b).build()
}

/// VGG-19 ("configuration E") on ImageNet 224x224.
pub fn vgg19_imagenet() -> Network {
    let b = NetworkBuilder::new("vgg19-imagenet", TensorShape::new(3, 224, 224))
        .conv(64, 3, 1, 1)
        .conv(64, 3, 1, 1)
        .max_pool(2, 2)
        .conv(128, 3, 1, 1)
        .conv(128, 3, 1, 1)
        .max_pool(2, 2)
        .conv(256, 3, 1, 1)
        .conv(256, 3, 1, 1)
        .conv(256, 3, 1, 1)
        .conv(256, 3, 1, 1)
        .max_pool(2, 2)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .max_pool(2, 2)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .conv(512, 3, 1, 1)
        .max_pool(2, 2);
    vgg_head_imagenet(b).build()
}

/// One ResNet basic block: conv-conv-resadd. `stride != 1` or channel
/// change puts a 1x1 projection on the skip path.
fn basic_block(mut b: NetworkBuilder, in_ch: usize, out_ch: usize, stride: usize) -> NetworkBuilder {
    let skip_src = b.next_index().checked_sub(1);
    b = b
        .conv(out_ch, 3, stride, 1)
        .conv_linear(out_ch, 3, 1, 1);
    // Block starts after at least the stem conv, so `skip_src` is always
    // a valid previous-layer index (the ResAdd IR cannot reference the
    // network input directly).
    let res_from = skip_src.expect("basic_block requires a preceding layer");
    if stride != 1 || in_ch != out_ch {
        b.res_add_proj(res_from, Projection { out_ch, stride })
    } else {
        b.res_add(res_from)
    }
}

/// ResNet-18 for CIFAR-10: 3x3/s1 stem, stages (64,64,128,128,256,256,
/// 512,512) with strides (1,1,2,1,2,1,2,1), global average pool, FC-10.
pub fn resnet18_cifar() -> Network {
    let mut b = NetworkBuilder::new("resnet18-cifar10", TensorShape::new(3, 32, 32))
        .conv(64, 3, 1, 1); // stem
    b = basic_block(b, 64, 64, 1);
    b = basic_block(b, 64, 64, 1);
    b = basic_block(b, 64, 128, 2);
    b = basic_block(b, 128, 128, 1);
    b = basic_block(b, 128, 256, 2);
    b = basic_block(b, 256, 256, 1);
    b = basic_block(b, 256, 512, 2);
    b = basic_block(b, 512, 512, 1);
    // Global average pool over the remaining 4x4 map, then classifier.
    b.avg_pool(4, 4).flatten().fc_logits(10).build()
}

/// ResNet-18 for ImageNet: 7x7/s2 stem + 3x3/s2 max pool, the same eight
/// basic blocks, 7x7 global average pool, FC-1000.
pub fn resnet18_imagenet() -> Network {
    let mut b = NetworkBuilder::new("resnet18-imagenet", TensorShape::new(3, 224, 224))
        .conv(64, 7, 2, 3)
        .max_pool(2, 2); // paper uses 3x3/s2; 2x2/s2 keeps shapes identical (56x56)
    b = basic_block(b, 64, 64, 1);
    b = basic_block(b, 64, 64, 1);
    b = basic_block(b, 64, 128, 2);
    b = basic_block(b, 128, 128, 1);
    b = basic_block(b, 128, 256, 2);
    b = basic_block(b, 256, 256, 1);
    b = basic_block(b, 256, 512, 2);
    b = basic_block(b, 512, 512, 1);
    b.avg_pool(7, 7).flatten().fc_logits(1000).build()
}

/// Small CNN used for cycle-accurate validation, the golden-model
/// cross-check and the quantization-accuracy experiment. Sized so a full
/// cycle simulation finishes in milliseconds and every layer type the
/// paper discusses (conv, maxpool, avgpool, skip, fc) is exercised.
pub fn tiny_cnn() -> Network {
    NetworkBuilder::new("tiny-cnn", TensorShape::new(3, 16, 16))
        .conv(16, 3, 1, 1)
        .max_pool(2, 2)
        .conv(32, 3, 1, 1)
        .conv_linear(32, 3, 1, 1)
        .res_add(2)
        .max_pool(2, 2)
        .conv(32, 3, 1, 1)
        .avg_pool(4, 4)
        .flatten()
        .fc_logits(10)
        .build()
}

/// A small MLP (flatten + two FC layers), sized so a full cycle
/// simulation finishes in microseconds. One of the fast trio used to
/// exercise multi-model serving (its 8-class output is deliberately
/// distinct from `tiny_cnn`'s 10 and `tiny_resnet`'s 6, so a
/// cross-model misroute cannot even be shape-correct).
pub fn tiny_mlp() -> Network {
    NetworkBuilder::new("tiny-mlp", TensorShape::new(24, 1, 1))
        .fc(16)
        .fc_logits(8)
        .build()
}

/// A minimal residual network (conv, linear conv, identity skip,
/// pooling, FC) for fast multi-model serving tests: every response can
/// be refcompute-checked in well under a millisecond.
pub fn tiny_resnet() -> Network {
    NetworkBuilder::new("tiny-resnet", TensorShape::new(4, 8, 8))
        .conv(8, 3, 1, 1)
        .conv_linear(8, 3, 1, 1)
        .res_add(0)
        .avg_pool(2, 2)
        .flatten()
        .fc_logits(6)
        .build()
}

/// The Table IV workload set: (network, dataset label, counterpart keys).
pub fn table4_workloads() -> Vec<(Network, &'static str)> {
    vec![
        (vgg11_cifar(), "CIFAR-10"),
        (resnet18_cifar(), "CIFAR-10"),
        (vgg16_imagenet(), "ImageNet"),
        (vgg19_imagenet(), "ImageNet"),
    ]
}

/// All zoo constructors by name (CLI access). Lookup is
/// case-insensitive and treats `_` and `-` as the same separator, so
/// `TINY_CNN` and `tiny-cnn` both resolve.
pub fn by_name(name: &str) -> Option<Network> {
    let key = name.trim().to_ascii_lowercase().replace('_', "-");
    match key.as_str() {
        "vgg11" | "vgg11-cifar10" => Some(vgg11_cifar()),
        "vgg16" | "vgg16-imagenet" => Some(vgg16_imagenet()),
        "vgg19" | "vgg19-imagenet" => Some(vgg19_imagenet()),
        "resnet18" | "resnet18-cifar10" => Some(resnet18_cifar()),
        "resnet18-imagenet" => Some(resnet18_imagenet()),
        "tiny" | "tiny-cnn" => Some(tiny_cnn()),
        "tiny-mlp" => Some(tiny_mlp()),
        "tiny-resnet" => Some(tiny_resnet()),
        _ => None,
    }
}

/// [`by_name`], with an error that lists every valid name. CLI and
/// serving paths should prefer this over unwrapping the `Option` so a
/// typo tells the user what *is* available.
pub fn lookup(name: &str) -> anyhow::Result<Network> {
    by_name(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown model {name:?}; available models: {}",
            MODEL_NAMES.join(", ")
        )
    })
}

/// Names accepted by [`by_name`].
pub const MODEL_NAMES: &[&str] = &[
    "vgg11-cifar10",
    "resnet18-cifar10",
    "vgg16-imagenet",
    "vgg19-imagenet",
    "resnet18-imagenet",
    "tiny-cnn",
    "tiny-mlp",
    "tiny-resnet",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_zoo_models_shape_check() {
        for name in MODEL_NAMES {
            let net = by_name(name).unwrap();
            let shapes = net.shapes().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!shapes.is_empty(), "{name}");
        }
    }

    #[test]
    fn vgg16_param_and_mac_counts_match_literature() {
        // VGG-16: ~138.3M params (with biases; we count weights only:
        // 138.34M - 13.4k biases ≈ 138.33M), 15.47 GMACs at 224x224.
        let net = vgg16_imagenet();
        let params = net.total_params().unwrap();
        assert!(
            (138_000_000..139_000_000).contains(&params),
            "params = {params}"
        );
        let macs = net.total_macs().unwrap();
        assert!(
            (15_300_000_000..15_600_000_000).contains(&macs),
            "macs = {macs}"
        );
    }

    #[test]
    fn vgg19_mac_count_matches_literature() {
        // VGG-19: ~19.6 GMACs at 224x224, ~143.7M params.
        let net = vgg19_imagenet();
        let macs = net.total_macs().unwrap();
        assert!(
            (19_400_000_000..19_800_000_000).contains(&macs),
            "macs = {macs}"
        );
    }

    #[test]
    fn vgg11_cifar_output_is_ten_classes() {
        let net = vgg11_cifar();
        assert_eq!(net.output_shape().unwrap(), TensorShape::new(10, 1, 1));
    }

    #[test]
    fn resnet18_cifar_structure() {
        let net = resnet18_cifar();
        let shapes = net.shapes().unwrap();
        // Stem output 64x32x32; final fc 10.
        assert_eq!(shapes[0], TensorShape::new(64, 32, 32));
        assert_eq!(*shapes.last().unwrap(), TensorShape::new(10, 1, 1));
        // ResNet-18 CIFAR: ~11.2M weight params.
        let params = net.total_params().unwrap();
        assert!(
            (11_000_000..11_400_000).contains(&params),
            "params = {params}"
        );
        // 8 residual adds.
        let n_res = net
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::ResAdd { .. }))
            .count();
        assert_eq!(n_res, 8);
    }

    #[test]
    fn resnet18_imagenet_shapes() {
        let net = resnet18_imagenet();
        let shapes = net.shapes().unwrap();
        assert_eq!(shapes[0], TensorShape::new(64, 112, 112));
        assert_eq!(shapes[1], TensorShape::new(64, 56, 56));
        assert_eq!(*shapes.last().unwrap(), TensorShape::new(1000, 1, 1));
        // ~1.8 GMACs (conv stem 2x2 pool variant keeps this in range).
        let macs = net.total_macs().unwrap();
        assert!(
            (1_700_000_000..2_000_000_000).contains(&macs),
            "macs = {macs}"
        );
    }

    #[test]
    fn tiny_cnn_is_small_and_valid() {
        let net = tiny_cnn();
        net.shapes().unwrap();
        assert!(net.total_macs().unwrap() < 10_000_000);
        assert_eq!(net.output_shape().unwrap().c, 10);
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("alexnet").is_none());
    }

    #[test]
    fn by_name_is_case_and_separator_insensitive() {
        for alias in ["TINY-CNN", "Tiny_Cnn", "  tiny-cnn  ", "TiNy"] {
            assert_eq!(by_name(alias).unwrap().name, "tiny-cnn", "{alias:?}");
        }
        assert_eq!(by_name("RESNET18_CIFAR10").unwrap().name, "resnet18-cifar10");
    }

    #[test]
    fn lookup_error_lists_available_models() {
        let err = lookup("alexnet").unwrap_err().to_string();
        for name in MODEL_NAMES {
            assert!(err.contains(name), "error {err:?} should list {name}");
        }
        assert_eq!(lookup("tiny-mlp").unwrap().name, "tiny-mlp");
    }

    #[test]
    fn fast_trio_has_distinct_shapes() {
        // The multi-model serving tests rely on the three fast models
        // disagreeing on both input and output geometry.
        let trio = [tiny_cnn(), tiny_mlp(), tiny_resnet()];
        for net in &trio {
            net.shapes().unwrap();
            assert!(net.total_macs().unwrap() < 10_000_000, "{}", net.name);
        }
        let ins: Vec<usize> = trio.iter().map(|n| n.input_len()).collect();
        let outs: Vec<usize> = trio
            .iter()
            .map(|n| n.output_shape().unwrap().c)
            .collect();
        assert_eq!(outs, vec![10, 8, 6]);
        assert!(ins[0] != ins[1] && ins[1] != ins[2] && ins[0] != ins[2]);
    }
}
