//! Fluent builder for [`Network`]s.
//!
//! Keeps the model zoo readable and gives downstream users a concise API:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this environment)
//! use domino::model::{NetworkBuilder, TensorShape};
//! let net = NetworkBuilder::new("demo", TensorShape::new(3, 32, 32))
//!     .conv(16, 3, 1, 1)
//!     .max_pool(2, 2)
//!     .flatten()
//!     .fc_logits(10)
//!     .build();
//! assert!(net.shapes().is_ok());
//! ```

use super::{Layer, LayerKind, Network, TensorShape};

/// Default requantization shift for 8-bit conv/fc accumulations. Chosen so
/// that a full 256-input dot product of bounded int8 values requantizes
/// back into int8 range; the JAX golden model uses the same constant
/// (python/compile/model.py).
pub const DEFAULT_REQUANT_SHIFT: u32 = 7;

pub struct NetworkBuilder {
    name: String,
    input: TensorShape,
    layers: Vec<Layer>,
}

impl NetworkBuilder {
    pub fn new(name: impl Into<String>, input: TensorShape) -> Self {
        Self {
            name: name.into(),
            input,
            layers: Vec::new(),
        }
    }

    fn push(mut self, kind: LayerKind, requant_shift: u32) -> Self {
        let idx = self.layers.len();
        let tag = match &kind {
            LayerKind::Conv2d { .. } => "conv",
            LayerKind::Fc { .. } => "fc",
            LayerKind::MaxPool2d { .. } => "maxpool",
            LayerKind::AvgPool2d { .. } => "avgpool",
            LayerKind::ResAdd { .. } => "res",
            LayerKind::Flatten => "flatten",
        };
        self.layers.push(Layer {
            name: format!("{tag}{idx}"),
            kind,
            requant_shift,
        });
        self
    }

    /// Conv + fused ReLU (the common CNN case).
    pub fn conv(self, out_ch: usize, kernel: usize, stride: usize, padding: usize) -> Self {
        self.push(
            LayerKind::Conv2d {
                out_ch,
                kernel,
                stride,
                padding,
                relu: true,
            },
            DEFAULT_REQUANT_SHIFT,
        )
    }

    /// Conv without activation (e.g. the second conv of a ResNet block,
    /// activated after the residual add).
    pub fn conv_linear(self, out_ch: usize, kernel: usize, stride: usize, padding: usize) -> Self {
        self.push(
            LayerKind::Conv2d {
                out_ch,
                kernel,
                stride,
                padding,
                relu: false,
            },
            DEFAULT_REQUANT_SHIFT,
        )
    }

    /// Conv with an explicit requantization shift.
    pub fn conv_shift(
        self,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        relu: bool,
        shift: u32,
    ) -> Self {
        self.push(
            LayerKind::Conv2d {
                out_ch,
                kernel,
                stride,
                padding,
                relu,
            },
            shift,
        )
    }

    /// FC + fused ReLU.
    pub fn fc(self, out_features: usize) -> Self {
        self.push(
            LayerKind::Fc {
                out_features,
                relu: true,
            },
            DEFAULT_REQUANT_SHIFT,
        )
    }

    /// FC without activation (logits layer).
    pub fn fc_logits(self, out_features: usize) -> Self {
        self.fc_logits_shift(out_features, DEFAULT_REQUANT_SHIFT)
    }

    /// Logits FC with an explicit requantization shift (used by the
    /// calibrated quantizer's deployment path).
    pub fn fc_logits_shift(self, out_features: usize, shift: u32) -> Self {
        self.push(
            LayerKind::Fc {
                out_features,
                relu: false,
            },
            shift,
        )
    }

    pub fn max_pool(self, kernel: usize, stride: usize) -> Self {
        self.push(LayerKind::MaxPool2d { kernel, stride }, 0)
    }

    pub fn avg_pool(self, kernel: usize, stride: usize) -> Self {
        self.push(LayerKind::AvgPool2d { kernel, stride }, 0)
    }

    /// Residual add from the output of layer `from` (absolute index).
    pub fn res_add(self, from: usize) -> Self {
        self.push(LayerKind::ResAdd { from, proj: None }, 0)
    }

    /// Residual add with a 1x1 strided projection on the skip path
    /// (ResNet downsampling blocks). The projection is requantized with
    /// [`DEFAULT_REQUANT_SHIFT`] like any other conv.
    pub fn res_add_proj(self, from: usize, proj: super::Projection) -> Self {
        self.push(
            LayerKind::ResAdd {
                from,
                proj: Some(proj),
            },
            DEFAULT_REQUANT_SHIFT,
        )
    }

    pub fn flatten(self) -> Self {
        self.push(LayerKind::Flatten, 0)
    }

    /// Index the *next* layer will get; used to record skip sources while
    /// building ResNets.
    pub fn next_index(&self) -> usize {
        self.layers.len()
    }

    pub fn build(self) -> Network {
        Network {
            name: self.name,
            input: self.input,
            layers: self.layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_names_layers_by_index() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 8, 8))
            .conv(4, 3, 1, 1)
            .max_pool(2, 2)
            .flatten()
            .fc_logits(10)
            .build();
        assert_eq!(net.layers[0].name, "conv0");
        assert_eq!(net.layers[1].name, "maxpool1");
        assert_eq!(net.layers[2].name, "flatten2");
        assert_eq!(net.layers[3].name, "fc3");
    }

    #[test]
    fn builder_produces_valid_network() {
        let net = NetworkBuilder::new("t", TensorShape::new(3, 32, 32))
            .conv(8, 3, 1, 1)
            .conv_linear(8, 3, 1, 1)
            .res_add(0)
            .max_pool(2, 2)
            .flatten()
            .fc(32)
            .fc_logits(10)
            .build();
        let shapes = net.shapes().unwrap();
        assert_eq!(shapes.last().unwrap().c, 10);
    }

    #[test]
    fn next_index_tracks_layer_count() {
        let b = NetworkBuilder::new("t", TensorShape::new(3, 8, 8)).conv(4, 3, 1, 1);
        assert_eq!(b.next_index(), 1);
    }
}
