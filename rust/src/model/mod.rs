//! DNN graph IR.
//!
//! Domino maps each layer of a feed-forward CNN onto a group of tiles
//! (paper Fig. 1(a)). This module defines the layer graph the compiler
//! consumes: a linear sequence of layers with optional residual skip
//! edges (ResNet), CHW tensor shapes, shape inference, and MAC/parameter
//! accounting used by the evaluation (TOPS, TOPS/W, TOPS/mm²).
//!
//! Quantization model: activations and weights are 8-bit (the paper's
//! evaluation precision); accumulations are 32-bit; each compute layer
//! carries a power-of-two requantization shift, so the entire network is
//! exactly reproducible across the Rust simulator, the Rust reference
//! (`refcompute`) and the JAX/Pallas golden model.

pub mod builder;
pub mod refcompute;
pub mod zoo;

pub use builder::NetworkBuilder;

/// Shape of an activation tensor in CHW order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorShape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl TensorShape {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w }
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::fmt::Display for TensorShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.c, self.h, self.w)
    }
}

/// One layer of the network.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution. Weight tensor is `K x K x C x M` (paper
    /// notation): `kernel` = K, input channels C come from the previous
    /// layer, `out_ch` = M. `relu` fuses the activation applied by the
    /// last tile's ROFM (paper Section III-B).
    Conv2d {
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        relu: bool,
    },
    /// Fully connected layer: `y = xW`, `W in R^{C_in x C_out}`
    /// (paper Section III-A).
    Fc { out_features: usize, relu: bool },
    /// Max pooling (ROFM `Cmp` function, Table II).
    MaxPool2d { kernel: usize, stride: usize },
    /// Average pooling (ROFM `Mul` scaling function, Table II).
    AvgPool2d { kernel: usize, stride: usize },
    /// Residual addition: adds the output of layer `from` (a previous
    /// layer index) to this layer's input. Routed through the RIFM→ROFM
    /// shortcut ("skip" connection, Table II `Bp.`). When the skip path
    /// changes shape (ResNet downsampling blocks) a 1x1 strided
    /// projection convolution is applied to the skip source first; its
    /// weights live in their own tile array like any other conv.
    ResAdd {
        from: usize,
        proj: Option<Projection>,
    },
    /// Flatten CHW to a vector (entering FC layers).
    Flatten,
}

/// 1x1 strided projection on a residual skip path (ResNet downsampling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Projection {
    pub out_ch: usize,
    pub stride: usize,
}

impl Projection {
    /// Output shape of the projection applied to `input` (kernel 1, pad 0).
    pub fn out_shape(&self, input: TensorShape) -> Option<TensorShape> {
        let h = conv_out(input.h, 1, self.stride, 0)?;
        let w = conv_out(input.w, 1, self.stride, 0)?;
        Some(TensorShape::new(self.out_ch, h, w))
    }
}

/// A named layer with quantization metadata.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Power-of-two requantization: `out = clamp(acc >> shift)` applied
    /// after Conv2d / Fc / ResAdd accumulation. Ignored for other kinds.
    pub requant_shift: u32,
}

/// A feed-forward network with optional residual skips.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub input: TensorShape,
    pub layers: Vec<Layer>,
}

/// Error produced by shape inference.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShapeError {
    /// Pool/conv window does not fit the input.
    WindowTooLarge { layer: usize, detail: String },
    /// A ResAdd references a layer whose shape mismatches.
    ResShapeMismatch {
        layer: usize,
        from: usize,
        got: TensorShape,
        want: TensorShape,
    },
    /// A ResAdd references a non-existent or future layer.
    BadResIndex { layer: usize, from: usize },
    /// An FC layer was applied to an unflattened tensor.
    FcOnSpatial { layer: usize },
}

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShapeError::WindowTooLarge { layer, detail } => {
                write!(f, "layer {layer}: window too large: {detail}")
            }
            ShapeError::ResShapeMismatch {
                layer,
                from,
                got,
                want,
            } => write!(
                f,
                "layer {layer}: residual from layer {from} has shape {got}, expected {want}"
            ),
            ShapeError::BadResIndex { layer, from } => {
                write!(f, "layer {layer}: residual index {from} out of range")
            }
            ShapeError::FcOnSpatial { layer } => {
                write!(f, "layer {layer}: FC applied to spatial tensor (missing Flatten?)")
            }
        }
    }
}

impl std::error::Error for ShapeError {}

/// Convolution output size: `floor((in + 2p - k)/s) + 1`.
pub fn conv_out(input: usize, kernel: usize, stride: usize, padding: usize) -> Option<usize> {
    let padded = input + 2 * padding;
    if padded < kernel || stride == 0 {
        return None;
    }
    Some((padded - kernel) / stride + 1)
}

impl Network {
    /// Number of elements in the input tensor.
    pub fn input_len(&self) -> usize {
        self.input.len()
    }

    /// Infer the output shape of every layer. `shapes()[i]` is the output
    /// shape of layer `i`; the input shape is `self.input`.
    pub fn shapes(&self) -> Result<Vec<TensorShape>, ShapeError> {
        let mut shapes: Vec<TensorShape> = Vec::with_capacity(self.layers.len());
        let mut cur = self.input;
        for (i, layer) in self.layers.iter().enumerate() {
            cur = match &layer.kind {
                LayerKind::Conv2d {
                    out_ch,
                    kernel,
                    stride,
                    padding,
                    ..
                } => {
                    let h = conv_out(cur.h, *kernel, *stride, *padding).ok_or_else(|| {
                        ShapeError::WindowTooLarge {
                            layer: i,
                            detail: format!("conv k={kernel} s={stride} p={padding} on {cur}"),
                        }
                    })?;
                    let w = conv_out(cur.w, *kernel, *stride, *padding).ok_or_else(|| {
                        ShapeError::WindowTooLarge {
                            layer: i,
                            detail: format!("conv k={kernel} s={stride} p={padding} on {cur}"),
                        }
                    })?;
                    TensorShape::new(*out_ch, h, w)
                }
                LayerKind::Fc { out_features, .. } => {
                    if cur.h != 1 || cur.w != 1 {
                        return Err(ShapeError::FcOnSpatial { layer: i });
                    }
                    TensorShape::new(*out_features, 1, 1)
                }
                LayerKind::MaxPool2d { kernel, stride }
                | LayerKind::AvgPool2d { kernel, stride } => {
                    let h = conv_out(cur.h, *kernel, *stride, 0).ok_or_else(|| {
                        ShapeError::WindowTooLarge {
                            layer: i,
                            detail: format!("pool k={kernel} s={stride} on {cur}"),
                        }
                    })?;
                    let w = conv_out(cur.w, *kernel, *stride, 0).ok_or_else(|| {
                        ShapeError::WindowTooLarge {
                            layer: i,
                            detail: format!("pool k={kernel} s={stride} on {cur}"),
                        }
                    })?;
                    TensorShape::new(cur.c, h, w)
                }
                LayerKind::ResAdd { from, proj } => {
                    if *from >= i {
                        return Err(ShapeError::BadResIndex { layer: i, from: *from });
                    }
                    let src = shapes[*from];
                    let skip = match proj {
                        Some(p) => p.out_shape(src).ok_or_else(|| ShapeError::WindowTooLarge {
                            layer: i,
                            detail: format!("projection s={} on {src}", p.stride),
                        })?,
                        None => src,
                    };
                    if skip != cur {
                        return Err(ShapeError::ResShapeMismatch {
                            layer: i,
                            from: *from,
                            got: skip,
                            want: cur,
                        });
                    }
                    cur
                }
                LayerKind::Flatten => TensorShape::new(cur.len(), 1, 1),
            };
            shapes.push(cur);
        }
        Ok(shapes)
    }

    /// Output shape of the whole network.
    pub fn output_shape(&self) -> Result<TensorShape, ShapeError> {
        Ok(self
            .shapes()?
            .last()
            .copied()
            .unwrap_or(self.input))
    }

    /// MACs per layer (one MAC = one multiply-accumulate). Layers without
    /// MACs (pool/flatten/res) report 0; following the paper's TOPS
    /// convention, 1 MAC = 2 ops.
    pub fn macs_per_layer(&self) -> Result<Vec<u64>, ShapeError> {
        let shapes = self.shapes()?;
        let mut in_shape = self.input;
        let mut macs = Vec::with_capacity(self.layers.len());
        for (i, layer) in self.layers.iter().enumerate() {
            let out = shapes[i];
            let m = match &layer.kind {
                LayerKind::Conv2d { out_ch, kernel, .. } => {
                    (kernel * kernel * in_shape.c * out_ch) as u64 * (out.h * out.w) as u64
                }
                LayerKind::Fc { out_features, .. } => (in_shape.c * out_features) as u64,
                LayerKind::ResAdd {
                    from,
                    proj: Some(p),
                } => {
                    // 1x1 projection conv on the skip path.
                    let src = shapes[*from];
                    (src.c * p.out_ch) as u64 * (out.h * out.w) as u64
                }
                _ => 0,
            };
            macs.push(m);
            in_shape = out;
        }
        Ok(macs)
    }

    /// Total MACs for one inference.
    pub fn total_macs(&self) -> Result<u64, ShapeError> {
        Ok(self.macs_per_layer()?.iter().sum())
    }

    /// Total ops (2 x MACs, the paper's TOPS convention).
    pub fn total_ops(&self) -> Result<u64, ShapeError> {
        Ok(2 * self.total_macs()?)
    }

    /// Weight parameters per layer (biases are not modeled; the paper's
    /// CIM arrays store weights only).
    pub fn params_per_layer(&self) -> Result<Vec<u64>, ShapeError> {
        let shapes = self.shapes()?;
        let mut in_shape = self.input;
        let mut params = Vec::with_capacity(self.layers.len());
        for (i, layer) in self.layers.iter().enumerate() {
            let p = match &layer.kind {
                LayerKind::Conv2d { out_ch, kernel, .. } => {
                    (kernel * kernel * in_shape.c * out_ch) as u64
                }
                LayerKind::Fc { out_features, .. } => (in_shape.c * out_features) as u64,
                LayerKind::ResAdd {
                    from,
                    proj: Some(pr),
                } => (shapes[*from].c * pr.out_ch) as u64,
                _ => 0,
            };
            params.push(p);
            in_shape = shapes[i];
        }
        Ok(params)
    }

    /// Total weight parameters.
    pub fn total_params(&self) -> Result<u64, ShapeError> {
        Ok(self.params_per_layer()?.iter().sum())
    }

    /// Indices of layers that hold weights (Conv2d / Fc / projected
    /// ResAdd), i.e. the layers the Domino mapper allocates tile arrays
    /// for.
    pub fn weight_layers(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| {
                matches!(
                    l.kind,
                    LayerKind::Conv2d { .. }
                        | LayerKind::Fc { .. }
                        | LayerKind::ResAdd { proj: Some(_), .. }
                )
            })
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(out_ch: usize, k: usize, s: usize, p: usize) -> Layer {
        Layer {
            name: "conv".into(),
            kind: LayerKind::Conv2d {
                out_ch,
                kernel: k,
                stride: s,
                padding: p,
                relu: true,
            },
            requant_shift: 7,
        }
    }

    #[test]
    fn conv_out_matches_standard_formula() {
        assert_eq!(conv_out(32, 3, 1, 1), Some(32));
        assert_eq!(conv_out(32, 3, 2, 1), Some(16));
        assert_eq!(conv_out(224, 7, 2, 3), Some(112));
        assert_eq!(conv_out(2, 3, 1, 0), None);
        assert_eq!(conv_out(4, 3, 0, 0), None);
    }

    #[test]
    fn shape_inference_simple_chain() {
        let net = Network {
            name: "t".into(),
            input: TensorShape::new(3, 32, 32),
            layers: vec![
                conv(16, 3, 1, 1),
                Layer {
                    name: "pool".into(),
                    kind: LayerKind::MaxPool2d { kernel: 2, stride: 2 },
                    requant_shift: 0,
                },
                Layer {
                    name: "flat".into(),
                    kind: LayerKind::Flatten,
                    requant_shift: 0,
                },
                Layer {
                    name: "fc".into(),
                    kind: LayerKind::Fc {
                        out_features: 10,
                        relu: false,
                    },
                    requant_shift: 7,
                },
            ],
        };
        let shapes = net.shapes().unwrap();
        assert_eq!(shapes[0], TensorShape::new(16, 32, 32));
        assert_eq!(shapes[1], TensorShape::new(16, 16, 16));
        assert_eq!(shapes[2], TensorShape::new(16 * 16 * 16, 1, 1));
        assert_eq!(shapes[3], TensorShape::new(10, 1, 1));
    }

    #[test]
    fn macs_and_params_counts() {
        let net = Network {
            name: "t".into(),
            input: TensorShape::new(3, 8, 8),
            layers: vec![conv(4, 3, 1, 1)],
        };
        // 3*3*3*4 params, x 8*8 output positions
        assert_eq!(net.total_params().unwrap(), 108);
        assert_eq!(net.total_macs().unwrap(), 108 * 64);
        assert_eq!(net.total_ops().unwrap(), 2 * 108 * 64);
    }

    #[test]
    fn fc_on_spatial_is_rejected() {
        let net = Network {
            name: "t".into(),
            input: TensorShape::new(3, 8, 8),
            layers: vec![Layer {
                name: "fc".into(),
                kind: LayerKind::Fc {
                    out_features: 10,
                    relu: false,
                },
                requant_shift: 7,
            }],
        };
        assert!(matches!(net.shapes(), Err(ShapeError::FcOnSpatial { layer: 0 })));
    }

    #[test]
    fn res_add_shape_checked() {
        let net = Network {
            name: "t".into(),
            input: TensorShape::new(4, 8, 8),
            layers: vec![
                conv(4, 3, 1, 1),
                conv(4, 3, 1, 1),
                Layer {
                    name: "res".into(),
                    kind: LayerKind::ResAdd { from: 0, proj: None },
                    requant_shift: 0,
                },
            ],
        };
        assert!(net.shapes().is_ok());

        let bad = Network {
            name: "t".into(),
            input: TensorShape::new(4, 8, 8),
            layers: vec![
                conv(8, 3, 1, 1),
                conv(4, 3, 1, 1),
                Layer {
                    name: "res".into(),
                    kind: LayerKind::ResAdd { from: 0, proj: None },
                    requant_shift: 0,
                },
            ],
        };
        assert!(matches!(
            bad.shapes(),
            Err(ShapeError::ResShapeMismatch { .. })
        ));
    }

    #[test]
    fn res_add_future_index_rejected() {
        let net = Network {
            name: "t".into(),
            input: TensorShape::new(4, 8, 8),
            layers: vec![Layer {
                name: "res".into(),
                kind: LayerKind::ResAdd { from: 0, proj: None },
                requant_shift: 0,
            }],
        };
        assert!(matches!(net.shapes(), Err(ShapeError::BadResIndex { .. })));
    }

    #[test]
    fn weight_layers_are_conv_and_fc_only() {
        let net = Network {
            name: "t".into(),
            input: TensorShape::new(3, 8, 8),
            layers: vec![
                conv(4, 3, 1, 1),
                Layer {
                    name: "pool".into(),
                    kind: LayerKind::MaxPool2d { kernel: 2, stride: 2 },
                    requant_shift: 0,
                },
                Layer {
                    name: "flat".into(),
                    kind: LayerKind::Flatten,
                    requant_shift: 0,
                },
                Layer {
                    name: "fc".into(),
                    kind: LayerKind::Fc {
                        out_features: 10,
                        relu: false,
                    },
                    requant_shift: 7,
                },
            ],
        };
        assert_eq!(net.weight_layers(), vec![0, 3]);
    }
}
