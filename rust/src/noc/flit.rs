//! Flit-level mesh model — the Noxim substitution.
//!
//! The paper obtains on-chip data-transmission energy from Noxim, a
//! flit-accurate NoC simulator. The slot-level engine (`crate::sim`)
//! charges link bits analytically; this module provides the missing
//! *contention* fidelity: it replays a compiled stage's steady-state
//! traffic as flits through wormhole routers with finite input buffers,
//! XY routing and credit flow control, and verifies that the COM
//! schedule's traffic actually fits the paper's 40 Gb/s inter-tile
//! links with bounded queueing — the physical assumption behind the
//! periodic-schedule model (one IFM beat + one psum beat per 2-cycle
//! slot).
//!
//! Link arithmetic (Section IV-A): 40 Gb/s per link at a 10 MHz
//! instruction step = 4000 bits per step per link = one
//! [`FLIT_BITS`]-bit flit per *peripheral* cycle (160 MHz FDM, 16
//! peripheral cycles per step: 16 x 250 b = 4000 b).

use std::collections::VecDeque;

use crate::coordinator::program::{Program, StageKind};
use crate::coordinator::schedule::ConvGeometry;
use crate::noc::{Coord, Dir};

/// Flit payload in bits: 250 b x 16 peripheral cycles = 4000 b/step.
pub const FLIT_BITS: u64 = 250;
/// Peripheral (flit) cycles per 10 MHz instruction step.
pub const FLITS_PER_STEP: u64 = 16;
/// Input-buffer depth per port, in flits (2 x 64 b regs x ... modeled
/// as a small wormhole buffer; Table III lists 64 b x 2 input buffers,
/// we allow 8 flits of elasticity like Noxim's default 8-flit FIFO).
pub const BUFFER_FLITS: usize = 8;

/// Which of the two physical router networks a flow rides. The dual
/// routers are the paper's first listed contribution ("Domino changes
/// the conventional NoC tile structure by using dual routers for
/// different usages"): IFM beats travel RIFM-to-RIFM while psum/OFM
/// beats travel ROFM-to-ROFM, on separate links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterNet {
    Rifm,
    Rofm,
}

/// One traffic demand: `bits` injected at `src` toward `dst`, every
/// `period_steps` instruction steps.
#[derive(Clone, Copy, Debug)]
pub struct Flow {
    pub src: Coord,
    pub dst: Coord,
    pub bits_per_period: u64,
    pub period_steps: u64,
    pub net: RouterNet,
}

impl Flow {
    /// Offered load on each traversed link, in flits per step.
    pub fn flits_per_step(&self) -> f64 {
        (self.bits_per_period as f64 / FLIT_BITS as f64) / self.period_steps as f64
    }
}

/// Extract the steady-state flow set of a compiled program: one flow
/// per active link of every conv/FC chain (IFM forwarding beats +
/// psum/OFM hand-offs), at the stage's pipelined rate.
pub fn program_flows(program: &Program) -> Vec<Flow> {
    let mut flows = Vec::new();
    // FC columns move one vector per image: their period is the
    // pipeline's image period (the slowest conv stream), not a pixel
    // slot.
    let image_period_steps = program
        .stages
        .iter()
        .filter_map(|s| match &s.kind {
            StageKind::Conv(c) => {
                let g = ConvGeometry::new(c.k, c.stride, c.padding, c.in_shape.h, c.in_shape.w);
                Some(2 * (g.stream_slots() as u64).div_ceil(c.dup as u64))
            }
            _ => None,
        })
        .max()
        .unwrap_or(2)
        .max(2);
    for stage in &program.stages {
        match &stage.kind {
            StageKind::Conv(c) => conv_flows(c, &mut flows),
            StageKind::Fc(f) => {
                for col in &f.columns {
                    for pair in col.tiles.windows(2) {
                        flows.push(Flow {
                            src: pair[0].coord,
                            dst: pair[1].coord,
                            bits_per_period: (pair[1].cols * 32) as u64,
                            period_steps: image_period_steps,
                            net: RouterNet::Rofm,
                        });
                    }
                }
            }
            StageKind::Res(r) => {
                if let Some(p) = &r.proj {
                    conv_flows(p, &mut flows);
                }
            }
            _ => {}
        }
    }
    flows
}

fn conv_flows(c: &crate::coordinator::program::ConvStage, flows: &mut Vec<Flow>) {
    let g = ConvGeometry::new(c.k, c.stride, c.padding, c.in_shape.h, c.in_shape.w);
    // steady state: one pixel slot per 2 steps; with duplication the
    // replicas each carry 1/dup of the rate (same per-link load)
    let slot_steps = 2u64;
    let valid_frac = (g.out_h * g.out_w) as f64 / g.stream_slots() as f64;
    for chain in &c.chains {
        for pair in chain.tiles.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            if a.coord.chip != b.coord.chip {
                continue; // inter-chip: serial transceivers, not mesh
            }
            // IFM forwarding beat (RIFM net; one physical beat per
            // `pack` pixel slots under in-buffer shifting)
            let pack = match a.rifm.shift_step {
                64 => 4u64,
                128 => 2,
                _ => 1,
            };
            flows.push(Flow {
                src: a.coord,
                dst: b.coord,
                bits_per_period: (a.rows * 8) as u64 * pack,
                period_steps: slot_steps * pack,
                net: RouterNet::Rifm,
            });
            // psum beat (ROFM net; valid slots only)
            flows.push(Flow {
                src: a.coord,
                dst: b.coord,
                bits_per_period: ((a.cols * 32) as f64 * valid_frac) as u64,
                period_steps: slot_steps,
                net: RouterNet::Rofm,
            });
        }
    }
}

/// Static link-utilization analysis: accumulate every flow's offered
/// load over the XY path between its endpoints; report the worst link.
#[derive(Clone, Debug)]
pub struct LinkReport {
    /// (from, to) of the most loaded link.
    pub hottest: (Coord, Coord),
    /// Offered load of the hottest link (flits/step; capacity is
    /// [`FLITS_PER_STEP`]).
    pub peak_flits_per_step: f64,
    /// Utilization of the hottest link (1.0 = saturated 40 Gb/s).
    pub peak_utilization: f64,
    /// Number of distinct links carrying traffic.
    pub active_links: usize,
    /// Mean utilization over active links.
    pub mean_utilization: f64,
}

/// XY route between two same-chip coordinates (col first, then row —
/// dimension-ordered, deadlock-free).
pub fn xy_route(a: Coord, b: Coord) -> Vec<Coord> {
    assert_eq!(a.chip, b.chip, "xy_route is intra-chip");
    let mut path = vec![a];
    let mut cur = a;
    while cur.col != b.col {
        cur.col = if b.col > cur.col { cur.col + 1 } else { cur.col - 1 };
        path.push(cur);
    }
    while cur.row != b.row {
        cur.row = if b.row > cur.row { cur.row + 1 } else { cur.row - 1 };
        path.push(cur);
    }
    path
}

/// Dual-router analysis: per-network utilization plus the
/// what-if-single-router combined load (the conventional NoC the paper
/// argues against).
#[derive(Clone, Debug)]
pub struct DualRouterReport {
    pub rifm: LinkReport,
    pub rofm: LinkReport,
    /// Both traffic classes forced onto one physical network.
    pub single_router: LinkReport,
}

/// Evaluate the paper's dual-router claim on a flow set.
pub fn dual_router_report(flows: &[Flow]) -> DualRouterReport {
    let rifm: Vec<Flow> = flows.iter().copied().filter(|f| f.net == RouterNet::Rifm).collect();
    let rofm: Vec<Flow> = flows.iter().copied().filter(|f| f.net == RouterNet::Rofm).collect();
    DualRouterReport {
        rifm: link_utilization(&rifm),
        rofm: link_utilization(&rofm),
        single_router: link_utilization(flows),
    }
}

/// Accumulate flows over XY paths.
pub fn link_utilization(flows: &[Flow]) -> LinkReport {
    use std::collections::HashMap;
    let mut load: HashMap<(Coord, Coord), f64> = HashMap::new();
    for f in flows {
        if f.src.chip != f.dst.chip {
            continue;
        }
        let path = xy_route(f.src, f.dst);
        for w in path.windows(2) {
            *load.entry((w[0], w[1])).or_default() += f.flits_per_step();
        }
    }
    let (hottest, peak) = load
        .iter()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(&k, &v)| (k, v))
        .unwrap_or(((Coord::new(0, 0, 0), Coord::new(0, 0, 0)), 0.0));
    let mean = if load.is_empty() {
        0.0
    } else {
        load.values().sum::<f64>() / load.len() as f64
    };
    LinkReport {
        hottest,
        peak_flits_per_step: peak,
        peak_utilization: peak / FLITS_PER_STEP as f64,
        active_links: load.len(),
        mean_utilization: mean / FLITS_PER_STEP as f64,
    }
}

// ------------------------------------------------------------------
// Dynamic flit simulation (wormhole, credit-based)
// ------------------------------------------------------------------

/// A flit in flight.
#[derive(Clone, Copy, Debug)]
struct Flit {
    dst: Coord,
    injected_at: u64,
}

/// One router port's input FIFO.
#[derive(Clone, Debug, Default)]
struct PortFifo {
    q: VecDeque<Flit>,
}

/// Flit-accurate mesh simulation results.
#[derive(Clone, Copy, Debug)]
pub struct FlitSimReport {
    pub cycles: u64,
    pub flits_delivered: u64,
    pub flits_dropped_at_injection: u64,
    pub max_latency: u64,
    pub mean_latency: f64,
    /// Peak occupancy observed across all port FIFOs.
    pub peak_queue: usize,
}

/// Simulate `steps` instruction steps of the flow set on a
/// `rows x cols` single-chip mesh with wormhole XY routing, one flit
/// per link per peripheral cycle, and 8-flit input FIFOs with
/// backpressure. Deterministic: flows inject round-robin on their
/// period schedule.
pub fn simulate_flits(
    flows: &[Flow],
    rows: usize,
    cols: usize,
    steps: u64,
) -> FlitSimReport {
    // per-node, per-direction input fifos
    let idx = |c: Coord| c.row * cols + c.col;
    let n = rows * cols;
    let mut fifos: Vec<[PortFifo; 5]> = (0..n)
        .map(|_| std::array::from_fn(|_| PortFifo::default()))
        .collect();
    const LOCAL: usize = 4;
    let dir_ix = |d: Dir| match d {
        Dir::North => 0,
        Dir::East => 1,
        Dir::South => 2,
        Dir::West => 3,
    };

    let mut delivered = 0u64;
    let mut dropped = 0u64;
    let mut lat_sum = 0u64;
    let mut lat_max = 0u64;
    let mut peak_queue = 0usize;

    // precompute per-flow flit count per period
    let per_period: Vec<u64> = flows
        .iter()
        .map(|f| f.bits_per_period.div_ceil(FLIT_BITS))
        .collect();

    let total_cycles = steps * FLITS_PER_STEP;
    for cycle in 0..total_cycles {
        let step = cycle / FLITS_PER_STEP;
        // 1. injection at period boundaries (first cycles of the step)
        for (fi, f) in flows.iter().enumerate() {
            if f.src.chip != 0 || f.dst.chip != 0 {
                continue;
            }
            if step % f.period_steps == 0 {
                let k = cycle % FLITS_PER_STEP;
                if k < per_period[fi].min(FLITS_PER_STEP) {
                    let fifo = &mut fifos[idx(f.src)][LOCAL];
                    if fifo.q.len() < BUFFER_FLITS * 4 {
                        fifo.q.push_back(Flit {
                            dst: f.dst,
                            injected_at: cycle,
                        });
                    } else {
                        dropped += 1;
                    }
                }
            }
        }

        // 2. route: each router forwards at most one flit per output
        //    link per cycle (XY: cols first)
        // collect moves (input-port arbitration: round-robin by cycle)
        let mut moves: Vec<(usize, usize, Flit, Option<usize>)> = Vec::new();
        let mut out_claimed: Vec<[bool; 5]> = vec![[false; 5]; n];
        for node in 0..n {
            let (r, c) = (node / cols, node % cols);
            let here = Coord::new(0, r, c);
            for p in 0..5 {
                let port = (p + cycle as usize) % 5; // rotate priority
                let Some(&flit) = fifos[node][port].q.front() else {
                    continue;
                };
                // next hop by XY
                let out_dir = if flit.dst.col != c {
                    Some(if flit.dst.col > c { Dir::East } else { Dir::West })
                } else if flit.dst.row != r {
                    Some(if flit.dst.row > r { Dir::South } else { Dir::North })
                } else {
                    None // arrived
                };
                match out_dir {
                    None => {
                        if !out_claimed[node][LOCAL] {
                            out_claimed[node][LOCAL] = true;
                            moves.push((node, port, flit, None));
                        }
                    }
                    Some(d) => {
                        let nr = match d {
                            Dir::North => r.wrapping_sub(1),
                            Dir::South => r + 1,
                            _ => r,
                        };
                        let nc = match d {
                            Dir::East => c + 1,
                            Dir::West => c.wrapping_sub(1),
                            _ => c,
                        };
                        if nr >= rows || nc >= cols {
                            continue; // mis-specified flow; hold
                        }
                        let nnode = nr * cols + nc;
                        let in_port = dir_ix(d.opposite());
                        // credit: room in the downstream fifo?
                        if !out_claimed[node][dir_ix(d)]
                            && fifos[nnode][in_port].q.len() < BUFFER_FLITS
                        {
                            out_claimed[node][dir_ix(d)] = true;
                            moves.push((node, port, flit, Some(nnode * 8 + in_port)));
                        }
                    }
                }
                let _ = here;
            }
        }
        for (node, port, flit, dst) in moves {
            fifos[node][port].q.pop_front();
            match dst {
                None => {
                    delivered += 1;
                    let lat = cycle - flit.injected_at;
                    lat_sum += lat;
                    lat_max = lat_max.max(lat);
                }
                Some(enc) => {
                    fifos[enc / 8][enc % 8].q.push_back(flit);
                }
            }
        }
        for node in &fifos {
            for p in node {
                peak_queue = peak_queue.max(p.q.len());
            }
        }
    }

    FlitSimReport {
        cycles: total_cycles,
        flits_delivered: delivered,
        flits_dropped_at_injection: dropped,
        max_latency: lat_max,
        mean_latency: if delivered > 0 {
            lat_sum as f64 / delivered as f64
        } else {
            0.0
        },
        peak_queue,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Compiler;
    use crate::model::zoo;

    #[test]
    fn xy_route_is_dimension_ordered() {
        let p = xy_route(Coord::new(0, 0, 0), Coord::new(0, 2, 3));
        assert_eq!(p.len(), 6);
        // cols first
        assert_eq!(p[1], Coord::new(0, 0, 1));
        assert_eq!(p[3], Coord::new(0, 0, 3));
        assert_eq!(p[5], Coord::new(0, 2, 3));
    }

    #[test]
    fn single_flow_utilization() {
        let f = Flow {
            src: Coord::new(0, 0, 0),
            dst: Coord::new(0, 0, 1),
            bits_per_period: 4000,
            period_steps: 2,
            net: RouterNet::Rofm,
        };
        let r = link_utilization(&[f]);
        assert_eq!(r.active_links, 1);
        // 4000 b / 250 b = 16 flits per 2 steps = 8 flits/step = 50%
        assert!((r.peak_utilization - 0.5).abs() < 1e-9);
    }

    #[test]
    fn com_schedule_fits_the_dual_router_links() {
        // The paper's core bandwidth claim: with IFM beats on the RIFM
        // network and psum beats on the ROFM network, COM traffic never
        // oversubscribes the 40 Gb/s links.
        for (net, _) in zoo::table4_workloads() {
            let p = Compiler::default().compile_analysis(&net).unwrap();
            let r = dual_router_report(&program_flows(&p));
            assert!(
                r.rifm.peak_utilization <= 1.0 + 1e-9,
                "{}: RIFM peak {:.2}",
                net.name,
                r.rifm.peak_utilization
            );
            assert!(
                r.rofm.peak_utilization <= 1.0 + 1e-9,
                "{}: ROFM peak {:.2}",
                net.name,
                r.rofm.peak_utilization
            );
        }
    }

    #[test]
    fn single_router_would_oversubscribe() {
        // ...and a conventional single-router tile would NOT fit the
        // same traffic on ImageNet-scale maps (deep layers stream
        // near-full valid fractions with 256-wide psums): the
        // architectural justification for the paper's dual-router
        // contribution, reproduced.
        let p = Compiler::default().compile_analysis(&zoo::vgg16_imagenet()).unwrap();
        let r = dual_router_report(&program_flows(&p));
        assert!(
            r.single_router.peak_utilization > 1.0,
            "combined load {:.3} should exceed one link",
            r.single_router.peak_utilization
        );
        assert!(r.rifm.peak_utilization <= 1.0);
        assert!(r.rofm.peak_utilization <= 1.0);
    }

    #[test]
    fn flit_sim_delivers_under_capacity() {
        let flows = vec![
            Flow {
                src: Coord::new(0, 0, 0),
                dst: Coord::new(0, 1, 2),
                bits_per_period: 2000,
                period_steps: 2,
                net: RouterNet::Rofm,
            },
            Flow {
                src: Coord::new(0, 1, 0),
                dst: Coord::new(0, 0, 2),
                bits_per_period: 2000,
                period_steps: 2,
                net: RouterNet::Rofm,
            },
        ];
        let r = simulate_flits(&flows, 3, 3, 50);
        assert_eq!(r.flits_dropped_at_injection, 0);
        assert!(r.flits_delivered > 0);
        // uncontended XY: latency ≈ hops, far below a period
        assert!(r.mean_latency < 16.0, "mean latency {}", r.mean_latency);
        assert!(r.peak_queue <= BUFFER_FLITS);
    }

    #[test]
    fn flit_sim_backpressures_oversubscription() {
        // two full-rate flows sharing one link: backpressure, deep
        // queues and rising latency — the regime COM's placement avoids
        let flows = vec![
            Flow {
                src: Coord::new(0, 0, 0),
                dst: Coord::new(0, 0, 3),
                bits_per_period: 4000,
                period_steps: 1,
                net: RouterNet::Rofm,
            },
            Flow {
                src: Coord::new(0, 0, 1),
                dst: Coord::new(0, 0, 3),
                bits_per_period: 4000,
                period_steps: 1,
                net: RouterNet::Rofm,
            },
        ];
        let r = simulate_flits(&flows, 1, 4, 100);
        assert!(
            r.flits_dropped_at_injection > 0 || r.peak_queue >= BUFFER_FLITS,
            "oversubscribed link must back up: {r:?}"
        );
    }

    #[test]
    fn tiny_cnn_flit_sim_matches_static_analysis() {
        let p = Compiler::default().compile_analysis(&zoo::tiny_cnn()).unwrap();
        let flows: Vec<Flow> = program_flows(&p)
            .into_iter()
            .filter(|f| f.src.chip == 0 && f.dst.chip == 0)
            .collect();
        let stat = link_utilization(&flows);
        assert!(stat.peak_utilization <= 1.0);
        let r = simulate_flits(&flows, 15, 16, 40);
        assert_eq!(
            r.flits_dropped_at_injection, 0,
            "under-capacity traffic must not drop"
        );
        assert!(r.peak_queue <= BUFFER_FLITS);
    }
}
