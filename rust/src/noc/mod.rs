//! 2-D mesh NoC topology: coordinates, directions, placement and link
//! accounting (paper Fig. 1(a): tiles interconnected in a 2-D mesh, a
//! layer mapped to a contiguous group of tiles).

pub mod flit;
pub mod link;
pub mod packet;

pub use link::{InterChipLink, LinkKind};
pub use packet::{IfmPacket, OfmPacket, Packet, PsumArena, PsumPacket, PsumRef};

/// Mesh coordinate (row, col) of a tile; `chip` distinguishes chips when
/// a network does not fit on one (Table IV: "240 x N chips").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord {
    pub chip: usize,
    pub row: usize,
    pub col: usize,
}

impl Coord {
    pub fn new(chip: usize, row: usize, col: usize) -> Self {
        Self { chip, row, col }
    }

    /// Manhattan distance within a chip; `None` across chips (inter-chip
    /// hops go through the serial transceivers instead of the mesh).
    pub fn hops(&self, other: &Coord) -> Option<usize> {
        (self.chip == other.chip).then(|| {
            self.row.abs_diff(other.row) + self.col.abs_diff(other.col)
        })
    }
}

impl std::fmt::Display for Coord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "c{}({},{})", self.chip, self.row, self.col)
    }
}

/// Port directions of the RIFM/ROFM routers (paper Fig. 1(b): I/O ports
/// in four directions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    North,
    East,
    South,
    West,
}

impl Dir {
    pub const ALL: [Dir; 4] = [Dir::North, Dir::East, Dir::South, Dir::West];

    pub fn opposite(self) -> Dir {
        match self {
            Dir::North => Dir::South,
            Dir::South => Dir::North,
            Dir::East => Dir::West,
            Dir::West => Dir::East,
        }
    }

    /// The direction from `a` to an adjacent `b`, if adjacent.
    pub fn between(a: Coord, b: Coord) -> Option<Dir> {
        if a.chip != b.chip {
            return None;
        }
        match (
            b.row as isize - a.row as isize,
            b.col as isize - a.col as isize,
        ) {
            (-1, 0) => Some(Dir::North),
            (1, 0) => Some(Dir::South),
            (0, 1) => Some(Dir::East),
            (0, -1) => Some(Dir::West),
            _ => None,
        }
    }
}

/// Serpentine (boustrophedon) placement of a chain of `n` tiles into a
/// mesh of width `mesh_cols`, starting at tile index `start` (flattened).
/// Consecutive chain positions are always mesh-adjacent, which is what
/// makes every partial-sum hop a single-link traversal — the physical
/// basis of the COM dataflow's locality claim.
pub fn serpentine(start: usize, n: usize, mesh_cols: usize, tiles_per_chip: usize) -> Vec<Coord> {
    assert!(mesh_cols > 0 && tiles_per_chip >= mesh_cols);
    (0..n)
        .map(|i| {
            let flat = start + i;
            let chip = flat / tiles_per_chip;
            let within = flat % tiles_per_chip;
            let row = within / mesh_cols;
            let col_in_row = within % mesh_cols;
            // odd rows run right-to-left so row transitions stay adjacent
            let col = if row % 2 == 0 {
                col_in_row
            } else {
                mesh_cols - 1 - col_in_row
            };
            Coord::new(chip, row, col)
        })
        .collect()
}

/// Column-serpentine placement: the same boustrophedon walk as
/// [`serpentine`], rotated 90° — chains run *down* columns (odd columns
/// bottom-to-top), so consecutive chain positions are still always
/// mesh-adjacent but the traffic landscape is transposed: long chains
/// stack their psum hops on vertical links instead of horizontal ones.
/// This is the mapping explorer's alternative `Placement` strategy.
pub fn column_major(start: usize, n: usize, mesh_cols: usize, tiles_per_chip: usize) -> Vec<Coord> {
    assert!(mesh_cols > 0 && tiles_per_chip >= mesh_cols);
    let mesh_rows = tiles_per_chip.div_ceil(mesh_cols);
    (0..n)
        .map(|i| {
            let flat = start + i;
            let chip = flat / tiles_per_chip;
            let within = flat % tiles_per_chip;
            let col = within / mesh_rows;
            let row_in_col = within % mesh_rows;
            // odd columns run bottom-to-top so column transitions stay
            // adjacent
            let row = if col % 2 == 0 {
                row_in_col
            } else {
                mesh_rows - 1 - row_in_col
            };
            Coord::new(chip, row, col)
        })
        .collect()
}

/// Check that consecutive coords of a chain are mesh-adjacent (or cross a
/// chip boundary, which uses the inter-chip transceivers).
pub fn chain_is_local(coords: &[Coord]) -> bool {
    coords.windows(2).all(|w| {
        w[0].chip != w[1].chip || w[0].hops(&w[1]) == Some(1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::for_all;

    #[test]
    fn hops_same_chip() {
        let a = Coord::new(0, 1, 2);
        let b = Coord::new(0, 3, 5);
        assert_eq!(a.hops(&b), Some(5));
        let c = Coord::new(1, 1, 2);
        assert_eq!(a.hops(&c), None);
    }

    #[test]
    fn dir_opposites() {
        for d in Dir::ALL {
            assert_eq!(d.opposite().opposite(), d);
        }
    }

    #[test]
    fn dir_between_adjacent() {
        let a = Coord::new(0, 2, 2);
        assert_eq!(Dir::between(a, Coord::new(0, 1, 2)), Some(Dir::North));
        assert_eq!(Dir::between(a, Coord::new(0, 3, 2)), Some(Dir::South));
        assert_eq!(Dir::between(a, Coord::new(0, 2, 3)), Some(Dir::East));
        assert_eq!(Dir::between(a, Coord::new(0, 2, 1)), Some(Dir::West));
        assert_eq!(Dir::between(a, Coord::new(0, 3, 3)), None);
    }

    #[test]
    fn serpentine_chains_are_mesh_local() {
        for_all("serpentine_local", 50, |rng| {
            let cols = rng.range(2, 16);
            let rows = rng.range(2, 15);
            let per_chip = cols * rows;
            let start = rng.below(per_chip);
            let n = rng.range(1, 3 * per_chip);
            let coords = serpentine(start, n, cols, per_chip);
            assert_eq!(coords.len(), n);
            assert!(chain_is_local(&coords), "{coords:?}");
        });
    }

    #[test]
    fn column_major_chains_are_mesh_local() {
        for_all("column_major_local", 50, |rng| {
            let cols = rng.range(2, 16);
            let rows = rng.range(2, 15);
            let per_chip = cols * rows;
            let start = rng.below(per_chip);
            let n = rng.range(1, 3 * per_chip);
            let coords = column_major(start, n, cols, per_chip);
            assert_eq!(coords.len(), n);
            assert!(chain_is_local(&coords), "{coords:?}");
        });
    }

    #[test]
    fn column_major_snake_layout() {
        // 3x3 chip: column 0 top-down, column 1 reversed
        let coords = column_major(0, 6, 3, 9);
        assert_eq!(coords[0], Coord::new(0, 0, 0));
        assert_eq!(coords[2], Coord::new(0, 2, 0));
        assert_eq!(coords[3], Coord::new(0, 2, 1));
        assert_eq!(coords[5], Coord::new(0, 0, 1));
    }

    #[test]
    fn column_major_crosses_chips() {
        // 4 tiles/chip (2x2): a 6-tile chain spans 2 chips.
        let coords = column_major(0, 6, 2, 4);
        assert_eq!(coords[3].chip, 0);
        assert_eq!(coords[4].chip, 1);
        assert_eq!(coords[4], Coord::new(1, 0, 0));
    }

    #[test]
    fn column_major_stays_inside_the_serpentine_bounding_box() {
        // default chip geometry: 240 tiles as 15 rows x 16 cols either way
        let s = serpentine(0, 240, 16, 240);
        let c = column_major(0, 240, 16, 240);
        let bound = |v: &[Coord]| {
            (
                v.iter().map(|x| x.row).max().unwrap(),
                v.iter().map(|x| x.col).max().unwrap(),
            )
        };
        assert_eq!(bound(&s), (14, 15));
        assert_eq!(bound(&c), (14, 15));
    }

    #[test]
    fn serpentine_crosses_chips() {
        // 4 tiles/chip (2x2): a 6-tile chain spans 2 chips.
        let coords = serpentine(0, 6, 2, 4);
        assert_eq!(coords[3].chip, 0);
        assert_eq!(coords[4].chip, 1);
        assert_eq!(coords[4], Coord::new(1, 0, 0));
    }

    #[test]
    fn serpentine_snake_layout() {
        let coords = serpentine(0, 6, 3, 9);
        // row 0: (0,0) (0,1) (0,2); row 1 reversed: (1,2) (1,1) (1,0)
        assert_eq!(coords[2], Coord::new(0, 0, 2));
        assert_eq!(coords[3], Coord::new(0, 1, 2));
        assert_eq!(coords[5], Coord::new(0, 1, 0));
    }
}
