//! Link models: on-chip mesh links and inter-chip serial transceivers.
//!
//! Section IV-A: inter-tile bandwidth is 40 Gb/s (10 MHz instruction
//! steps, 160 MHz FDM peripherals); inter-chip connections are eight
//! 80 Gb/s transceivers at 0.55 pJ/b (Razavi-style wireline, [11]).

use crate::consts;

/// Which physical link a transfer used (selects the energy/bandwidth
/// model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Mesh link between adjacent tiles on the same chip.
    OnChip,
    /// Serial transceiver between chips.
    InterChip,
}

impl LinkKind {
    /// The link class a transfer between chips `a` and `b` rides on:
    /// the on-chip mesh when both endpoints share a chip, a serial
    /// transceiver otherwise.
    pub fn between(a: usize, b: usize) -> Self {
        if a == b {
            LinkKind::OnChip
        } else {
            LinkKind::InterChip
        }
    }
}

/// Aggregate inter-chip transceiver: checks bandwidth feasibility and
/// accounts transferred bits.
#[derive(Clone, Debug, Default)]
pub struct InterChipLink {
    pub bits_transferred: u64,
}

impl InterChipLink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total inter-chip bandwidth in bits per second.
    pub fn total_bandwidth_bps() -> f64 {
        consts::INTERCHIP_LANES as f64 * consts::INTERCHIP_GBPS_PER_LANE * 1e9
    }

    /// Bits one instruction step (10 MHz) can move across the chip
    /// boundary.
    pub fn bits_per_step() -> f64 {
        Self::total_bandwidth_bps() / consts::STEP_HZ
    }

    /// Record a transfer of `bits`; returns the number of steps the
    /// transfer occupies (≥ 1), for stall modeling.
    pub fn transfer(&mut self, bits: u64) -> u64 {
        self.bits_transferred += bits;
        let per_step = Self::bits_per_step();
        ((bits as f64 / per_step).ceil() as u64).max(1)
    }
}

/// Bits one on-chip mesh link can move per instruction step.
pub fn onchip_bits_per_step() -> f64 {
    consts::TILE_LINK_GBPS * 1e9 / consts::STEP_HZ
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interchip_bandwidth_is_640_gbps() {
        assert_eq!(InterChipLink::total_bandwidth_bps(), 640e9);
        // 640 Gb/s over 10 MHz steps = 64 kb per step
        assert_eq!(InterChipLink::bits_per_step(), 64_000.0);
    }

    #[test]
    fn onchip_link_fits_one_packet_per_step() {
        // 40 Gb/s over 10 MHz steps = 4000 bits per step: enough for a
        // 256-lane i8 IFM beat (2048 b) but requiring 2 steps for a
        // 256-lane i32 psum beat - the paper's two-subcycle structure.
        assert_eq!(onchip_bits_per_step(), 4000.0);
    }

    #[test]
    fn between_classifies_by_chip() {
        assert_eq!(LinkKind::between(0, 0), LinkKind::OnChip);
        assert_eq!(LinkKind::between(2, 2), LinkKind::OnChip);
        assert_eq!(LinkKind::between(0, 1), LinkKind::InterChip);
        assert_eq!(LinkKind::between(3, 1), LinkKind::InterChip);
    }

    #[test]
    fn transfer_counts_steps() {
        let mut l = InterChipLink::new();
        assert_eq!(l.transfer(1), 1);
        assert_eq!(l.transfer(64_000), 1);
        assert_eq!(l.transfer(64_001), 2);
        assert_eq!(l.bits_transferred, 128_002);
    }
}
