//! Packet types carried by the Domino NoC.
//!
//! Granularity: one packet carries one *pixel vector* — all channels of
//! one feature-map position handled by a tile (≤ N_c = 256 int8 values
//! for IFMs, ≤ N_m = 256 int32 partial sums). This matches the paper's
//! model where one 10 MHz instruction step moves one data beat between
//! adjacent tiles (the 160 MHz FDM peripheral serialises it over the
//! physical link within the step). Energy is charged per bit actually
//! moved, so packet granularity does not distort the energy model.

/// An input-feature-map beat: one spatial position's channel slice.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IfmPacket {
    /// Padded-stream raster index (see `sim::engine` for the stream
    /// layout). Padding positions carry zero data.
    pub slot: usize,
    /// Channel values (a `cblock` slice of the full pixel).
    pub data: Vec<i8>,
}

/// A partial-sum / group-sum beat moving along a tile chain.
///
/// This is the *owned* form, kept for [`Packet`] payloads, tests and
/// trace tooling. The cycle engine's hot path moves [`PsumRef`]
/// handles into a [`PsumArena`] instead, so a psum hop is a small
/// `Copy` header move rather than a `Vec<i32>` reallocation (§Perf).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PsumPacket {
    /// Output position (oy, ox) this sum belongs to.
    pub opos: (usize, usize),
    /// Running 32-bit sums for the chain's output-channel block.
    pub data: Vec<i32>,
}

/// A slim partial-sum handle: the lane values live in a [`PsumArena`]
/// slab, so ROFM FIFOs and inter-tile register queues move this `Copy`
/// header instead of an owned buffer. The tag (`opos`) stays on the
/// handle — it is what the engine's schedule-agreement checks compare.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PsumRef {
    /// Output position (oy, ox) this sum belongs to.
    pub opos: (usize, usize),
    /// Slab slot index inside the owning arena.
    pub slot: u32,
}

/// A preallocated slab of fixed-width psum lane buffers plus a free
/// list. One arena per conv chain: every psum in a chain has the same
/// lane count (the chain's output-channel block width), so slots are
/// uniform and allocation is a free-list pop.
///
/// The arena is sized at engine construction from the chain's geometry
/// (tiles in flight + one row period per row-head FIFO). If the event
/// stream ever needs more, the slab grows — counted in
/// [`Self::grows`], which the engine debug-asserts stable once an
/// image has completed (the conv event sequence is input-independent,
/// so steady state never grows).
#[derive(Clone, Debug)]
pub struct PsumArena {
    lanes: usize,
    slab: Vec<i32>,
    /// Free slot indices (LIFO; refilled wholesale by [`Self::reset`]).
    free: Vec<u32>,
    slots: u32,
    grows: u64,
}

impl PsumArena {
    /// An arena of `slots` buffers, `lanes` i32 values each.
    pub fn new(lanes: usize, slots: usize) -> Self {
        assert!(lanes > 0, "psum lane width must be positive");
        let slots = slots.clamp(1, u32::MAX as usize) as u32;
        Self {
            lanes,
            slab: vec![0; lanes * slots as usize],
            free: (0..slots).rev().collect(),
            slots,
            grows: 0,
        }
    }

    /// Lane count of every slot.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Total slot capacity.
    pub fn slots(&self) -> usize {
        self.slots as usize
    }

    /// Slots currently allocated (drain check: must be 0 between
    /// images).
    pub fn in_use(&self) -> usize {
        self.slots as usize - self.free.len()
    }

    /// Times the slab had to grow past its construction-time estimate.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// `(in_use, slots)` snapshot for occupancy probes.
    pub fn occupancy(&self) -> (usize, usize) {
        (self.in_use(), self.slots as usize)
    }

    /// Allocate a slot for output position `opos`. The lane values are
    /// *not* zeroed — the caller overwrites them (e.g. via
    /// `Pe::mvm_into`). Grows the slab by ~50% when the free list is
    /// empty.
    pub fn alloc(&mut self, opos: (usize, usize)) -> PsumRef {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                let add = (self.slots / 2).max(1);
                let total = self.slots as usize + add as usize;
                self.slab.resize(total * self.lanes, 0);
                // keep the free list's capacity at the full slot count
                // so a later `reset` (which refills it wholesale) stays
                // allocation-free
                self.free.reserve(total - self.free.len());
                for s in (self.slots + 1..self.slots + add).rev() {
                    self.free.push(s);
                }
                let slot = self.slots;
                self.slots += add;
                self.grows += 1;
                slot
            }
        };
        PsumRef { opos, slot }
    }

    /// Return a slot to the free list.
    pub fn free(&mut self, r: PsumRef) {
        debug_assert!(r.slot < self.slots, "freeing a foreign psum slot");
        self.free.push(r.slot);
    }

    /// The lane values of `r`.
    pub fn data(&self, r: PsumRef) -> &[i32] {
        let o = r.slot as usize * self.lanes;
        &self.slab[o..o + self.lanes]
    }

    /// Mutable lane values of `r`.
    pub fn data_mut(&mut self, r: PsumRef) -> &mut [i32] {
        let o = r.slot as usize * self.lanes;
        &mut self.slab[o..o + self.lanes]
    }

    /// Return every slot to the free list (image boundary). Performs no
    /// allocation: the free list always has capacity for every slot.
    pub fn reset(&mut self) {
        self.free.clear();
        self.free.extend((0..self.slots).rev());
    }
}

/// A finished output-feature-map beat (post activation/pooling, i8).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OfmPacket {
    /// Output position (oy, ox).
    pub opos: (usize, usize),
    /// Output-channel block values.
    pub data: Vec<i8>,
}

/// Any NoC packet.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Packet {
    Ifm(IfmPacket),
    Psum(PsumPacket),
    Ofm(OfmPacket),
}

impl Packet {
    /// Payload size in bits (i8 = 8 b lanes, psum lanes carried at 32 b),
    /// used for link-energy accounting (0.55 pJ/b inter-chip, Noxim-style
    /// per-bit on-chip charging).
    pub fn bits(&self) -> u64 {
        match self {
            Packet::Ifm(p) => 8 * p.data.len() as u64,
            Packet::Psum(p) => 32 * p.data.len() as u64,
            Packet::Ofm(p) => 8 * p.data.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packet_bits() {
        let ifm = Packet::Ifm(IfmPacket {
            slot: 0,
            data: vec![0; 256],
        });
        assert_eq!(ifm.bits(), 2048);
        let psum = Packet::Psum(PsumPacket {
            opos: (0, 0),
            data: vec![0; 256],
        });
        assert_eq!(psum.bits(), 8192);
        let ofm = Packet::Ofm(OfmPacket {
            opos: (0, 0),
            data: vec![0; 16],
        });
        assert_eq!(ofm.bits(), 128);
    }

    #[test]
    fn arena_alloc_free_reuse() {
        let mut a = PsumArena::new(4, 2);
        assert_eq!(a.lanes(), 4);
        assert_eq!(a.slots(), 2);
        assert_eq!(a.in_use(), 0);
        let r1 = a.alloc((0, 0));
        let r2 = a.alloc((0, 1));
        assert_eq!(a.in_use(), 2);
        assert_ne!(r1.slot, r2.slot);
        a.data_mut(r1).copy_from_slice(&[1, 2, 3, 4]);
        a.data_mut(r2).copy_from_slice(&[5, 6, 7, 8]);
        assert_eq!(a.data(r1), &[1, 2, 3, 4]);
        assert_eq!(a.data(r2), &[5, 6, 7, 8]);
        a.free(r1);
        assert_eq!(a.in_use(), 1);
        // freed slot is reused; no growth needed
        let r3 = a.alloc((1, 0));
        assert_eq!(r3.slot, r1.slot);
        assert_eq!(a.grows(), 0);
    }

    #[test]
    fn arena_grows_past_estimate_and_reset_restores_all() {
        let mut a = PsumArena::new(2, 1);
        let refs: Vec<PsumRef> = (0..5).map(|i| a.alloc((0, i))).collect();
        assert_eq!(a.in_use(), 5);
        assert!(a.grows() > 0, "had to grow past the 1-slot estimate");
        assert!(a.slots() >= 5);
        // every slot is distinct and addressable
        for (i, r) in refs.iter().enumerate() {
            a.data_mut(*r).fill(i as i32);
        }
        for (i, r) in refs.iter().enumerate() {
            assert_eq!(a.data(*r), &[i as i32, i as i32]);
        }
        let grown = a.slots();
        a.reset();
        assert_eq!(a.in_use(), 0);
        assert_eq!(a.slots(), grown, "reset keeps the grown capacity");
        // a full re-allocation round needs no further growth
        let g = a.grows();
        for i in 0..grown {
            a.alloc((1, i));
        }
        assert_eq!(a.grows(), g);
    }

    #[test]
    fn arena_reset_is_allocation_free() {
        // `reset` refills the free list in place; capacity must already
        // cover every slot (including slots added by growth).
        let mut a = PsumArena::new(3, 2);
        for i in 0..7 {
            a.alloc((0, i));
        }
        a.reset();
        let cap = {
            // drain the free list fully, then reset again: the refill
            // stays within the existing capacity
            let total = a.slots();
            for i in 0..total {
                a.alloc((0, i));
            }
            total
        };
        a.reset();
        assert_eq!(a.in_use(), 0);
        assert_eq!(a.slots(), cap);
    }
}
