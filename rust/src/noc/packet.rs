//! Packet types carried by the Domino NoC.
//!
//! Granularity: one packet carries one *pixel vector* — all channels of
//! one feature-map position handled by a tile (≤ N_c = 256 int8 values
//! for IFMs, ≤ N_m = 256 int32 partial sums). This matches the paper's
//! model where one 10 MHz instruction step moves one data beat between
//! adjacent tiles (the 160 MHz FDM peripheral serialises it over the
//! physical link within the step). Energy is charged per bit actually
//! moved, so packet granularity does not distort the energy model.

/// An input-feature-map beat: one spatial position's channel slice.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IfmPacket {
    /// Padded-stream raster index (see `sim::engine` for the stream
    /// layout). Padding positions carry zero data.
    pub slot: usize,
    /// Channel values (a `cblock` slice of the full pixel).
    pub data: Vec<i8>,
}

/// A partial-sum / group-sum beat moving along a tile chain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PsumPacket {
    /// Output position (oy, ox) this sum belongs to.
    pub opos: (usize, usize),
    /// Running 32-bit sums for the chain's output-channel block.
    pub data: Vec<i32>,
}

/// A finished output-feature-map beat (post activation/pooling, i8).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OfmPacket {
    /// Output position (oy, ox).
    pub opos: (usize, usize),
    /// Output-channel block values.
    pub data: Vec<i8>,
}

/// Any NoC packet.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Packet {
    Ifm(IfmPacket),
    Psum(PsumPacket),
    Ofm(OfmPacket),
}

impl Packet {
    /// Payload size in bits (i8 = 8 b lanes, psum lanes carried at 32 b),
    /// used for link-energy accounting (0.55 pJ/b inter-chip, Noxim-style
    /// per-bit on-chip charging).
    pub fn bits(&self) -> u64 {
        match self {
            Packet::Ifm(p) => 8 * p.data.len() as u64,
            Packet::Psum(p) => 32 * p.data.len() as u64,
            Packet::Ofm(p) => 8 * p.data.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packet_bits() {
        let ifm = Packet::Ifm(IfmPacket {
            slot: 0,
            data: vec![0; 256],
        });
        assert_eq!(ifm.bits(), 2048);
        let psum = Packet::Psum(PsumPacket {
            opos: (0, 0),
            data: vec![0; 256],
        });
        assert_eq!(psum.bits(), 8192);
        let ofm = Packet::Ofm(OfmPacket {
            opos: (0, 0),
            data: vec![0; 16],
        });
        assert_eq!(ofm.bits(), 128);
    }
}
