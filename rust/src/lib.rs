//! # Domino — Computing-On-the-Move NoC/CIM accelerator (reproduction)
//!
//! This crate reproduces the system described in *"A Customized NoC
//! Architecture to Enable Highly Localized Computing-On-the-Move DNN
//! Dataflow"* (Zhou, He, Xiao, Liu, Huang — 2021): a Computing-In-Memory
//! DNN inference accelerator organised as a 2-D mesh Network-on-Chip of
//! tiles, each containing a CIM crossbar (PE), an input-feature-map router
//! (RIFM) and an output/partial-sum router (ROFM) driven by distributed
//! periodic instruction schedules.
//!
//! ## Crate layout
//!
//! * [`model`] — DNN graph IR, the model zoo (VGG-11/16/19, ResNet-18) and
//!   an int8 functional reference (`refcompute`) used as the correctness
//!   oracle for the simulator.
//! * [`coordinator`] — the paper's contribution as an explicit mapping
//!   plane: the phase-split compiler (`coordinator::plan`: allocate →
//!   place → schedule → partition around the `MappingPlan` IR, with
//!   pluggable serpentine/column-major `Placement`;
//!   `coordinator::mapper` materializes plans into programs), the
//!   cost-model-driven mapping explorer (`coordinator::explore`:
//!   pooling × placement × mesh × alignment ranked analytically per
//!   objective) and the periodic C-type/M-type instruction schedules
//!   (`coordinator::schedule`, `coordinator::isa`).
//! * [`tile`] — microarchitecture of one tile: `tile::rifm`,
//!   `tile::rofm`, `tile::pe`.
//! * [`noc`] — 2-D mesh topology, packets and link models.
//! * [`sim`] — the cycle-accurate engine (single-image `run_image` and
//!   the batched, thread-parallel `run_batch`, bit-exact with each
//!   other), statistics, the layer-synchronized pipeline timing model,
//!   and the COM dataflow trace (reproduces the paper's Fig. 3(b)).
//!   Per-tile runtime state is built once per engine and reset between
//!   images; `PooledEngine`/`EnginePool` keep one warm engine per
//!   model for the serving and batch paths (no per-request spin-up).
//! * [`energy`] — Table III component energy/area constants, event-based
//!   energy accounting and technology/voltage/precision normalization.
//! * [`perfmodel`] — closed-form layer-level performance model validated
//!   against the cycle simulator and used for full-network Table IV runs.
//! * [`counterparts`] — analytic models of the five comparison
//!   architectures and the Table IV normalization pipeline.
//! * [`baselines`] — conventional WS+im2col dataflow and the two pooling
//!   schemes of Fig. 4, for ablations.
//! * [`runtime`] — PJRT runtime that loads the JAX/Pallas golden model
//!   (AOT-lowered HLO text in `artifacts/`) for cross-validation;
//!   compiles against an API-compatible stub unless the `pjrt` feature
//!   (and a vendored `xla` crate) is enabled.
//! * [`serve`] — the production-style inference server: bounded queue
//!   with backpressure, worker pool, micro-batched dequeueing, with
//!   two interchangeable backends — the AOT artifact over PJRT and the
//!   cycle-accurate simulator (`Server::start_sim`, artifact-free,
//!   refcompute-checkable). The sim backend is multi-model: a
//!   versioned `ModelRegistry` routes tagged requests, supports
//!   hot-swap/load/unload while serving (in-flight requests drain on
//!   their version, never dropped), and every response is stamped with
//!   the exact model version that served it. Around the core sits one
//!   typed service API (`serve::api`: data/admin/observability planes
//!   through a single `Service::dispatch`), a std-only wire protocol
//!   (`serve::wire`: length-prefixed hand-rolled JSON frames), a TCP
//!   endpoint (`serve::net`, `domino serve --listen`), an in-crate
//!   client (`serve::client`, `domino client …`), per-model metrics
//!   (`serve::metrics`: p50/p95/p99, counts, queue-depth gauges) and
//!   registry persistence (`serve --registry-file`). Mappings are
//!   per-model: `Load` requests carry an optional
//!   `serve::api::MappingSpec`, `ModelInfo` reports mapping +
//!   placement stats, and the manifest persists each model's exact
//!   `ArchConfig` across restarts.
//! * [`eval`] — experiment drivers for every table and figure.

pub mod baselines;
pub mod benchutil;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod counterparts;
pub mod energy;
pub mod eval;
pub mod model;
pub mod noc;
pub mod perfmodel;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testutil;
pub mod tile;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Architectural constants fixed by the paper's evaluation setup
/// (Section IV-A and Table III).
pub mod consts {
    /// CIM crossbar rows (input channels per tile), Section IV-A.
    pub const N_C: usize = 256;
    /// CIM crossbar columns (output channels per tile), Section IV-A.
    pub const N_M: usize = 256;
    /// Instruction step frequency (Hz): "the step frequency for the
    /// execution of one instruction is 10 MHz".
    pub const STEP_HZ: f64 = 10.0e6;
    /// Peripheral clock (FDM), Section IV-A.
    pub const PERIPHERAL_HZ: f64 = 160.0e6;
    /// Inter-tile bandwidth: 40 Gb/s.
    pub const TILE_LINK_GBPS: f64 = 40.0;
    /// Inter-chip transceivers: eight 80 Gb/s lanes.
    pub const INTERCHIP_LANES: usize = 8;
    pub const INTERCHIP_GBPS_PER_LANE: f64 = 80.0;
    /// Activation/weight precision (bits).
    pub const PRECISION_BITS: u32 = 8;
    /// Supply voltage (V).
    pub const VDD: f64 = 1.0;
    /// Technology node (nm).
    pub const TECH_NM: u32 = 45;
    /// CIM cores (tiles) per chip used in Table IV ("240 x N chips").
    pub const TILES_PER_CHIP: usize = 240;
    /// ROFM schedule table: 128 entries of 16 bits (Table III).
    pub const SCHEDULE_TABLE_ENTRIES: usize = 128;
    /// RIFM buffer bytes (Table III: 256 B x 1).
    pub const RIFM_BUFFER_BYTES: usize = 256;
    /// ROFM data buffer bytes (Table III: 16 KiB).
    pub const ROFM_BUFFER_BYTES: usize = 16 * 1024;
}
