//! The in-crate client for the TCP endpoint: typed wrappers over one
//! `serve::wire` framed connection. Every method is a thin
//! `Request -> Response` round-trip through [`Client::call`]; typed
//! helpers unwrap the expected variant and turn
//! [`api::Response::Error`] into an `Err`, so call sites read like the
//! in-process API. The benches, the protocol smoke test and the
//! `domino client …` CLI subcommands all drive the server through
//! this type.
//!
//! Two submission modes share the connection:
//!
//! - **Synchronous** ([`Client::call`] and the typed helpers): one
//!   request, wait for its response. Frames are untagged, i.e. pure
//!   protocol v1 — works against any endpoint.
//! - **Pipelined** ([`Client::submit`] / [`Client::await_response`]):
//!   requests carry a request id (protocol v2) and many may be in
//!   flight at once on the one connection; responses complete out of
//!   order and are claimed by id. This is how one connection carries
//!   real load — the round-trip latency is paid once per *window*,
//!   not once per request.
//!
//! A client whose call dies mid-round-trip poisons itself (the frame
//! stream may be desynchronized); [`Client::reconnect`] re-establishes
//! the connection in place, keeping the address and read timeout.
//! [`Client::connect_with_backoff`] / [`Client::reconnect_with_backoff`]
//! are the bounded-retry versions: exponential backoff with a
//! deterministic per-address jitter (no RNG dependency), and a typed
//! [`RetryExhausted`] error once the attempt budget is spent so
//! callers can tell "kept refusing" from an ordinary transport error.

use std::collections::{HashMap, HashSet};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::api::{
    CanaryReply, FaultReply, InferReply, MappingSpec, ModelDesc, Request, Response, StatsReply,
    TraceReply,
};
use super::registry::ModelStamp;
use super::wire;

/// Typed terminal error of the bounded-retry connect paths: the
/// attempt budget is spent and the address still does not answer.
/// Carried as the root cause inside the returned `anyhow::Error`, so
/// callers distinguish "gave up after N attempts" from a one-shot
/// transport failure with `err.downcast_ref::<RetryExhausted>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RetryExhausted {
    /// The address that kept refusing.
    pub addr: String,
    /// How many connection attempts were made.
    pub attempts: u32,
}

impl std::fmt::Display for RetryExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "gave up connecting to {} after {} attempts",
            self.addr, self.attempts
        )
    }
}

impl std::error::Error for RetryExhausted {}

/// Backoff before retry `attempt` (0-based): exponential doubling
/// from `base` (capped at `base << 6`) plus a deterministic jitter in
/// `[0, delay/4)` hashed from `(addr, attempt)`. Deterministic on
/// purpose — the schedule is reproducible in tests and needs no RNG
/// dependency — while still de-correlating: clients retrying
/// *different* addresses (a router walking its replica set) spread
/// out instead of hammering in lockstep.
fn backoff_delay(addr: &str, attempt: u32, base: Duration) -> Duration {
    let exp = base.saturating_mul(1 << attempt.min(6));
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in addr.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h ^= u64::from(attempt);
    h = h.wrapping_mul(0x100_0000_01b3);
    let jitter_cap = (exp.as_micros() as u64 / 4).max(1);
    exp + Duration::from_micros(h % jitter_cap)
}

/// One framed connection to a `serve::net` endpoint.
pub struct Client {
    stream: TcpStream,
    /// The dialed address, kept for [`Self::reconnect`].
    addr: String,
    /// The configured timeout, reapplied on reconnect.
    read_timeout: Option<Duration>,
    /// Set when a call died mid-round-trip (write or read failure,
    /// e.g. a read timeout). The framing is then unsynchronized: the
    /// late response is still in flight and would be decoded as the
    /// answer to the *next* request — silent misattribution when the
    /// variants happen to match. Every subsequent call fails fast
    /// instead; [`Self::reconnect`] recovers.
    poisoned: bool,
    /// Next request id for [`Self::submit`] (per-connection counter;
    /// the endpoint scopes ids per connection, so a fresh connection
    /// may reuse them).
    next_rid: u64,
    /// Ids submitted but not yet claimed by [`Self::await_response`].
    outstanding: HashSet<u64>,
    /// Responses that arrived while waiting for a *different* id,
    /// parked until their id is awaited.
    ready: HashMap<u64, Response>,
}

impl Client {
    /// Connect to `addr` (e.g. `127.0.0.1:7700`).
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow!("failed to connect to {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            stream,
            addr: addr.to_string(),
            read_timeout: None,
            poisoned: false,
            next_rid: 0,
            outstanding: HashSet::new(),
            ready: HashMap::new(),
        })
    }

    /// [`Self::connect`] with a bounded retry budget: up to
    /// `attempts` dials, sleeping [`backoff_delay`] (exponential +
    /// deterministic jitter) between them. Ends in the typed
    /// [`RetryExhausted`] error once the budget is spent, with the
    /// last dial failure attached as context.
    pub fn connect_with_backoff(addr: &str, attempts: u32, base: Duration) -> Result<Self> {
        let attempts = attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..attempts {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
            if attempt + 1 < attempts {
                std::thread::sleep(backoff_delay(addr, attempt, base));
            }
        }
        let root = anyhow::Error::new(RetryExhausted {
            addr: addr.to_string(),
            attempts,
        });
        Err(match last {
            Some(e) => root.context(format!("last attempt: {e:#}")),
            None => root,
        })
    }

    /// [`Self::reconnect`] with the same bounded-retry policy as
    /// [`Self::connect_with_backoff`]; on success the poison is
    /// cleared and the read timeout reapplied, exactly like a single
    /// successful `reconnect`.
    pub fn reconnect_with_backoff(&mut self, attempts: u32, base: Duration) -> Result<()> {
        let attempts = attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..attempts {
            match self.reconnect() {
                Ok(()) => return Ok(()),
                Err(e) => last = Some(e),
            }
            if attempt + 1 < attempts {
                std::thread::sleep(backoff_delay(&self.addr, attempt, base));
            }
        }
        let root = anyhow::Error::new(RetryExhausted {
            addr: self.addr.clone(),
            attempts,
        });
        Err(match last {
            Some(e) => root.context(format!("last attempt: {e:#}")),
            None => root,
        })
    }

    /// Bound how long a single response may take; `None` (the
    /// default) waits indefinitely. A timeout surfaces as an error
    /// from the next call and poisons the connection (the late
    /// response would otherwise answer the wrong request).
    pub fn set_read_timeout(&mut self, dur: Option<Duration>) -> Result<()> {
        self.read_timeout = dur;
        self.stream
            .set_read_timeout(dur)
            .map_err(|e| anyhow!("set read timeout: {e}"))
    }

    /// Whether a previous call died mid-round-trip, leaving the frame
    /// stream unsynchronized (see [`Self::call`]).
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// Re-establish the connection in place: dial the original
    /// address again, reapply the configured read timeout, and clear
    /// the poison. Responses to requests submitted on the old
    /// connection are gone — outstanding pipelined ids are dropped
    /// and can never be awaited (awaiting one reports it unknown);
    /// resubmit the work instead.
    pub fn reconnect(&mut self) -> Result<()> {
        let stream = TcpStream::connect(&self.addr)
            .map_err(|e| anyhow!("failed to reconnect to {}: {e}", self.addr))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(self.read_timeout)
            .map_err(|e| anyhow!("set read timeout: {e}"))?;
        self.stream = stream;
        self.poisoned = false;
        self.outstanding.clear();
        self.ready.clear();
        Ok(())
    }

    /// One raw round-trip: send `req`, receive the typed response
    /// (which may be [`Response::Error`] — the typed helpers below
    /// convert that into `Err`). Any transport failure mid-call
    /// poisons the client: request and response frames alternate
    /// strictly on one connection, so after a half-finished round-trip
    /// the next read could return the *previous* request's late
    /// response. Poisoned clients fail fast; reconnect to recover.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        if self.poisoned {
            bail!(
                "connection poisoned by an earlier mid-call transport error \
                 (a stale response may be in flight); reconnect"
            );
        }
        let r = self.call_inner(req);
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn call_inner(&mut self, req: &Request) -> Result<Response> {
        wire::write_frame(&mut self.stream, &wire::encode_request(req))?;
        // the untagged request's response is the next *untagged* frame;
        // tagged frames arriving first belong to pipelined submits
        // still in flight — park them for their await
        loop {
            let frame = wire::read_frame(&mut self.stream)?
                .ok_or_else(|| anyhow!("server closed the connection"))?;
            let (resp, rid) = wire::decode_response_tagged(&frame)?;
            match rid {
                None => return Ok(resp),
                Some(r) if self.outstanding.remove(&r) => {
                    self.ready.insert(r, resp);
                }
                Some(r) => bail!("server answered unknown request id {r}"),
            }
        }
    }

    /// Pipelined submission: send `req` tagged with a fresh request
    /// id and return immediately. Many submits may be in flight at
    /// once on this one connection; claim each response with
    /// [`Self::await_response`]. Any transport failure poisons the
    /// client exactly like [`Self::call`].
    pub fn submit(&mut self, req: &Request) -> Result<u64> {
        if self.poisoned {
            bail!(
                "connection poisoned by an earlier mid-call transport error \
                 (a stale response may be in flight); reconnect"
            );
        }
        let rid = self.next_rid;
        self.next_rid += 1;
        let r = wire::write_frame(
            &mut self.stream,
            &wire::encode_request_tagged(req, Some(rid)),
        );
        if let Err(e) = r {
            self.poisoned = true;
            return Err(e);
        }
        self.outstanding.insert(rid);
        Ok(rid)
    }

    /// Claim the response to a prior [`Self::submit`] *only if it has
    /// already been read off the wire and parked* by an earlier await
    /// on this connection. Never touches the socket: `None` means
    /// "not arrived yet", not "unknown id". The cluster's pipelined
    /// connection pool builds its leader/follower protocol on this —
    /// one thread drives the socket with [`Self::await_response`]
    /// (parking everyone else's responses as they arrive) while the
    /// waiting threads poll the parked set without blocking on reads.
    pub fn take_ready(&mut self, rid: u64) -> Option<Response> {
        self.ready.remove(&rid)
    }

    /// Claim the response to a prior [`Self::submit`]. Responses to
    /// *other* outstanding ids that arrive first are parked, so
    /// awaiting in any order works. An id that was never submitted
    /// (or already claimed) is an error without touching the wire.
    pub fn await_response(&mut self, rid: u64) -> Result<Response> {
        if let Some(resp) = self.ready.remove(&rid) {
            return Ok(resp);
        }
        if self.poisoned {
            bail!(
                "connection poisoned by an earlier mid-call transport error \
                 (a stale response may be in flight); reconnect"
            );
        }
        if !self.outstanding.contains(&rid) {
            bail!("request id {rid} is not outstanding on this connection");
        }
        loop {
            let frame = match wire::read_frame(&mut self.stream) {
                Ok(Some(f)) => f,
                Ok(None) => {
                    self.poisoned = true;
                    bail!("server closed the connection with request id {rid} in flight");
                }
                Err(e) => {
                    self.poisoned = true;
                    return Err(e);
                }
            };
            let (resp, got) = match wire::decode_response_tagged(&frame) {
                Ok(v) => v,
                Err(e) => {
                    self.poisoned = true;
                    return Err(e);
                }
            };
            match got {
                Some(r) if r == rid => {
                    self.outstanding.remove(&rid);
                    return Ok(resp);
                }
                Some(r) if self.outstanding.remove(&r) => {
                    self.ready.insert(r, resp);
                }
                _ => {
                    // an untagged or never-submitted id mid-pipeline
                    // means the stream is not what we think it is
                    self.poisoned = true;
                    bail!(
                        "response stream desynchronized: got {} while awaiting request id {rid}",
                        match got {
                            Some(r) => format!("unknown request id {r}"),
                            None => "an untagged response".to_string(),
                        }
                    );
                }
            }
        }
    }

    /// Pipelined [`Self::infer`]: submit one image, claim the typed
    /// reply later with [`Self::await_infer`].
    pub fn infer_submit(&mut self, model: Option<&str>, image: Vec<i8>) -> Result<u64> {
        self.submit(&Request::Infer {
            model: model.map(str::to_string),
            image,
        })
    }

    /// Claim a pipelined infer: unwraps the reply like [`Self::infer`].
    pub fn await_infer(&mut self, rid: u64) -> Result<InferReply> {
        match Self::ok(self.await_response(rid)?)? {
            Response::Infer(r) => Ok(r),
            other => bail!("unexpected response to infer: {other:?}"),
        }
    }

    fn ok(resp: Response) -> Result<Response> {
        match resp {
            Response::Error { message } => bail!("server error: {message}"),
            other => Ok(other),
        }
    }

    /// Data plane: run one image on `model` (`None` = the sole loaded
    /// model). The reply carries the serving model version's stamp for
    /// refcompute cross-checks.
    pub fn infer(&mut self, model: Option<&str>, image: Vec<i8>) -> Result<InferReply> {
        let resp = self.call(&Request::Infer {
            model: model.map(str::to_string),
            image,
        })?;
        match Self::ok(resp)? {
            Response::Infer(r) => Ok(r),
            other => bail!("unexpected response to infer: {other:?}"),
        }
    }

    /// Admin plane: load a zoo model (compiler-default weight seed,
    /// service-default mapping).
    pub fn load(&mut self, model: &str) -> Result<ModelStamp> {
        self.load_mapped(model, None, None)
    }

    /// Admin plane: load a zoo model with an explicit weight seed.
    pub fn load_seeded(&mut self, model: &str, seed: u64) -> Result<ModelStamp> {
        self.load_mapped(model, Some(seed), None)
    }

    /// Admin plane: load a zoo model with an optional weight seed and
    /// an optional per-model mapping (e.g. a `domino map explore`
    /// winner). Mapping fields left `None` fall back to the server's
    /// service-wide defaults.
    pub fn load_mapped(
        &mut self,
        model: &str,
        seed: Option<u64>,
        mapping: Option<MappingSpec>,
    ) -> Result<ModelStamp> {
        let req = match seed {
            Some(seed) => Request::LoadSeeded {
                model: model.to_string(),
                seed,
                mapping,
            },
            None => Request::Load {
                model: model.to_string(),
                mapping,
            },
        };
        match Self::ok(self.call(&req)?)? {
            Response::Loaded(st) => Ok(st),
            other => bail!("unexpected response to load: {other:?}"),
        }
    }

    /// Admin plane: hot-swap a loaded model (`seed: Some(_)` makes the
    /// new weights observable).
    pub fn swap(&mut self, model: &str, seed: Option<u64>) -> Result<ModelStamp> {
        let resp = self.call(&Request::Swap {
            model: model.to_string(),
            seed,
        })?;
        match Self::ok(resp)? {
            Response::Swapped(st) => Ok(st),
            other => bail!("unexpected response to swap: {other:?}"),
        }
    }

    /// Admin plane: unload a model (in-flight requests drain on their
    /// version).
    pub fn unload(&mut self, model: &str) -> Result<ModelStamp> {
        let resp = self.call(&Request::Unload {
            model: model.to_string(),
        })?;
        match Self::ok(resp)? {
            Response::Unloaded(st) => Ok(st),
            other => bail!("unexpected response to unload: {other:?}"),
        }
    }

    /// Observability plane: describe every loaded model.
    pub fn models(&mut self) -> Result<Vec<ModelDesc>> {
        match Self::ok(self.call(&Request::ListModels)?)? {
            Response::Models(m) => Ok(m),
            other => bail!("unexpected response to list_models: {other:?}"),
        }
    }

    /// Observability plane: describe one loaded model.
    pub fn model_info(&mut self, model: &str) -> Result<ModelDesc> {
        let resp = self.call(&Request::ModelInfo {
            model: model.to_string(),
        })?;
        match Self::ok(resp)? {
            Response::Info(d) => Ok(d),
            other => bail!("unexpected response to model_info: {other:?}"),
        }
    }

    /// Observability plane: per-model serving metrics.
    pub fn stats(&mut self) -> Result<StatsReply> {
        match Self::ok(self.call(&Request::Stats)?)? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected response to stats: {other:?}"),
        }
    }

    /// Fault plane: arm (or with an empty `plan`, disarm) a
    /// deterministic fault plan on `model` and get back the
    /// diagnostic report from the server's seeded probe run.
    pub fn fault_inject(&mut self, model: &str, plan: &str) -> Result<FaultReply> {
        let resp = self.call(&Request::FaultInject {
            model: model.to_string(),
            plan: plan.to_string(),
        })?;
        match Self::ok(resp)? {
            Response::Fault(f) => Ok(f),
            other => bail!("unexpected response to fault_inject: {other:?}"),
        }
    }

    /// Fault plane: run a seeded canary inference on `model` against
    /// its refcompute oracle. `heal: true` additionally re-maps the
    /// model around any armed fault sites when the canary fails.
    pub fn canary(&mut self, model: &str, seed: u64, heal: bool) -> Result<CanaryReply> {
        let resp = self.call(&Request::Canary {
            model: model.to_string(),
            seed,
            heal,
        })?;
        match Self::ok(resp)? {
            Response::Canary(c) => Ok(c),
            other => bail!("unexpected response to canary: {other:?}"),
        }
    }

    /// Observability plane: record one seeded image on `model` under a
    /// flight recorder and pull back the first `window` events plus a
    /// link-utilization heatmap of the busiest stage.
    pub fn trace(&mut self, model: &str, image_seed: u64, window: u64) -> Result<TraceReply> {
        let resp = self.call(&Request::Trace {
            model: model.to_string(),
            image_seed,
            window,
        })?;
        match Self::ok(resp)? {
            Response::Trace(t) => Ok(t),
            other => bail!("unexpected response to trace: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_is_deterministic_exponential_and_jittered() {
        let base = Duration::from_millis(10);
        // deterministic: same (addr, attempt) -> same delay
        for attempt in 0..4 {
            assert_eq!(
                backoff_delay("127.0.0.1:7700", attempt, base),
                backoff_delay("127.0.0.1:7700", attempt, base)
            );
        }
        // exponential envelope: delay n lies in [base<<n, (base<<n)*1.25)
        for attempt in 0..5u32 {
            let d = backoff_delay("127.0.0.1:7700", attempt, base);
            let floor = base * (1 << attempt);
            assert!(d >= floor, "attempt {attempt}: {d:?} < {floor:?}");
            assert!(d < floor + floor / 4 + Duration::from_micros(1));
        }
        // the exponent caps: attempt 20 does not overflow past <<6
        let capped = backoff_delay("127.0.0.1:7700", 20, base);
        let cap_floor = base * (1 << 6);
        assert!(capped >= cap_floor && capped < cap_floor * 2);
        // different addresses land on different jitters (de-correlated)
        assert_ne!(
            backoff_delay("10.0.0.1:7700", 3, base),
            backoff_delay("10.0.0.2:7700", 3, base)
        );
    }

    #[test]
    fn connect_with_backoff_ends_in_typed_retry_exhausted() {
        // grab a free port, then close the listener so dials refuse
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let addr = format!("127.0.0.1:{port}");
        let err = Client::connect_with_backoff(&addr, 2, Duration::from_millis(1))
            .err()
            .expect("connecting to a closed port must fail");
        let typed = err
            .downcast_ref::<RetryExhausted>()
            .expect("root cause must be RetryExhausted");
        assert_eq!(typed.attempts, 2);
        assert_eq!(typed.addr, addr);
        assert!(typed.to_string().contains("after 2 attempts"));
    }
}
