//! The in-crate client for the TCP endpoint: typed wrappers over one
//! `serve::wire` framed connection. Every method is a thin
//! `Request -> Response` round-trip through [`Client::call`]; typed
//! helpers unwrap the expected variant and turn
//! [`api::Response::Error`] into an `Err`, so call sites read like the
//! in-process API. The benches, the protocol smoke test and the
//! `domino client …` CLI subcommands all drive the server through
//! this type.

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::api::{
    InferReply, MappingSpec, ModelDesc, Request, Response, StatsReply, TraceReply,
};
use super::registry::ModelStamp;
use super::wire;

/// One framed connection to a `serve::net` endpoint.
pub struct Client {
    stream: TcpStream,
    /// Set when a call died mid-round-trip (write or read failure,
    /// e.g. a read timeout). The framing is then unsynchronized: the
    /// late response is still in flight and would be decoded as the
    /// answer to the *next* request — silent misattribution when the
    /// variants happen to match. Every subsequent call fails fast
    /// instead; reconnect to recover.
    poisoned: bool,
}

impl Client {
    /// Connect to `addr` (e.g. `127.0.0.1:7700`).
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow!("failed to connect to {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            stream,
            poisoned: false,
        })
    }

    /// Bound how long a single response may take; `None` (the
    /// default) waits indefinitely. A timeout surfaces as an error
    /// from the next call and poisons the connection (the late
    /// response would otherwise answer the wrong request).
    pub fn set_read_timeout(&mut self, dur: Option<Duration>) -> Result<()> {
        self.stream
            .set_read_timeout(dur)
            .map_err(|e| anyhow!("set read timeout: {e}"))
    }

    /// Whether a previous call died mid-round-trip, leaving the frame
    /// stream unsynchronized (see [`Self::call`]).
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// One raw round-trip: send `req`, receive the typed response
    /// (which may be [`Response::Error`] — the typed helpers below
    /// convert that into `Err`). Any transport failure mid-call
    /// poisons the client: request and response frames alternate
    /// strictly on one connection, so after a half-finished round-trip
    /// the next read could return the *previous* request's late
    /// response. Poisoned clients fail fast; reconnect to recover.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        if self.poisoned {
            bail!(
                "connection poisoned by an earlier mid-call transport error \
                 (a stale response may be in flight); reconnect"
            );
        }
        let r = self.call_inner(req);
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn call_inner(&mut self, req: &Request) -> Result<Response> {
        wire::write_frame(&mut self.stream, &wire::encode_request(req))?;
        let frame = wire::read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        wire::decode_response(&frame)
    }

    fn ok(resp: Response) -> Result<Response> {
        match resp {
            Response::Error { message } => bail!("server error: {message}"),
            other => Ok(other),
        }
    }

    /// Data plane: run one image on `model` (`None` = the sole loaded
    /// model). The reply carries the serving model version's stamp for
    /// refcompute cross-checks.
    pub fn infer(&mut self, model: Option<&str>, image: Vec<i8>) -> Result<InferReply> {
        let resp = self.call(&Request::Infer {
            model: model.map(str::to_string),
            image,
        })?;
        match Self::ok(resp)? {
            Response::Infer(r) => Ok(r),
            other => bail!("unexpected response to infer: {other:?}"),
        }
    }

    /// Admin plane: load a zoo model (compiler-default weight seed,
    /// service-default mapping).
    pub fn load(&mut self, model: &str) -> Result<ModelStamp> {
        self.load_mapped(model, None, None)
    }

    /// Admin plane: load a zoo model with an explicit weight seed.
    pub fn load_seeded(&mut self, model: &str, seed: u64) -> Result<ModelStamp> {
        self.load_mapped(model, Some(seed), None)
    }

    /// Admin plane: load a zoo model with an optional weight seed and
    /// an optional per-model mapping (e.g. a `domino map explore`
    /// winner). Mapping fields left `None` fall back to the server's
    /// service-wide defaults.
    pub fn load_mapped(
        &mut self,
        model: &str,
        seed: Option<u64>,
        mapping: Option<MappingSpec>,
    ) -> Result<ModelStamp> {
        let req = match seed {
            Some(seed) => Request::LoadSeeded {
                model: model.to_string(),
                seed,
                mapping,
            },
            None => Request::Load {
                model: model.to_string(),
                mapping,
            },
        };
        match Self::ok(self.call(&req)?)? {
            Response::Loaded(st) => Ok(st),
            other => bail!("unexpected response to load: {other:?}"),
        }
    }

    /// Admin plane: hot-swap a loaded model (`seed: Some(_)` makes the
    /// new weights observable).
    pub fn swap(&mut self, model: &str, seed: Option<u64>) -> Result<ModelStamp> {
        let resp = self.call(&Request::Swap {
            model: model.to_string(),
            seed,
        })?;
        match Self::ok(resp)? {
            Response::Swapped(st) => Ok(st),
            other => bail!("unexpected response to swap: {other:?}"),
        }
    }

    /// Admin plane: unload a model (in-flight requests drain on their
    /// version).
    pub fn unload(&mut self, model: &str) -> Result<ModelStamp> {
        let resp = self.call(&Request::Unload {
            model: model.to_string(),
        })?;
        match Self::ok(resp)? {
            Response::Unloaded(st) => Ok(st),
            other => bail!("unexpected response to unload: {other:?}"),
        }
    }

    /// Observability plane: describe every loaded model.
    pub fn models(&mut self) -> Result<Vec<ModelDesc>> {
        match Self::ok(self.call(&Request::ListModels)?)? {
            Response::Models(m) => Ok(m),
            other => bail!("unexpected response to list_models: {other:?}"),
        }
    }

    /// Observability plane: describe one loaded model.
    pub fn model_info(&mut self, model: &str) -> Result<ModelDesc> {
        let resp = self.call(&Request::ModelInfo {
            model: model.to_string(),
        })?;
        match Self::ok(resp)? {
            Response::Info(d) => Ok(d),
            other => bail!("unexpected response to model_info: {other:?}"),
        }
    }

    /// Observability plane: per-model serving metrics.
    pub fn stats(&mut self) -> Result<StatsReply> {
        match Self::ok(self.call(&Request::Stats)?)? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected response to stats: {other:?}"),
        }
    }

    /// Observability plane: record one seeded image on `model` under a
    /// flight recorder and pull back the first `window` events plus a
    /// link-utilization heatmap of the busiest stage.
    pub fn trace(&mut self, model: &str, image_seed: u64, window: u64) -> Result<TraceReply> {
        let resp = self.call(&Request::Trace {
            model: model.to_string(),
            image_seed,
            window,
        })?;
        match Self::ok(resp)? {
            Response::Trace(t) => Ok(t),
            other => bail!("unexpected response to trace: {other:?}"),
        }
    }
}
