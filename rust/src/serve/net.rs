//! The TCP endpoint: `serve::api` over `serve::wire` frames. One
//! accept thread, one thread per connection (bounded by
//! [`NetConfig::max_conns`]), every decoded request routed through the
//! same [`Service::dispatch`] the in-process path uses — so a remote
//! call *is* the local call, stamp and all. The accept loop feeds the
//! server's existing bounded queue; backpressure and per-model
//! validation errors come back as typed [`api::Response::Error`]
//! frames, exactly like any other failure.
//!
//! Shutdown is a graceful drain: the accept loop stops taking
//! connections, each connection thread finishes the request it is
//! already dispatching and writes its response, idle connections
//! close at their next poll tick, and [`NetServer::shutdown`] joins
//! them all before returning. A frame only *partially* received when
//! the stop lands is abandoned with a framing error — a stalled peer
//! must not be able to block shutdown indefinitely.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::api::{self, Service};
use super::wire;

/// Endpoint tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Maximum concurrent client connections; further connections get
    /// a typed `Error` response and are closed (bounded accept loop).
    pub max_conns: usize,
    /// How often idle reads and the accept loop wake to poll the stop
    /// flag (drain latency at shutdown).
    pub poll: Duration,
    /// Deadline for writing one response frame. A client that stops
    /// reading (full send buffer) is treated as dead once this
    /// elapses, so a stalled connection can never block
    /// [`NetServer::shutdown`]'s drain-and-join.
    pub write_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            max_conns: 64,
            poll: Duration::from_millis(100),
            write_timeout: Duration::from_secs(30),
        }
    }
}

/// A running TCP endpoint. Dropping it (or calling
/// [`Self::shutdown`]) stops the accept loop and drains every
/// connection.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:7700`; port 0 picks an ephemeral
    /// port — read the result off [`Self::local_addr`]) and start
    /// serving `service`. A bind failure names the address that
    /// failed, so "port in use" is diagnosable from the message alone.
    pub fn bind(addr: &str, service: Arc<Service>) -> Result<Self> {
        Self::bind_with(addr, service, NetConfig::default())
    }

    /// [`Self::bind`] with explicit [`NetConfig`].
    pub fn bind_with(addr: &str, service: Arc<Service>, cfg: NetConfig) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("failed to bind {addr}"))?;
        let local_addr = listener
            .local_addr()
            .with_context(|| format!("local_addr of listener on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("set listener non-blocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let accept_handle = std::thread::Builder::new()
            .name("domino-net-accept".to_string())
            .spawn(move || accept_loop(listener, service, accept_stop, cfg))
            .context("spawn accept thread")?;
        Ok(Self {
            local_addr,
            stop,
            accept_handle: Some(accept_handle),
        })
    }

    /// The actually-bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, drain every live connection, join the threads.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            h.join()
                .map_err(|_| anyhow::anyhow!("net accept thread panicked"))?;
        }
        Ok(())
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    service: Arc<Service>,
    stop: Arc<AtomicBool>,
    cfg: NetConfig,
) {
    let live = Arc::new(AtomicUsize::new(0));
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                conns.retain(|h| !h.is_finished());
                if live.load(Ordering::SeqCst) >= cfg.max_conns {
                    refuse(stream, &cfg, &service);
                    continue;
                }
                live.fetch_add(1, Ordering::SeqCst);
                let service = Arc::clone(&service);
                let stop = Arc::clone(&stop);
                let live_conn = Arc::clone(&live);
                let spawned = std::thread::Builder::new()
                    .name(format!("domino-net-conn-{peer}"))
                    .spawn(move || {
                        if let Err(e) = handle_conn(stream, &service, &stop, cfg) {
                            eprintln!("domino-net: connection {peer}: {e:#}");
                        }
                        live_conn.fetch_sub(1, Ordering::SeqCst);
                    });
                match spawned {
                    Ok(h) => conns.push(h),
                    Err(e) => {
                        live.fetch_sub(1, Ordering::SeqCst);
                        eprintln!("domino-net: spawn connection thread: {e}");
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(cfg.poll);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("domino-net: accept error: {e}");
                std::thread::sleep(cfg.poll);
            }
        }
    }
    // graceful drain: every connection thread finishes its in-flight
    // request and observes `stop` at its next idle poll
    for h in conns {
        let _ = h.join();
    }
}

/// Over-capacity connection: answer with a typed error, then close —
/// and count it, so an operator watching `Stats` sees connection-level
/// shedding instead of a mysteriously quiet endpoint.
fn refuse(mut stream: TcpStream, cfg: &NetConfig, service: &Service) {
    service.note_conn_refused();
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let resp = api::Response::Error {
        message: format!(
            "server at connection capacity ({}); retry later",
            cfg.max_conns
        ),
    };
    let _ = wire::write_frame(&mut stream, &wire::encode_response(&resp));
}

/// One connection: read a frame, dispatch, answer, repeat. A frame
/// that decodes but fails in dispatch is a typed `Error` *response*;
/// a frame that does not decode gets a typed `Error` response too and
/// the connection stays usable (framing is still intact). A framing
/// error (oversized length prefix, truncation) is unrecoverable: we
/// best-effort send one last `Error` frame and close.
fn handle_conn(
    mut stream: TcpStream,
    service: &Service,
    stop: &AtomicBool,
    cfg: NetConfig,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(cfg.poll))
        .context("set read timeout")?;
    // a client that stops reading must look dead, not immortal: a
    // blocked write would otherwise pin this thread past shutdown
    stream
        .set_write_timeout(Some(cfg.write_timeout))
        .context("set write timeout")?;
    let stop_fn = || stop.load(Ordering::SeqCst);
    loop {
        let frame = match wire::read_frame_cancellable(&mut stream, &stop_fn) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(()), // client closed, or drained at stop
            Err(e) => {
                let resp = api::Response::Error {
                    message: format!("framing error: {e:#}"),
                };
                let _ = wire::write_frame(&mut stream, &wire::encode_response(&resp));
                return Err(e);
            }
        };
        let resp = match wire::decode_request(&frame) {
            Ok(req) => service.dispatch(req),
            Err(e) => api::Response::Error {
                message: format!("bad request: {e:#}"),
            },
        };
        wire::write_frame(&mut stream, &wire::encode_response(&resp))
            .context("write response frame")?;
    }
}
