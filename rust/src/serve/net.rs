//! The TCP endpoint: `serve::api` over `serve::wire` frames, served by
//! a **nonblocking poll loop** — one event thread owns the listener
//! and every connection (accept + read + write, no thread per socket),
//! and a small dispatcher pool executes decoded requests through the
//! same [`api::Dispatcher::dispatch`] the in-process path uses. So a
//! remote call *is* the local call, stamp and all — and the dispatch
//! surface is a trait, so the same endpoint fronts a leaf `Service` or
//! a `serve::cluster` router unchanged.
//!
//! ## Protocol v2: many frames in flight per connection
//!
//! A request frame may carry a `"rid"` (see `wire::decode_request_tagged`).
//! Tagged requests dispatch concurrently and complete **out of order**;
//! each response echoes its rid. Untagged (v1) requests keep the v1
//! contract: their responses are released in request arrival order, so
//! a v1 single-frame peer — or a v1 peer that pipelines without rids —
//! observes exactly the old behavior. A rid already in flight on the
//! same connection is answered with a typed error (tagged with that
//! rid) without dispatching; it cannot desynchronize the stream.
//!
//! ## Error taxonomy (unchanged from v1)
//!
//! A frame that decodes but fails in dispatch is a typed `Error`
//! *response*; a frame that does not decode gets a typed `Error`
//! response too and the connection stays usable (framing is still
//! intact). A framing error — oversized length prefix — is
//! unrecoverable: one last `Error` frame, then close. Connections over
//! [`NetConfig::max_conns`] get a typed refusal frame and are closed
//! (counted via [`api::Dispatcher::note_conn_refused`]).
//!
//! ## Shutdown
//!
//! A graceful drain: the loop stops accepting and reading, frames
//! already received whole are still dispatched, every in-flight
//! dispatch completes and its response is flushed, then connections
//! close. A frame only *partially* received when the stop lands is
//! abandoned, and a peer that stops reading its responses is declared
//! dead after [`NetConfig::write_timeout`] without write progress — a
//! stalled peer must not block shutdown indefinitely.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::api::{self, Dispatcher};
use super::wire;

/// Endpoint tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Maximum concurrent client connections; further connections get
    /// a typed `Error` response and are closed.
    pub max_conns: usize,
    /// Upper bound on how long the event loop sleeps when idle (the
    /// loop wakes immediately on dispatch completions; this bounds the
    /// latency of *noticing* new bytes and the stop flag).
    pub poll: Duration,
    /// Deadline for making write progress on one connection. A client
    /// that stops reading (full send buffer) is treated as dead once
    /// this elapses, so a stalled connection can never block
    /// [`NetServer::shutdown`]'s drain.
    pub write_timeout: Duration,
    /// Dispatcher threads executing decoded requests. This bounds how
    /// many requests the endpoint runs concurrently *outside* the
    /// server's own worker queue (traces run inline on these threads).
    pub dispatchers: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            max_conns: 64,
            poll: Duration::from_millis(100),
            write_timeout: Duration::from_secs(30),
            dispatchers: 4,
        }
    }
}

/// Typed rejection of a zero-size dispatcher pool: an endpoint with no
/// dispatcher threads could accept connections but never answer them,
/// so [`NetServer::bind_with`] refuses it up front. Carried as the
/// root cause inside the returned `anyhow::Error`, so callers (and the
/// CLI) distinguish the config mistake from a bind failure with
/// `err.downcast_ref::<ZeroDispatchers>()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ZeroDispatchers;

impl std::fmt::Display for ZeroDispatchers {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "dispatcher pool size must be >= 1 (got 0)")
    }
}

impl std::error::Error for ZeroDispatchers {}

/// A running TCP endpoint. Dropping it (or calling
/// [`Self::shutdown`]) stops the loop and drains every connection.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    loop_handle: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:7700`; port 0 picks an ephemeral
    /// port — read the result off [`Self::local_addr`]) and start
    /// serving `service` (any [`api::Dispatcher`]: a leaf `Service` or
    /// a cluster `Router`). A bind failure names the address that
    /// failed, so "port in use" is diagnosable from the message alone.
    pub fn bind<D: Dispatcher>(addr: &str, service: Arc<D>) -> Result<Self> {
        Self::bind_with(addr, service, NetConfig::default())
    }

    /// [`Self::bind`] with explicit [`NetConfig`].
    pub fn bind_with<D: Dispatcher>(
        addr: &str,
        service: Arc<D>,
        cfg: NetConfig,
    ) -> Result<Self> {
        if cfg.dispatchers == 0 {
            return Err(anyhow::Error::new(ZeroDispatchers)
                .context(format!("refusing to bind {addr}")));
        }
        let service: Arc<dyn Dispatcher> = service;
        let listener =
            TcpListener::bind(addr).with_context(|| format!("failed to bind {addr}"))?;
        let local_addr = listener
            .local_addr()
            .with_context(|| format!("local_addr of listener on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("set listener non-blocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let loop_stop = Arc::clone(&stop);
        let loop_handle = std::thread::Builder::new()
            .name("domino-net-loop".to_string())
            .spawn(move || event_loop(listener, service, loop_stop, cfg))
            .context("spawn net event loop")?;
        Ok(Self {
            local_addr,
            stop,
            loop_handle: Some(loop_handle),
        })
    }

    /// The actually-bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, drain every live connection, join the threads.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.loop_handle.take() {
            h.join()
                .map_err(|_| anyhow::anyhow!("net event loop panicked"))?;
        }
        Ok(())
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch pool
// ---------------------------------------------------------------------------

/// How a response is slotted back into its connection's stream:
/// `Seq` = untagged (v1) request, released in arrival order; `Rid` =
/// tagged (v2) request, released as soon as it completes.
#[derive(Clone, Copy, Debug)]
enum Slot {
    Seq(u64),
    Rid(u64),
}

struct Job {
    conn: u64,
    slot: Slot,
    req: api::Request,
}

struct Done {
    conn: u64,
    slot: Slot,
    bytes: Vec<u8>,
}

#[derive(Default)]
struct DispatchQueue {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
    stop: AtomicBool,
}

impl DispatchQueue {
    fn push(&self, job: Job) {
        self.jobs.lock().unwrap().push_back(job);
        self.cv.notify_one();
    }

    fn is_empty(&self) -> bool {
        self.jobs.lock().unwrap().is_empty()
    }

    /// Publish the stop flag under the jobs mutex (a store outside the
    /// lock could slot between a dispatcher's emptiness check and its
    /// wait — the classic missed wakeup; same discipline as
    /// `Server::shutdown`).
    fn stop_all(&self) {
        let _jobs = self.jobs.lock().unwrap();
        self.stop.store(true, Ordering::SeqCst);
        drop(_jobs);
        self.cv.notify_all();
    }
}

/// Encode `resp` for `slot`, downgrading a response too large to frame
/// (possible only for pathological trace windows) to a typed error
/// instead of killing the connection.
fn encode_for_slot(resp: &api::Response, slot: Slot) -> Vec<u8> {
    let rid = match slot {
        Slot::Seq(_) => None,
        Slot::Rid(r) => Some(r),
    };
    let bytes = wire::encode_response_tagged(resp, rid);
    if bytes.len() <= wire::MAX_FRAME {
        return bytes;
    }
    wire::encode_response_tagged(
        &api::Response::Error {
            message: format!(
                "response of {} bytes exceeds the {}-byte frame limit",
                bytes.len(),
                wire::MAX_FRAME
            ),
        },
        rid,
    )
}

fn dispatcher_entry(
    q: Arc<DispatchQueue>,
    service: Arc<dyn Dispatcher>,
    done_tx: mpsc::Sender<Done>,
) {
    loop {
        let job = {
            let mut jobs = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.pop_front() {
                    break Some(j);
                }
                if q.stop.load(Ordering::SeqCst) {
                    break None;
                }
                jobs = q.cv.wait(jobs).unwrap();
            }
        };
        let Some(job) = job else { return };
        let done = run_job(&*service, job);
        if done_tx.send(done).is_err() {
            return; // event loop gone
        }
    }
}

/// Execute one job. A panic inside dispatch (a bug, not a typed
/// failure) becomes a typed error response: losing the completion
/// would leave its connection's in-flight accounting stuck and wedge
/// the drain.
fn run_job(service: &dyn Dispatcher, job: Job) -> Done {
    let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        service.dispatch(job.req)
    }))
    .unwrap_or_else(|_| api::Response::Error {
        message: "internal error: dispatch panicked".to_string(),
    });
    Done {
        conn: job.conn,
        slot: job.slot,
        bytes: encode_for_slot(&resp, job.slot),
    }
}

// ---------------------------------------------------------------------------
// Connection state
// ---------------------------------------------------------------------------

/// Per-connection cap on concurrently dispatched requests: past it the
/// loop stops reading the socket, so a peer that floods frames gets
/// TCP backpressure instead of an unbounded job queue.
const CONN_INFLIGHT_CAP: usize = 256;

/// Per-connection cap on unflushed response bytes: past it the loop
/// stops reading, so a peer that streams undecodable frames (each of
/// which earns an immediate error response) cannot grow the write
/// buffer without bound while never reading any of it.
const CONN_WBUF_CAP: usize = 4 << 20;

struct Conn {
    id: u64,
    stream: TcpStream,
    peer: String,
    /// Unparsed received bytes (at most one partial frame plus a read
    /// chunk — complete frames are consumed as they appear).
    rbuf: Vec<u8>,
    /// Pending outgoing bytes and how far they have been written.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Arrival-order counter for untagged requests…
    next_seq: u64,
    /// …and the next one whose response may be released.
    release_seq: u64,
    /// Untagged responses that completed out of order, held until
    /// every earlier one has been released.
    held: BTreeMap<u64, Vec<u8>>,
    /// Rids currently in flight (duplicates are refused without
    /// dispatching).
    live_rids: HashSet<u64>,
    /// Dispatched-but-not-completed requests (both kinds).
    inflight: usize,
    /// No more reads: peer closed, framing broke, or drain started.
    eof: bool,
    /// Remove immediately (write side failed or stalled out).
    dead: bool,
    /// When the current write stall started.
    stalled_since: Option<Instant>,
}

impl Conn {
    fn new(id: u64, stream: TcpStream, peer: String) -> Self {
        Self {
            id,
            stream,
            peer,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            next_seq: 0,
            release_seq: 0,
            held: BTreeMap::new(),
            live_rids: HashSet::new(),
            inflight: 0,
            eof: false,
            dead: false,
            stalled_since: None,
        }
    }

    fn push_frame(&mut self, payload: &[u8]) {
        self.wbuf
            .extend_from_slice(&(payload.len() as u32).to_be_bytes());
        self.wbuf.extend_from_slice(payload);
    }

    /// Slot a completed response in, releasing every untagged response
    /// that is now in order.
    fn complete(&mut self, slot: Slot, bytes: Vec<u8>) {
        match slot {
            Slot::Rid(r) => {
                self.live_rids.remove(&r);
                self.push_frame(&bytes);
            }
            Slot::Seq(s) => {
                self.held.insert(s, bytes);
                while let Some(b) = self.held.remove(&self.release_seq) {
                    self.push_frame(&b);
                    self.release_seq += 1;
                }
            }
        }
    }

    /// True once nothing more can happen on this connection.
    fn finished(&self) -> bool {
        self.dead || (self.eof && self.inflight == 0 && self.wpos == self.wbuf.len())
    }
}

// ---------------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------------

fn event_loop(
    listener: TcpListener,
    service: Arc<dyn Dispatcher>,
    stop: Arc<AtomicBool>,
    cfg: NetConfig,
) {
    let q = Arc::new(DispatchQueue::default());
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut pool = Vec::new();
    // `bind_with` rejects dispatchers == 0 (ZeroDispatchers), so the
    // pool is never empty.
    for d in 0..cfg.dispatchers {
        let spawned = std::thread::Builder::new()
            .name(format!("domino-net-dispatch-{d}"))
            .spawn({
                let q = Arc::clone(&q);
                let service = Arc::clone(&service);
                let done_tx = done_tx.clone();
                move || dispatcher_entry(q, service, done_tx)
            });
        match spawned {
            Ok(h) => pool.push(h),
            Err(e) => eprintln!("domino-net: spawn dispatcher: {e}"),
        }
    }
    drop(done_tx);

    let idle = cfg.poll.min(Duration::from_micros(500));
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_conn = 0u64;
    let mut chunk = vec![0u8; 64 * 1024];
    let mut draining = false;

    loop {
        let mut progress = false;

        if !draining && stop.load(Ordering::SeqCst) {
            // drain transition: frames already received whole are
            // still served; partial frames are abandoned
            draining = true;
            for c in conns.values_mut() {
                parse_frames(c, &q);
                c.eof = true;
                c.rbuf.clear();
            }
            progress = true;
        }

        if !draining {
            progress |= accept_new(&listener, &mut conns, &mut next_conn, &service, &cfg);
            for c in conns.values_mut() {
                progress |= read_and_parse(c, &mut chunk, &q);
            }
        }

        // completions: drain whatever the dispatchers finished
        while let Ok(done) = done_rx.try_recv() {
            if let Some(c) = conns.get_mut(&done.conn) {
                c.inflight -= 1;
                c.complete(done.slot, done.bytes);
            }
            progress = true;
        }

        // degenerate fallback: with no dispatcher threads at all,
        // execute queued jobs inline so the endpoint still functions
        if pool.is_empty() {
            let job = q.jobs.lock().unwrap().pop_front();
            if let Some(job) = job {
                let done = run_job(&*service, job);
                if let Some(c) = conns.get_mut(&done.conn) {
                    c.inflight -= 1;
                    c.complete(done.slot, done.bytes);
                }
                progress = true;
            }
        }

        for c in conns.values_mut() {
            progress |= flush_writes(c, cfg.write_timeout);
        }
        conns.retain(|_, c| !c.finished());

        if draining
            && q.is_empty()
            && conns.values().all(|c| c.inflight == 0)
            && conns.values().all(|c| c.wpos == c.wbuf.len() || c.dead)
        {
            break;
        }

        if !progress {
            // sleep on the completion channel: a finishing dispatch
            // wakes the loop immediately, new socket bytes are noticed
            // within `idle`
            match done_rx.recv_timeout(idle) {
                Ok(done) => {
                    if let Some(c) = conns.get_mut(&done.conn) {
                        c.inflight -= 1;
                        c.complete(done.slot, done.bytes);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // all dispatchers gone (only possible if none were
                    // ever spawned); inline fallback above still runs
                    std::thread::sleep(idle);
                }
            }
        }
    }

    q.stop_all();
    for h in pool {
        let _ = h.join();
    }
}

/// Accept every connection currently pending (bounded per tick).
/// Over-capacity connections get a typed refusal frame, a
/// [`Dispatcher::note_conn_refused`] tick, and a close — an operator
/// watching `Stats` sees connection-level shedding instead of a
/// mysteriously quiet endpoint.
fn accept_new(
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_conn: &mut u64,
    service: &Arc<dyn Dispatcher>,
    cfg: &NetConfig,
) -> bool {
    let mut progress = false;
    for _ in 0..16 {
        match listener.accept() {
            Ok((stream, peer)) => {
                progress = true;
                if conns.len() >= cfg.max_conns {
                    refuse(stream, cfg, service.as_ref());
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                stream.set_nodelay(true).ok();
                let id = *next_conn;
                *next_conn += 1;
                conns.insert(id, Conn::new(id, stream, peer.to_string()));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                eprintln!("domino-net: accept error: {e}");
                break;
            }
        }
    }
    progress
}

fn refuse(mut stream: TcpStream, cfg: &NetConfig, service: &dyn Dispatcher) {
    service.note_conn_refused();
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let resp = api::Response::Error {
        message: format!(
            "server at connection capacity ({}); retry later",
            cfg.max_conns
        ),
    };
    let _ = wire::write_frame(&mut stream, &wire::encode_response(&resp));
}

/// Pull whatever bytes the socket has, then consume every complete
/// frame in the buffer. Returns true on any progress.
fn read_and_parse(c: &mut Conn, chunk: &mut [u8], q: &Arc<DispatchQueue>) -> bool {
    if c.eof || c.dead {
        return false;
    }
    if c.inflight >= CONN_INFLIGHT_CAP || c.wbuf.len() - c.wpos >= CONN_WBUF_CAP {
        return false;
    }
    let mut progress = false;
    for _ in 0..8 {
        match c.stream.read(chunk) {
            Ok(0) => {
                c.eof = true;
                progress = true;
                break;
            }
            Ok(n) => {
                c.rbuf.extend_from_slice(&chunk[..n]);
                progress = true;
                parse_frames(c, q);
                if c.eof
                    || c.inflight >= CONN_INFLIGHT_CAP
                    || c.wbuf.len() - c.wpos >= CONN_WBUF_CAP
                {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(e) => {
                // the read side died; finish in-flight work, the write
                // side will discover its own fate
                eprintln!("domino-net: connection {}: read: {e}", c.peer);
                c.eof = true;
                break;
            }
        }
    }
    if progress {
        parse_frames(c, q);
    }
    progress
}

/// Consume every complete frame in `c.rbuf`: decode, then either
/// enqueue a dispatch job or complete immediately (decode errors,
/// duplicate rids). A framing error poisons the connection: one last
/// `Error` frame, reads stop, the flush-then-close path takes over.
fn parse_frames(c: &mut Conn, q: &Arc<DispatchQueue>) {
    loop {
        let range = match wire::frame_in_buffer(&c.rbuf) {
            Ok(Some(r)) => r,
            Ok(None) => return,
            Err(e) => {
                let err = api::Response::Error {
                    message: format!("framing error: {e:#}"),
                };
                c.push_frame(&wire::encode_response(&err));
                c.eof = true;
                c.rbuf.clear();
                return;
            }
        };
        let consumed = range.end;
        match wire::decode_request_tagged(&c.rbuf[range]) {
            Ok((req, None)) => {
                let seq = c.next_seq;
                c.next_seq += 1;
                c.inflight += 1;
                q.push(Job {
                    conn: c.id,
                    slot: Slot::Seq(seq),
                    req,
                });
            }
            Ok((req, Some(rid))) => {
                if c.live_rids.contains(&rid) {
                    // refuse without dispatching: the duplicate cannot
                    // desync the stream, both completions would carry
                    // the same rid
                    let err = api::Response::Error {
                        message: format!(
                            "bad request: request id {rid} is already in flight on this connection"
                        ),
                    };
                    c.push_frame(&wire::encode_response_tagged(&err, Some(rid)));
                } else {
                    c.live_rids.insert(rid);
                    c.inflight += 1;
                    q.push(Job {
                        conn: c.id,
                        slot: Slot::Rid(rid),
                        req,
                    });
                }
            }
            Err(e) => {
                // decodes as a frame but not as a request: a typed
                // error response on a surviving connection, occupying
                // an ordered slot so v1 pipelined peers stay in sync
                let seq = c.next_seq;
                c.next_seq += 1;
                let err = api::Response::Error {
                    message: format!("bad request: {e:#}"),
                };
                c.complete(Slot::Seq(seq), wire::encode_response(&err));
            }
        }
        c.rbuf.drain(..consumed);
    }
}

fn flush_writes(c: &mut Conn, write_timeout: Duration) -> bool {
    if c.dead || c.wpos == c.wbuf.len() {
        // fully flushed: reset the buffer so it doesn't grow forever
        if c.wpos > 0 {
            c.wbuf.clear();
            c.wpos = 0;
        }
        c.stalled_since = None;
        return false;
    }
    let mut progress = false;
    while c.wpos < c.wbuf.len() {
        match c.stream.write(&c.wbuf[c.wpos..]) {
            Ok(0) => {
                c.dead = true;
                return true;
            }
            Ok(n) => {
                c.wpos += n;
                c.stalled_since = None;
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // no room: start (or check) the stall clock — a peer
                // that stopped reading is dead after write_timeout
                match c.stalled_since {
                    None => c.stalled_since = Some(Instant::now()),
                    Some(t0) if t0.elapsed() > write_timeout => {
                        eprintln!(
                            "domino-net: connection {}: write stalled past {:?}; dropping",
                            c.peer, write_timeout
                        );
                        c.dead = true;
                        return true;
                    }
                    Some(_) => {}
                }
                break;
            }
            Err(e) => {
                eprintln!("domino-net: connection {}: write: {e}", c.peer);
                c.dead = true;
                return true;
            }
        }
    }
    if c.wpos == c.wbuf.len() {
        c.wbuf.clear();
        c.wpos = 0;
    }
    progress
}
