//! Traffic record/replay + scenario harness: serving under hostile
//! reality instead of well-behaved closed loops.
//!
//! Three planes in one module:
//!
//! - **Recorder** ([`TrafficRecorder`]): a [`DispatchTap`] armed on a
//!   live [`Service`] that captures every request/response pair with a
//!   microsecond arrival offset into a [`TrafficLog`]. The on-disk
//!   format reuses the wire codec verbatim — length-prefixed frames of
//!   the same hand-rolled JSON `serve::wire` speaks, one header frame
//!   (format name + version) followed by one frame per entry — so a
//!   log survives protocol revisions exactly as well as the wire does.
//! - **Replayer** ([`replay`]/[`replay_with`]): re-issues a recorded
//!   log against a service at a configurable [`ReplaySpeed`]
//!   (wall-clock, max-rate, or scaled) and diffs each live response
//!   against the recorded one byte-for-byte after stripping the
//!   fields that legitimately vary run-to-run (timing splits,
//!   point-in-time stats) — see [`comparable_bytes`]. Same seeds, same
//!   models ⇒ byte-identical logits and stamps, turning "handles the
//!   same traffic the same way" into a checked property. Logs that
//!   contain timing-dependent backpressure rejections replay
//!   byte-identically too under [`AdmissionMode::Recorded`], which
//!   re-applies the recorded accept/reject decisions instead of
//!   re-racing the queue.
//! - **Scenario generator**: open-loop [`Arrival`] schedules (uniform,
//!   Poisson, bursty), an [`overload`] scenario that pushes past
//!   `queue_cap` and proves rejection stays *typed* (zero dropped
//!   accepted requests, zero untyped failures), an [`admin_storm`]
//!   (swap/load under burst), a TCP [`slow_loris`] dribbling bytes
//!   into the frame reader while well-behaved peers stay served, and
//!   an SLO-conditioned load search ([`slo_search`]: max sustained
//!   rate at p99 below a bound). [`scenario_suite`] bundles them for
//!   the `domino traffic scenario` CLI and the `serve_sim_throughput`
//!   bench's `scenarios` section in `BENCH_serve.json`.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::api::{DispatchTap, Request, Response, Service};
use super::metrics::LatencyStats;
use super::wire::{self, Json};
use crate::testutil::Rng;

/// Magic string identifying a traffic log's header frame.
pub const TRAFFIC_LOG_FORMAT: &str = "domino-traffic-log";
/// Current on-disk log format revision.
pub const TRAFFIC_LOG_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// Log format
// ---------------------------------------------------------------------------

/// One recorded dispatch: arrival offset (µs since the recorder was
/// armed), the request, and the response the service produced.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LogEntry {
    pub at_us: u64,
    pub request: Request,
    pub response: Response,
}

/// A recorded traffic session, ordered by arrival.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TrafficLog {
    pub entries: Vec<LogEntry>,
}

impl TrafficLog {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize to the framed on-disk format: a header frame
    /// (`format`/`version`/`entries`) then one frame per entry, each
    /// frame the same length-prefixed JSON the wire speaks.
    pub fn save_to<W: std::io::Write>(&self, w: &mut W) -> Result<()> {
        let header = wire::obj(vec![
            ("format", wire::s(TRAFFIC_LOG_FORMAT)),
            ("version", wire::u(TRAFFIC_LOG_VERSION)),
            ("entries", wire::u(self.entries.len() as u64)),
        ]);
        wire::write_frame(w, wire::encode(&header).as_bytes())?;
        for e in &self.entries {
            let frame = wire::obj(vec![
                ("at_us", wire::u(e.at_us)),
                ("request", wire::request_to_json(&e.request)),
                ("response", wire::response_to_json(&e.response)),
            ]);
            wire::write_frame(w, wire::encode(&frame).as_bytes())?;
        }
        Ok(())
    }

    /// Parse a log from its framed form. The header's `entries` count
    /// is verified, so a truncated file is an error, never a silently
    /// shorter session.
    pub fn load_from<R: Read>(r: &mut R) -> Result<Self> {
        let header = wire::read_frame(r)?
            .ok_or_else(|| anyhow!("empty traffic log (no header frame)"))?;
        let header = wire::decode(
            std::str::from_utf8(&header).context("traffic log header is not UTF-8")?,
        )?;
        let format = wire::str_field(&header, "format")?;
        ensure!(
            format == TRAFFIC_LOG_FORMAT,
            "not a traffic log (format {format:?})"
        );
        let version = wire::u64_field(&header, "version")?;
        ensure!(
            version == TRAFFIC_LOG_VERSION,
            "traffic log version {version} is not supported (this build reads {TRAFFIC_LOG_VERSION})"
        );
        let expected = wire::u64_field(&header, "entries")? as usize;
        let mut entries = Vec::with_capacity(expected.min(1 << 20));
        while let Some(frame) = wire::read_frame(r)? {
            let v = wire::decode(
                std::str::from_utf8(&frame).context("traffic log entry is not UTF-8")?,
            )?;
            entries.push(LogEntry {
                at_us: wire::u64_field(&v, "at_us")?,
                request: wire::request_from_json(wire::field(&v, "request")?)?,
                response: wire::response_from_json(wire::field(&v, "response")?)?,
            });
        }
        ensure!(
            entries.len() == expected,
            "traffic log truncated: header promises {expected} entries, found {}",
            entries.len()
        );
        Ok(Self { entries })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(
            File::create(path).with_context(|| format!("create {}", path.display()))?,
        );
        self.save_to(&mut w)?;
        w.flush()
            .with_context(|| format!("flush {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        Self::load_from(&mut r)
            .with_context(|| format!("parse traffic log {}", path.display()))
    }
}

// ---------------------------------------------------------------------------
// Recorder
// ---------------------------------------------------------------------------

/// The dispatch tap that captures a [`TrafficLog`] from a live
/// service. Arm with [`TrafficRecorder::arm`]; every dispatch from
/// any thread (local callers and TCP connections alike) is appended
/// with its arrival offset. Call [`TrafficRecorder::finish`] to take
/// the log (typically after `Service::clear_tap`).
pub struct TrafficRecorder {
    start: Instant,
    entries: Mutex<Vec<LogEntry>>,
}

impl TrafficRecorder {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            start: Instant::now(),
            entries: Mutex::new(Vec::new()),
        })
    }

    /// Create a recorder and arm it on `service` in one step.
    pub fn arm(service: &Service) -> Arc<Self> {
        let rec = Self::new();
        service.set_tap(Arc::clone(&rec) as Arc<dyn DispatchTap>);
        rec
    }

    /// Entries captured so far.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take the captured log (the recorder keeps running if still
    /// armed; entries recorded after this call start a fresh log).
    pub fn finish(&self) -> TrafficLog {
        TrafficLog {
            entries: std::mem::take(&mut *self.entries.lock().unwrap()),
        }
    }
}

impl DispatchTap for TrafficRecorder {
    fn on_dispatch(&self, req: &Request, resp: &Response) {
        let at_us = self.start.elapsed().as_micros() as u64;
        self.entries.lock().unwrap().push(LogEntry {
            at_us,
            request: req.clone(),
            response: resp.clone(),
        });
    }
}

// ---------------------------------------------------------------------------
// Replayer
// ---------------------------------------------------------------------------

/// How fast to re-issue a recorded log.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplaySpeed {
    /// Honor the recorded arrival offsets (1x wall-clock).
    Wallclock,
    /// Issue back-to-back, as fast as the loop can go.
    MaxRate,
    /// Scale the recorded gaps: `num/den` is a *rate* multiplier, so
    /// `Scaled { num: 2, den: 1 }` replays twice as fast (half the
    /// gaps) and `Scaled { num: 1, den: 2 }` at half speed.
    Scaled { num: u32, den: u32 },
}

impl ReplaySpeed {
    /// Parse `"1x"`, `"max"`, or `"Nx"` (e.g. `"4x"`, `"0.5x"` is
    /// spelled `"1/2x"`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "1x" | "wallclock" => Ok(Self::Wallclock),
            "max" | "max-rate" => Ok(Self::MaxRate),
            other => {
                let body = other
                    .strip_suffix('x')
                    .ok_or_else(|| anyhow!("bad replay speed {other:?} (want 1x, max, Nx or N/Mx)"))?;
                let (num, den) = match body.split_once('/') {
                    Some((n, d)) => (n.parse()?, d.parse()?),
                    None => (body.parse()?, 1u32),
                };
                ensure!(num > 0 && den > 0, "replay speed must be positive");
                Ok(Self::Scaled { num, den })
            }
        }
    }

    fn scale_gap(&self, gap_us: u64) -> Option<u64> {
        match *self {
            Self::Wallclock => Some(gap_us),
            Self::MaxRate => None,
            Self::Scaled { num, den } => {
                Some((gap_us as u128 * den as u128 / num as u128) as u64)
            }
        }
    }
}

/// How a replay treats recorded *admission decisions* — accepted
/// requests vs typed backpressure rejections.
///
/// Backpressure is timing-dependent: whether a request found the
/// queue full depends on worker pace and replay speed, so a log
/// containing rejections cannot replay byte-identically by re-racing
/// admission ([`AdmissionMode::Live`]). [`AdmissionMode::Recorded`]
/// re-applies the recorded decisions instead: entries recorded as
/// backpressure rejections are reproduced without dispatching (the
/// decision, and therefore the response bytes, are exact), and
/// entries recorded as accepted retry through transient live
/// backpressure until the service admits them. With admission pinned,
/// determinism is back: same seeds, same models ⇒ byte-identical
/// responses at any replay speed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Re-race admission live: every entry is dispatched, and a log
    /// with recorded rejections may legitimately diverge.
    #[default]
    Live,
    /// Re-apply recorded accept/reject decisions (see above).
    Recorded,
}

impl AdmissionMode {
    /// Parse `"live"` or `"recorded"`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "live" => Ok(Self::Live),
            "recorded" => Ok(Self::Recorded),
            other => bail!("bad admission mode {other:?} (want live or recorded)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Live => "live",
            Self::Recorded => "recorded",
        }
    }
}

/// Whether a response is the typed backpressure rejection the
/// bounded queue sheds load with.
fn is_backpressure(resp: &Response) -> bool {
    matches!(resp, Response::Error { message } if message.contains("backpressure"))
}

/// Outcome of one replay pass.
#[derive(Clone, Debug, Default)]
pub struct ReplayReport {
    /// Entries re-issued.
    pub total: u64,
    /// Live response byte-identical to the recorded one (after
    /// stripping run-varying fields — see [`comparable_bytes`]).
    pub matched: u64,
    /// Comparable but different.
    pub mismatched: u64,
    /// Not comparable (point-in-time `Stats` replies).
    pub skipped: u64,
    pub elapsed: Duration,
    /// Human-readable description of the first divergence.
    pub first_mismatch: Option<String>,
    /// Recorded backpressure rejections re-applied without
    /// dispatching ([`AdmissionMode::Recorded`] only).
    pub rejections_reapplied: u64,
    /// Live transient-backpressure retries absorbed for entries the
    /// recording accepted ([`AdmissionMode::Recorded`] only).
    pub backpressure_retries: u64,
}

impl ReplayReport {
    /// True when every comparable response matched.
    pub fn is_identical(&self) -> bool {
        self.mismatched == 0
    }
}

/// The byte-comparable form of a response for replay diffing, or
/// `None` when the variant cannot be compared across runs. Server-side
/// timing splits (`queue_us`/`exec_us`) legitimately differ run to run
/// and are zeroed; `Stats` replies are point-in-time counters and are
/// skipped entirely. Everything semantic — logits, stamps, model
/// descriptions, trace events, error messages — must reproduce
/// byte-for-byte at the same seeds.
pub fn comparable_bytes(resp: &Response) -> Option<Vec<u8>> {
    match resp {
        Response::Stats(_) => None,
        Response::Infer(r) => {
            let mut c = r.clone();
            c.queue_us = 0;
            c.exec_us = 0;
            Some(wire::encode_response(&Response::Infer(c)))
        }
        other => Some(wire::encode_response(other)),
    }
}

/// Replay `log` through an arbitrary dispatch function at `speed`,
/// diffing every live response against the recorded one. The dispatch
/// function abstracts the target: `Service::dispatch` for in-process
/// replay ([`replay`]), a `Client` round-trip for replay against a
/// remote endpoint (`domino traffic replay --addr`).
pub fn replay_with<F: FnMut(Request) -> Response>(
    log: &TrafficLog,
    speed: ReplaySpeed,
    dispatch: F,
) -> ReplayReport {
    replay_with_admission(log, speed, AdmissionMode::Live, dispatch)
}

/// [`replay_with`] with an explicit [`AdmissionMode`]. Under
/// [`AdmissionMode::Recorded`], recorded backpressure rejections are
/// reproduced without dispatching and recorded-accepted entries retry
/// through transient live backpressure (bounded; a queue that never
/// drains still surfaces as a mismatch rather than a hang).
pub fn replay_with_admission<F: FnMut(Request) -> Response>(
    log: &TrafficLog,
    speed: ReplaySpeed,
    admission: AdmissionMode,
    mut dispatch: F,
) -> ReplayReport {
    // bounded retry budget per accepted entry: plenty for a transient
    // full queue, finite for a wedged one
    const MAX_RETRIES: u32 = 200;
    const RETRY_PAUSE: Duration = Duration::from_millis(2);
    let mut report = ReplayReport::default();
    let start = Instant::now();
    let mut prev_at = log.entries.first().map(|e| e.at_us).unwrap_or(0);
    let mut due = Duration::ZERO;
    for e in &log.entries {
        if let Some(gap) = speed.scale_gap(e.at_us.saturating_sub(prev_at)) {
            due += Duration::from_micros(gap);
            let now = start.elapsed();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        prev_at = e.at_us;
        if admission == AdmissionMode::Recorded && is_backpressure(&e.response) {
            // the recorded decision was "reject": re-apply it verbatim
            // instead of re-racing the queue — byte-exact by
            // construction
            report.total += 1;
            report.matched += 1;
            report.rejections_reapplied += 1;
            continue;
        }
        let mut live = dispatch(e.request.clone());
        if admission == AdmissionMode::Recorded && !is_backpressure(&e.response) {
            let mut retries = 0;
            while is_backpressure(&live) && retries < MAX_RETRIES {
                std::thread::sleep(RETRY_PAUSE);
                live = dispatch(e.request.clone());
                retries += 1;
            }
            report.backpressure_retries += u64::from(retries);
        }
        report.total += 1;
        match (comparable_bytes(&e.response), comparable_bytes(&live)) {
            (Some(want), Some(got)) => {
                if want == got {
                    report.matched += 1;
                } else {
                    report.mismatched += 1;
                    if report.first_mismatch.is_none() {
                        report.first_mismatch = Some(format!(
                            "entry {} ({:?}): recorded {} bytes != live {} bytes\n  recorded: {}\n  live:     {}",
                            report.total - 1,
                            request_kind(&e.request),
                            want.len(),
                            got.len(),
                            String::from_utf8_lossy(&want[..want.len().min(160)]),
                            String::from_utf8_lossy(&got[..got.len().min(160)]),
                        ));
                    }
                }
            }
            _ => report.skipped += 1,
        }
    }
    report.elapsed = start.elapsed();
    report
}

/// [`replay_with`] against a local [`Service`].
pub fn replay(log: &TrafficLog, service: &Service, speed: ReplaySpeed) -> ReplayReport {
    replay_with(log, speed, |req| service.dispatch(req))
}

/// [`replay_with_admission`] against a local [`Service`].
pub fn replay_admission(
    log: &TrafficLog,
    service: &Service,
    speed: ReplaySpeed,
    admission: AdmissionMode,
) -> ReplayReport {
    replay_with_admission(log, speed, admission, |req| service.dispatch(req))
}

fn request_kind(req: &Request) -> &'static str {
    match req {
        Request::Infer { .. } => "infer",
        Request::Load { .. } => "load",
        Request::LoadSeeded { .. } => "load_seeded",
        Request::Swap { .. } => "swap",
        Request::Unload { .. } => "unload",
        Request::ListModels => "list_models",
        Request::ModelInfo { .. } => "model_info",
        Request::Stats => "stats",
        Request::Trace { .. } => "trace",
    }
}

// ---------------------------------------------------------------------------
// Arrival schedules
// ---------------------------------------------------------------------------

/// Open-loop arrival process for scenario traffic.
#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// Evenly spaced arrivals at `rate` requests/second.
    Uniform { rate: u64 },
    /// Poisson process with mean `rate` requests/second
    /// (exponentially distributed gaps, deterministic in `seed`).
    Poisson { rate: u64, seed: u64 },
    /// `burst` back-to-back arrivals, then `gap_us` of silence.
    Bursty { burst: usize, gap_us: u64 },
}

/// The first `n` arrival offsets (µs from schedule start) of the
/// process — a pure function, so scenarios are reproducible.
pub fn arrival_offsets_us(a: Arrival, n: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(n);
    match a {
        Arrival::Uniform { rate } => {
            let gap = 1_000_000 / rate.max(1);
            for i in 0..n as u64 {
                out.push(i * gap);
            }
        }
        Arrival::Poisson { rate, seed } => {
            let mut rng = Rng::new(seed ^ 0x7261_6666_6963); // "raffic"
            let mut t = 0.0f64;
            let rate = rate.max(1) as f64;
            for _ in 0..n {
                // inverse-CDF exponential gap; clamp the uniform away
                // from 0 so ln never sees it
                let u = rng.f64().max(1e-12);
                t += -u.ln() / rate * 1e6;
                out.push(t as u64);
            }
        }
        Arrival::Bursty { burst, gap_us } => {
            let burst = burst.max(1);
            for i in 0..n {
                out.push((i / burst) as u64 * gap_us);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Scenarios
// ---------------------------------------------------------------------------

/// Outcome of the [`overload`] scenario. The invariant that makes
/// backpressure *backpressure* (and not collapse): every submission is
/// accounted for — `accepted + rejected == submitted`, `failed == 0`,
/// and `dropped == 0` (no request vanished without a response).
#[derive(Clone, Copy, Debug, Default)]
pub struct OverloadReport {
    pub submitted: u64,
    /// Answered with logits.
    pub accepted: u64,
    /// Typed backpressure rejections (`queue full … backpressure`).
    pub rejected: u64,
    /// Any other error — must be 0 under pure overload.
    pub failed: u64,
    /// Submissions that got no response at all — must always be 0.
    pub dropped: u64,
}

/// Push `threads` concurrent open-loop submitters at a service until
/// `submitted` total requests have been issued — deliberately far past
/// `queue_cap` — and classify every response. Overload must produce
/// typed rejections, zero untyped failures, zero drops.
pub fn overload(
    service: &Service,
    model: &str,
    submitted: usize,
    threads: usize,
    seed: u64,
) -> Result<OverloadReport> {
    let input_len = model_input_len(service, model)?;
    let accepted = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let answered = AtomicU64::new(0);
    let threads = threads.max(1);
    let per_thread = submitted.div_ceil(threads);
    let submitted = per_thread * threads;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let image = Rng::new(seed.wrapping_add(t as u64)).i8_vec(input_len, 31);
            let (accepted, rejected, failed, answered) =
                (&accepted, &rejected, &failed, &answered);
            let service = &service;
            scope.spawn(move || {
                for _ in 0..per_thread {
                    let resp = service.dispatch(Request::Infer {
                        model: Some(model.to_string()),
                        image: image.clone(),
                    });
                    answered.fetch_add(1, Ordering::Relaxed);
                    match resp {
                        Response::Infer(_) => accepted.fetch_add(1, Ordering::Relaxed),
                        Response::Error { message } if message.contains("backpressure") => {
                            rejected.fetch_add(1, Ordering::Relaxed)
                        }
                        _ => failed.fetch_add(1, Ordering::Relaxed),
                    };
                }
            });
        }
    });
    Ok(OverloadReport {
        submitted: submitted as u64,
        accepted: accepted.into_inner(),
        rejected: rejected.into_inner(),
        failed: failed.into_inner(),
        dropped: submitted as u64 - answered.into_inner(),
    })
}

/// Outcome of an open-loop [`burst_run`]: how the data plane behaved
/// under a fixed arrival schedule.
#[derive(Clone, Debug, Default)]
pub struct BurstReport {
    pub submitted: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub failed: u64,
    pub dropped: u64,
    /// End-to-end latency from *scheduled arrival* to response — the
    /// open-loop number (includes time spent behind schedule), which
    /// is the one an SLO is written against.
    pub p50_us: Option<u64>,
    pub p99_us: Option<u64>,
}

/// Drive `model` with open-loop arrivals at the given schedule using a
/// small dispatcher pool: request `i` is issued at `offsets[i]` (or as
/// soon after as a dispatcher frees up — falling behind schedule is
/// charged to latency, exactly like a real overloaded frontend).
pub fn burst_run(
    service: &Service,
    model: &str,
    offsets_us: &[u64],
    dispatchers: usize,
    seed: u64,
) -> Result<BurstReport> {
    let input_len = model_input_len(service, model)?;
    let accepted = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let answered = AtomicU64::new(0);
    let lat = Mutex::new(LatencyStats::default());
    let dispatchers = dispatchers.max(1);
    let start = Instant::now();
    std::thread::scope(|scope| {
        for d in 0..dispatchers {
            let image = Rng::new(seed.wrapping_add(d as u64)).i8_vec(input_len, 31);
            let (accepted, rejected, failed, answered, lat) =
                (&accepted, &rejected, &failed, &answered, &lat);
            let service = &service;
            scope.spawn(move || {
                // dispatcher d owns arrivals d, d+K, d+2K, …
                for &off in offsets_us.iter().skip(d).step_by(dispatchers) {
                    let due = Duration::from_micros(off);
                    let now = start.elapsed();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    let resp = service.dispatch(Request::Infer {
                        model: Some(model.to_string()),
                        image: image.clone(),
                    });
                    let done = start.elapsed();
                    answered.fetch_add(1, Ordering::Relaxed);
                    match resp {
                        Response::Infer(_) => {
                            accepted.fetch_add(1, Ordering::Relaxed);
                            lat.lock().unwrap().record(done.saturating_sub(due));
                        }
                        Response::Error { message } if message.contains("backpressure") => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        _ => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let lat = lat.into_inner().unwrap();
    Ok(BurstReport {
        submitted: offsets_us.len() as u64,
        accepted: accepted.into_inner(),
        rejected: rejected.into_inner(),
        failed: failed.into_inner(),
        dropped: offsets_us.len() as u64 - answered.into_inner(),
        p50_us: lat.percentile(50.0),
        p99_us: lat.percentile(99.0),
    })
}

/// Outcome of [`admin_storm`]: data-plane and admin-plane requests
/// interleaved under burst.
#[derive(Clone, Copy, Debug, Default)]
pub struct StormReport {
    pub infers_ok: u64,
    pub infers_rejected: u64,
    pub infers_failed: u64,
    pub swaps_ok: u64,
    pub loads_ok: u64,
    pub admin_failed: u64,
    /// Distinct model versions observed in infer stamps — > 1 proves
    /// the storm actually swapped under live traffic.
    pub versions_seen: u64,
}

/// Mixed admin+data storm: `infer_threads` flood `model` with
/// inference while the admin plane hot-swaps it and re-loads a second
/// model `admin_rounds` times. Every response must stay typed, every
/// infer must be served by *some* coherent version (the stamp says
/// which), and nothing may drop.
pub fn admin_storm(
    service: &Service,
    model: &str,
    side_model: &str,
    admin_rounds: usize,
    infer_threads: usize,
    infers_per_thread: usize,
    seed: u64,
) -> Result<StormReport> {
    let input_len = model_input_len(service, model)?;
    let infers_ok = AtomicU64::new(0);
    let infers_rejected = AtomicU64::new(0);
    let infers_failed = AtomicU64::new(0);
    let swaps_ok = AtomicU64::new(0);
    let loads_ok = AtomicU64::new(0);
    let admin_failed = AtomicU64::new(0);
    let versions = Mutex::new(std::collections::BTreeSet::new());
    std::thread::scope(|scope| {
        for t in 0..infer_threads.max(1) {
            let image = Rng::new(seed.wrapping_add(1000 + t as u64)).i8_vec(input_len, 31);
            let (infers_ok, infers_rejected, infers_failed, versions) =
                (&infers_ok, &infers_rejected, &infers_failed, &versions);
            let service = &service;
            scope.spawn(move || {
                for _ in 0..infers_per_thread {
                    match service.dispatch(Request::Infer {
                        model: Some(model.to_string()),
                        image: image.clone(),
                    }) {
                        Response::Infer(r) => {
                            infers_ok.fetch_add(1, Ordering::Relaxed);
                            if let Some(stamp) = r.model {
                                versions.lock().unwrap().insert(stamp.version);
                            }
                        }
                        Response::Error { message } if message.contains("backpressure") => {
                            infers_rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        _ => {
                            infers_failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
        // the admin storm runs on this thread, concurrent with the flood
        for round in 0..admin_rounds {
            match service.dispatch(Request::Swap {
                model: model.to_string(),
                seed: Some(seed.wrapping_add(round as u64)),
            }) {
                Response::Swapped(_) => swaps_ok.fetch_add(1, Ordering::Relaxed),
                _ => admin_failed.fetch_add(1, Ordering::Relaxed),
            };
            // churn the side model through unload→reload each round
            // (a plain re-load would be refused as already loaded);
            // with no distinct side model there is nothing to churn —
            // unloading the primary would starve the flood
            if side_model != model {
                match service.dispatch(Request::Unload {
                    model: side_model.to_string(),
                }) {
                    Response::Unloaded(_) => {}
                    _ => {
                        admin_failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                match service.dispatch(Request::LoadSeeded {
                    model: side_model.to_string(),
                    seed: seed.wrapping_add(round as u64),
                    mapping: None,
                }) {
                    Response::Loaded(_) => loads_ok.fetch_add(1, Ordering::Relaxed),
                    _ => admin_failed.fetch_add(1, Ordering::Relaxed),
                };
            }
        }
    });
    Ok(StormReport {
        infers_ok: infers_ok.into_inner(),
        infers_rejected: infers_rejected.into_inner(),
        infers_failed: infers_failed.into_inner(),
        swaps_ok: swaps_ok.into_inner(),
        loads_ok: loads_ok.into_inner(),
        admin_failed: admin_failed.into_inner(),
        versions_seen: versions.into_inner().unwrap().len() as u64,
    })
}

/// Outcome of [`slow_loris`]: the endpoint stayed serviceable while a
/// hostile peer dribbled bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct LorisReport {
    /// Well-behaved infers completed *during* the dribble.
    pub wellbehaved_ok: u64,
    /// The dribbled frame, once finally complete, was answered.
    pub loris_answered: bool,
    /// How long the dribble held its connection open.
    pub dribble_ms: u64,
}

/// Slow-loris a live TCP endpoint: connect, then write one valid
/// request frame a few bytes at a time with pauses, while a
/// well-behaved client hammers the same endpoint. The frame reader
/// must neither hang the server nor corrupt the slow connection — the
/// dribbled request is eventually answered like any other.
pub fn slow_loris(
    addr: &str,
    model: &str,
    input_len: usize,
    wellbehaved_requests: usize,
    pause: Duration,
) -> Result<LorisReport> {
    let image = Rng::new(0x1015).i8_vec(input_len, 31);
    let payload = wire::encode_request(&Request::Infer {
        model: Some(model.to_string()),
        image,
    });
    let mut framed = Vec::with_capacity(payload.len() + 4);
    framed.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    framed.extend_from_slice(&payload);

    let mut loris = std::net::TcpStream::connect(addr)
        .with_context(|| format!("loris connect {addr}"))?;
    loris.set_nodelay(true).ok();

    let start = Instant::now();
    let wellbehaved_ok = AtomicU64::new(0);
    let mut loris_answered = false;
    std::thread::scope(|scope| -> Result<()> {
        let ok = &wellbehaved_ok;
        let handle = scope.spawn(move || -> Result<()> {
            let mut client = super::client::Client::connect(addr)?;
            let image = Rng::new(0xbe57).i8_vec(input_len, 31);
            for _ in 0..wellbehaved_requests {
                client.infer(Some(model), image.clone())?;
                ok.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        });
        // dribble the frame in ~40 slices, pausing between them, while
        // the well-behaved client runs (size-adaptive so a large image
        // payload doesn't stretch the dribble unboundedly)
        let slice = (framed.len() / 40).max(1);
        for chunk in framed.chunks(slice) {
            loris.write_all(chunk).context("loris dribble")?;
            loris.flush().ok();
            std::thread::sleep(pause);
        }
        // the frame is finally complete: the server owes a response
        if let Some(frame) = wire::read_frame(&mut loris)? {
            loris_answered = matches!(
                wire::decode_response(&frame)?,
                Response::Infer(_)
            );
        }
        handle
            .join()
            .map_err(|_| anyhow!("well-behaved client thread panicked"))?
            .context("well-behaved client failed during the dribble")?;
        Ok(())
    })?;
    Ok(LorisReport {
        wellbehaved_ok: wellbehaved_ok.into_inner(),
        loris_answered,
        dribble_ms: start.elapsed().as_millis() as u64,
    })
}

/// Outcome of [`slo_search`].
#[derive(Clone, Debug, Default)]
pub struct SloReport {
    /// The p99 bound the search was conditioned on (µs).
    pub slo_p99_us: u64,
    /// Highest tested rate (req/s) that met the SLO (0 = none did).
    pub max_rate_per_s: u64,
    /// p99 measured at that rate (µs).
    pub p99_at_max_us: u64,
    /// Every `(rate, p99_us, met_slo)` probe, in test order.
    pub probes: Vec<(u64, u64, bool)>,
}

/// SLO-conditioned load search: find the highest open-loop request
/// rate the service sustains with p99 latency under `slo_us`
/// microseconds (and zero rejections/drops). Rates ramp geometrically
/// from `start_rate` until the SLO breaks, then one bisection step
/// refines the boundary — cheap enough for CI, honest enough to trend.
pub fn slo_search(
    service: &Service,
    model: &str,
    slo_us: u64,
    start_rate: u64,
    requests_per_probe: usize,
    seed: u64,
) -> Result<SloReport> {
    let mut report = SloReport {
        slo_p99_us: slo_us,
        ..Default::default()
    };
    let mut probe = |rate: u64, report: &mut SloReport| -> Result<bool> {
        let offsets = arrival_offsets_us(Arrival::Uniform { rate }, requests_per_probe);
        let r = burst_run(service, model, &offsets, 8, seed)?;
        let p99 = r.p99_us.unwrap_or(u64::MAX);
        let ok = r.rejected == 0 && r.failed == 0 && r.dropped == 0 && p99 <= slo_us;
        report.probes.push((rate, p99, ok));
        if ok && rate > report.max_rate_per_s {
            report.max_rate_per_s = rate;
            report.p99_at_max_us = p99;
        }
        Ok(ok)
    };
    let mut rate = start_rate.max(1);
    let mut last_ok = 0u64;
    // geometric ramp until the SLO breaks (bounded: 12 doublings)
    for _ in 0..12 {
        if probe(rate, &mut report)? {
            last_ok = rate;
            rate *= 2;
        } else {
            break;
        }
    }
    // one bisection step between the last passing and first failing rate
    if last_ok > 0 && rate > last_ok {
        let mid = last_ok + (rate - last_ok) / 2;
        if mid != last_ok && mid != rate {
            probe(mid, &mut report)?;
        }
    }
    Ok(report)
}

fn model_input_len(service: &Service, model: &str) -> Result<usize> {
    let reg = service
        .server()
        .registry()
        .ok_or_else(|| anyhow!("scenario needs the sim backend (no registry)"))?;
    let mv = reg
        .get(model)
        .ok_or_else(|| anyhow!("model {model:?} is not loaded"))?;
    Ok(mv.input_len())
}

// ---------------------------------------------------------------------------
// Scenario suite (CLI + bench entry point)
// ---------------------------------------------------------------------------

/// Aggregated outcome of [`scenario_suite`], serializable into the
/// bench's `BENCH_serve.json` `scenarios` section.
#[derive(Clone, Debug)]
pub struct SuiteReport {
    pub queue_cap: usize,
    pub overload: OverloadReport,
    pub burst: BurstReport,
    pub storm: StormReport,
    pub loris: Option<LorisReport>,
    pub slo: SloReport,
}

impl SuiteReport {
    /// Wire-JSON rendering (integers only, like everything else the
    /// codec emits) for `BENCH_serve.json`.
    pub fn to_json(&self) -> Json {
        let b = |x: bool| Json::Bool(x);
        let mut fields = vec![
            ("name", wire::s("scenarios")),
            ("queue_cap", wire::u(self.queue_cap as u64)),
            ("overload_submitted", wire::u(self.overload.submitted)),
            ("overload_accepted", wire::u(self.overload.accepted)),
            ("overload_rejected", wire::u(self.overload.rejected)),
            ("overload_failed", wire::u(self.overload.failed)),
            ("overload_dropped", wire::u(self.overload.dropped)),
            ("burst_submitted", wire::u(self.burst.submitted)),
            ("burst_accepted", wire::u(self.burst.accepted)),
            ("burst_rejected", wire::u(self.burst.rejected)),
            ("burst_p99_us", wire::u(self.burst.p99_us.unwrap_or(0))),
            ("storm_infers_ok", wire::u(self.storm.infers_ok)),
            ("storm_swaps_ok", wire::u(self.storm.swaps_ok)),
            ("storm_versions_seen", wire::u(self.storm.versions_seen)),
            ("slo_p99_us", wire::u(self.slo.slo_p99_us)),
            ("slo_max_rate_per_s", wire::u(self.slo.max_rate_per_s)),
            ("slo_p99_at_max_us", wire::u(self.slo.p99_at_max_us)),
        ];
        if let Some(l) = &self.loris {
            fields.push(("loris_wellbehaved_ok", wire::u(l.wellbehaved_ok)));
            fields.push(("loris_answered", b(l.loris_answered)));
        }
        Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

/// Build a deliberately small service (2 workers, shallow queue — the
/// point is to *hit* the limits) over `models`, run every scenario
/// against it, and enforce the suite invariants: typed rejection under
/// overload with zero drops and zero untyped failures, admin storms
/// that never wedge the data plane, a slow-loris that cannot starve
/// well-behaved peers, and an SLO search that found a sustained rate.
/// Violations are `Err` so the CI leg fails loudly.
pub fn scenario_suite(models: &[String], smoke: bool, seed: u64) -> Result<SuiteReport> {
    use super::registry::ModelRegistry;
    use super::server::{ServeConfig, Server};
    use crate::coordinator::ArchConfig;
    use crate::model::zoo;

    ensure!(!models.is_empty(), "scenario suite needs at least one model");
    let queue_cap = 8;
    let registry = Arc::new(ModelRegistry::new());
    let mut canonical: Vec<String> = Vec::new();
    for m in models {
        let net = zoo::lookup(m)?;
        registry.load_seeded(&net.name, &net, ArchConfig::default(), Some(seed))?;
        canonical.push(net.name);
    }
    let server = Server::start_multi(
        ServeConfig {
            workers: 2,
            max_batch: 4,
            queue_cap,
            ..ServeConfig::default()
        },
        registry,
    )?;
    let service = Arc::new(Service::new(server, ArchConfig::default()));
    let primary = canonical[0].clone();
    let side = canonical.get(1).cloned().unwrap_or_else(|| primary.clone());

    // 1) overload past queue_cap: typed rejection, not collapse
    let (submitted, threads) = if smoke { (128, 32) } else { (512, 48) };
    let overload_report = overload(&service, &primary, submitted, threads, seed)?;
    ensure!(
        overload_report.dropped == 0,
        "overload dropped {} accepted requests",
        overload_report.dropped
    );
    ensure!(
        overload_report.failed == 0,
        "overload produced {} untyped failures",
        overload_report.failed
    );
    ensure!(
        overload_report.rejected > 0,
        "overload of {} requests over a {queue_cap}-deep queue never hit backpressure",
        overload_report.submitted
    );
    ensure!(
        overload_report.accepted + overload_report.rejected == overload_report.submitted,
        "overload accounting leak"
    );

    // 2) bursty open-loop arrivals (Poisson thinks in averages; bursts
    // are what actually fill queues)
    let n = if smoke { 48 } else { 192 };
    let offsets = arrival_offsets_us(
        Arrival::Bursty {
            burst: 12,
            gap_us: 30_000,
        },
        n,
    );
    let burst_report = burst_run(&service, &primary, &offsets, 8, seed ^ 1)?;
    ensure!(burst_report.dropped == 0, "burst dropped requests");
    ensure!(burst_report.failed == 0, "burst produced untyped failures");

    // 3) admin storm: swap/load under burst
    let rounds = if smoke { 2 } else { 6 };
    let storm_report = admin_storm(
        &service,
        &primary,
        &side,
        rounds,
        4,
        if smoke { 4 } else { 12 },
        seed ^ 2,
    )?;
    ensure!(
        storm_report.infers_failed == 0,
        "admin storm produced {} untyped infer failures",
        storm_report.infers_failed
    );
    ensure!(
        storm_report.admin_failed == 0,
        "admin storm produced {} admin failures",
        storm_report.admin_failed
    );
    ensure!(
        storm_report.swaps_ok == rounds as u64,
        "admin storm lost swaps"
    );

    // 4) slow-loris against a real TCP endpoint on this service
    let net_server = super::net::NetServer::bind("127.0.0.1:0", Arc::clone(&service))?;
    let addr = net_server.local_addr().to_string();
    let input_len = model_input_len(&service, &primary)?;
    let loris_report = slow_loris(
        &addr,
        &primary,
        input_len,
        if smoke { 6 } else { 24 },
        Duration::from_millis(if smoke { 5 } else { 10 }),
    )?;
    net_server.shutdown()?;
    ensure!(
        loris_report.wellbehaved_ok > 0,
        "slow-loris starved the well-behaved client"
    );
    ensure!(
        loris_report.loris_answered,
        "the dribbled frame was never answered"
    );

    // 5) SLO-conditioned load search
    let slo_report = slo_search(
        &service,
        &primary,
        200_000, // p99 < 200 ms — generous for tiny models on CI
        25,
        if smoke { 24 } else { 96 },
        seed ^ 3,
    )?;
    ensure!(
        slo_report.max_rate_per_s > 0,
        "no tested rate met the p99 SLO"
    );

    let report = SuiteReport {
        queue_cap,
        overload: overload_report,
        burst: burst_report,
        storm: storm_report,
        loris: Some(loris_report),
        slo: slo_report,
    };
    match Arc::try_unwrap(service) {
        Ok(svc) => {
            svc.shutdown()?;
        }
        Err(_) => bail!("scenario threads leaked a service handle"),
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ArchConfig;
    use crate::model::zoo;
    use crate::serve::registry::ModelRegistry;
    use crate::serve::server::{ServeConfig, Server};

    fn start_service(queue_cap: usize) -> Service {
        let registry = Arc::new(ModelRegistry::new());
        let net = zoo::tiny_mlp();
        registry
            .load_seeded(&net.name, &net, ArchConfig::default(), Some(0xF00D))
            .unwrap();
        let server = Server::start_multi(
            ServeConfig {
                workers: 2,
                max_batch: 4,
                queue_cap,
                ..ServeConfig::default()
            },
            registry,
        )
        .unwrap();
        Service::new(server, ArchConfig::default())
    }

    fn input_len(service: &Service) -> usize {
        model_input_len(service, "tiny-mlp").unwrap()
    }

    #[test]
    fn log_roundtrips_through_framed_bytes() {
        let log = TrafficLog {
            entries: vec![
                LogEntry {
                    at_us: 0,
                    request: Request::LoadSeeded {
                        model: "tiny-mlp".into(),
                        seed: 7,
                        mapping: None,
                    },
                    response: Response::Error {
                        message: "say \"hi\"\n".into(),
                    },
                },
                LogEntry {
                    at_us: 1234,
                    request: Request::Infer {
                        model: Some("tiny-mlp".into()),
                        image: vec![i8::MIN, 0, i8::MAX],
                    },
                    response: Response::Infer(super::super::api::InferReply {
                        logits: vec![1, -2, 3],
                        model: None,
                        queue_us: 9,
                        exec_us: 11,
                    }),
                },
            ],
        };
        let mut bytes = Vec::new();
        log.save_to(&mut bytes).unwrap();
        let back = TrafficLog::load_from(&mut bytes.as_slice()).unwrap();
        assert_eq!(log, back);

        // a truncated log is an error, not a silently shorter session
        let cut = bytes.len() - 10;
        assert!(TrafficLog::load_from(&mut &bytes[..cut]).is_err());

        // a wrong-format header is rejected by name
        let mut other = Vec::new();
        wire::write_frame(&mut other, br#"{"format":"nope","version":1,"entries":0}"#)
            .unwrap();
        let err = TrafficLog::load_from(&mut other.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("not a traffic log"), "{err:#}");
    }

    #[test]
    fn recorder_captures_and_replay_matches_byte_for_byte() {
        let service = start_service(64);
        let rec = TrafficRecorder::arm(&service);
        let image = Rng::new(3).i8_vec(input_len(&service), 31);
        for _ in 0..3 {
            let resp = service.dispatch(Request::Infer {
                model: Some("tiny-mlp".into()),
                image: image.clone(),
            });
            assert!(matches!(resp, Response::Infer(_)));
        }
        service.dispatch(Request::ModelInfo {
            model: "tiny-mlp".into(),
        });
        service.dispatch(Request::Stats);
        service.clear_tap();
        // after clear_tap nothing more is recorded
        service.dispatch(Request::Stats);
        let log = rec.finish();
        assert_eq!(log.len(), 5);
        assert!(
            log.entries.windows(2).all(|w| w[0].at_us <= w[1].at_us),
            "arrival offsets are monotonic"
        );

        // replay against a *fresh* service built the same way: every
        // comparable response byte-identical, stats skipped
        let fresh = start_service(64);
        let report = replay(&log, &fresh, ReplaySpeed::MaxRate);
        assert_eq!(report.total, 5);
        assert_eq!(report.skipped, 1, "stats replies are not comparable");
        assert_eq!(
            report.mismatched, 0,
            "replay diverged: {:?}",
            report.first_mismatch
        );
        assert_eq!(report.matched, 4);

        service.shutdown().unwrap();
        fresh.shutdown().unwrap();
    }

    #[test]
    fn replay_detects_divergence() {
        let service = start_service(64);
        let image = Rng::new(4).i8_vec(input_len(&service), 31);
        let resp = service.dispatch(Request::Infer {
            model: Some("tiny-mlp".into()),
            image: image.clone(),
        });
        let mut log = TrafficLog {
            entries: vec![LogEntry {
                at_us: 0,
                request: Request::Infer {
                    model: Some("tiny-mlp".into()),
                    image,
                },
                response: resp,
            }],
        };
        // corrupt the recorded logits: the replayer must notice
        if let Response::Infer(r) = &mut log.entries[0].response {
            r.logits[0] = r.logits[0].wrapping_add(1);
        }
        let report = replay(&log, &service, ReplaySpeed::MaxRate);
        assert_eq!(report.mismatched, 1);
        assert!(report.first_mismatch.is_some());
        assert!(!report.is_identical());
        service.shutdown().unwrap();
    }

    /// The admission-determinism satellite: a log containing recorded
    /// backpressure rejections replays byte-identically under
    /// `AdmissionMode::Recorded` (the rejection is re-applied, not
    /// re-raced), while `Live` legitimately diverges on an uncontended
    /// service that now accepts the request.
    #[test]
    fn recorded_admission_replays_backpressure_logs_byte_identically() {
        let service = start_service(64);
        let image = Rng::new(5).i8_vec(input_len(&service), 31);
        let infer = Request::Infer {
            model: Some("tiny-mlp".into()),
            image,
        };
        let ok = service.dispatch(infer.clone());
        assert!(matches!(ok, Response::Infer(_)));
        // the middle entry was shed by the queue when recorded; the
        // exact message the server uses for that decision
        let rejected = Response::Error {
            message: "queue full (64): backpressure".into(),
        };
        assert!(is_backpressure(&rejected));
        let log = TrafficLog {
            entries: vec![
                LogEntry {
                    at_us: 0,
                    request: infer.clone(),
                    response: ok.clone(),
                },
                LogEntry {
                    at_us: 10,
                    request: infer.clone(),
                    response: rejected,
                },
                LogEntry {
                    at_us: 20,
                    request: infer,
                    response: ok,
                },
            ],
        };

        // recorded admission: byte-identical, the rejection re-applied
        let report =
            replay_admission(&log, &service, ReplaySpeed::MaxRate, AdmissionMode::Recorded);
        assert_eq!(report.total, 3);
        assert_eq!(report.rejections_reapplied, 1);
        assert!(
            report.is_identical(),
            "recorded admission diverged: {:?}",
            report.first_mismatch
        );
        assert_eq!(report.matched, 3);

        // live admission re-races the queue: uncontended, the service
        // now accepts the request the recording rejected — a mismatch
        let report = replay_admission(&log, &service, ReplaySpeed::MaxRate, AdmissionMode::Live);
        assert_eq!(report.mismatched, 1);

        assert_eq!(AdmissionMode::parse("recorded").unwrap(), AdmissionMode::Recorded);
        assert_eq!(AdmissionMode::parse("live").unwrap(), AdmissionMode::Live);
        assert!(AdmissionMode::parse("sometimes").is_err());

        service.shutdown().unwrap();
    }

    #[test]
    fn timing_fields_do_not_affect_comparison() {
        let a = Response::Infer(super::super::api::InferReply {
            logits: vec![1, 2],
            model: None,
            queue_us: 10,
            exec_us: 20,
        });
        let b = Response::Infer(super::super::api::InferReply {
            logits: vec![1, 2],
            model: None,
            queue_us: 99,
            exec_us: 1,
        });
        assert_eq!(comparable_bytes(&a), comparable_bytes(&b));
        assert!(comparable_bytes(&Response::Stats(
            super::super::api::StatsReply {
                served: 0,
                rejected: 0,
                failed: 0,
                conns_refused: 0,
                trace_rejected: 0,
                models: vec![],
            }
        ))
        .is_none());
    }

    #[test]
    fn arrival_schedules_are_deterministic_and_shaped() {
        // uniform: constant gaps
        let u = arrival_offsets_us(Arrival::Uniform { rate: 1000 }, 5);
        assert_eq!(u, vec![0, 1000, 2000, 3000, 4000]);

        // poisson: deterministic in the seed, strictly increasing,
        // mean gap near 1/rate
        let p1 = arrival_offsets_us(Arrival::Poisson { rate: 1000, seed: 9 }, 500);
        let p2 = arrival_offsets_us(Arrival::Poisson { rate: 1000, seed: 9 }, 500);
        assert_eq!(p1, p2);
        assert_ne!(
            p1,
            arrival_offsets_us(Arrival::Poisson { rate: 1000, seed: 10 }, 500)
        );
        assert!(p1.windows(2).all(|w| w[0] <= w[1]));
        let mean_gap = *p1.last().unwrap() as f64 / (p1.len() - 1) as f64;
        assert!(
            (500.0..2000.0).contains(&mean_gap),
            "poisson mean gap {mean_gap} is far from 1000 µs"
        );

        // bursty: groups of `burst` share an offset, separated by gaps
        let b = arrival_offsets_us(
            Arrival::Bursty {
                burst: 3,
                gap_us: 500,
            },
            7,
        );
        assert_eq!(b, vec![0, 0, 0, 500, 500, 500, 1000]);
    }

    #[test]
    fn replay_speed_parses_and_scales() {
        assert_eq!(ReplaySpeed::parse("1x").unwrap(), ReplaySpeed::Wallclock);
        assert_eq!(ReplaySpeed::parse("max").unwrap(), ReplaySpeed::MaxRate);
        assert_eq!(
            ReplaySpeed::parse("4x").unwrap(),
            ReplaySpeed::Scaled { num: 4, den: 1 }
        );
        assert_eq!(
            ReplaySpeed::parse("1/2x").unwrap(),
            ReplaySpeed::Scaled { num: 1, den: 2 }
        );
        assert!(ReplaySpeed::parse("fast").is_err());
        assert!(ReplaySpeed::parse("0x").is_err());

        // 2x halves the gaps; max-rate removes them
        let two_x = ReplaySpeed::Scaled { num: 2, den: 1 };
        assert_eq!(two_x.scale_gap(1000), Some(500));
        assert_eq!(ReplaySpeed::Wallclock.scale_gap(1000), Some(1000));
        assert_eq!(ReplaySpeed::MaxRate.scale_gap(1000), None);
    }

    #[test]
    fn overload_rejects_typed_and_drops_nothing() {
        let service = start_service(4);
        let r = overload(&service, "tiny-mlp", 96, 24, 0xBEEF).unwrap();
        assert_eq!(r.dropped, 0, "every submission must get a response");
        assert_eq!(r.failed, 0, "overload must fail typed, not untyped");
        assert_eq!(r.accepted + r.rejected, r.submitted);
        assert!(
            r.rejected > 0,
            "24 threads over a 4-deep queue must hit backpressure"
        );
        // the rejections are visible in per-model metrics too
        let stats = match service.dispatch(Request::Stats) {
            Response::Stats(s) => s,
            other => panic!("expected Stats, got {other:?}"),
        };
        assert_eq!(stats.rejected, r.rejected);
        service.shutdown().unwrap();
    }
}
