//! Per-model serving observability: latency percentiles, request
//! counts and queue-depth gauges, split by model name (the former
//! aggregate-only counters live on through `Server::served()` etc.).
//!
//! The hub is updated inline by the submit path (enqueue / reject) and
//! the worker loop (dequeue / served / failed). Latency samples are
//! kept in a bounded sliding window per model, so a long-running
//! server's percentiles track *recent* behaviour and memory stays
//! constant; totals are monotonic counters. A snapshot of the whole
//! hub is what the `serve::api` `Stats` request returns — local and
//! remote callers read the identical structure.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Metric key used for requests that carry no model tag (the PJRT
/// backend and single-model `submit` on servers without a registry).
pub const UNTAGGED_MODEL: &str = "default";

/// Sliding-window size for per-model latency percentiles.
const LATENCY_WINDOW: usize = 4096;

/// Nearest-rank percentile of `samples` (microseconds). `None` when
/// empty. Shared by [`LatencyStats`] and the per-model windows so both
/// report identically.
pub fn percentile_us(samples: &[u64], p: f64) -> Option<u64> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    percentile_of_sorted(&v, p)
}

/// Nearest-rank percentile of an already-sorted sample set. Extracted
/// from [`percentile_us`] so callers that need several ranks of the
/// same window (the snapshot path) sort once and read many — the
/// results are bit-identical to calling `percentile_us` per rank.
pub fn percentile_of_sorted(sorted: &[u64], p: f64) -> Option<u64> {
    if sorted.is_empty() {
        return None;
    }
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    Some(sorted[rank.min(sorted.len() - 1)])
}

/// Latency statistics helper for load tests (unbounded sample set;
/// use [`MetricsHub`] for long-running per-model accounting).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Percentile (0-100) by nearest-rank.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        percentile_us(&self.samples_us, p)
    }

    pub fn summary(&self) -> String {
        match (
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
        ) {
            (Some(p50), Some(p95), Some(p99)) => format!(
                "p50 {p50} us, p95 {p95} us, p99 {p99} us (n={})",
                self.count()
            ),
            _ => "no samples".to_string(),
        }
    }
}

/// Live counters for one model name.
#[derive(Default)]
struct ModelMetrics {
    served: u64,
    failed: u64,
    rejected: u64,
    /// Flight-recorder traces served for this model (`Request::Trace`).
    traced: u64,
    /// Requests currently sitting in the bounded queue (gauge:
    /// incremented at enqueue, decremented when a worker dequeues).
    queue_depth: u64,
    /// Total latency samples ever recorded (may exceed the window).
    samples: u64,
    /// Sliding window of the most recent end-to-end latencies (us).
    window: Vec<u64>,
    /// Next slot to overwrite once the window is full (ring cursor).
    cursor: usize,
    /// The fault plane flagged this model as producing silently-wrong
    /// outputs (canary mismatch / armed fault injection). Cleared when
    /// a re-map heals it.
    degraded: bool,
}

impl ModelMetrics {
    fn record_latency(&mut self, us: u64) {
        self.samples += 1;
        if self.window.len() < LATENCY_WINDOW {
            self.window.push(us);
        } else {
            self.window[self.cursor] = us;
            self.cursor = (self.cursor + 1) % LATENCY_WINDOW;
        }
    }
}

/// Point-in-time view of one model's metrics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelMetricsSnapshot {
    pub model: String,
    pub served: u64,
    pub failed: u64,
    pub rejected: u64,
    /// Flight-recorder traces served (`Request::Trace`).
    pub traced: u64,
    pub queue_depth: u64,
    /// Total latency samples recorded (percentiles cover the most
    /// recent window of them).
    pub samples: u64,
    pub p50_us: Option<u64>,
    pub p95_us: Option<u64>,
    pub p99_us: Option<u64>,
    /// The fault plane flagged this model as silently corrupting
    /// outputs; serving continues but responses are suspect until a
    /// re-map clears the flag.
    pub degraded: bool,
}

/// The per-model metrics hub shared by the submit path and the worker
/// loop. One mutex over a name-keyed map: the serving path takes it a
/// handful of times per request, which is noise next to a cycle-level
/// simulation, and keeps every counter and its latency window in one
/// consistent place.
#[derive(Default)]
pub struct MetricsHub {
    models: Mutex<BTreeMap<String, ModelMetrics>>,
}

impl MetricsHub {
    pub fn new() -> Self {
        Self::default()
    }

    fn with<F: FnOnce(&mut ModelMetrics)>(&self, model: &str, f: F) {
        let mut map = self.models.lock().unwrap();
        // fast path: the entry almost always exists, so the steady
        // state pays no key allocation — only the first request for a
        // new model name allocates
        if let Some(m) = map.get_mut(model) {
            f(m);
            return;
        }
        f(map.entry(model.to_string()).or_default());
    }

    /// A request for `model` entered the queue.
    pub(crate) fn on_enqueue(&self, model: &str) {
        self.with(model, |m| m.queue_depth += 1);
    }

    /// A request for `model` was refused by backpressure (queue full).
    pub(crate) fn on_reject(&self, model: &str) {
        self.with(model, |m| m.rejected += 1);
    }

    /// A worker pulled a request for `model` out of the queue.
    pub(crate) fn on_dequeue(&self, model: &str) {
        self.with(model, |m| m.queue_depth = m.queue_depth.saturating_sub(1));
    }

    /// A request for `model` was answered; `latency` is its end-to-end
    /// time (queue wait + attributed execution).
    pub(crate) fn on_served(&self, model: &str, latency: Duration) {
        self.with(model, |m| {
            m.served += 1;
            m.record_latency(latency.as_micros() as u64);
        });
    }

    /// A request for `model` failed in execution after being accepted.
    pub(crate) fn on_failed(&self, model: &str) {
        self.with(model, |m| m.failed += 1);
    }

    /// A flight-recorder trace was served for `model`
    /// (`Request::Trace` — the observability plane, not the data
    /// plane: traced runs do not count as served inferences).
    pub(crate) fn on_trace(&self, model: &str) {
        self.with(model, |m| m.traced += 1);
    }

    /// Set or clear the fault plane's degraded flag for `model`
    /// (canary mismatch sets it, a successful re-map clears it).
    pub(crate) fn set_degraded(&self, model: &str, degraded: bool) {
        self.with(model, |m| m.degraded = degraded);
    }

    /// Snapshot every model's counters and window percentiles, in name
    /// order.
    pub fn snapshot(&self) -> Vec<ModelMetricsSnapshot> {
        let map = self.models.lock().unwrap();
        map.iter()
            .map(|(name, m)| {
                // sort the window once per model and read all three
                // ranks from it (previously one clone+sort per
                // percentile, 3x the work on a 4096-sample window)
                let mut sorted = m.window.clone();
                sorted.sort_unstable();
                ModelMetricsSnapshot {
                    model: name.clone(),
                    served: m.served,
                    failed: m.failed,
                    rejected: m.rejected,
                    traced: m.traced,
                    queue_depth: m.queue_depth,
                    samples: m.samples,
                    p50_us: percentile_of_sorted(&sorted, 50.0),
                    p95_us: percentile_of_sorted(&sorted, 95.0),
                    p99_us: percentile_of_sorted(&sorted, 99.0),
                    degraded: m.degraded,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i));
        }
        assert_eq!(s.percentile(50.0), Some(51)); // nearest-rank on 1..=100
        assert_eq!(s.percentile(99.0), Some(99));
        assert_eq!(s.percentile(100.0), Some(100));
        assert_eq!(LatencyStats::default().percentile(50.0), None);
    }

    #[test]
    fn hub_tracks_counts_gauges_and_percentiles_per_model() {
        let hub = MetricsHub::new();
        // queue depth is a gauge: up on enqueue, down on dequeue
        hub.on_enqueue("a");
        hub.on_enqueue("a");
        hub.on_enqueue("b");
        let snap = hub.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].model, "a");
        assert_eq!(snap[0].queue_depth, 2);
        assert_eq!(snap[1].model, "b");
        assert_eq!(snap[1].queue_depth, 1);
        // no samples yet -> no percentiles
        assert_eq!(snap[0].p50_us, None);

        hub.on_dequeue("a");
        hub.on_served("a", Duration::from_micros(100));
        hub.on_dequeue("a");
        hub.on_served("a", Duration::from_micros(300));
        hub.on_dequeue("b");
        hub.on_failed("b");
        hub.on_reject("b");

        let snap = hub.snapshot();
        let a = &snap[0];
        assert_eq!((a.served, a.failed, a.rejected, a.queue_depth), (2, 0, 0, 0));
        assert_eq!(a.samples, 2);
        // nearest-rank on 2 samples: rank = (0.5 * 1).round() = 1, so
        // the p50 of [100, 300] is 300 (same formula LatencyStats has
        // always used — pinned by `latency_percentiles` above)
        assert_eq!(a.p50_us, Some(300));
        assert_eq!(a.p99_us, Some(300));
        let b = &snap[1];
        assert_eq!((b.served, b.failed, b.rejected, b.queue_depth), (0, 1, 1, 0));
        assert_eq!(b.p50_us, None);
    }

    #[test]
    fn window_is_bounded_but_totals_are_not() {
        let hub = MetricsHub::new();
        let n = (LATENCY_WINDOW + 100) as u64;
        for i in 0..n {
            hub.on_served("m", Duration::from_micros(i));
        }
        let snap = hub.snapshot();
        assert_eq!(snap[0].served, n);
        assert_eq!(snap[0].samples, n);
        // the window slid: the smallest retained sample is >= 100
        assert!(snap[0].p50_us.unwrap() >= 100);
    }

    #[test]
    fn traces_count_separately_from_serving() {
        let hub = MetricsHub::new();
        hub.on_trace("m");
        hub.on_trace("m");
        let snap = hub.snapshot();
        assert_eq!(snap[0].traced, 2);
        assert_eq!(snap[0].served, 0, "a trace is not a served inference");
    }

    #[test]
    fn sorted_percentiles_match_per_call_sorting() {
        // the snapshot path sorts once and reads three ranks; pin that
        // it is bit-identical to the historical sort-per-percentile
        let mut samples = Vec::new();
        let mut x = 12345u64;
        for _ in 0..1000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            samples.push(x >> 33);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for p in [0.0, 1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile_of_sorted(&sorted, p), percentile_us(&samples, p));
        }
        assert_eq!(percentile_of_sorted(&[], 50.0), None);
    }

    #[test]
    fn degraded_flag_sets_and_clears() {
        let hub = MetricsHub::new();
        hub.on_served("m", Duration::from_micros(7));
        assert!(!hub.snapshot()[0].degraded);
        hub.set_degraded("m", true);
        assert!(hub.snapshot()[0].degraded);
        hub.set_degraded("m", false);
        assert!(!hub.snapshot()[0].degraded);
    }

    #[test]
    fn dequeue_never_underflows() {
        let hub = MetricsHub::new();
        hub.on_dequeue("ghost");
        assert_eq!(hub.snapshot()[0].queue_depth, 0);
    }
}
