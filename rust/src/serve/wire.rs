//! The dependency-free wire protocol: length-prefixed frames carrying
//! a hand-rolled JSON encoding of [`super::api::Request`] /
//! [`super::api::Response`]. The build image is offline (no serde),
//! so the codec is ~std-only by design — and deliberately small: the
//! only JSON the protocol needs is null/bool/integer/string/array/
//! object. Floating-point numbers are rejected on decode (nothing in
//! the API produces one, and refusing them keeps every value
//! bit-exactly round-trippable).
//!
//! ## Framing
//!
//! A frame is a 4-byte big-endian length followed by that many bytes
//! of UTF-8 JSON. Frames above [`MAX_FRAME`] are rejected *before*
//! the payload is read, so an oversized (or hostile) length prefix
//! can't allocate unbounded memory. A clean EOF between frames reads
//! as `None`; an EOF inside a frame is an error ("truncated frame").
//!
//! ## Strings
//!
//! Encoding escapes `"`/`\\` and every control character; decoding
//! understands the full JSON escape set including `\uXXXX` with
//! surrogate pairs. Model names are arbitrary user strings, so the
//! codec is property-tested against quoting/escaping round-trips in
//! `rust/tests/wire_properties.rs`.

use std::io::{self, Read, Write};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::api;
use super::metrics::ModelMetricsSnapshot;
use super::registry::ModelStamp;
use crate::coordinator::{ArchConfig, Placement, PoolingScheme};
use crate::sim::flight::{Event, EventKind};

/// Hard cap on a single frame's payload (64 MiB) — far above any real
/// request (the largest zoo input is ~150 k int8 values, well under
/// 1 MiB of JSON) but small enough that a hostile length prefix
/// cannot OOM the server.
pub const MAX_FRAME: usize = 64 << 20;

/// Maximum JSON nesting depth accepted by the decoder (the protocol
/// itself never nests deeper than 4; the cap stops a `[[[[…` depth
/// bomb from overflowing the parser's stack).
const MAX_DEPTH: usize = 64;

// ---------------------------------------------------------------------------
// JSON value model
// ---------------------------------------------------------------------------

/// The wire protocol's JSON value. Numbers are integers only (i128
/// holds the full u64 and i64 ranges losslessly).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i128),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i128> {
        match self {
            Json::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object field lookup (first match; the encoder never emits
    /// duplicate keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Encoder
// ---------------------------------------------------------------------------

/// Serialize a [`Json`] value to compact JSON text.
pub fn encode(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Int(i) => {
            out.push_str(&i.to_string());
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(fields) => {
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Decoder
// ---------------------------------------------------------------------------

/// Parse JSON text into a [`Json`] value. Rejects floats, lone
/// surrogates, unescaped control characters, trailing data and
/// nesting beyond [`MAX_DEPTH`] — always with an error, never a
/// panic.
pub fn decode(text: &str) -> Result<Json> {
    let mut p = Parser {
        s: text,
        b: text.as_bytes(),
        i: 0,
    };
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing data after JSON value at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    s: &'a str,
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("JSON nesting deeper than {MAX_DEPTH}");
        }
        self.skip_ws();
        match self.peek() {
            None => bail!("unexpected end of JSON at offset {}", self.i),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.int(),
            Some(c) => bail!("unexpected byte {:?} at offset {}", c as char, self.i),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.i += 1; // consume '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.i += 1; // consume '{'
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                bail!("expected a string key at offset {}", self.i);
            }
            let key = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                bail!("expected ':' at offset {}", self.i);
            }
            self.i += 1;
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn int(&mut self) -> Result<Json> {
        let start = self.i;
        let neg = if self.peek() == Some(b'-') {
            self.i += 1;
            true
        } else {
            false
        };
        let mut val: i128 = 0;
        let mut digits = 0usize;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                digits += 1;
                val = val
                    .checked_mul(10)
                    .and_then(|v| v.checked_add((c - b'0') as i128))
                    .ok_or_else(|| {
                        anyhow::anyhow!("integer too large at offset {start}")
                    })?;
                self.i += 1;
            } else {
                break;
            }
        }
        if digits == 0 {
            bail!("expected digits at offset {}", self.i);
        }
        if matches!(self.peek(), Some(b'.' | b'e' | b'E')) {
            bail!(
                "floating-point numbers are not part of the wire protocol (offset {start})"
            );
        }
        Ok(Json::Int(if neg { -val } else { val }))
    }

    /// Parse a string starting at a `"` byte. Raw runs are copied by
    /// byte range (every slice boundary sits on an ASCII `"` or `\`,
    /// so the str indexing is always on a char boundary).
    fn string(&mut self) -> Result<String> {
        self.i += 1; // consume '"'
        let mut out = String::new();
        let mut run_start = self.i;
        loop {
            let Some(c) = self.peek() else {
                bail!("unterminated string at offset {}", self.i)
            };
            match c {
                b'"' => {
                    out.push_str(&self.s[run_start..self.i]);
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    out.push_str(&self.s[run_start..self.i]);
                    self.i += 1;
                    let Some(e) = self.peek() else {
                        bail!("unterminated escape at offset {}", self.i)
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            if (0xD800..=0xDBFF).contains(&hi) {
                                // high surrogate: a \uXXXX low surrogate
                                // must follow
                                if self.peek() == Some(b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..=0xDFFF).contains(&lo) {
                                        bail!(
                                            "invalid low surrogate \\u{lo:04x} at offset {}",
                                            self.i
                                        );
                                    }
                                    let cp =
                                        0x10000 + (((hi - 0xD800) << 10) | (lo - 0xDC00));
                                    out.push(char::from_u32(cp).ok_or_else(|| {
                                        anyhow::anyhow!("invalid surrogate pair")
                                    })?);
                                } else {
                                    bail!("lone high surrogate at offset {}", self.i);
                                }
                            } else if (0xDC00..=0xDFFF).contains(&hi) {
                                bail!("lone low surrogate at offset {}", self.i);
                            } else {
                                out.push(char::from_u32(hi).ok_or_else(|| {
                                    anyhow::anyhow!("invalid \\u escape")
                                })?);
                            }
                        }
                        other => bail!(
                            "invalid escape \\{} at offset {}",
                            other as char,
                            self.i
                        ),
                    }
                    run_start = self.i;
                }
                c if c < 0x20 => {
                    bail!("unescaped control character in string at offset {}", self.i)
                }
                _ => {
                    self.i += 1;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let Some(c) = self.peek() else {
                bail!("truncated \\u escape at offset {}", self.i)
            };
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a') as u32 + 10,
                b'A'..=b'F' => (c - b'A') as u32 + 10,
                _ => bail!("invalid hex digit in \\u escape at offset {}", self.i),
            };
            v = v * 16 + d;
            self.i += 1;
        }
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// Typed field extraction
// ---------------------------------------------------------------------------

/// Required object field.
pub fn field<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key)
        .ok_or_else(|| anyhow::anyhow!("missing field {key:?}"))
}

pub fn str_field(v: &Json, key: &str) -> Result<String> {
    field(v, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("field {key:?} must be a string"))
}

/// Missing or `null` reads as `None`.
pub fn opt_str_field(v: &Json, key: &str) -> Result<Option<String>> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.clone())),
        Some(_) => bail!("field {key:?} must be a string or null"),
    }
}

fn int_as_u64(j: &Json, what: &str) -> Result<u64> {
    let i = j
        .as_int()
        .ok_or_else(|| anyhow::anyhow!("{what} must be an integer"))?;
    u64::try_from(i).map_err(|_| anyhow::anyhow!("{what} out of u64 range: {i}"))
}

pub fn u64_field(v: &Json, key: &str) -> Result<u64> {
    int_as_u64(field(v, key)?, &format!("field {key:?}"))
}

/// Missing or `null` reads as `None`.
pub fn opt_u64_field(v: &Json, key: &str) -> Result<Option<u64>> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(j) => Ok(Some(int_as_u64(j, &format!("field {key:?}"))?)),
    }
}

pub fn bool_field(v: &Json, key: &str) -> Result<bool> {
    match field(v, key)? {
        Json::Bool(b) => Ok(*b),
        _ => bail!("field {key:?} must be a boolean"),
    }
}

/// Missing or `null` reads as `None`.
pub fn opt_bool_field(v: &Json, key: &str) -> Result<Option<bool>> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => bail!("field {key:?} must be a boolean or null"),
    }
}

/// An array of integers, each within i8 range.
pub fn i8_vec_field(v: &Json, key: &str) -> Result<Vec<i8>> {
    let arr = field(v, key)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("field {key:?} must be an array"))?;
    arr.iter()
        .enumerate()
        .map(|(i, j)| {
            let x = j
                .as_int()
                .ok_or_else(|| anyhow::anyhow!("{key}[{i}] must be an integer"))?;
            i8::try_from(x).map_err(|_| anyhow::anyhow!("{key}[{i}] out of i8 range: {x}"))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// api::Request / api::Response <-> JSON
// ---------------------------------------------------------------------------

pub(crate) fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub(crate) fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub(crate) fn u(x: u64) -> Json {
    Json::Int(x as i128)
}

fn opt_u(x: Option<u64>) -> Json {
    x.map(u).unwrap_or(Json::Null)
}

fn i8s(v: &[i8]) -> Json {
    Json::Arr(v.iter().map(|&b| Json::Int(b as i128)).collect())
}

fn opt_s(x: Option<&str>) -> Json {
    x.map(s).unwrap_or(Json::Null)
}

fn opt_b(x: Option<bool>) -> Json {
    x.map(Json::Bool).unwrap_or(Json::Null)
}

/// The optional per-model mapping carried by `load` / `load_seeded`.
pub fn mapping_spec_to_json(m: &api::MappingSpec) -> Json {
    obj(vec![
        ("pooling", opt_s(m.pooling.map(PoolingScheme::name))),
        ("placement", opt_s(m.placement.map(Placement::name))),
        ("mesh_cols", opt_u(m.mesh_cols)),
        ("chip_aligned", opt_b(m.chip_aligned)),
        ("sync_chips", opt_u(m.sync_chips)),
    ])
}

pub fn mapping_spec_from_json(v: &Json) -> Result<api::MappingSpec> {
    Ok(api::MappingSpec {
        pooling: opt_str_field(v, "pooling")?
            .map(|p| PoolingScheme::parse(&p))
            .transpose()?,
        placement: opt_str_field(v, "placement")?
            .map(|p| Placement::parse(&p))
            .transpose()?,
        mesh_cols: opt_u64_field(v, "mesh_cols")?,
        chip_aligned: opt_bool_field(v, "chip_aligned")?,
        sync_chips: opt_u64_field(v, "sync_chips")?,
    })
}

fn opt_mapping_field(v: &Json) -> Result<Option<api::MappingSpec>> {
    match v.get("mapping") {
        None | Some(Json::Null) => Ok(None),
        Some(m) => Ok(Some(mapping_spec_from_json(m)?)),
    }
}

/// A complete [`ArchConfig`] record — the registry manifest's
/// per-model mapping persistence.
pub fn arch_to_json(a: &ArchConfig) -> Json {
    obj(vec![
        ("n_c", u(a.n_c as u64)),
        ("n_m", u(a.n_m as u64)),
        ("tiles_per_chip", u(a.tiles_per_chip as u64)),
        ("mesh_cols", u(a.mesh_cols as u64)),
        ("pooling", s(a.pooling.name())),
        ("placement", s(a.placement.name())),
        ("chip_aligned", Json::Bool(a.chip_aligned_chains)),
        ("sync_chips", opt_u(a.sync_chips.map(|c| c as u64))),
    ])
}

pub fn arch_from_json(v: &Json) -> Result<ArchConfig> {
    let usize_field = |key: &str| -> Result<usize> {
        usize::try_from(u64_field(v, key)?)
            .map_err(|_| anyhow::anyhow!("field {key:?} out of range"))
    };
    let a = ArchConfig {
        n_c: usize_field("n_c")?,
        n_m: usize_field("n_m")?,
        tiles_per_chip: usize_field("tiles_per_chip")?,
        mesh_cols: usize_field("mesh_cols")?,
        pooling: PoolingScheme::parse(&str_field(v, "pooling")?)?,
        placement: Placement::parse(&str_field(v, "placement")?)?,
        chip_aligned_chains: bool_field(v, "chip_aligned")?,
        sync_chips: match opt_u64_field(v, "sync_chips")? {
            None => None,
            Some(c) => Some(
                usize::try_from(c)
                    .map_err(|_| anyhow::anyhow!("field \"sync_chips\" out of range"))?,
            ),
        },
    };
    // validate the geometry here, at the parse boundary: a corrupted
    // or hand-edited manifest must surface as a typed error, not as a
    // panic inside the placement asserts or a divide-by-zero in the
    // water-fill when the entry is restored
    if a.n_c == 0
        || a.n_m == 0
        || a.mesh_cols == 0
        || a.tiles_per_chip < a.mesh_cols
        || a.sync_chips
            .is_some_and(|c| c.checked_mul(a.tiles_per_chip).is_none())
    {
        bail!(
            "arch record has invalid geometry (n_c/n_m/mesh_cols must be > 0, \
             tiles_per_chip >= mesh_cols, sync_chips within tile arithmetic range)"
        );
    }
    Ok(a)
}

pub fn request_to_json(req: &api::Request) -> Json {
    use api::Request as R;
    match req {
        R::Infer { model, image } => obj(vec![
            ("type", s("infer")),
            ("model", model.as_deref().map(s).unwrap_or(Json::Null)),
            ("image", i8s(image)),
        ]),
        R::Load { model, mapping } => obj(vec![
            ("type", s("load")),
            ("model", s(model)),
            (
                "mapping",
                mapping
                    .as_ref()
                    .map(mapping_spec_to_json)
                    .unwrap_or(Json::Null),
            ),
        ]),
        R::LoadSeeded {
            model,
            seed,
            mapping,
        } => obj(vec![
            ("type", s("load_seeded")),
            ("model", s(model)),
            ("seed", u(*seed)),
            (
                "mapping",
                mapping
                    .as_ref()
                    .map(mapping_spec_to_json)
                    .unwrap_or(Json::Null),
            ),
        ]),
        R::Swap { model, seed } => obj(vec![
            ("type", s("swap")),
            ("model", s(model)),
            ("seed", opt_u(*seed)),
        ]),
        R::Unload { model } => obj(vec![("type", s("unload")), ("model", s(model))]),
        R::ListModels => obj(vec![("type", s("list_models"))]),
        R::ModelInfo { model } => obj(vec![("type", s("model_info")), ("model", s(model))]),
        R::Stats => obj(vec![("type", s("stats"))]),
        R::Trace {
            model,
            image_seed,
            window,
        } => obj(vec![
            ("type", s("trace")),
            ("model", s(model)),
            ("image_seed", u(*image_seed)),
            ("window", u(*window)),
        ]),
        R::FaultInject { model, plan } => obj(vec![
            ("type", s("fault_inject")),
            ("model", s(model)),
            // the plan travels as its canonical spec string
            // (`FaultPlan::parse`/`spec` round-trip bit-exactly)
            ("plan", s(plan)),
        ]),
        R::Canary { model, seed, heal } => obj(vec![
            ("type", s("canary")),
            ("model", s(model)),
            ("seed", u(*seed)),
            ("heal", Json::Bool(*heal)),
        ]),
    }
}

pub fn decode_request(frame: &[u8]) -> Result<api::Request> {
    let text = std::str::from_utf8(frame).context("request frame is not UTF-8")?;
    let v = decode(text)?;
    request_from_json(&v)
}

/// Decode a request from an already-parsed [`Json`] value. Split out
/// of [`decode_request`] so formats that *embed* requests in a larger
/// document (the traffic log, `serve::traffic`) reuse the exact same
/// decoder the wire speaks.
pub fn request_from_json(v: &Json) -> Result<api::Request> {
    let t = str_field(v, "type")?;
    match t.as_str() {
        "infer" => Ok(api::Request::Infer {
            model: opt_str_field(v, "model")?,
            image: i8_vec_field(v, "image")?,
        }),
        "load" => Ok(api::Request::Load {
            model: str_field(v, "model")?,
            mapping: opt_mapping_field(v)?,
        }),
        "load_seeded" => Ok(api::Request::LoadSeeded {
            model: str_field(v, "model")?,
            seed: u64_field(v, "seed")?,
            mapping: opt_mapping_field(v)?,
        }),
        "swap" => Ok(api::Request::Swap {
            model: str_field(v, "model")?,
            seed: opt_u64_field(v, "seed")?,
        }),
        "unload" => Ok(api::Request::Unload {
            model: str_field(v, "model")?,
        }),
        "list_models" => Ok(api::Request::ListModels),
        "model_info" => Ok(api::Request::ModelInfo {
            model: str_field(v, "model")?,
        }),
        "stats" => Ok(api::Request::Stats),
        "trace" => Ok(api::Request::Trace {
            model: str_field(v, "model")?,
            image_seed: u64_field(v, "image_seed")?,
            window: u64_field(v, "window")?,
        }),
        "fault_inject" => Ok(api::Request::FaultInject {
            model: str_field(v, "model")?,
            plan: str_field(v, "plan")?,
        }),
        "canary" => Ok(api::Request::Canary {
            model: str_field(v, "model")?,
            seed: u64_field(v, "seed")?,
            heal: bool_field(v, "heal")?,
        }),
        other => bail!("unknown request type {other:?}"),
    }
}

pub fn encode_request(req: &api::Request) -> Vec<u8> {
    encode(&request_to_json(req)).into_bytes()
}

fn stamp_to_json(st: &ModelStamp) -> Json {
    obj(vec![
        ("name", s(&st.name)),
        ("id", u(st.id)),
        ("version", u(st.version)),
    ])
}

fn stamp_from_json(v: &Json) -> Result<ModelStamp> {
    Ok(ModelStamp {
        name: Arc::from(str_field(v, "name")?.as_str()),
        id: u64_field(v, "id")?,
        version: u64_field(v, "version")?,
    })
}

fn mapping_desc_to_json(m: &api::MappingDesc) -> Json {
    obj(vec![
        ("pooling", s(&m.pooling)),
        ("placement", s(&m.placement)),
        ("mesh_cols", u(m.mesh_cols)),
        ("chip_aligned", Json::Bool(m.chip_aligned)),
        ("sync_chips", opt_u(m.sync_chips)),
        ("tiles", u(m.tiles)),
        ("chips", u(m.chips)),
        ("worst_link_permille", u(m.worst_link_permille)),
        ("images_per_s", u(m.images_per_s)),
        ("pj_per_image", u(m.pj_per_image)),
    ])
}

fn mapping_desc_from_json(v: &Json) -> Result<api::MappingDesc> {
    Ok(api::MappingDesc {
        pooling: str_field(v, "pooling")?,
        placement: str_field(v, "placement")?,
        mesh_cols: u64_field(v, "mesh_cols")?,
        chip_aligned: bool_field(v, "chip_aligned")?,
        sync_chips: opt_u64_field(v, "sync_chips")?,
        tiles: u64_field(v, "tiles")?,
        chips: u64_field(v, "chips")?,
        worst_link_permille: u64_field(v, "worst_link_permille")?,
        images_per_s: u64_field(v, "images_per_s")?,
        pj_per_image: u64_field(v, "pj_per_image")?,
    })
}

/// The `ModelDesc` JSON shape — also what `domino models --json`
/// emits, so scripts parse the same representation the network speaks.
pub fn desc_to_json(d: &api::ModelDesc) -> Json {
    obj(vec![
        ("name", s(&d.name)),
        ("id", u(d.id)),
        ("version", u(d.version)),
        ("input_len", u(d.input_len)),
        ("classes", u(d.classes)),
        ("layers", u(d.layers)),
        ("params", u(d.params)),
        ("macs", u(d.macs)),
        (
            "mapping",
            d.mapping
                .as_ref()
                .map(mapping_desc_to_json)
                .unwrap_or(Json::Null),
        ),
    ])
}

fn desc_from_json(v: &Json) -> Result<api::ModelDesc> {
    Ok(api::ModelDesc {
        name: str_field(v, "name")?,
        id: u64_field(v, "id")?,
        version: u64_field(v, "version")?,
        input_len: u64_field(v, "input_len")?,
        classes: u64_field(v, "classes")?,
        layers: u64_field(v, "layers")?,
        params: u64_field(v, "params")?,
        macs: u64_field(v, "macs")?,
        mapping: match v.get("mapping") {
            None | Some(Json::Null) => None,
            Some(m) => Some(mapping_desc_from_json(m)?),
        },
    })
}

fn snapshot_to_json(m: &ModelMetricsSnapshot) -> Json {
    obj(vec![
        ("model", s(&m.model)),
        ("served", u(m.served)),
        ("failed", u(m.failed)),
        ("rejected", u(m.rejected)),
        ("traced", u(m.traced)),
        ("queue_depth", u(m.queue_depth)),
        ("samples", u(m.samples)),
        ("p50_us", opt_u(m.p50_us)),
        ("p95_us", opt_u(m.p95_us)),
        ("p99_us", opt_u(m.p99_us)),
        ("degraded", Json::Bool(m.degraded)),
    ])
}

fn snapshot_from_json(v: &Json) -> Result<ModelMetricsSnapshot> {
    Ok(ModelMetricsSnapshot {
        model: str_field(v, "model")?,
        served: u64_field(v, "served")?,
        failed: u64_field(v, "failed")?,
        rejected: u64_field(v, "rejected")?,
        traced: u64_field(v, "traced")?,
        queue_depth: u64_field(v, "queue_depth")?,
        samples: u64_field(v, "samples")?,
        p50_us: opt_u64_field(v, "p50_us")?,
        p95_us: opt_u64_field(v, "p95_us")?,
        p99_us: opt_u64_field(v, "p99_us")?,
        // optional (default false) so frames recorded before the fault
        // plane existed still decode
        degraded: opt_bool_field(v, "degraded")?.unwrap_or(false),
    })
}

/// One flight-recorder [`Event`] as a compact 7-integer array
/// `[kind, stage, chain, ci, slot, a, b]` (field order of the binary
/// record). An object per event would triple the payload of a trace
/// window for no information.
fn event_to_json(e: &Event) -> Json {
    Json::Arr(vec![
        u(e.kind as u8 as u64),
        u(e.stage as u64),
        u(e.chain as u64),
        u(e.ci as u64),
        u(e.slot as u64),
        u(e.a as u64),
        u(e.b as u64),
    ])
}

fn event_from_json(v: &Json) -> Result<Event> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("event must be a 7-integer array"))?;
    if arr.len() != 7 {
        bail!("event array has {} elements, expected 7", arr.len());
    }
    let int = |i: usize, what: &str, max: u64| -> Result<u64> {
        let x = int_as_u64(&arr[i], what)?;
        if x > max {
            bail!("{what} out of range: {x}");
        }
        Ok(x)
    };
    let tag = int(0, "event kind", u8::MAX as u64)? as u8;
    Ok(Event {
        kind: EventKind::from_u8(tag)
            .ok_or_else(|| anyhow::anyhow!("unknown event kind tag {tag}"))?,
        stage: int(1, "event stage", u16::MAX as u64)? as u16,
        chain: int(2, "event chain", u16::MAX as u64)? as u16,
        ci: int(3, "event ci", u16::MAX as u64)? as u16,
        slot: int(4, "event slot", u32::MAX as u64)? as u32,
        a: int(5, "event a", u32::MAX as u64)? as u32,
        b: int(6, "event b", u32::MAX as u64)? as u32,
    })
}

fn trace_reply_to_json(t: &api::TraceReply) -> Json {
    obj(vec![
        ("model", stamp_to_json(&t.model)),
        ("image_seed", u(t.image_seed)),
        ("events_total", u(t.events_total)),
        ("dropped", u(t.dropped)),
        (
            "events",
            Json::Arr(t.events.iter().map(event_to_json).collect()),
        ),
        ("scores", i8s(&t.scores)),
        ("heatmap", s(&t.heatmap)),
    ])
}

fn trace_reply_from_json(v: &Json) -> Result<api::TraceReply> {
    let arr = field(v, "events")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("field \"events\" must be an array"))?;
    Ok(api::TraceReply {
        model: stamp_from_json(field(v, "model")?)?,
        image_seed: u64_field(v, "image_seed")?,
        events_total: u64_field(v, "events_total")?,
        dropped: u64_field(v, "dropped")?,
        events: arr.iter().map(event_from_json).collect::<Result<_>>()?,
        scores: i8_vec_field(v, "scores")?,
        heatmap: str_field(v, "heatmap")?,
    })
}

pub fn response_to_json(resp: &api::Response) -> Json {
    use api::Response as R;
    match resp {
        R::Infer(r) => obj(vec![
            ("type", s("infer")),
            ("logits", i8s(&r.logits)),
            (
                "model",
                r.model.as_ref().map(stamp_to_json).unwrap_or(Json::Null),
            ),
            ("queue_us", u(r.queue_us)),
            ("exec_us", u(r.exec_us)),
        ]),
        R::Loaded(st) => obj(vec![("type", s("loaded")), ("model", stamp_to_json(st))]),
        R::Swapped(st) => obj(vec![("type", s("swapped")), ("model", stamp_to_json(st))]),
        R::Unloaded(st) => obj(vec![("type", s("unloaded")), ("model", stamp_to_json(st))]),
        R::Models(list) => obj(vec![
            ("type", s("models")),
            ("models", Json::Arr(list.iter().map(desc_to_json).collect())),
        ]),
        R::Info(d) => obj(vec![("type", s("info")), ("model", desc_to_json(d))]),
        R::Stats(st) => obj(vec![
            ("type", s("stats")),
            ("served", u(st.served)),
            ("rejected", u(st.rejected)),
            ("failed", u(st.failed)),
            ("conns_refused", u(st.conns_refused)),
            ("trace_rejected", u(st.trace_rejected)),
            (
                "models",
                Json::Arr(st.models.iter().map(snapshot_to_json).collect()),
            ),
        ]),
        R::Trace(t) => {
            let mut fields = vec![("type".to_string(), s("trace"))];
            if let Json::Obj(body) = trace_reply_to_json(t) {
                fields.extend(body);
            }
            Json::Obj(fields)
        }
        R::Fault(f) => obj(vec![
            ("type", s("fault")),
            ("model", stamp_to_json(&f.model)),
            ("armed", Json::Bool(f.armed)),
            ("sites", u(f.sites)),
            ("fires", u(f.fires)),
            ("lanes", u(f.lanes)),
            ("corrupted", Json::Bool(f.corrupted)),
            ("mismatched", u(f.mismatched)),
            ("outputs", u(f.outputs)),
            ("report", s(&f.report)),
        ]),
        R::Canary(c) => obj(vec![
            ("type", s("canary")),
            ("model", stamp_to_json(&c.model)),
            ("ok", Json::Bool(c.ok)),
            ("mismatched", u(c.mismatched)),
            ("outputs", u(c.outputs)),
            ("remapped", Json::Bool(c.remapped)),
            ("healed", Json::Bool(c.healed)),
            ("version", u(c.version)),
        ]),
        R::Error { message } => obj(vec![("type", s("error")), ("message", s(message))]),
    }
}

pub fn decode_response(frame: &[u8]) -> Result<api::Response> {
    let text = std::str::from_utf8(frame).context("response frame is not UTF-8")?;
    let v = decode(text)?;
    response_from_json(&v)
}

/// Decode a response from an already-parsed [`Json`] value (the
/// counterpart of [`request_from_json`] for embedding responses in
/// larger documents — see the traffic log in `serve::traffic`).
pub fn response_from_json(v: &Json) -> Result<api::Response> {
    let t = str_field(v, "type")?;
    match t.as_str() {
        "infer" => Ok(api::Response::Infer(api::InferReply {
            logits: i8_vec_field(v, "logits")?,
            model: match v.get("model") {
                None | Some(Json::Null) => None,
                Some(m) => Some(stamp_from_json(m)?),
            },
            queue_us: u64_field(v, "queue_us")?,
            exec_us: u64_field(v, "exec_us")?,
        })),
        "loaded" => Ok(api::Response::Loaded(stamp_from_json(field(v, "model")?)?)),
        "swapped" => Ok(api::Response::Swapped(stamp_from_json(field(v, "model")?)?)),
        "unloaded" => Ok(api::Response::Unloaded(stamp_from_json(field(
            v, "model",
        )?)?)),
        "models" => {
            let arr = field(v, "models")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("field \"models\" must be an array"))?;
            Ok(api::Response::Models(
                arr.iter().map(desc_from_json).collect::<Result<_>>()?,
            ))
        }
        "info" => Ok(api::Response::Info(desc_from_json(field(v, "model")?)?)),
        "stats" => {
            let arr = field(v, "models")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("field \"models\" must be an array"))?;
            Ok(api::Response::Stats(api::StatsReply {
                served: u64_field(v, "served")?,
                rejected: u64_field(v, "rejected")?,
                failed: u64_field(v, "failed")?,
                // optional (default 0) so frames recorded before the
                // shedding counters existed still decode — traffic
                // logs outlive protocol revisions
                conns_refused: opt_u64_field(v, "conns_refused")?.unwrap_or(0),
                trace_rejected: opt_u64_field(v, "trace_rejected")?.unwrap_or(0),
                models: arr.iter().map(snapshot_from_json).collect::<Result<_>>()?,
            }))
        }
        "trace" => Ok(api::Response::Trace(trace_reply_from_json(v)?)),
        "fault" => Ok(api::Response::Fault(api::FaultReply {
            model: stamp_from_json(field(v, "model")?)?,
            armed: bool_field(v, "armed")?,
            sites: u64_field(v, "sites")?,
            fires: u64_field(v, "fires")?,
            lanes: u64_field(v, "lanes")?,
            corrupted: bool_field(v, "corrupted")?,
            mismatched: u64_field(v, "mismatched")?,
            outputs: u64_field(v, "outputs")?,
            report: str_field(v, "report")?,
        })),
        "canary" => Ok(api::Response::Canary(api::CanaryReply {
            model: stamp_from_json(field(v, "model")?)?,
            ok: bool_field(v, "ok")?,
            mismatched: u64_field(v, "mismatched")?,
            outputs: u64_field(v, "outputs")?,
            remapped: bool_field(v, "remapped")?,
            healed: bool_field(v, "healed")?,
            version: u64_field(v, "version")?,
        })),
        "error" => Ok(api::Response::Error {
            message: str_field(v, "message")?,
        }),
        other => bail!("unknown response type {other:?}"),
    }
}

pub fn encode_response(resp: &api::Response) -> Vec<u8> {
    encode(&response_to_json(resp)).into_bytes()
}

// ---------------------------------------------------------------------------
// v2 tagging: request ids for pipelined connections
// ---------------------------------------------------------------------------
//
// Protocol rev 2 adds one optional field to both envelopes: `"rid"`,
// a client-chosen u64 request id. A frame carrying a rid may complete
// out of order — the response echoes the rid so a pipelined client can
// match many in-flight frames on one connection. Frames *without* a
// rid keep the v1 contract (responses in request order), and because
// every decoder in this module extracts fields by name and ignores
// unknown ones, v1 peers interoperate with v2 peers unchanged:
// `encode_*_tagged(.., None)` is byte-identical to the v1 encoding,
// and a v1 decoder simply never looks at `"rid"`.

/// Append the v2 request id to an encoded envelope. `rid: None`
/// leaves the value untouched — the exact v1 bytes.
fn tag(v: Json, rid: Option<u64>) -> Json {
    match (v, rid) {
        (Json::Obj(mut fields), Some(r)) => {
            fields.push(("rid".to_string(), u(r)));
            Json::Obj(fields)
        }
        (v, _) => v,
    }
}

/// [`encode_request`] plus an optional v2 request id.
pub fn encode_request_tagged(req: &api::Request, rid: Option<u64>) -> Vec<u8> {
    encode(&tag(request_to_json(req), rid)).into_bytes()
}

/// [`decode_request`] plus the optional v2 request id. A v1 frame
/// (no `"rid"`) decodes with `None`.
pub fn decode_request_tagged(frame: &[u8]) -> Result<(api::Request, Option<u64>)> {
    let text = std::str::from_utf8(frame).context("request frame is not UTF-8")?;
    let v = decode(text)?;
    let rid = opt_u64_field(&v, "rid")?;
    Ok((request_from_json(&v)?, rid))
}

/// [`encode_response`] plus an optional v2 request id.
pub fn encode_response_tagged(resp: &api::Response, rid: Option<u64>) -> Vec<u8> {
    encode(&tag(response_to_json(resp), rid)).into_bytes()
}

/// [`decode_response`] plus the optional v2 request id. A v1 frame
/// (no `"rid"`) decodes with `None`.
pub fn decode_response_tagged(frame: &[u8]) -> Result<(api::Response, Option<u64>)> {
    let text = std::str::from_utf8(frame).context("response frame is not UTF-8")?;
    let v = decode(text)?;
    let rid = opt_u64_field(&v, "rid")?;
    Ok((response_from_json(&v)?, rid))
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame and flush.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!(
            "frame of {} bytes exceeds the {MAX_FRAME}-byte limit",
            payload.len()
        );
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())
        .context("write frame header")?;
    w.write_all(payload).context("write frame payload")?;
    w.flush().context("flush frame")?;
    Ok(())
}

enum Fill {
    Full,
    /// Clean EOF (or a requested stop) before the first byte.
    End,
}

/// Fill `buf` completely. `clean_end` permits an EOF (or stop) before
/// any byte arrived; mid-buffer it is always an error. Timeouts
/// (`WouldBlock`/`TimedOut`) poll the `stop` callback when one is
/// given; without one they surface as errors (the blocking client
/// path, where a read timeout set by the caller is a real deadline).
fn fill<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    stop: Option<&dyn Fn() -> bool>,
    clean_end: bool,
) -> Result<Fill> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && clean_end {
                    return Ok(Fill::End);
                }
                bail!(
                    "connection closed mid-frame ({filled} of {} bytes)",
                    buf.len()
                );
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                ) =>
            {
                match stop {
                    Some(should_stop) => {
                        if should_stop() {
                            if filled == 0 && clean_end {
                                return Ok(Fill::End);
                            }
                            bail!("shutdown interrupted a partially received frame");
                        }
                        // not stopping: keep waiting for the peer
                    }
                    None => return Err(e).context("read frame timed out"),
                }
            }
            Err(e) => return Err(e).context("read frame"),
        }
    }
    Ok(Fill::Full)
}

fn read_frame_impl<R: Read>(
    r: &mut R,
    stop: Option<&dyn Fn() -> bool>,
) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match fill(r, &mut len_buf, stop, true)? {
        Fill::End => return Ok(None),
        Fill::Full => {}
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds the {MAX_FRAME}-byte limit");
    }
    let mut buf = vec![0u8; len];
    match fill(r, &mut buf, stop, false)? {
        Fill::End => unreachable!("clean_end is false for the payload"),
        Fill::Full => Ok(Some(buf)),
    }
}

/// Read one frame. `Ok(None)` on a clean EOF between frames; errors on
/// truncation or an oversized length prefix (before reading the
/// payload).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    read_frame_impl(r, None)
}

/// [`read_frame`] for readers with a read timeout: each timeout polls
/// `stop`, so an idle connection drains promptly at shutdown
/// (`Ok(None)`). A frame that keeps making progress is still received
/// whole, but a frame stuck *partially* received when `stop` is set
/// errors out — a stalled peer must not block shutdown.
pub fn read_frame_cancellable<R: Read>(
    r: &mut R,
    stop: &dyn Fn() -> bool,
) -> Result<Option<Vec<u8>>> {
    read_frame_impl(r, Some(stop))
}

/// Incremental framing for nonblocking readers: inspect an
/// accumulation buffer for one complete frame. `Ok(None)` means more
/// bytes are needed; `Ok(Some(range))` is the payload's byte range
/// within `buf` — it starts at 4 (past the length prefix), and the
/// caller consumes `range.end` bytes total. A hostile length prefix
/// is rejected here, before any payload accumulates.
pub fn frame_in_buffer(buf: &[u8]) -> Result<Option<std::ops::Range<usize>>> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds the {MAX_FRAME}-byte limit");
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some(4..4 + len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn scalar_roundtrips() {
        for v in [
            Json::Null,
            Json::Bool(true),
            Json::Bool(false),
            Json::Int(0),
            Json::Int(-128),
            Json::Int(127),
            Json::Int(u64::MAX as i128),
            Json::Int(-(u64::MAX as i128)),
            Json::Str(String::new()),
            Json::Arr(vec![]),
            Json::Obj(vec![]),
        ] {
            assert_eq!(decode(&encode(&v)).unwrap(), v, "{v:?}");
        }
    }

    #[test]
    fn string_escaping_roundtrips() {
        for raw in [
            "plain",
            "with \"quotes\" and \\backslashes\\",
            "newline\nreturn\rtab\tnull\u{0}bell\u{7}",
            "unicode: caffè 日本語 😀",
            "/slashes/ are fine",
            "\u{1F} edge of control range",
        ] {
            let v = Json::Str(raw.to_string());
            let text = encode(&v);
            assert_eq!(decode(&text).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn decoder_accepts_standard_json_forms() {
        // whitespace, \u escapes (incl. a surrogate pair), nested
        // structures written by other encoders
        let v = decode(" { \"a\" : [ 1 , -2 , null , true ] , \"s\" : \"\\u0041\\ud83d\\ude00\" } ")
            .unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "A😀");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Json::Int(-2));
    }

    #[test]
    fn decoder_rejects_malformed_input_without_panicking() {
        for bad in [
            "",
            "{",
            "[1,",
            "\"unterminated",
            "{\"a\":}",
            "{\"a\" 1}",
            "nul",
            "tru",
            "1.5",
            "1e9",
            "-",
            "[1] trailing",
            "\"\\q\"",
            "\"\\u12\"",
            "\"\\ud800\"",
            "\"\\udc00\"",
            "\"\u{1}\"",
            "{\"a\":1,}",
            "[1 2]",
            "123456789012345678901234567890123456789012345",
        ] {
            assert!(decode(bad).is_err(), "{bad:?} should be rejected");
        }
        // depth bomb: deeper than MAX_DEPTH must error, not overflow
        let bomb = "[".repeat(MAX_DEPTH + 8);
        assert!(decode(&bomb).is_err());
    }

    #[test]
    fn frame_roundtrip_and_clean_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF -> None");
    }

    #[test]
    fn truncated_and_oversized_frames_error() {
        // truncated header
        let mut r = Cursor::new(vec![0u8, 0]);
        assert!(read_frame(&mut r).is_err());
        // truncated payload
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(buf.len() - 2);
        let mut r = Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
        // hostile length prefix: rejected before any allocation
        let mut r = Cursor::new(((MAX_FRAME + 1) as u32).to_be_bytes().to_vec());
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
        // writer side refuses oversize too
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(write_frame(&mut Vec::new(), &big).is_err());
    }

    #[test]
    fn request_json_is_stable() {
        let req = api::Request::Infer {
            model: Some("tiny-cnn".to_string()),
            image: vec![-128, 0, 127],
        };
        assert_eq!(
            String::from_utf8(encode_request(&req)).unwrap(),
            r#"{"type":"infer","model":"tiny-cnn","image":[-128,0,127]}"#
        );
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
    }

    #[test]
    fn load_with_mapping_roundtrips_and_stays_stable() {
        let req = api::Request::LoadSeeded {
            model: "tiny-cnn".to_string(),
            seed: 7,
            mapping: Some(api::MappingSpec {
                pooling: Some(PoolingScheme::WeightDuplication),
                placement: Some(Placement::ColumnMajor),
                mesh_cols: Some(12),
                chip_aligned: Some(true),
                sync_chips: None,
            }),
        };
        assert_eq!(
            String::from_utf8(encode_request(&req)).unwrap(),
            r#"{"type":"load_seeded","model":"tiny-cnn","seed":7,"mapping":{"pooling":"weight-duplication","placement":"column-major","mesh_cols":12,"chip_aligned":true,"sync_chips":null}}"#
        );
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
        // a mapping-free load decodes whether the field is absent or null
        let bare = decode_request(br#"{"type":"load","model":"m"}"#).unwrap();
        assert_eq!(
            bare,
            api::Request::Load {
                model: "m".to_string(),
                mapping: None
            }
        );
        // invalid names inside a mapping are typed errors
        assert!(decode_request(
            br#"{"type":"load","model":"m","mapping":{"pooling":"diagonal"}}"#
        )
        .is_err());
        assert!(decode_request(
            br#"{"type":"load","model":"m","mapping":{"chip_aligned":3}}"#
        )
        .is_err());
    }

    #[test]
    fn arch_config_roundtrips_bit_exactly() {
        let mut a = ArchConfig::default();
        a.pooling = PoolingScheme::WeightDuplication;
        a.placement = Placement::ColumnMajor;
        a.mesh_cols = 20;
        a.chip_aligned_chains = true;
        a.sync_chips = Some(5);
        for arch in [ArchConfig::default(), a] {
            let text = encode(&arch_to_json(&arch));
            assert_eq!(arch_from_json(&decode(&text).unwrap()).unwrap(), arch);
        }
        // a partial record is rejected (the manifest writes full ones)
        assert!(arch_from_json(&decode(r#"{"n_c":256}"#).unwrap()).is_err());
        // corrupted geometry is a typed error at the parse boundary,
        // never a panic when the entry is later restored
        for bad in [
            r#"{"n_c":0,"n_m":256,"tiles_per_chip":240,"mesh_cols":16,"pooling":"block-reuse","placement":"serpentine","chip_aligned":false,"sync_chips":null}"#,
            r#"{"n_c":256,"n_m":256,"tiles_per_chip":240,"mesh_cols":0,"pooling":"block-reuse","placement":"serpentine","chip_aligned":false,"sync_chips":null}"#,
            r#"{"n_c":256,"n_m":256,"tiles_per_chip":8,"mesh_cols":16,"pooling":"block-reuse","placement":"serpentine","chip_aligned":false,"sync_chips":null}"#,
            r#"{"n_c":256,"n_m":256,"tiles_per_chip":240,"mesh_cols":16,"pooling":"block-reuse","placement":"serpentine","chip_aligned":false,"sync_chips":18446744073709551615}"#,
            r#"{"n_c":256,"n_m":256,"tiles_per_chip":240,"mesh_cols":16,"pooling":"diagonal","placement":"serpentine","chip_aligned":false,"sync_chips":null}"#,
        ] {
            assert!(
                arch_from_json(&decode(bad).unwrap()).is_err(),
                "{bad} should be rejected"
            );
        }
    }

    #[test]
    fn trace_request_json_is_stable() {
        let req = api::Request::Trace {
            model: "tiny-cnn".to_string(),
            image_seed: 7,
            window: 64,
        };
        assert_eq!(
            String::from_utf8(encode_request(&req)).unwrap(),
            r#"{"type":"trace","model":"tiny-cnn","image_seed":7,"window":64}"#
        );
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
    }

    #[test]
    fn fault_plane_frames_are_stable_and_roundtrip() {
        // requests: pinned bytes + round-trips
        let inject = api::Request::FaultInject {
            model: "tiny-cnn".to_string(),
            plan: "tile:0:1:2:stuck:-7;link:0:0:3:flip:5@10-90".to_string(),
        };
        assert_eq!(
            String::from_utf8(encode_request(&inject)).unwrap(),
            r#"{"type":"fault_inject","model":"tiny-cnn","plan":"tile:0:1:2:stuck:-7;link:0:0:3:flip:5@10-90"}"#
        );
        assert_eq!(decode_request(&encode_request(&inject)).unwrap(), inject);
        let canary = api::Request::Canary {
            model: "tiny-cnn".to_string(),
            seed: 42,
            heal: true,
        };
        assert_eq!(
            String::from_utf8(encode_request(&canary)).unwrap(),
            r#"{"type":"canary","model":"tiny-cnn","seed":42,"heal":true}"#
        );
        assert_eq!(decode_request(&encode_request(&canary)).unwrap(), canary);

        // replies round-trip bit-exactly
        let stamp = ModelStamp {
            name: Arc::from("tiny-cnn"),
            id: 9,
            version: 3,
        };
        let fault = api::Response::Fault(api::FaultReply {
            model: stamp.clone(),
            armed: true,
            sites: 2,
            fires: 1000,
            lanes: 64_000,
            corrupted: true,
            mismatched: 4,
            outputs: 10,
            report: "tile:0:1:2:stuck:-7 fires 1000\n".to_string(),
        });
        assert_eq!(decode_response(&encode_response(&fault)).unwrap(), fault);
        let canary = api::Response::Canary(api::CanaryReply {
            model: stamp,
            ok: false,
            mismatched: 4,
            outputs: 10,
            remapped: true,
            healed: true,
            version: 4,
        });
        assert_eq!(decode_response(&encode_response(&canary)).unwrap(), canary);

        // missing fields are typed errors
        assert!(decode_request(br#"{"type":"fault_inject","model":"m"}"#).is_err());
        assert!(decode_request(br#"{"type":"canary","model":"m","seed":1}"#).is_err());
    }

    #[test]
    fn snapshot_degraded_flag_is_back_compatible() {
        let m = ModelMetricsSnapshot {
            model: "m".to_string(),
            served: 1,
            failed: 0,
            rejected: 0,
            traced: 0,
            queue_depth: 0,
            samples: 1,
            p50_us: Some(5),
            p95_us: Some(5),
            p99_us: Some(5),
            degraded: true,
        };
        let text = encode(&snapshot_to_json(&m));
        assert_eq!(snapshot_from_json(&decode(&text).unwrap()).unwrap(), m);
        // a pre-fault-plane frame (no "degraded" field) decodes as
        // not-degraded — traffic logs outlive protocol revisions
        let legacy = r#"{"model":"m","served":1,"failed":0,"rejected":0,"traced":0,"queue_depth":0,"samples":1,"p50_us":5,"p95_us":5,"p99_us":5}"#;
        let got = snapshot_from_json(&decode(legacy).unwrap()).unwrap();
        assert!(!got.degraded);
    }

    #[test]
    fn trace_reply_roundtrips_bit_exactly() {
        let reply = api::TraceReply {
            model: ModelStamp {
                name: Arc::from("tiny-cnn"),
                id: 3,
                version: 2,
            },
            image_seed: 7,
            events_total: 9000,
            dropped: 12,
            events: vec![
                Event {
                    kind: EventKind::Acc,
                    stage: 0,
                    chain: 1,
                    ci: 4,
                    slot: 19,
                    a: 2,
                    b: 3,
                },
                Event {
                    kind: EventKind::LinkTx,
                    stage: 2,
                    chain: u16::MAX,
                    ci: u16::MAX,
                    slot: u32::MAX,
                    a: 4096,
                    b: 1,
                },
            ],
            scores: vec![-128, 0, 127],
            heatmap: "link utilization\n####".to_string(),
        };
        let resp = api::Response::Trace(reply.clone());
        let bytes = encode_response(&resp);
        assert_eq!(decode_response(&bytes).unwrap(), resp);
        // the events travel as compact 7-int arrays in record order
        let v = decode(std::str::from_utf8(&bytes).unwrap()).unwrap();
        assert_eq!(
            v.get("events").unwrap().as_arr().unwrap()[0],
            Json::Arr(vec![
                Json::Int(0),
                Json::Int(0),
                Json::Int(1),
                Json::Int(4),
                Json::Int(19),
                Json::Int(2),
                Json::Int(3),
            ])
        );
        // malformed events are typed errors, never panics
        for bad in [
            r#"{"type":"trace","model":{"name":"m","id":1,"version":1},"image_seed":0,"events_total":0,"dropped":0,"events":[[0,0,0,0,0,0]],"scores":[],"heatmap":""}"#,
            r#"{"type":"trace","model":{"name":"m","id":1,"version":1},"image_seed":0,"events_total":0,"dropped":0,"events":[[99,0,0,0,0,0,0]],"scores":[],"heatmap":""}"#,
            r#"{"type":"trace","model":{"name":"m","id":1,"version":1},"image_seed":0,"events_total":0,"dropped":0,"events":[[0,70000,0,0,0,0,0]],"scores":[],"heatmap":""}"#,
        ] {
            assert!(decode_response(bad.as_bytes()).is_err(), "{bad}");
        }
    }

    #[test]
    fn tagged_encoding_is_v1_when_untagged_and_roundtrips_rids() {
        let req = api::Request::Infer {
            model: Some("tiny-cnn".to_string()),
            image: vec![-128, 0, 127],
        };
        // rid: None is byte-identical to the v1 encoding
        assert_eq!(encode_request_tagged(&req, None), encode_request(&req));
        // a tagged frame carries the rid and round-trips it
        let bytes = encode_request_tagged(&req, Some(42));
        assert_eq!(
            String::from_utf8(bytes.clone()).unwrap(),
            r#"{"type":"infer","model":"tiny-cnn","image":[-128,0,127],"rid":42}"#
        );
        assert_eq!(decode_request_tagged(&bytes).unwrap(), (req.clone(), Some(42)));
        // the v1 decoder ignores the rid entirely (forward compat)
        assert_eq!(decode_request(&bytes).unwrap(), req);
        // and a v1 frame decodes as untagged through the v2 decoder
        assert_eq!(
            decode_request_tagged(&encode_request(&req)).unwrap(),
            (req, None)
        );

        let resp = api::Response::Error {
            message: "nope".to_string(),
        };
        assert_eq!(encode_response_tagged(&resp, None), encode_response(&resp));
        let bytes = encode_response_tagged(&resp, Some(u64::MAX));
        assert_eq!(
            decode_response_tagged(&bytes).unwrap(),
            (resp.clone(), Some(u64::MAX))
        );
        assert_eq!(decode_response(&bytes).unwrap(), resp);
        // a negative or non-integer rid is a typed error, not a panic
        assert!(decode_request_tagged(br#"{"type":"stats","rid":-1}"#).is_err());
        assert!(decode_response_tagged(br#"{"type":"error","message":"m","rid":"x"}"#).is_err());
    }

    #[test]
    fn frame_in_buffer_handles_partial_complete_and_hostile() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        // partial prefixes need more bytes
        for cut in 0..buf.len() {
            assert_eq!(frame_in_buffer(&buf[..cut]).unwrap(), None, "cut {cut}");
        }
        // the complete buffer yields the payload range
        let range = frame_in_buffer(&buf).unwrap().unwrap();
        assert_eq!(&buf[range], b"hello");
        // trailing bytes of the next frame don't confuse it
        let mut two = buf.clone();
        two.extend_from_slice(&buf[..3]);
        assert_eq!(&two[frame_in_buffer(&two).unwrap().unwrap()], b"hello");
        // a hostile length prefix errors before buffering a payload
        let hostile = ((MAX_FRAME + 1) as u32).to_be_bytes();
        let err = frame_in_buffer(&hostile).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn unknown_types_and_bad_fields_are_typed_errors() {
        assert!(decode_request(br#"{"type":"frobnicate"}"#).is_err());
        assert!(decode_request(br#"{"model":"x"}"#).is_err());
        // i8 range enforced
        assert!(decode_request(br#"{"type":"infer","model":null,"image":[128]}"#).is_err());
        assert!(decode_request(br#"{"type":"infer","model":null,"image":[-129]}"#).is_err());
        // seeds are u64: negatives rejected
        assert!(decode_request(br#"{"type":"load_seeded","model":"m","seed":-1}"#).is_err());
        assert!(decode_response(br#"{"type":"nope"}"#).is_err());
        assert!(decode_response(b"\xff\xfe").is_err(), "non-UTF-8 frame");
    }
}
